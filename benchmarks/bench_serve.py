"""§Serving harness: continuous batching over the paged KV cache vs static
batching, on a mixed-length workload (DESIGN.md §8).

The workload is the serving regime static batching is worst at: every group
of ``slots`` requests mixes one long generation with several short ones, so
the static batch decodes at the pace of its longest member while the paged
engine backfills freed slots from the admission queue. tokens/s counts
USEFUL tokens only (what each request asked for) in both modes.

Three configurations over the same requests:
  * continuous — paged f32 KV pool, per-step admission (the engine);
  * static    — pad each group to its longest prompt, decode to its longest
                generation (the legacy serve loop);
  * continuous_q8 — the int8 quantized-page pool (error model DESIGN.md §8).

Each mode runs twice and the second (warm, compile-free) run is reported.
Writes BENCH_serve.json — scripts/check_serve.py gates the continuous/static
ratio against benchmarks/serve_baseline.json; scripts/update_perf.py renders
the §Serving table in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import os
import sys

import jax


def _workload_pairs(quick: bool) -> list[tuple[int, int]]:
    """(prompt_len, gen_len) pairs, skewed within each group of 4."""
    group = [(32, 96), (8, 4), (8, 4), (16, 8)]
    reps = 2 if quick else 4
    return group * reps


def bench_serve(quick: bool = False, emit=print):
    from repro.configs import get_arch
    from repro.launch.serve import make_workload, run_continuous, run_static
    from repro.models import init_params, reduced

    arch = get_arch("qwen3-32b")
    cfg = reduced(arch.model, layers=2, d_model=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pairs = _workload_pairs(quick)
    slots, page_size, chunk = 4, 8, 16

    def continuous(quantized):
        return run_continuous(
            params, cfg, make_workload(cfg, pairs), slots=slots,
            page_size=page_size, chunk=chunk, quantized=quantized,
        ).to_dict()

    def static():
        return run_static(
            params, cfg, make_workload(cfg, pairs), batch=slots
        )

    reports = {}
    for name, fn in (
        ("continuous", lambda: continuous(False)),
        ("static", static),
        ("continuous_q8", lambda: continuous(True)),
    ):
        fn()  # compile-warm run (fresh jit closures per call)
        reports[name] = fn()
        emit(
            f"serve/{name}", reports[name]["wall_s"] * 1e6,
            f"tok_s={reports[name]['tokens_per_s']:.1f};"
            f"p50_first_ms={reports[name]['first_token_p50_ms']:.0f};"
            f"p99_done_ms={reports[name]['completion_p99_ms']:.0f}",
        )

    ratio = (
        reports["continuous"]["tokens_per_s"]
        / reports["static"]["tokens_per_s"]
    )
    q8_ratio = (
        reports["continuous_q8"]["tokens_per_s"]
        / reports["static"]["tokens_per_s"]
    )
    emit("serve/continuous_over_static", 0.0, f"ratio={ratio:.2f}x")

    out = {
        "arch": "qwen3-32b(reduced)",
        "slots": slots,
        "page_size": page_size,
        "chunk": chunk,
        "workload": [list(p) for p in pairs],
        "n_requests": len(pairs),
        "backend": "ref(cpu)" if jax.default_backend() != "tpu" else "pallas",
        "quick": bool(quick),  # quick numbers are noisy — flagged so the
                               # rendered table never passes them off as
                               # the official trajectory
        "continuous_over_static": ratio,
        "q8_over_static": q8_ratio,
        **{k: v for k, v in reports.items()},
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    def _emit(name, us, derived):
        print(f"{name},{us:.2f},{derived}", flush=True)

    bench_serve(quick=args.quick, emit=_emit)
