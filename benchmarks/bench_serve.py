"""§Serving harness: continuous batching over the paged KV cache vs static
batching, on a mixed-length workload (DESIGN.md §8).

The workload is the serving regime static batching is worst at: every group
of ``slots`` requests mixes one long generation with several short ones, so
the static batch decodes at the pace of its longest member while the paged
engine backfills freed slots from the admission queue. tokens/s counts
USEFUL tokens only (what each request asked for) in both modes.

Three configurations over the same requests:
  * continuous — paged f32 KV pool, per-step admission (the engine);
  * static    — pad each group to its longest prompt, decode to its longest
                generation (the legacy serve loop);
  * continuous_q8 — the int8 quantized-page pool (error model DESIGN.md §8).

Two more sections exercise the COW/preemption machinery (DESIGN.md §8):
  * shared_prefix — grouped requests over a few distinct long prompt
    prefixes (the shared-system-prompt regime), served with and without
    prefix sharing. ``prefill_token_reduction`` is deterministic arithmetic
    (prompt tokens actually prefilled, unshared / shared) and is what CI
    gates; ``shared_over_unshared`` is the wall-clock tokens/s ratio.
  * preemption — the mixed workload over a pool ~half its working set, so
    expected-admission must preempt (swap pages to host, resume later);
    the section records that every request still completed.

Each mode runs twice and the second (warm, compile-free) run is reported.
Writes BENCH_serve.json — scripts/check_serve.py gates the continuous/static
ratio and the shared-prefix win against benchmarks/serve_baseline.json;
scripts/update_perf.py renders the §Serving table in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import os
import sys

import jax
import numpy as np


def _workload_pairs(quick: bool) -> list[tuple[int, int]]:
    """(prompt_len, gen_len) pairs, skewed within each group of 4."""
    group = [(32, 96), (8, 4), (8, 4), (16, 8)]
    reps = 2 if quick else 4
    return group * reps


def _shared_prefix_workload(cfg, quick: bool, seed: int = 2):
    """Requests grouped over distinct long prefixes: 64 requests over 8
    prefixes (full) / 16 over 4 (quick), generating 8 tokens each. The
    first request of a group is the bare 50-token prefix (the system
    prompt alone); the rest extend it with a 6-token unique tail. Grouped
    arrival order, the way a shared-system-prompt batch actually lands.
    The prefix length is deliberately NOT page-aligned (50 = 6 full pages
    + 2 rows at page size 8): followers map the donor's partial tail page
    too and COW-split it on their first prefill write."""
    from repro.launch.scheduler import Request

    rng = np.random.default_rng(seed)
    n_prefix, per = (4, 4) if quick else (8, 8)
    plen, tail_len, gen = 50, 6, 8
    reqs = []
    for _ in range(n_prefix):
        prefix = rng.integers(0, cfg.vocab_size, size=plen)
        for j in range(per):
            tail = rng.integers(0, cfg.vocab_size, size=tail_len)
            prompt = prefix if j == 0 else np.concatenate([prefix, tail])
            reqs.append(
                Request(
                    rid=len(reqs),
                    prompt=np.asarray(prompt, np.int32),
                    max_new=gen,
                )
            )
    return reqs, {"n_prefixes": n_prefix, "per_prefix": per,
                  "prefix_len": plen, "tail_len": tail_len, "gen": gen}


def bench_serve(quick: bool = False, emit=print):
    from repro.configs import get_arch
    from repro.launch.serve import (
        build_paged_steps,
        make_workload,
        run_continuous,
        run_static,
    )
    from repro.models import init_params, reduced

    arch = get_arch("qwen3-32b")
    cfg = reduced(arch.model, layers=2, d_model=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pairs = _workload_pairs(quick)
    slots, page_size, chunk = 4, 8, 16
    # one compiled step set / static jit cache for EVERY run below: the warm
    # pass pays compilation once, measured passes never recompile
    steps = build_paged_steps(params, cfg)
    static_jits: dict = {}

    def continuous(quantized):
        return run_continuous(
            params, cfg, make_workload(cfg, pairs), slots=slots,
            page_size=page_size, chunk=chunk, quantized=quantized,
            steps=steps,
        ).to_dict()

    def static():
        return run_static(
            params, cfg, make_workload(cfg, pairs), batch=slots,
            jit_cache=static_jits,
        )

    reports = {}
    for name, fn in (
        ("continuous", lambda: continuous(False)),
        ("static", static),
        ("continuous_q8", lambda: continuous(True)),
    ):
        fn()  # compile-warm run (fresh jit closures per call)
        reports[name] = fn()
        emit(
            f"serve/{name}", reports[name]["wall_s"] * 1e6,
            f"tok_s={reports[name]['tokens_per_s']:.1f};"
            f"p50_first_ms={reports[name]['first_token_p50_ms']:.0f};"
            f"p99_done_ms={reports[name]['completion_p99_ms']:.0f}",
        )

    ratio = (
        reports["continuous"]["tokens_per_s"]
        / reports["static"]["tokens_per_s"]
    )
    q8_ratio = (
        reports["continuous_q8"]["tokens_per_s"]
        / reports["static"]["tokens_per_s"]
    )
    emit("serve/continuous_over_static", 0.0, f"ratio={ratio:.2f}x")

    # -- shared-prefix section (COW prefix sharing on vs off) ---------------
    def shared_run(share):
        reqs, _ = _shared_prefix_workload(cfg, quick)
        return run_continuous(
            params, cfg, reqs, slots=slots, page_size=page_size,
            chunk=chunk, share_prefix=share, steps=steps,
        ).to_dict()

    for share in (True, False):
        shared_run(share)  # compile-warm
    sp_on, sp_off = shared_run(True), shared_run(False)
    _, sp_meta = _shared_prefix_workload(cfg, quick)
    sp = {
        **sp_meta,
        "n_requests": sp_on["n_requests"],
        "shared": sp_on,
        "unshared": sp_off,
        "shared_over_unshared": (
            sp_on["tokens_per_s"] / sp_off["tokens_per_s"]
        ),
        "prefill_token_reduction": (
            sp_off["prefill_tokens"] / max(1, sp_on["prefill_tokens"])
        ),
    }
    emit(
        "serve/shared_prefix", sp_on["wall_s"] * 1e6,
        f"tok_s_ratio={sp['shared_over_unshared']:.2f}x;"
        f"prefill_reduction={sp['prefill_token_reduction']:.2f}x;"
        f"cow_splits={sp_on['cow_splits']}",
    )

    # -- preemption section (pool ~half the working set) --------------------
    longest = max(p + g for p, g in pairs)
    max_pages = -(-longest // page_size)
    # 1.5 worst-case residents: the workload's two concurrent long
    # generations cannot both fit, so the engine must preempt
    tight_npage = 1 + max_pages + max_pages // 2

    def preempt_run():
        return run_continuous(
            params, cfg, make_workload(cfg, pairs), slots=slots,
            page_size=page_size, chunk=chunk, npage=tight_npage,
            steps=steps,
        ).to_dict()

    preempt_run()  # compile-warm
    pre = preempt_run()
    assert pre["preemptions"] > 0, "tight pool failed to force preemption"
    assert pre["n_requests"] == len(pairs), "a preempted request was lost"
    preemption = {
        "npage": tight_npage,
        "roomy_tokens_per_s": reports["continuous"]["tokens_per_s"],
        **pre,
    }
    emit(
        "serve/preemption", pre["wall_s"] * 1e6,
        f"tok_s={pre['tokens_per_s']:.1f};"
        f"preemptions={pre['preemptions']};"
        f"swapped_pages={pre['swapped_pages']}",
    )

    out = {
        "arch": "qwen3-32b(reduced)",
        "slots": slots,
        "page_size": page_size,
        "chunk": chunk,
        "workload": [list(p) for p in pairs],
        "n_requests": len(pairs),
        "backend": "ref(cpu)" if jax.default_backend() != "tpu" else "pallas",
        "quick": bool(quick),  # quick numbers are noisy — flagged so the
                               # rendered table never passes them off as
                               # the official trajectory
        "continuous_over_static": ratio,
        "q8_over_static": q8_ratio,
        "shared_prefix": sp,
        "preemption": preemption,
        **{k: v for k, v in reports.items()},
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    def _emit(name, us, derived):
        print(f"{name},{us:.2f},{derived}", flush=True)

    bench_serve(quick=args.quick, emit=_emit)
