"""Federated PP-MARINA reproduction harness (writes BENCH_pp.json).

Two measurements, rendered into EXPERIMENTS.md §Federated partial
participation by scripts/update_perf.py:

* **Loss-vs-bits curves** — the paper's Figs. 1–2 comparison shape on the
  Dirichlet(α) non-IID binclass problem (core/problems.py): PP-MARINA at
  r ∈ {8, 4} vs full-participation MARINA vs DIANA vs compressed GD (DCGD),
  all on the same RandK wire, each method's x-axis the FLEET-total uplink
  bits its ledger booked (wire.py truth). The table reports ‖∇f‖² reached at
  matched bit budgets across α ∈ {0.1, 1, ∞} heterogeneity.
* **Round-time rows** — the r/n compute+wire saving on a real mesh: an
  8-fake-device subprocess times the full-participation compressed round vs
  the cohort-mapped PP round (only r of n shards backprop, r payload rows on
  the wire) on the reduced-qwen LM step, and books the per-round wire bits
  from repro.core.wire.

Run: PYTHONPATH=src python -m benchmarks.bench_pp [--quick]
(or  PYTHONPATH=src python -m benchmarks.run --only pp [--quick])
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DCGD,
    Diana,
    Marina,
    PPMarina,
    RandK,
    diana_alpha,
    diana_gamma,
    marina_gamma,
    pp_marina_gamma,
)
from repro.core import wire
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_dirichlet_binclass,
    nonconvex_binclass_loss,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")

N_CLIENTS, M_LOCAL, DIM = 20, 64, 50
BUDGETS_MBITS = (1.0, 4.0, 16.0)   # matched fleet-uplink budgets


def _gradsq(x, data):
    flat = BinClassData(a=data.a.reshape(-1, DIM), y=data.y.reshape(-1))
    return float(jnp.sum(binclass_full_grad(x, flat) ** 2))


def _loss(x, data):
    flat = BinClassData(a=data.a.reshape(-1, DIM), y=data.y.reshape(-1))
    return float(nonconvex_binclass_loss(x, flat))


def _methods(data, L, quick):
    """(name, method, r) — every entry rides the same Rand3 wire."""
    comp = RandK(k=3)
    omega = comp.omega(DIM)
    grad = jax.grad(nonconvex_binclass_loss)
    p_full = comp.default_p(DIM)
    out = [
        ("marina", Marina(grad, comp, marina_gamma(L, omega, p_full, N_CLIENTS),
                          p_full), None),
    ]
    for r in ((4,) if quick else (8, 4)):
        p = p_full * r / N_CLIENTS
        out.append((
            f"pp_marina_r{r}",
            PPMarina(grad, comp, pp_marina_gamma(L, omega, p, r), p, r=r,
                     replace=False),
            r,
        ))
    out.append(("diana", Diana(grad, comp, diana_gamma(L, omega, N_CLIENTS),
                               diana_alpha(omega), N_CLIENTS), None))
    out.append(("dcgd", DCGD(grad, comp,
                             0.3 / (L * (1 + omega / N_CLIENTS)), N_CLIENTS),
                None))
    return out


def _run_curve(method, name, data, steps, every):
    if name in ("diana", "dcgd"):
        state = method.init(jnp.zeros((DIM,)))
    else:
        state = method.init(jnp.zeros((DIM,)), data)
    step = jax.jit(method.step)
    bits = down = 0.0
    pts = [{"round": 0, "mbits_up": 0.0, "mbits_down": 0.0,
            "gradsq": _gradsq(state.params, data),
            "loss": _loss(state.params, data)}]
    t0 = time.time()
    for k in range(steps):
        state, met = step(state, jax.random.PRNGKey(k), data)
        bits += float(met.bits_per_worker) * N_CLIENTS   # fleet uplink
        down += float(met.down_bits) * N_CLIENTS
        if (k + 1) % every == 0:
            pts.append({
                "round": k + 1,
                "mbits_up": bits / 1e6,
                "mbits_down": down / 1e6,
                "gradsq": _gradsq(state.params, data),
                "loss": _loss(state.params, data),
            })
    us = (time.time() - t0) / steps * 1e6
    return pts, us


def bench_pp_curves(quick=False, emit=print):
    steps = 600 if quick else 4000
    every = 50 if quick else 100
    alphas = (0.1, float("inf")) if quick else (0.1, 1.0, float("inf"))
    curves = []
    for alpha in alphas:
        data = make_dirichlet_binclass(
            jax.random.PRNGKey(7), N_CLIENTS, M_LOCAL, DIM, alpha=alpha
        )
        L = binclass_smoothness(data)
        for name, method, r in _methods(data, L, quick):
            pts, us = _run_curve(method, name, data, steps, every)
            curves.append({
                "alpha": "inf" if np.isinf(alpha) else alpha,
                "method": name, "r": r, "steps": steps, "points": pts,
            })
            emit(f"pp_curve/alpha{curves[-1]['alpha']}/{name}", us,
                 f"final_gradsq={pts[-1]['gradsq']:.2e};"
                 f"Mbits={pts[-1]['mbits_up']:.2f}")
    return curves


def budget_table(curves):
    """‖∇f‖² reached within each matched fleet-uplink budget (best point at
    or under the budget — methods that never log under it get null)."""
    rows = []
    for alpha in sorted({c["alpha"] for c in curves}, key=str):
        row = {"alpha": alpha, "budgets": {}}
        for budget in BUDGETS_MBITS:
            cell = {}
            for c in (c for c in curves if c["alpha"] == alpha):
                under = [p["gradsq"] for p in c["points"]
                         if p["mbits_up"] <= budget]
                cell[c["method"]] = min(under) if under else None
            row["budgets"][str(budget)] = cell
        rows.append(row)
    return rows


_ROUNDTIME_PROG = textwrap.dedent(
    """
    import os, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.launch.distributed import build_train_steps, BLOCK, KB
    from repro.launch.mesh import make_federated_mesh
    from repro.models import reduced, init_params
    from repro.core import wire

    REPS = %(reps)d
    mesh = make_federated_mesh(4, model=2)
    arch = get_arch("qwen1.5-0.5b")
    # large enough that the two vmapped backprops dominate the round — the
    # regime the r/n cohort-compute saving targets (a tiny model measures
    # gather overhead instead of compute)
    arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=256))
    cfg = arch.model
    n, r, b = 4, 2, 4

    def build(part):
        return build_train_steps(
            arch, mesh, multi_pod=False, global_batch=n*b, seq_len=64,
            gamma=0.1, dtype=jnp.float32, replicate_params=True,
            participation=part, p=0.1,
        )

    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (n, b, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    sel = jnp.array([1, 2], jnp.int32)

    def timeit(bundle, args):
        fn, _ = bundle.fns["compressed_step"]
        with bundle.mesh:
            p_, g_ = fn(*args)                      # compile + warm
            best = float("inf")
            for _ in range(REPS):
                p_ = jax.tree.map(jnp.array, params)
                g_ = jax.tree.map(jnp.zeros_like, params)
                t0 = time.perf_counter()
                p_, g_ = fn(p_, g_, *args[2:])
                jax.block_until_ready(jax.tree.leaves(g_)[0])
                best = min(best, (time.perf_counter() - t0) * 1e6)
        return best

    full = build(None)
    key = jax.random.PRNGKey(3)
    full_us = timeit(full, (jax.tree.map(jnp.array, params),
                            jax.tree.map(jnp.zeros_like, params), batch, key))
    pp = build((r, "without"))
    pp_us = timeit(pp, (jax.tree.map(jnp.array, params),
                        jax.tree.map(jnp.zeros_like, params), batch, key, sel))

    d = sum(int(jnp.size(t)) for t in jax.tree.leaves(params))
    nblk = -(-d // BLOCK)
    zeta = wire.seeded_randk_bits(nblk, KB)
    print("ROUNDTIME_JSON " + json.dumps({
        "n": n, "r": r, "d": d,
        "full_us": full_us, "pp_us": pp_us,
        "speedup": full_us / pp_us,
        "wire_bits_full": wire.pp_uplink_total_bits(n, zeta),
        "wire_bits_pp": wire.pp_uplink_total_bits(r, zeta),
        "cohort_compute": bool(pp.meta["cohort_compute"]),
    }))
    """
)


def bench_pp_roundtime(quick=False, emit=print):
    prog = _ROUNDTIME_PROG % {"reps": 3 if quick else 10}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=env, timeout=900,
    )
    if out.returncode != 0:
        emit("pp_roundtime/FAILED", 0.0, out.stderr.strip()[-200:])
        return None
    line = [l for l in out.stdout.splitlines()
            if l.startswith("ROUNDTIME_JSON ")][0]
    row = json.loads(line[len("ROUNDTIME_JSON "):])
    emit("pp_roundtime/mesh4x2", row["pp_us"],
         f"full_us={row['full_us']:.0f};speedup={row['speedup']:.2f}x;"
         f"wire={row['wire_bits_full']/row['wire_bits_pp']:.1f}x")
    return row


def bench_pp(quick=False, emit=None):
    """Entry point shared with benchmarks.run (--only pp)."""
    if emit is None:
        def emit(name, us, derived):
            print(f"{name},{us:.2f},{derived}", flush=True)
    curves = bench_pp_curves(quick=quick, emit=emit)
    roundtime = bench_pp_roundtime(quick=quick, emit=emit)
    out = {
        "quick": bool(quick),
        "problem": {"n_clients": N_CLIENTS, "m_local": M_LOCAL, "d": DIM,
                    "compressor": "rand3", "scheme": "without"},
        "budgets_mbits": list(BUDGETS_MBITS),
        "curves": curves,
        "budget_table": budget_table(curves),
        "roundtime": roundtime,
    }
    path = os.path.join(ROOT, "BENCH_pp.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_pp(quick=args.quick)


if __name__ == "__main__":
    main()
