"""Federated PP-MARINA reproduction harness (writes BENCH_pp.json).

Three measurements, rendered into EXPERIMENTS.md (§Federated partial
participation + §Byzantine robustness) by scripts/update_perf.py:

* **Loss-vs-bits curves** — the paper's Figs. 1–2 comparison shape on the
  Dirichlet(α) non-IID binclass problem (core/problems.py): PP-MARINA at
  r ∈ {8, 4} vs full-participation MARINA vs DIANA vs compressed GD (DCGD),
  all on the same RandK wire, each method's x-axis the FLEET-total uplink
  bits its ledger booked (wire.py truth). The table reports ‖∇f‖² reached at
  matched bit budgets across α ∈ {0.1, 1, ∞} heterogeneity.
* **Round-time rows** — the r/n compute+wire saving on a real mesh: an
  8-fake-device subprocess times the full-participation compressed round vs
  the cohort-mapped PP round (only r of n shards backprop, r payload rows on
  the wire) on the reduced-qwen LM step, and books the per-round wire bits
  from repro.core.wire.
* **Straggler wall-clock curves** (`--only async`) — the deadline-cohort
  harness of DESIGN.md §4.10: synchronous MARINA (every round waits for the
  slowest client) vs DeadlineMarina at honest-quantile deadlines (missed
  clients ride the carry table as PP non-participants), with and without
  stale-difference acceptance, under lognormal / exponential / fixed-slow
  compute-time models (core/roundtime.py). Reports simulated wall clock to
  MATCHED loss — the `async` section of BENCH_pp.json.
* **Adversarial grid** (`--only robust`) — the Byzantine stress test of
  DESIGN.md §4.9: attack (sign_flip / omniscient mean_shift / label_flip /
  drop) × GAR (mean / trimmed_mean / coordinate_median / krum / norm_clip)
  × faulty fraction ∈ {0, 1/8, 1/4} on PP-MARINA over the dense 4-bit QSGD
  wire, final honest-objective loss at MATCHED bit budgets (every payload
  cell books identical wire bits; only `drop` books fewer — the carry
  substitution's exact uploaded-row accounting). Plus the robust round-time
  rows: the fused robust epilogues vs the fused mean on the reduced-qwen
  flat layout — the `scripts/check_robust.py` CI gate metric.

Run: PYTHONPATH=src python -m benchmarks.bench_pp [--quick]
     [--only pp|robust|async|all]
(or  PYTHONPATH=src python -m benchmarks.run --only pp|robust|async [--quick])
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DCGD,
    DeadlineMarina,
    Diana,
    FaultSpec,
    Marina,
    PPMarina,
    RandK,
    RoundTimeModel,
    ServerAggregator,
    async_marina_gamma,
    diana_alpha,
    diana_gamma,
    flip_binclass_labels,
    make_compressor,
    marina_gamma,
    pp_marina_gamma,
)
from repro.core import wire
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_dirichlet_binclass,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
)

ROOT = os.path.join(os.path.dirname(__file__), "..")

N_CLIENTS, M_LOCAL, DIM = 20, 64, 50
BUDGETS_MBITS = (1.0, 4.0, 16.0)   # matched fleet-uplink budgets


def _gradsq(x, data):
    flat = BinClassData(a=data.a.reshape(-1, DIM), y=data.y.reshape(-1))
    return float(jnp.sum(binclass_full_grad(x, flat) ** 2))


def _loss(x, data):
    flat = BinClassData(a=data.a.reshape(-1, DIM), y=data.y.reshape(-1))
    return float(nonconvex_binclass_loss(x, flat))


def _methods(data, L, quick):
    """(name, method, r) — every entry rides the same Rand3 wire."""
    comp = RandK(k=3)
    omega = comp.omega(DIM)
    grad = jax.grad(nonconvex_binclass_loss)
    p_full = comp.default_p(DIM)
    out = [
        ("marina", Marina(grad, comp, marina_gamma(L, omega, p_full, N_CLIENTS),
                          p_full), None),
    ]
    for r in ((4,) if quick else (8, 4)):
        p = p_full * r / N_CLIENTS
        out.append((
            f"pp_marina_r{r}",
            PPMarina(grad, comp, pp_marina_gamma(L, omega, p, r), p, r=r,
                     replace=False),
            r,
        ))
    out.append(("diana", Diana(grad, comp, diana_gamma(L, omega, N_CLIENTS),
                               diana_alpha(omega), N_CLIENTS), None))
    out.append(("dcgd", DCGD(grad, comp,
                             0.3 / (L * (1 + omega / N_CLIENTS)), N_CLIENTS),
                None))
    return out


def _run_curve(method, name, data, steps, every):
    if name in ("diana", "dcgd"):
        state = method.init(jnp.zeros((DIM,)))
    else:
        state = method.init(jnp.zeros((DIM,)), data)
    step = jax.jit(method.step)
    bits = down = 0.0
    pts = [{"round": 0, "mbits_up": 0.0, "mbits_down": 0.0,
            "gradsq": _gradsq(state.params, data),
            "loss": _loss(state.params, data)}]
    t0 = time.time()
    for k in range(steps):
        state, met = step(state, jax.random.PRNGKey(k), data)
        bits += float(met.bits_per_worker) * N_CLIENTS   # fleet uplink
        down += float(met.down_bits) * N_CLIENTS
        if (k + 1) % every == 0:
            pts.append({
                "round": k + 1,
                "mbits_up": bits / 1e6,
                "mbits_down": down / 1e6,
                "gradsq": _gradsq(state.params, data),
                "loss": _loss(state.params, data),
            })
    us = (time.time() - t0) / steps * 1e6
    return pts, us


def bench_pp_curves(quick=False, emit=print):
    steps = 600 if quick else 4000
    every = 50 if quick else 100
    alphas = (0.1, float("inf")) if quick else (0.1, 1.0, float("inf"))
    curves = []
    for alpha in alphas:
        data = make_dirichlet_binclass(
            jax.random.PRNGKey(7), N_CLIENTS, M_LOCAL, DIM, alpha=alpha
        )
        L = binclass_smoothness(data)
        for name, method, r in _methods(data, L, quick):
            pts, us = _run_curve(method, name, data, steps, every)
            curves.append({
                "alpha": "inf" if np.isinf(alpha) else alpha,
                "method": name, "r": r, "steps": steps, "points": pts,
            })
            emit(f"pp_curve/alpha{curves[-1]['alpha']}/{name}", us,
                 f"final_gradsq={pts[-1]['gradsq']:.2e};"
                 f"Mbits={pts[-1]['mbits_up']:.2f}")
    return curves


def budget_table(curves):
    """‖∇f‖² reached within each matched fleet-uplink budget (best point at
    or under the budget — methods that never log under it get null)."""
    rows = []
    for alpha in sorted({c["alpha"] for c in curves}, key=str):
        row = {"alpha": alpha, "budgets": {}}
        for budget in BUDGETS_MBITS:
            cell = {}
            for c in (c for c in curves if c["alpha"] == alpha):
                under = [p["gradsq"] for p in c["points"]
                         if p["mbits_up"] <= budget]
                cell[c["method"]] = min(under) if under else None
            row["budgets"][str(budget)] = cell
        rows.append(row)
    return rows


_ROUNDTIME_PROG = textwrap.dedent(
    """
    import os, json, time
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_arch
    from repro.launch.distributed import build_train_steps, BLOCK, KB
    from repro.launch.topology import make_federated_mesh
    from repro.models import reduced, init_params
    from repro.core import wire

    REPS = %(reps)d
    mesh = make_federated_mesh(4, model=2)
    arch = get_arch("qwen1.5-0.5b")
    # large enough that the two vmapped backprops dominate the round — the
    # regime the r/n cohort-compute saving targets (a tiny model measures
    # gather overhead instead of compute)
    arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=256))
    cfg = arch.model
    n, r, b = 4, 2, 4

    def build(part):
        return build_train_steps(
            arch, mesh, multi_pod=False, global_batch=n*b, seq_len=64,
            gamma=0.1, dtype=jnp.float32, replicate_params=True,
            participation=part, p=0.1,
        )

    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (n, b, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    sel = jnp.array([1, 2], jnp.int32)

    def timeit(bundle, args):
        fn, _ = bundle.fns["compressed_step"]
        with bundle.mesh:
            p_, g_ = fn(*args)                      # compile + warm
            best = float("inf")
            for _ in range(REPS):
                p_ = jax.tree.map(jnp.array, params)
                g_ = jax.tree.map(jnp.zeros_like, params)
                t0 = time.perf_counter()
                p_, g_ = fn(p_, g_, *args[2:])
                jax.block_until_ready(jax.tree.leaves(g_)[0])
                best = min(best, (time.perf_counter() - t0) * 1e6)
        return best

    full = build(None)
    key = jax.random.PRNGKey(3)
    full_us = timeit(full, (jax.tree.map(jnp.array, params),
                            jax.tree.map(jnp.zeros_like, params), batch, key))
    pp = build((r, "without"))
    pp_us = timeit(pp, (jax.tree.map(jnp.array, params),
                        jax.tree.map(jnp.zeros_like, params), batch, key, sel))

    d = sum(int(jnp.size(t)) for t in jax.tree.leaves(params))
    nblk = -(-d // BLOCK)
    zeta = wire.seeded_randk_bits(nblk, KB)
    print("ROUNDTIME_JSON " + json.dumps({
        "n": n, "r": r, "d": d,
        "full_us": full_us, "pp_us": pp_us,
        "speedup": full_us / pp_us,
        "wire_bits_full": wire.pp_uplink_total_bits(n, zeta),
        "wire_bits_pp": wire.pp_uplink_total_bits(r, zeta),
        "cohort_compute": bool(pp.meta["cohort_compute"]),
    }))
    """
)


def bench_pp_roundtime(quick=False, emit=print):
    prog = _ROUNDTIME_PROG % {"reps": 3 if quick else 10}
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=env, timeout=900,
    )
    if out.returncode != 0:
        emit("pp_roundtime/FAILED", 0.0, out.stderr.strip()[-200:])
        return None
    line = [l for l in out.stdout.splitlines()
            if l.startswith("ROUNDTIME_JSON ")][0]
    row = json.loads(line[len("ROUNDTIME_JSON "):])
    emit("pp_roundtime/mesh4x2", row["pp_us"],
         f"full_us={row['full_us']:.0f};speedup={row['speedup']:.2f}x;"
         f"wire={row['wire_bits_full']/row['wire_bits_pp']:.1f}x")
    return row


# --- Byzantine-robust adversarial grid (DESIGN.md §4.9) --------------------
#
# Calibrated so the acceptance claim is measurable on CPU in minutes: n = 20
# clients (f = 5 at the ¼ fraction), r = 16 cohorts, dense 4-bit QSGD wire
# (coordinate-wise GARs need comparable per-coordinate payloads — see the
# aggregators.py wire-compatibility note), moderate heterogeneity (the trim
# bias of asymmetric contamination under symmetric trimming scales with the
# honest spread — at heterogeneity ≫ 0.5 even a perfect GAR drifts >10% off
# the attack-free loss, which is the ROBUSTNESS-UTILITY tradeoff, not a bug).
ROB_N, ROB_R, ROB_M, ROB_D = 20, 16, 32, 20
ROB_F = 5                       # assumed Byzantine bound (= ⌊n/4⌋)
ROB_GAMMA, ROB_P = 0.1, 0.3
ROB_SCALE = 10.0                # attack amplitude
ROB_HET = 0.3

ROB_GARS = (
    ("mean", ServerAggregator("mean")),
    ("trimmed_mean", ServerAggregator("trimmed_mean", f=ROB_F)),
    ("coordinate_median", ServerAggregator("coordinate_median")),
    ("krum", ServerAggregator("krum", f=ROB_F)),
    ("norm_clip", ServerAggregator("norm_clip")),
)


def _rob_eval(x, data):
    flat = BinClassData(a=data.a.reshape(-1, ROB_D), y=data.y.reshape(-1))
    return (float(nonconvex_binclass_loss(x, flat)),
            float(jnp.sum(binclass_full_grad(x, flat) ** 2)))


def _rob_method(gar, faults):
    agg = None if gar.rule == "mean" else gar
    return PPMarina(
        jax.grad(nonconvex_binclass_loss),
        make_compressor("qsgd", s=7),
        ROB_GAMMA, ROB_P, r=ROB_R, replace=False, carry=True,
        aggregator=agg, faults=faults,
    )


def _rob_run(method, data, eval_data, steps):
    state = method.init(jnp.zeros((ROB_D,)), data)
    step = jax.jit(method.step)
    bits = 0.0
    t0 = time.time()
    for k in range(steps):
        state, met = step(state, jax.random.PRNGKey(k), data)
        bits += float(met.bits_per_worker) * ROB_N
    us = (time.time() - t0) / steps * 1e6
    loss, gradsq = _rob_eval(state.params, eval_data)
    return loss, gradsq, bits / 1e6, us


def bench_robust_grid(quick=False, emit=print):
    """attack × GAR × faulty-fraction grid → final honest-objective loss.

    Every cell runs the same optimizer/wire/step count, so the fleet bit
    budgets match by construction (the `mbits_up` column proves it — only
    `drop` books fewer bits, exactly r − #dropped uploads per round).
    `label_flip` poisons the DATA (the faulty clients follow the protocol
    honestly on flipped labels); all cells are evaluated on the clean data."""
    steps = 150 if quick else 300
    fracs = (0.125, 0.25) if not quick else (0.25,)
    attacks = (("sign_flip", "payload"), ("mean_shift", "payload"),
               ("label_flip", "data"))
    if quick:
        attacks = attacks[:2]
        gars = ROB_GARS[:3]
    else:
        gars = ROB_GARS
    data = make_synthetic_binclass(
        jax.random.PRNGKey(11), ROB_N, ROB_M, ROB_D, heterogeneity=ROB_HET
    )
    cells = []

    def run_cell(attack, frac, gar_name, gar, run_data, faults):
        loss, gradsq, mbits, us = _rob_run(
            _rob_method(gar, faults), run_data, data, steps
        )
        cells.append({
            "attack": attack, "frac": frac, "gar": gar_name,
            "f_assumed": gar.f if gar.rule in ("trimmed_mean", "krum") else None,
            "final_loss": loss, "final_gradsq": gradsq, "mbits_up": mbits,
        })
        emit(f"robust/{attack}_f{frac}/{gar_name}", us,
             f"loss={loss:.4f};gradsq={gradsq:.2e};Mbits={mbits:.2f}")

    # fault-free baselines: one per GAR (the robustness *cost* at f = 0)
    for gar_name, gar in gars:
        run_cell("none", 0.0, gar_name, gar, data, None)
    free = next(c for c in cells if c["gar"] == "mean")["final_loss"]

    for attack, kind in attacks:
        for frac in fracs:
            poisoned = (flip_binclass_labels(data, int(frac * ROB_N))
                        if kind == "data" else data)
            faults = (FaultSpec(attack, frac=frac, scale=ROB_SCALE)
                      if kind == "payload" else None)
            for gar_name, gar in gars:
                run_cell(attack, frac, gar_name, gar, poisoned, faults)

    # dropped clients: a transport fault, not an adversary — the server
    # substitutes the carry row (Δ̂_i = 0) and books only actual uploads
    run_cell("drop", 0.25, "mean", ServerAggregator("mean"), data,
             FaultSpec("drop", frac=0.25))

    for c in cells:
        c["loss_vs_free"] = c["final_loss"] / free
    return {"n": ROB_N, "r": ROB_R, "m_local": ROB_M, "d": ROB_D,
            "compressor": "qsgd_s7", "gamma": ROB_GAMMA, "p": ROB_P,
            "heterogeneity": ROB_HET, "scale": ROB_SCALE, "steps": steps,
            "free_loss": free, "cells": cells}


def bench_robust_roundtime(quick=False, emit=print):
    """Fused robust rounds vs the fused mean on the reduced-qwen flat layout
    (nblk ≈ 1699 f32 blocks, n = 8 worker rows, dense QSGD uplink).

    `round_*` times the full `FlatEngine.fused_round` (quantize → decode →
    GAR → g/x epilogue) — the unit a compressed round actually pays, and the
    CI gate metric (scripts/check_robust.py: robust/mean ≤ 1.25). The
    isolated sync-epilogue ratio is recorded too but NOT gated on CPU: the
    mean epilogue is one memory-bound pass while the trimmed ref is a
    compute-bound O(n²/2) compare-exchange network — on TPU the Pallas
    kernel's extra compares ride in-register on the same HBM traffic as the
    mean, which is where the ~1.2× epilogue claim lives."""
    from repro.core import flat
    from repro.kernels import epilogue as epi

    n = 8
    nblk = 425 if quick else 1699   # quick: ~0.44M params, full: reduced qwen
    bufs = jax.random.normal(jax.random.PRNGKey(0), (n, nblk, 1024))
    x2d = jax.random.normal(jax.random.PRNGKey(1), (nblk, 1024))
    g2d = jnp.zeros((nblk, 1024))
    gamma = 0.1
    trim = ServerAggregator("trimmed_mean", f=2)
    med = ServerAggregator("coordinate_median")
    lo_t, hi_t = trim.trim_bounds(n)
    lo_m, hi_m = med.trim_bounds(n)

    params = {"w": jnp.zeros((nblk * 1024,), jnp.float32)}
    eng = flat.FlatEngine(layout=flat.make_layout(params), sampler="qsgd", s=7)
    kr = jax.random.PRNGKey(2)

    # arrays cross as jit ARGUMENTS (closed-over arrays are compile-time
    # constants XLA is free to fold — a nullary jit would time nothing)
    fns = {
        "round_mean": jax.jit(
            lambda k, b, g, x: eng.fused_round(k, b, n, g, x, gamma)),
        "round_trimmed": jax.jit(
            lambda k, b, g, x: eng.fused_round(k, b, n, g, x, gamma,
                                               aggregator=trim)),
        "round_median": jax.jit(
            lambda k, b, g, x: eng.fused_round(k, b, n, g, x, gamma,
                                               aggregator=med)),
        "sync_mean": jax.jit(
            lambda k, b, g, x: epi.mean_epilogue(b, x, gamma)),
        "sync_trimmed": jax.jit(
            lambda k, b, g, x: epi.trimmed_sync_epilogue(
                b, x, gamma, lo_t, hi_t)),
        "sync_median": jax.jit(
            lambda k, b, g, x: epi.trimmed_sync_epilogue(
                b, x, gamma, lo_m, hi_m)),
    }
    args_ = (kr, bufs, g2d, x2d)
    # interleaved min-of-trials (the bench_compression discipline): every
    # candidate measured in each trial window so load noise hits all alike
    for fn in fns.values():
        jax.block_until_ready(fn(*args_))
    rounds = 5 if quick else 12
    best = {name: float("inf") for name in fns}
    for _ in range(rounds):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args_))
            best[name] = min(best[name], (time.perf_counter() - t0) * 1e6)

    row = {
        "n": n, "d": nblk * 1024,
        "backend": "ref(cpu)" if jax.default_backend() != "tpu" else "pallas",
        **{k: v for k, v in best.items()},
        "round_trimmed_over_mean": best["round_trimmed"] / best["round_mean"],
        "round_median_over_mean": best["round_median"] / best["round_mean"],
        "sync_trimmed_over_mean": best["sync_trimmed"] / best["sync_mean"],
        "sync_median_over_mean": best["sync_median"] / best["sync_mean"],
    }
    emit("robust/roundtime", best["round_trimmed"],
         f"mean_us={best['round_mean']:.0f};"
         f"trimmed={row['round_trimmed_over_mean']:.2f}x;"
         f"median={row['round_median_over_mean']:.2f}x")
    return row


# --- Straggler / deadline wall-clock harness (DESIGN.md §4.10) -------------
#
# The paper's curves are loss-vs-bits; a federated fleet also pays WALL
# CLOCK, and a synchronous round costs the slowest client. The harness runs
# DeadlineMarina on the same Dirichlet non-IID problem and Rand3 wire as the
# pp curves, under three straggler distributions, and reports simulated
# wall clock to a MATCHED loss: synchronous full participation (a deadline
# no draw reaches — bit-identical trajectory to Marina carry, wall = max
# client time per round) vs deadline cohorts at honest-quantile deadlines,
# with and without stale-difference acceptance.

#: deadline no compute-time draw ever reaches: every client makes every
#: round, so the trajectory IS synchronous MARINA and the wall clock pays
#: max_i T_i — the baseline the deadline variants race.
NEVER_MISS_S = 1e9

ASYNC_TIMES = {
    # multiplicative heterogeneity with a heavy right tail (σ = 1: the p99
    # honest client is ~6× the median)
    "lognormal": RoundTimeModel(dist="lognormal", mean_s=1.0, sigma=1.0),
    # memoryless service times
    "exponential": RoundTimeModel(dist="exponential", mean_s=1.0),
    # two persistently slow clients at 8×: the static-drop regime — a
    # deadline permanently excludes the same cohort every round
    "fixed_slow": RoundTimeModel(
        dist="fixed", mean_s=1.0, slow_ids=(3, 11), slow_factor=8.0
    ),
}


def _expected_arrive_frac(tm: RoundTimeModel, deadline: float) -> float:
    """Expected per-round arrival fraction under a deadline: honest clients
    beat it w.p. 1 − miss_prob; the persistently slow set (slow_factor ≥
    deadline/mean for every model here) is counted fully missing."""
    slow = len(tm.slow_ids) / N_CLIENTS
    return (1.0 - tm.miss_prob(deadline)) * (1.0 - slow)


def _run_async_curve(method, data, steps, every):
    state = method.init(jnp.zeros((DIM,)), data)
    step = jax.jit(method.step)
    bits = wall = up = 0.0
    pts = [{"round": 0, "wall_s": 0.0, "mbits_up": 0.0,
            "loss": _loss(state.params, data),
            "gradsq": _gradsq(state.params, data)}]
    t0 = time.time()
    for k in range(steps):
        state, met = step(state, jax.random.PRNGKey(k), data)
        bits += float(met.bits_per_worker) * N_CLIENTS   # fleet uplink
        wall += float(met.wall_clock_s)
        up += float(met.uploaded)
        if (k + 1) % every == 0:
            pts.append({
                "round": k + 1,
                "wall_s": wall,
                "mbits_up": bits / 1e6,
                "loss": _loss(state.params, data),
                "gradsq": _gradsq(state.params, data),
            })
    us = (time.time() - t0) / steps * 1e6
    return pts, up / (steps * N_CLIENTS), us


def bench_async_curves(quick=False, emit=print):
    """Loss-vs-wall-clock curves per straggler distribution: synchronous
    MARINA vs deadline cohorts (tau_max = 0) vs deadline + stale acceptance
    (tau_max = 2), every variant at its heuristic stepsize
    (:func:`async_marina_gamma` on the expected arrival fraction)."""
    steps = 400 if quick else 2000
    every = 25 if quick else 50
    data = make_dirichlet_binclass(
        jax.random.PRNGKey(7), N_CLIENTS, M_LOCAL, DIM, alpha=0.1
    )
    L = binclass_smoothness(data)
    comp = RandK(k=3)
    omega = comp.omega(DIM)
    p = comp.default_p(DIM)
    grad = jax.grad(nonconvex_binclass_loss)
    names = ("lognormal", "fixed_slow") if quick else tuple(ASYNC_TIMES)
    quants = (0.8,) if quick else (0.6, 0.8)
    curves = []
    for dist_name in names:
        tm = ASYNC_TIMES[dist_name]
        variants = [(
            "sync", None, 0,
            DeadlineMarina(
                grad, comp, marina_gamma(L, omega, p, N_CLIENTS), p,
                deadline=NEVER_MISS_S, times=tm,
            ),
        )]
        for q in quants:
            dl = tm.deadline_for_quantile(q)
            arrive = _expected_arrive_frac(tm, dl)
            variants.append((
                f"deadline_q{q:g}", q, 0,
                DeadlineMarina(
                    grad, comp,
                    async_marina_gamma(
                        L, omega, p, N_CLIENTS, arrive_frac=arrive
                    ),
                    p, deadline=dl, times=tm,
                ),
            ))
        # stale acceptance at the tightest deadline: late uploads land
        # within 2 rounds instead of vanishing; γ additionally degrades
        # with the anchor-age heuristic
        q = quants[0]
        dl = tm.deadline_for_quantile(q)
        arrive = _expected_arrive_frac(tm, dl)
        variants.append((
            f"deadline_q{q:g}_tau2", q, 2,
            DeadlineMarina(
                grad, comp,
                async_marina_gamma(
                    L, omega, p, N_CLIENTS, arrive_frac=arrive, staleness=1.0
                ),
                p, deadline=dl, times=tm, tau_max=2,
            ),
        ))
        for vname, q, tau, method in variants:
            pts, arrived, us = _run_async_curve(method, data, steps, every)
            curves.append({
                "dist": dist_name, "variant": vname, "quantile": q,
                "tau_max": tau, "deadline_s": float(method.deadline),
                "gamma": float(method.gamma), "steps": steps,
                "arrived_frac": arrived, "points": pts,
            })
            emit(f"async/{dist_name}/{vname}", us,
                 f"final_loss={pts[-1]['loss']:.4f};"
                 f"wall_s={pts[-1]['wall_s']:.1f};arrived={arrived:.2f}")
    return curves


def async_wall_table(curves):
    """Simulated wall clock to a MATCHED loss, per distribution: the target
    is the worst final loss among that distribution's variants (so every
    variant reaches it), wall_s the first logged point at/below it, and
    speedup_vs_sync the headline — how much sooner the deadline round
    delivers the same loss than waiting for the slowest client."""
    rows = []
    for dist in sorted({c["dist"] for c in curves}):
        group = [c for c in curves if c["dist"] == dist]
        target = max(c["points"][-1]["loss"] for c in group)
        row = {"dist": dist, "target_loss": target,
               "wall_s": {}, "rounds": {}}
        for c in group:
            hit = next(
                (pt for pt in c["points"] if pt["loss"] <= target), None
            )
            row["wall_s"][c["variant"]] = hit["wall_s"] if hit else None
            row["rounds"][c["variant"]] = hit["round"] if hit else None
        sync_wall = row["wall_s"].get("sync")
        row["speedup_vs_sync"] = {
            v: (sync_wall / w if sync_wall and w else None)
            for v, w in row["wall_s"].items()
        }
        rows.append(row)
    return rows


def _write_merged(update):
    """Read-merge-update BENCH_pp.json so `--only robust` doesn't clobber
    the pp curves (and vice versa). The write is ATOMIC: the merged JSON is
    serialized to a temp file in the same directory and os.replace'd over
    the target, so a run killed mid-write (a CI timeout on `--quick`) can
    only ever leave a stray temp file — never a truncated/corrupt
    BENCH_pp.json that would take the other sections' results with it."""
    path = os.path.join(ROOT, "BENCH_pp.json")
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    out.update(update)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(out, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)
    return out


def bench_pp(quick=False, emit=None):
    """Entry point shared with benchmarks.run (--only pp)."""
    if emit is None:
        def emit(name, us, derived):
            print(f"{name},{us:.2f},{derived}", flush=True)
    curves = bench_pp_curves(quick=quick, emit=emit)
    roundtime = bench_pp_roundtime(quick=quick, emit=emit)
    return _write_merged({
        "quick": bool(quick),
        "problem": {"n_clients": N_CLIENTS, "m_local": M_LOCAL, "d": DIM,
                    "compressor": "rand3", "scheme": "without"},
        "budgets_mbits": list(BUDGETS_MBITS),
        "curves": curves,
        "budget_table": budget_table(curves),
        "roundtime": roundtime,
    })


def bench_robust(quick=False, emit=None):
    """Entry point shared with benchmarks.run (--only robust)."""
    if emit is None:
        def emit(name, us, derived):
            print(f"{name},{us:.2f},{derived}", flush=True)
    grid = bench_robust_grid(quick=quick, emit=emit)
    roundtime = bench_robust_roundtime(quick=quick, emit=emit)
    return _write_merged({
        "robust": {"quick": bool(quick), **grid, "roundtime": roundtime},
    })


def bench_async(quick=False, emit=None):
    """Entry point shared with benchmarks.run (--only async)."""
    if emit is None:
        def emit(name, us, derived):
            print(f"{name},{us:.2f},{derived}", flush=True)
    curves = bench_async_curves(quick=quick, emit=emit)
    return _write_merged({
        "async": {
            "quick": bool(quick),
            "problem": {"n_clients": N_CLIENTS, "m_local": M_LOCAL,
                        "d": DIM, "compressor": "rand3", "alpha": 0.1},
            "curves": curves,
            "wall_table": async_wall_table(curves),
        },
    })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only", default="all", choices=("pp", "robust", "async", "all")
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.only in ("pp", "all"):
        bench_pp(quick=args.quick)
    if args.only in ("robust", "all"):
        bench_robust(quick=args.quick)
    if args.only in ("async", "all"):
        bench_async(quick=args.quick)


if __name__ == "__main__":
    main()
