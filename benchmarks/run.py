"""Benchmark harness — one entry per paper table/figure.

    Table 1  → bench_comm_complexity   (iterations & bits to ε-stationarity:
               MARINA vs DIANA vs DCGD, RandK sweep — the paper's headline)
    Fig. 1   → bench_binclass          (eq. 11 problem, full-batch methods)
    Fig. 1b  → bench_vr                (VR-MARINA vs VR-DIANA oracle complexity)
    Table PP → bench_pp                (PP-MARINA client-sampling sweep)
    Fig. 2   → bench_lm                (LM training proxy for ResNet18/CIFAR100:
               loss reached per transmitted bit)
    §Kernels → bench_kernels           (compression kernel wall time vs jnp ref)
    §Perf    → bench_compression       (per-leaf tree path vs fused flat engine,
               µs/round at d ∈ {1e5, 1e6}, n ∈ {4, 16}; writes
               BENCH_compression.json for the perf trajectory)
    §Perf    → bench_roundstep         (end-to-end train-step wall clock:
               sync vs compressed, two-backprop vs grad-carry + fused
               epilogue, dense vs compressed downlink; writes
               BENCH_roundstep.json — the CI regression gate)
    §7       → bench_roundstep_mp      (2-process jax.distributed smoke row:
               the compressed carry round across a real process boundary vs
               the 1-process fake-device mesh, with the transport's
               bits-by-tier ledger; merges a `multiproc` section into
               BENCH_roundstep.json)
    §4.9     → bench_robust            (Byzantine adversarial grid: attack ×
               GAR × faulty fraction on PP-MARINA + robust round-time rows;
               merges into BENCH_pp.json — gated by scripts/check_robust.py)
    §8       → bench_serve             (continuous vs static batching over
               the paged KV cache, mixed-length workload, f32 vs int8 pages;
               writes BENCH_serve.json — gated by scripts/check_serve.py)
    §4.10    → bench_async             (straggler wall-clock harness:
               synchronous MARINA vs deadline cohorts vs stale acceptance
               under lognormal/exponential/fixed-slow compute times; merges
               the `async` section into BENCH_pp.json)

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = step wall time;
derived = the figure-of-merit for that table).

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DCGD,
    Diana,
    Marina,
    RandK,
    VRMarina,
    diana_alpha,
    diana_gamma,
    make_gd,
    marina_gamma,
    vr_marina_gamma,
)
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
    sample_minibatch,
)

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.2f},{derived}", flush=True)


def _grad_sqnorm(x, data, d):
    flat = BinClassData(a=data.a.reshape(-1, d), y=data.y.reshape(-1))
    return float(jnp.sum(binclass_full_grad(x, flat) ** 2))


def _run_to_target(method, state, data, d, target, max_steps, extra=None):
    step = jax.jit(method.step)
    bits = 0.0
    t0 = time.time()
    k = 0
    for k in range(max_steps):
        key = jax.random.PRNGKey(k)
        if extra is not None:
            state, met = step(state, key, data, extra(key))
        else:
            state, met = step(state, key, data)
        bits += float(met.bits_per_worker)
        if (k + 1) % 50 == 0 and _grad_sqnorm(state.params, data, d) < target:
            break
    us = (time.time() - t0) / (k + 1) * 1e6
    return state, bits, k + 1, us


# ---------------------------------------------------------------------------


def bench_comm_complexity(quick=False):
    """Table 1: bits-to-ε for MARINA vs DIANA vs DCGD across RandK levels."""
    n, m, d = 10, 128, 100
    data = make_synthetic_binclass(jax.random.PRNGKey(0), n, m, d)
    L = binclass_smoothness(data)
    grad_fn = jax.grad(nonconvex_binclass_loss)
    x0 = jnp.zeros((d,))
    target = 1e-4
    max_steps = 800 if quick else 4000
    for K in ((5,) if quick else (1, 5, 10)):
        comp = RandK(k=K)
        omega = comp.omega(d)
        p = comp.default_p(d)
        mar = Marina(grad_fn, comp, marina_gamma(L, omega, p, n), p)
        _, bits, it, us = _run_to_target(mar, mar.init(x0, data), data, d, target, max_steps)
        emit(f"table1/marina_rand{K}", us, f"iters={it};Mbits={bits/1e6:.3f}")
        dia = Diana(grad_fn, comp, diana_gamma(L, omega, n), diana_alpha(omega), n)
        _, bits, it, us = _run_to_target(dia, dia.init(x0), data, d, target, max_steps)
        emit(f"table1/diana_rand{K}", us, f"iters={it};Mbits={bits/1e6:.3f}")
        dc = DCGD(grad_fn, comp, 0.25 / (L * (1 + omega / n)), n)
        _, bits, it, us = _run_to_target(dc, dc.init(x0), data, d, target, max_steps)
        emit(f"table1/dcgd_rand{K}", us, f"iters={it};Mbits={bits/1e6:.3f}")


def bench_binclass(quick=False):
    """Fig. 1 row 1: MARINA vs GD on eq. (11), bits to target."""
    n, m, d = 5, 256, 80
    data = make_synthetic_binclass(jax.random.PRNGKey(1), n, m, d)
    L = binclass_smoothness(data)
    grad_fn = jax.grad(nonconvex_binclass_loss)
    x0 = jnp.zeros((d,))
    target = 1e-4
    steps = 500 if quick else 3000
    gd = make_gd(grad_fn, 1.0 / L)
    _, bits, it, us = _run_to_target(gd, gd.init(x0, data), data, d, target, steps)
    emit("fig1/gd", us, f"iters={it};Mbits={bits/1e6:.3f}")
    comp = RandK(k=5)
    p = comp.default_p(d)
    mar = Marina(grad_fn, comp, marina_gamma(L, comp.omega(d), p, n), p)
    _, bits, it, us = _run_to_target(mar, mar.init(x0, data), data, d, target, steps)
    emit("fig1/marina_rand5", us, f"iters={it};Mbits={bits/1e6:.3f}")


def bench_vr(quick=False):
    """Fig. 1 row 2: VR-MARINA — oracle calls & bits to target with b'≈m/16."""
    n, m, d = 5, 128, 60
    data = make_synthetic_binclass(jax.random.PRNGKey(2), n, m, d)
    L = binclass_smoothness(data)
    grad_fn = jax.grad(nonconvex_binclass_loss)
    comp = RandK(k=3)
    bprime = max(2, m // 16)
    p = min(comp.default_p(d), bprime / (m + bprime))
    gamma = vr_marina_gamma(L, L, comp.omega(d), p, n, bprime)
    vr = VRMarina(grad_fn, grad_fn, comp, gamma, p)
    target = 3e-4
    steps = 600 if quick else 6000

    state = vr.init(jnp.zeros((d,)), data)
    step = jax.jit(vr.step)
    bits = oracle = 0.0
    t0 = time.time()
    k = 0
    for k in range(steps):
        key = jax.random.PRNGKey(k)
        mb = sample_minibatch(jax.random.fold_in(key, 1), data, bprime)
        state, met = step(state, key, data, mb)
        bits += float(met.bits_per_worker)
        oracle += float(met.oracle_calls)
        if (k + 1) % 100 == 0 and _grad_sqnorm(state.params, data, d) < target:
            break
    us = (time.time() - t0) / (k + 1) * 1e6
    emit("fig1/vr_marina_rand3", us,
         f"iters={k+1};oracle={oracle:.0f};Mbits={bits/1e6:.3f}")


def bench_pp(quick=False):
    """Federated PP harness (benchmarks/bench_pp.py): loss-vs-bits curves on
    Dirichlet non-IID clients + the mesh round-time r/n saving. Writes
    BENCH_pp.json, rendered into EXPERIMENTS.md by update_perf.py."""
    from benchmarks.bench_pp import bench_pp as run_pp

    run_pp(quick=quick, emit=emit)


def bench_robust(quick=False):
    """Byzantine-robust harness (benchmarks/bench_pp.py --only robust): the
    attack × GAR × fraction grid + robust round-time rows. Merges the
    ``robust`` section into BENCH_pp.json; scripts/check_robust.py gates."""
    from benchmarks.bench_pp import bench_robust as run_robust

    run_robust(quick=quick, emit=emit)


def bench_async(quick=False):
    """Straggler/deadline harness (benchmarks/bench_pp.py --only async):
    simulated wall clock to matched loss — synchronous MARINA vs deadline
    cohorts vs stale acceptance under lognormal/exponential/fixed-slow
    client compute times. Merges the ``async`` section into BENCH_pp.json."""
    from benchmarks.bench_pp import bench_async as run_async

    run_async(quick=quick, emit=emit)


def bench_serve(quick=False):
    """Serving harness (benchmarks/bench_serve.py): continuous batching over
    the paged KV cache vs static batching on a mixed-length workload, plus
    the int8 quantized-page pool. Writes BENCH_serve.json — gated by
    scripts/check_serve.py, rendered into EXPERIMENTS.md §Serving."""
    from benchmarks.bench_serve import bench_serve as run_serve

    run_serve(quick=quick, emit=emit)


def bench_lm(quick=False):
    """Fig. 2 proxy: tiny-LM loss after a fixed bit budget, VR-MARINA vs baselines."""
    from repro.models import init_params
    from repro.models.config import ModelConfig, dense_stack
    from repro.train import TrainConfig, Trainer

    cfg = ModelConfig(
        name="bench-lm", arch_type="dense", d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=256, segments=dense_stack(2),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    steps = 10 if quick else 40
    for method, gamma in (("vr_marina", 0.1), ("diana", 0.1), ("dcgd", 0.1)):
        tcfg = TrainConfig(
            method=method, compressor="randk", comp_kwargs={"k": 0.02},
            gamma=gamma, n_workers=3, batch_per_worker=4, mb_per_worker=2,
            steps=steps, log_every=max(1, steps // 4),
        )
        t0 = time.time()
        _, hist = Trainer(cfg, tcfg, params).run()
        us = (time.time() - t0) / steps * 1e6
        emit(
            f"fig2/{method}", us,
            f"loss0={hist.loss[0]:.3f};lossK={hist.loss[-1]:.3f};"
            f"Mbits={hist.bits_cum[-1]/1e6:.2f}",
        )


def bench_kernels(quick=False):
    """Kernel wall time (interpret mode on CPU — correctness path) vs jnp ref."""
    from repro.kernels import ops, ref

    d = 1 << 16
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    key = jax.random.PRNGKey(1)
    reps = 3 if quick else 10

    def timeit(fn):
        fn()  # compile
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn())
        return (time.time() - t0) / reps * 1e6

    us = timeit(lambda: ops.randk_compress(x, key, kb=8))
    emit("kernels/randk_compress_interp", us, f"d={d};kb=8")
    v, o = ops.randk_compress(x, key, kb=8)
    us = timeit(lambda: ops.randk_decompress_mean(v[None], o[None], d))
    emit("kernels/scatter_decompress_interp", us, f"d={d}")
    us = timeit(lambda: ops.qsgd_compress(x, key, s=4))
    emit("kernels/qsgd_compress_interp", us, f"d={d};s=4")

    x2d = ops.pad_to_blocks(x, 1024)
    offs = ops.jittered_offsets(key, x2d.shape[0], 1024, 8)
    ref_fn = jax.jit(lambda: ref.randk_block_compress_ref(x2d, offs, 128.0))
    us = timeit(ref_fn)
    emit("kernels/randk_ref_jnp", us, f"d={d}")


def _synthetic_grad_tree(key, d):
    """Multi-leaf gradient-like tree with Σ sizes = d (ragged on purpose)."""
    sizes = [d // 2, d // 4, d // 8, d - d // 2 - d // 4 - d // 8]
    ks = jax.random.split(key, len(sizes))
    tree = {}
    for i, (s, k) in enumerate(zip(sizes, ks)):
        rows = max(1, s // 512)
        cols = s // rows
        lead = s - rows * cols
        tree[f"w{i}"] = jax.random.normal(k, (rows, cols))
        if lead:
            tree[f"b{i}"] = jax.random.normal(jax.random.fold_in(k, 1), (lead,))
    return tree


def bench_compression(quick=False):
    """Fused flat engine vs per-leaf tree path: one full compressed-round
    aggregate (compress all n workers + server mean) at d ∈ {1e5, 1e6},
    n ∈ {4, 16}; plus the Perm-K disjoint-aggregation round vs the matched-
    budget independent-mask n·K all-gather round, and the packed quantization
    wire (DESIGN.md §4.6): dense 4-bit block-QSGD and the RandK∘QSGD
    composition vs the f32 wire the same ω-quantizers shipped before this
    engine existed (payload-bytes and wall-clock deltas). Writes
    BENCH_compression.json (consumed by scripts/update_perf.py) so the perf
    trajectory is tracked across PRs. ``quick`` (the CI mode) trims to
    d = 1e5 and 3 reps — noisy, flagged in the JSON."""
    from repro.core import QSGD, RandK, make_engine, wire
    from repro.core.marina import _compress_workers, _decompress_mean
    from repro.core.compressors import tree_dim

    reps = 3 if quick else 10
    kb, block = 8, 1024
    s = 7  # 4-bit wire: levels fit signed nibbles
    entries = []
    for d in ((100_000,) if quick else (100_000, 1_000_000)):
        tree = _synthetic_grad_tree(jax.random.PRNGKey(0), d)
        assert tree_dim(tree) == d
        eng = make_engine(tree, kb=kb, block=block)
        # matched budget: RandK keeps ~1/128 of each leaf = nblk·kb of d
        comp = RandK(k=kb / block)
        for n in (4, 16):
            diffs = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n, *x.shape)) * 1.0, tree
            )
            key = jax.random.PRNGKey(1)

            @jax.jit
            def per_leaf_round(key, diffs):
                payloads = _compress_workers(comp, key, diffs, n)
                return _decompress_mean(comp, payloads, tree, n)

            @jax.jit
            def flat_round(key, diffs):
                return eng.fused_delta(key, diffs, n)

            # Perm-K (disjoint d/n shards per worker) vs the independent-mask
            # all-gather at the SAME per-worker coordinate budget K_w =
            # padded/n: RandK with kb = B/n coords per block per worker.
            eng_pk = make_engine(tree, block=block, sampler="permk")
            eng_match = make_engine(tree, kb=block // n, block=block)

            @jax.jit
            def permk_round(key, diffs):
                return eng_pk.fused_delta(key, diffs, n)

            @jax.jit
            def allgather_round(key, diffs):
                return eng_match.fused_delta(key, diffs, n)

            # packed quantization wire: dense 4-bit block-QSGD (per-block
            # norms, nibble-packed levels) and the RandK∘QSGD composition at
            # the SAME kb as the flat-fused RandK round it rides on.
            eng_q = make_engine(tree, block=block, sampler="qsgd", s=s)
            eng_rq = make_engine(
                tree, kb=kb, block=block, sampler="randk_qsgd", s=s
            )
            comp_q = QSGD(s=s)

            @jax.jit
            def qsgd_dense_round(key, diffs):
                return eng_q.fused_delta(key, diffs, n)

            @jax.jit
            def randk_qsgd_round(key, diffs):
                return eng_rq.fused_delta(key, diffs, n)

            @jax.jit
            def per_leaf_qsgd_round(key, diffs):
                payloads = _compress_workers(comp_q, key, diffs, n)
                return _decompress_mean(comp_q, payloads, tree, n)

            def timeit_many(fns):
                # interleaved min-of-trials: every candidate is measured in
                # each trial window, so transient CPU load (which swings
                # non-adjacent sequences ±50% in this container) hits all of
                # them alike; the min is the comparable number.
                for fn in fns.values():
                    jax.block_until_ready(fn(key, diffs))  # compile
                trials, inner = 3, max(1, reps // 3)
                best = {name: float("inf") for name in fns}
                for _ in range(trials):
                    for name, fn in fns.items():
                        t0 = time.time()
                        for _ in range(inner):
                            jax.block_until_ready(fn(key, diffs))
                        best[name] = min(
                            best[name], (time.time() - t0) / inner * 1e6
                        )
                return best

            us = timeit_many({
                "tree": per_leaf_round,
                "flat": flat_round,
                "pk": permk_round,
                "ag": allgather_round,
                "q": qsgd_dense_round,
                "rq": randk_qsgd_round,
                "tree_q": per_leaf_qsgd_round,
            })
            us_tree, us_flat, us_pk, us_ag = (
                us["tree"], us["flat"], us["pk"], us["ag"]
            )
            us_q, us_rq, us_tree_q = us["q"], us["rq"], us["tree_q"]
            K = eng.layout.nblk * kb
            K_w = eng.layout.padded // n  # matched per-worker coordinates
            nblk = eng.layout.nblk
            entry = {
                "d": d,
                "n": n,
                "per_leaf_us": us_tree,
                "flat_fused_us": us_flat,
                "speedup": us_tree / us_flat,
                # aggregation-path peak floats (analytic): the tree path
                # materializes all n dense worker trees; the flat path holds
                # the n ζ-sized payloads + one dense accumulator.
                "per_leaf_agg_floats": n * d,
                "flat_agg_floats": n * K * 2 + eng.layout.padded,
                # --- disjoint-support aggregation (Perm-K) -----------------
                # payload bytes per compressed round at the production wire
                # dtypes, matched per-worker budget K_w: the independent-mask
                # all-gather moves (bf16 value + int16 index) per coordinate
                # for all n workers; the Perm-K exchange moves bf16 VALUES
                # ONLY (indices regenerate from the one shared 4-byte seed —
                # disjoint shards, nothing else on the wire).
                "permk_us": us_pk,
                "allgather_us": us_ag,
                "matched_coords_per_worker": K_w,
                "allgather_payload_bytes": n * K_w * (2 + 2) + n * 4,
                "disjoint_payload_bytes": n * K_w * 2 + 4,
                # --- packed quantization wire (DESIGN.md §4.6) -------------
                # packed wire (per-block f32 norms + 4-bit nibble levels)
                # vs the f32 wire a quantized round crossed BEFORE this
                # engine existed: launch/distributed.py had no quantized
                # collective (dense f32 diffs) and the flat engine no
                # quantized sampler (f32 values). NOTE the per-leaf sim
                # payload was already int8+norm in memory (ledger booked
                # ~4 bits/coord), so vs THAT representation the nibble win
                # is 2x — the f32 column is the wire, not the sim arrays.
                "qsgd_s": s,
                "qsgd_us": us_q,
                "per_leaf_qsgd_us": us_tree_q,
                "qsgd_packed_payload_bytes": wire.block_qsgd_bits(
                    nblk, block, s) / 8,
                "qsgd_f32_payload_bytes": wire.dense_f32_bits(
                    eng.layout.padded) / 8,
                "randk_qsgd_us": us_rq,
                "randk_qsgd_packed_payload_bytes": wire.randk_qsgd_bits(
                    nblk, kb, s) / 8,
                "randk_qsgd_f32_payload_bytes": wire.seeded_randk_bits(
                    nblk, kb) / 8,
            }
            entries.append(entry)
            emit(
                f"compression/d{d}_n{n}", us_flat,
                f"per_leaf_us={us_tree:.0f};speedup={entry['speedup']:.1f}x",
            )
            emit(
                f"compression/permk_d{d}_n{n}", us_pk,
                f"allgather_us={us_ag:.0f};"
                f"payload_B={entry['disjoint_payload_bytes']}"
                f"_vs_{entry['allgather_payload_bytes']}",
            )
            emit(
                f"compression/qsgd_d{d}_n{n}", us_q,
                f"per_leaf_qsgd_us={us_tree_q:.0f};"
                f"packed_B={entry['qsgd_packed_payload_bytes']:.0f}"
                f"_vs_f32_{entry['qsgd_f32_payload_bytes']:.0f}",
            )
            emit(
                f"compression/randk_qsgd_d{d}_n{n}", us_rq,
                f"flat_randk_us={us_flat:.0f};"
                f"packed_B={entry['randk_qsgd_packed_payload_bytes']:.0f}"
                f"_vs_f32_{entry['randk_qsgd_f32_payload_bytes']:.0f}",
            )

    out = {
        "block": block,
        "kb": kb,
        "qsgd_s": s,
        "backend": "ref(cpu)" if jax.default_backend() != "tpu" else "pallas",
        "reps": reps,
        "quick": bool(quick),   # quick numbers are noisy — flagged so the
                                # rendered perf log never passes them off as
                                # the official trajectory
        "entries": entries,
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_compression.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)


def _roundstep_problem(key, n, d):
    """Per-worker log-cosh regression through a (128, F) projection:
    loss_i(x) = Σ logcosh(reshape(x)·W − b_i).

    The contraction matters: an *elementwise* oracle lets XLA fuse the whole
    backprop through the RandK gather, so a "two-backprop" compressed round
    silently computes only ζ gradient coordinates and the benchmark would
    measure nothing. The matmul VJP (t @ Wᵀ) materializes the full (d,)
    gradient — the regime real models live in, and the cost the ISSUE's
    single-backprop rounds actually remove. The oracle is deterministic in x
    (fixed local b_i — the Alg. 1 regime where grad-carry is bit-exact)."""
    F = 64
    rows = d // 128
    assert rows * 128 == d, "roundstep dims are 128-aligned"
    kw, kb_ = jax.random.split(key)
    W = jax.random.normal(kw, (128, F)) / jnp.sqrt(128.0)
    b = jax.random.normal(kb_, (n, rows, F)) * 0.1
    batches = {"b": b}

    def loss(x, batch):
        z = x.reshape(rows, 128) @ W - batch["b"]
        # log cosh(z) = logaddexp(z, -z) - log 2 (stable)
        return jnp.sum(jnp.logaddexp(z, -z) - jnp.log(2.0))

    return jax.grad(loss), batches


def bench_roundstep(quick=False):
    """End-to-end MARINA train-step wall clock (jit-compiled, interleaved
    min-of-trials) at d ∈ {1e5, 1e6}, n ∈ {4, 16}:

    * sync round (p = 1) — the dense baseline, flat-psum exchange;
    * compressed round, two-backprop (the pre-carry seed path: flat-fused
      RandK uplink, dequant-mean + two tree.map passes server-side);
    * compressed round, grad-carry + fused epilogue (one backprop, one
      (nblk, B)-sweep epilogue kernel);
    * grad-carry + compressed downlink (Q_down = 4-bit block QSGD, s = 7).

    Wire bytes per compressed round (up + down, per worker) ride along from
    repro.core.wire — the downlink column is what the bits ledger used to
    silently ignore. Writes BENCH_roundstep.json (CI gates on the
    carry/sync ratio — scripts/check_roundstep.py)."""
    from repro.core import Marina, BlockRandK, make_downlink, make_engine, wire

    reps = 3 if quick else 10
    kb, block, s_down = 8, 1024, 7
    entries = []
    # ~1e5 and ~1e6, block-aligned (98·1024 and 976·1024)
    dims = ((100_352,) if quick else (100_352, 999_424))
    for d in dims:
        for n in (4, 16):
            grad_fn, batches = _roundstep_problem(jax.random.PRNGKey(0), n, d)
            x0 = jnp.zeros((d,))
            comp = BlockRandK(kb=kb, block=block)
            eng = make_engine(x0, kb=kb, block=block)
            down = make_downlink(eng, sampler="qsgd", s=s_down)
            gamma = 0.02

            def methods(p):
                return {
                    "two_backprop": Marina(grad_fn, comp, gamma, p, eng),
                    "carry_fused": Marina(grad_fn, comp, gamma, p, eng,
                                          carry=True),
                    "carry_down": Marina(grad_fn, comp, gamma, p, eng,
                                         carry=True, down_engine=down),
                }

            # p pins the lax.cond branch: p=1 times the sync round through
            # the full jitted step, p=0 the compressed round.
            sync_m = Marina(grad_fn, comp, gamma, 1.0, eng, carry=True)
            comp_ms = methods(0.0)

            fns = {}
            states = {}
            key = jax.random.PRNGKey(1)
            st0 = sync_m.init(x0, batches)
            fns["sync"] = jax.jit(sync_m.step)
            states["sync"] = st0
            for name, m in comp_ms.items():
                fns[name] = jax.jit(m.step)
                states[name] = m.init(x0, batches)

            # interleaved min-of-trials (same discipline as
            # bench_compression): each candidate measured in every trial
            # window so transient CPU load hits all alike.
            # per-call round-robin min-of-trials: steps here are 1–100 ms, so
            # single calls are timeable and interleaving at call granularity
            # gives every method the same draw from this container's load
            # noise (which swings coarser windows ±50%); the min converges
            # with the number of rounds.
            for name, fn in fns.items():
                jax.block_until_ready(fn(states[name], key, batches))  # compile
            # quick mode (the CI gate) only visits the small-d configs where
            # steps are milliseconds: take MORE draws there, not fewer — the
            # regression gate needs a converged min far more than CI minutes.
            rounds = max(2 * reps, 16) if quick else 2 * reps
            best = {name: float("inf") for name in fns}
            for _ in range(rounds):
                for name, fn in fns.items():
                    t0 = time.time()
                    st, _met = fn(states[name], key, batches)
                    jax.block_until_ready(st)
                    best[name] = min(best[name], (time.time() - t0) * 1e6)

            up_bits = eng.payload_bits(n)
            down_dense = wire.downlink_dense_bits(d)
            down_q = down.payload_bits(1)
            entry = {
                "d": d,
                "n": n,
                "sync_us": best["sync"],
                "two_backprop_us": best["two_backprop"],
                "carry_fused_us": best["carry_fused"],
                "carry_down_us": best["carry_down"],
                "carry_speedup": best["two_backprop"] / best["carry_fused"],
                # normalized (machine-portable) compressed/sync ratios — the
                # CI regression metric
                "carry_over_sync": best["carry_fused"] / best["sync"],
                "two_backprop_over_sync": best["two_backprop"] / best["sync"],
                # per-worker wire bits of one compressed round, both
                # directions (the up+down column EXPERIMENTS.md renders)
                "up_bits": up_bits,
                "down_bits_dense": down_dense,
                "down_bits_q": down_q,
                "total_bits_baseline": wire.round_total_bits(
                    up_bits, down_dense),
                "total_bits_down_q": wire.round_total_bits(up_bits, down_q),
                "wire_reduction": wire.round_total_bits(up_bits, down_dense)
                / wire.round_total_bits(up_bits, down_q),
            }
            entries.append(entry)
            emit(
                f"roundstep/d{d}_n{n}", best["carry_fused"],
                f"two_bp_us={best['two_backprop']:.0f};"
                f"speedup={entry['carry_speedup']:.2f}x;"
                f"wire_down={entry['wire_reduction']:.1f}x",
            )

    geo = float(
        np.exp(np.mean([np.log(e["carry_speedup"]) for e in entries]))
    )
    out = {
        "block": block,
        "kb": kb,
        "down_s": s_down,
        "backend": "ref(cpu)" if jax.default_backend() != "tpu" else "pallas",
        "reps": reps,
        "quick": bool(quick),
        # the headline: compressed-round wall clock, two-backprop → carry +
        # fused epilogue, geometric mean over the (d, n) grid
        "geomean_carry_speedup": geo,
        "entries": entries,
    }
    print(f"# geomean carry speedup: {geo:.2f}x", file=sys.stderr)
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_roundstep.json")
    if os.path.exists(path):
        # read-merge-update: the multiproc smoke section (bench_roundstep_mp)
        # survives a roundstep re-run and vice versa
        with open(path) as f:
            prev = json.load(f)
        if "multiproc" in prev:
            out["multiproc"] = prev["multiproc"]
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)}", file=sys.stderr)


_MP_ROUND_PROG = r"""
import json, os, time
from repro.launch import topology as topo
pid, nproc = topo.init_from_env()

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.launch import sharding as shd
from repro.launch.distributed import build_train_steps
from repro.models import init_params, reduced

n_dev = jax.device_count()
mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
arch = get_arch("qwen1.5-0.5b")
arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=64))
bundle = build_train_steps(
    arch, mesh, multi_pod=False, global_batch=2 * n_dev, seq_len=32,
    gamma=0.1, dtype=jnp.float32, grad_carry=True,
)
cfg = arch.model
rep = NamedSharding(mesh, P())
params = jax.jit(
    lambda: init_params(jax.random.PRNGKey(0), cfg, jnp.float32),
    out_shardings=rep,
)()
g0 = jax.tree.map(jnp.zeros_like, params)
h0 = jax.tree.map(lambda p: jnp.zeros((n_dev, *p.shape), p.dtype), params)
toks = jax.jit(
    lambda: jax.random.randint(
        jax.random.PRNGKey(1), (n_dev, 2, 32), 0, cfg.vocab_size
    ),
    out_shardings=rep,
)()
tr = bundle.transport
p_shard = tr.param_shardings
wlead = tr.waxes if len(tr.waxes) > 1 else tr.waxes[0]
h_shard = jax.tree.map(
    lambda ns: NamedSharding(mesh, P(wlead, *ns.spec)), p_shard
)
b_shard = NamedSharding(mesh, shd.batch_spec(tr.waxes, None, 3))
params = jax.device_put(params, p_shard)
g0 = jax.device_put(g0, p_shard)
h0 = jax.device_put(h0, h_shard)
batch = {"tokens": jax.device_put(toks, b_shard)}

rounds = int(os.environ.get("MARINA_MP_ROUNDS", "8"))
with bundle.mesh:
    fc, _ = bundle.fns["compressed_step"]
    x, g, h = fc(params, g0, h0, batch, np.asarray(jax.random.PRNGKey(7)))
    jax.block_until_ready(x)
    best = float("inf")
    for i in range(rounds):
        k = np.asarray(jax.random.PRNGKey(100 + i))
        t0 = time.time()
        x, g, h = fc(x, g, h, batch, k)
        jax.block_until_ready(x)
        best = min(best, (time.time() - t0) * 1e6)

led = bundle.transport.ledger
if pid == 0:
    print("MPBENCH " + json.dumps({
        "n_processes": nproc,
        "n_devices": n_dev,
        "compressed_us": best,
        "worker_tier": topo.detect_topology(mesh).tier_for_axes(("data",)),
        "wire_by_tier": led.by_tier(scope="compressed_step"),
    }), flush=True)
"""


def bench_roundstep_mp(quick=False):
    """2-process smoke row (ISSUE 7): the SAME compressed grad-carry round
    (reduced-qwen, 4 global devices) timed through a jax.distributed local
    cluster (2 processes × 2 devices — gloo collectives genuinely cross the
    process boundary, the simulated dcn) and through the historical
    1-process × 4-fake-device mesh. Merges a ``multiproc`` section into
    BENCH_roundstep.json (read-merge-update: the roundstep entries survive)
    carrying wall clocks, the worker-axis link tier, and the transport's
    bits-by-tier ledger for the compressed round."""
    from repro.launch.topology import spawn_local_cluster

    rounds = 6 if quick else 16
    section = {"quick": bool(quick), "rounds": rounds}
    for label, nproc, dev in (("2proc", 2, 2), ("1proc", 1, 4)):
        res = spawn_local_cluster(
            _MP_ROUND_PROG, num_processes=nproc, devices_per_process=dev,
            extra_env={"MARINA_MP_ROUNDS": str(rounds)},
        )
        bad = [r for r in res if r.returncode != 0]
        if bad:
            section[label] = {"ok": False, "error": bad[0].stderr[-800:]}
            print(f"# roundstep_mp/{label} FAILED:\n{bad[0].stderr[-2000:]}",
                  file=sys.stderr)
            continue
        line = next(
            ln for ln in res[0].stdout.splitlines() if ln.startswith("MPBENCH ")
        )
        payload = json.loads(line[len("MPBENCH "):])
        payload["ok"] = True
        section[label] = payload
        emit(
            f"roundstep_mp/{label}", payload["compressed_us"],
            f"tier={payload['worker_tier']};nproc={payload['n_processes']}",
        )
    if section.get("2proc", {}).get("ok") and section.get("1proc", {}).get("ok"):
        # the price of leaving the process: same algorithm, same wire bits,
        # collectives through gloo instead of one address space
        section["cross_process_slowdown"] = (
            section["2proc"]["compressed_us"] / section["1proc"]["compressed_us"]
        )

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_roundstep.json")
    out = {}
    if os.path.exists(path):
        with open(path) as f:
            out = json.load(f)
    out["multiproc"] = section
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {os.path.normpath(path)} (multiproc section)",
          file=sys.stderr)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    benches = {
        "comm_complexity": bench_comm_complexity,
        "binclass": bench_binclass,
        "vr": bench_vr,
        "pp": bench_pp,
        "robust": bench_robust,
        "async": bench_async,
        "lm": bench_lm,
        "serve": bench_serve,
        "kernels": bench_kernels,
        "compression": bench_compression,
        "roundstep": bench_roundstep,
        "roundstep_mp": bench_roundstep_mp,
    }
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        fn(quick=args.quick)
    print(f"# {len(ROWS)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
