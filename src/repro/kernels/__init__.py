"""Pallas TPU kernels for the compression hot path + pure-jnp oracles.

* ``randk.py``    — seeded RandK gather (`randk_seeded`, `randk_seeded_workers`)
                    and the server-side `scatter_accum` mean (DESIGN.md §5).
* ``permk.py``    — PermK correlated uplink (`permk_seeded_workers`): one
                    shared seeded affine permutation per block, worker-disjoint
                    chunk supports (DESIGN.md §4.5/§5).
* ``quantize.py`` — the packed quantization wire (DESIGN.md §4.6):
                    fused blockwise QSGD / natural uplinks
                    (`qsgd_block_workers`, `natural_block_workers`), the
                    fused dequantize-and-mean server kernels, the 4-bit
                    `nibble_pack`/`nibble_unpack` wire kernels, and the
                    legacy two-pass global-norm QSGD — all routed through
                    `flat.resolve_backend` (`backend="auto"`).
* ``epilogue.py`` — the fused server epilogue (DESIGN.md §4.7): one
                    (nblk, B)-tile sweep doing dequant/scatter-mean →
                    ``g += δ`` → ``x −= γ·g`` per wire family
                    (`delta`/`mean`/`scatter`/`qsgd`/`natural_epilogue`),
                    consuming either the n-worker uplink payloads or the
                    single compressed-downlink payload.
* ``ref.py``      — bit-exact pure-jnp oracles; the CPU/`ref` backend of the
                    flat engine (repro.core.flat) *is* these oracles.
* ``ops.py``      — jit'd flat-vector wrappers (padding, host-side samplers).
"""
