"""Pallas TPU kernels for the fused server epilogue (DESIGN.md §4.7/§5).

One (nblk, B)-tile HBM sweep finishes a compressed round on the receiving
side: dequantize/scatter-mean the worker payloads into the round delta,
advance the estimator ``g += δ`` and step the iterate ``x −= γ·g`` — three
passes (dequant-mean kernel + two ``tree.map`` sweeps) collapsed into one
kernel whose only dense traffic is reading (g, x) and writing (g', x') once.
The same kernels consume either direction's wire format: the n-worker uplink
payloads directly (no downlink configured), or the single server payload of
the compressed downlink ``Q_down(g^{k+1} − g^k)`` (n = 1), which makes them
the worker-side decompress-accumulate of the bidirectional wire.

Variants (one per wire family, mirroring the PR-3 kernel suite):

* ``delta_epilogue``   — already-dense δ (PermK concat-mean, tree paths).
* ``mean_epilogue``    — sync rounds: worker-mean of the packed gradient
                         buffers fused with the x update (the "sync rounds
                         ride the flat buffer" exchange).
* ``scatter_epilogue`` — seeded-RandK payloads: scatter-accumulate (one-hot
                         MXU matmuls, as in ``scatter_accum``) + apply.
* ``qsgd_epilogue``    — packed block-QSGD payloads: worker-indexed int8
                         dequant accumulation (input bandwidth stays int8).
* ``natural_epilogue`` — natural-compression payloads.
* ``trimmed_delta_epilogue`` / ``trimmed_sync_epilogue`` — Byzantine-robust
  rounds (DESIGN.md §4.9): coordinate-wise trimmed mean / median over the n
  worker rows via a sort-free rank selection, fused with the same update.

Every entry point takes ``backend="auto"`` and routes through
``repro.core.flat.resolve_backend``; the pure-jnp oracles live in
``kernels/ref.py`` (integer payload handling bit-exact, float accumulations
to the 1-ulp standard of DESIGN.md §4.4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _resolve(backend: str) -> str:
    from repro.core.flat import resolve_backend

    return resolve_backend(backend)


def _apply(g_new, x, gamma):
    """The shared tail: x' = (−γ)·g' + x, evaluated exactly like the
    per-leaf ``tree_axpy(-γ, g', x)`` so fused/unfused trajectories agree
    bit for bit (sign-flip and commuted add are IEEE-exact)."""
    return ((-gamma) * g_new + x.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Dense-δ and sync-mean epilogues
# ---------------------------------------------------------------------------


def _delta_epilogue_kernel(d_ref, g_ref, x_ref, gout_ref, xout_ref, *, gamma):
    g_new = g_ref[...].astype(jnp.float32) + d_ref[...].astype(jnp.float32)
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def delta_epilogue(delta2d, g2d, x2d, gamma: float, *, backend: str = "auto"):
    """(nblk, B) dense δ + g + x → (g' f32, x' x.dtype) in one sweep."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.delta_epilogue_ref(delta2d, g2d, x2d, float(gamma))
    nblk, B = g2d.shape
    return pl.pallas_call(
        functools.partial(_delta_epilogue_kernel, gamma=float(gamma)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(delta2d, g2d, x2d)


def _mean_epilogue_kernel(gb_ref, x_ref, gout_ref, xout_ref, *, n, gamma):
    B = x_ref.shape[-1]

    def body(w, acc):
        return acc + jax.lax.dynamic_index_in_dim(
            gb_ref[...], w, 0, keepdims=False
        ).astype(jnp.float32)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    g_new = acc / n
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def mean_epilogue(gbufs, x2d, gamma: float, *, backend: str = "auto"):
    """Sync-round epilogue: (n, nblk, B) packed worker gradients + x →
    (g' = worker mean f32, x' x.dtype). The worker mean runs over the ONE
    packed buffer — the fused psum replacing the per-leaf tree exchange."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.mean_epilogue_ref(gbufs, x2d, float(gamma))
    n, nblk, B = gbufs.shape
    return pl.pallas_call(
        functools.partial(_mean_epilogue_kernel, n=n, gamma=float(gamma)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(gbufs, x2d)


# ---------------------------------------------------------------------------
# Robust (GAR) epilogues: coordinate-wise trimmed mean / median over the n
# worker rows, fused with the g/x update (DESIGN.md §4.9). Sort-free k-th
# statistic: stable ranks (rank_i = #{v_j < v_i} + index tie-break) are a
# permutation of 0..n−1 per coordinate, so "keep ranks in [lo, hi)" selects
# exactly hi−lo values — O(n²·B) compares per tile, no data movement.
# ---------------------------------------------------------------------------


def _trimmed_rows(vals, n, lo, hi):
    """In-kernel trimmed mean of (n, 1, B) worker values → (1, B) f32.
    Accumulation order matches ``trimmed_mean_rows_ref`` loop for loop."""
    x = vals.astype(jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)

    def rank_body(j, acc):
        vj = jax.lax.dynamic_index_in_dim(x, j, 0, keepdims=True)
        lt = (vj < x).astype(jnp.int32)
        tie = (vj == x).astype(jnp.int32) * (iota > j).astype(jnp.int32)
        return acc + lt + tie

    ranks = jax.lax.fori_loop(
        0, n, rank_body, jnp.zeros(x.shape, jnp.int32)
    )
    keep = (ranks >= lo) & (ranks < hi)

    def sum_body(j, acc):
        # select, don't multiply: 0·NaN is NaN and trimming must drop
        # non-finite payload rows (they rank 0 — see the ref docstring)
        vj = jax.lax.dynamic_index_in_dim(x, j, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(keep, j, 0, keepdims=False)
        return acc + jnp.where(kj, vj, 0.0)

    acc = jax.lax.fori_loop(
        0, n, sum_body, jnp.zeros(x.shape[1:], jnp.float32)
    )
    return acc / (hi - lo)


def _trimmed_delta_kernel(
    b_ref, g_ref, x_ref, gout_ref, xout_ref, *, n, lo, hi, gamma
):
    g_new = g_ref[...].astype(jnp.float32) + _trimmed_rows(
        b_ref[...], n, lo, hi
    )
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def trimmed_delta_epilogue(bufs, g2d, x2d, gamma: float, lo: int, hi: int, *,
                           backend: str = "auto"):
    """Robust compressed-round epilogue: per-worker dense payload rows
    (n, nblk, B) + g + x → (g' = g + trimmed mean, x' = x − γ·g') in one
    sweep. ``(lo, hi)`` is the rank keep-window: (f, n−f) for the f-trimmed
    mean; the median bounds make the same kernel the coordinate-wise median."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.trimmed_delta_epilogue_ref(bufs, g2d, x2d, float(gamma),
                                               lo, hi)
    n, nblk, B = bufs.shape
    return pl.pallas_call(
        functools.partial(
            _trimmed_delta_kernel, n=n, lo=int(lo), hi=int(hi),
            gamma=float(gamma),
        ),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(bufs, g2d, x2d)


def _trimmed_sync_kernel(
    b_ref, x_ref, gout_ref, xout_ref, *, n, lo, hi, gamma
):
    g_new = _trimmed_rows(b_ref[...], n, lo, hi)
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def trimmed_sync_epilogue(bufs, x2d, gamma: float, lo: int, hi: int, *,
                          backend: str = "auto"):
    """Robust sync-round epilogue: (n, nblk, B) packed worker gradients + x →
    (g' = trimmed mean over workers, x' = x − γ·g') — ``mean_epilogue`` with
    the worker mean replaced by the rank-window trimmed mean."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.trimmed_sync_epilogue_ref(bufs, x2d, float(gamma), lo, hi)
    n, nblk, B = bufs.shape
    return pl.pallas_call(
        functools.partial(
            _trimmed_sync_kernel, n=n, lo=int(lo), hi=int(hi),
            gamma=float(gamma),
        ),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(bufs, x2d)


# ---------------------------------------------------------------------------
# Payload-consuming epilogues (the wire formats of DESIGN.md §4.2/§4.6)
# ---------------------------------------------------------------------------


def _scatter_epilogue_kernel(
    vals_ref, off_ref, g_ref, x_ref, gout_ref, xout_ref, *, n, gamma
):
    vals = vals_ref[...]      # (n, 1, kb)
    offs = off_ref[...]       # (n, 1, kb)
    kb = vals.shape[-1]
    B = g_ref.shape[-1]

    def body(w, acc):
        off_w = jax.lax.dynamic_index_in_dim(offs, w, 0, keepdims=False)
        val_w = jax.lax.dynamic_index_in_dim(vals, w, 0, keepdims=False)
        iota = jax.lax.broadcasted_iota(jnp.int32, (kb, B), 1)
        onehot = (iota == off_w.reshape(kb, 1)).astype(jnp.float32)
        return acc + jax.lax.dot_general(
            val_w.astype(jnp.float32), onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    g_new = g_ref[...].astype(jnp.float32) + acc / n
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def scatter_epilogue(values, offsets, g2d, x2d, gamma: float, *,
                     backend: str = "auto"):
    """Seeded-RandK epilogue: payloads (n, nblk, kb) ×2 + g + x → (g', x').
    The scatter-accumulate (one-hot MXU matmuls) and the g/x update share
    one grid sweep; per-worker dense trees are never materialized."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.scatter_epilogue_ref(values, offsets, g2d, x2d,
                                         float(gamma))
    n, nblk, kb = values.shape
    B = g2d.shape[-1]
    return pl.pallas_call(
        functools.partial(_scatter_epilogue_kernel, n=n, gamma=float(gamma)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, kb), lambda i: (0, i, 0)),
            pl.BlockSpec((n, 1, kb), lambda i: (0, i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(values.astype(jnp.float32), offsets, g2d, x2d)


def _qsgd_epilogue_kernel(
    q_ref, norm_ref, g_ref, x_ref, gout_ref, xout_ref, *, n, s, gamma
):
    B = g_ref.shape[-1]

    def body(w, acc):
        qw = jax.lax.dynamic_index_in_dim(q_ref[...], w, 0, keepdims=False)
        nw = jax.lax.dynamic_index_in_dim(norm_ref[...], w, 0, keepdims=False)
        return acc + qw.astype(jnp.float32) * (nw[0] / s)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    g_new = g_ref[...].astype(jnp.float32) + acc / n
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def qsgd_epilogue(levels, norms, g2d, x2d, gamma: float, s: int, *,
                  backend: str = "auto"):
    """Packed block-QSGD epilogue: (n, nblk, B) int8 levels + (n, nblk) f32
    norms + g + x → (g', x'). Same worker-indexed accumulation as
    ``qsgd_dequant_mean`` — input bandwidth stays int8 — fused with the
    estimator/iterate update."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.qsgd_epilogue_ref(levels, norms, g2d, x2d, float(gamma),
                                      s)
    n, nblk, B = levels.shape
    return pl.pallas_call(
        functools.partial(
            _qsgd_epilogue_kernel, n=n, s=int(s), gamma=float(gamma)
        ),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, i)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(levels, norms, g2d, x2d)


def _natural_epilogue_kernel(
    code_ref, scale_ref, g_ref, x_ref, gout_ref, xout_ref, *, n, gamma
):
    B = g_ref.shape[-1]

    def body(w, acc):
        cw = jax.lax.dynamic_index_in_dim(code_ref[...], w, 0, keepdims=False)
        sw = jax.lax.dynamic_index_in_dim(scale_ref[...], w, 0, keepdims=False)
        c = cw.astype(jnp.float32)
        mag = sw[0] * jnp.exp2(-(jnp.abs(c) - 1.0))
        return acc + jnp.where(c != 0, jnp.sign(c) * mag, 0.0)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    g_new = g_ref[...].astype(jnp.float32) + acc / n
    gout_ref[...] = g_new
    xout_ref[...] = _apply(g_new, x_ref[...], gamma).astype(xout_ref.dtype)


def natural_epilogue(codes, scales, g2d, x2d, gamma: float, *,
                     backend: str = "auto"):
    """Natural-compression epilogue: (n, nblk, B) int8 codes + (n, nblk) f32
    scales + g + x → (g', x'), decode-and-mean fused with the update."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.natural_epilogue_ref(codes, scales, g2d, x2d,
                                         float(gamma))
    n, nblk, B = codes.shape
    return pl.pallas_call(
        functools.partial(_natural_epilogue_kernel, n=n, gamma=float(gamma)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, i)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, B), jnp.float32),
            jax.ShapeDtypeStruct((nblk, B), x2d.dtype),
        ],
        interpret=(backend == "pallas_interpret"),
    )(codes, scales, g2d, x2d)
