"""Pallas TPU kernel for the PermK correlated compressor (DESIGN.md §5).

PermK partitions every block's coordinates across the n workers through one
SHARED seeded permutation, so unlike ``randk_seeded_workers`` the grid reads a
single scalar seed and derives worker-DISJOINT supports from the program id.
A full Fisher–Yates permutation does not map to the TPU; instead each block
uses a seeded *affine* bijection

    π_b(t) = (a_b · t + c_b) mod B,   a_b odd  (a unit of Z_B, B = 2^k)

with (a_b, c_b) drawn from the murmur3 counter RNG at counters (2b, 2b+1) —
pure uint32 VPU arithmetic, bit-exactly reproduced by
``ref.affine_perm_params_ref``. Worker w gathers permuted slots
[w·B/n, (w+1)·B/n): the n supports partition the block, so the server mean is
collision-free (``scatter_accum`` degenerates to assembly; the jnp ref also
provides a scatter-free inverse-perm gather, ``ref.permk_concat_mean_ref``).

The gather itself is the repo's idiomatic one-hot matmul against an iota
(kernels/randk.py) so the irregular indices ride the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .randk import murmur_bits


def _permk_workers_kernel(
    seed_ref, x_ref, vals_ref, off_ref, *, nblk: int, n: int
):
    i = pl.program_id(0)          # global block id over n·nblk
    w = i // nblk                 # worker
    b = i % nblk                  # worker-local block (same π for every w!)
    x = x_ref[...]                # (1, B)
    B = x.shape[-1]
    chunk = vals_ref.shape[-1]    # B // n
    seed = seed_ref[0].astype(jnp.uint32)
    # shared per-block affine permutation: counters (2b, 2b+1)
    a = (murmur_bits(seed, jnp.uint32(2 * b)) | jnp.uint32(1)) & jnp.uint32(B - 1)
    c = murmur_bits(seed, jnp.uint32(2 * b + 1)) & jnp.uint32(B - 1)
    t = (
        jax.lax.broadcasted_iota(jnp.uint32, (1, chunk), 1)
        + jnp.uint32(w * chunk)
    )
    off = ((a * t + c) & jnp.uint32(B - 1)).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (chunk, B), 1)
    onehot = (iota == off.reshape(chunk, 1)).astype(x.dtype)
    vals = jax.lax.dot_general(
        onehot, x.reshape(B, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    vals_ref[...] = (vals.reshape(1, chunk) * n).astype(vals_ref.dtype)
    off_ref[...] = off


def permk_seeded_workers(
    x3d: jax.Array, seed: jax.Array, *, interpret: bool = True
):
    """PermK uplink: (n, nblk, B) + one shared uint32 seed → values/offsets,
    both (n, nblk, B/n). Values carry the ×n Perm-K scale; the n workers'
    offsets partition [0, B) in every block. Requires n | B (powers of two)."""
    n, nblk, B = x3d.shape
    assert B & (B - 1) == 0, "block width must be a power of two"
    assert B % n == 0, "worker count must divide the block width"
    chunk = B // n
    x2d = x3d.reshape(n * nblk, B)
    vals, offs = pl.pallas_call(
        functools.partial(_permk_workers_kernel, nblk=nblk, n=n),
        grid=(n * nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * nblk, chunk), x3d.dtype),
            jax.ShapeDtypeStruct((n * nblk, chunk), jnp.int32),
        ],
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.int32), x2d)
    return vals.reshape(n, nblk, chunk), offs.reshape(n, nblk, chunk)
