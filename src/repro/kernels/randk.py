"""Pallas TPU kernels for RandK compression / server-side decompression.

TPU adaptation (DESIGN.md §3/§5): a GPU RandK uses cuRAND + global gather +
atomics. Neither maps to the TPU. Instead:

* the flat gradient is reshaped to ``(nblk, B)`` blocks; each grid step owns one
  ``(1, B)`` VMEM tile (B a multiple of 128 → lane-aligned);
* *gather* and *scatter* are expressed as one-hot matmuls against an iota —
  a (kb, B) comparison matrix contracted on the MXU, which is the idiomatic
  TPU way to move irregular indices through a systolic array;
* the index sampler runs on the host side of the jit (indices are K ≪ d values,
  so their HBM traffic is negligible), keeping the kernel deterministic and
  exactly testable against ref.py. A seeded in-kernel sampler using
  ``pltpu.prng_random_bits`` is provided for the production path
  (``randk_seeded``) and validated statistically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Gather (compress): values[i, j] = x[i, offsets[i, j]] * scale
# ---------------------------------------------------------------------------


def _randk_gather_kernel(x_ref, off_ref, out_ref, *, scale: float):
    x = x_ref[...]            # (1, B)
    off = off_ref[...]        # (1, kb)
    B = x.shape[-1]
    kb = off.shape[-1]
    # one-hot (kb, B) gather matrix; contraction runs on the MXU
    iota = jax.lax.broadcasted_iota(jnp.int32, (kb, B), 1)
    onehot = (iota == off.reshape(kb, 1)).astype(x.dtype)
    vals = jax.lax.dot_general(
        onehot, x.reshape(B, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (kb, 1)
    out_ref[...] = (vals.reshape(1, kb) * scale).astype(out_ref.dtype)


def randk_gather(
    x2d: jax.Array, offsets: jax.Array, scale: float, *, interpret: bool = True
) -> jax.Array:
    """x2d (nblk, B), offsets (nblk, kb) → (nblk, kb) scaled values."""
    nblk, B = x2d.shape
    _, kb = offsets.shape
    return pl.pallas_call(
        functools.partial(_randk_gather_kernel, scale=float(scale)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, kb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, kb), x2d.dtype),
        interpret=interpret,
    )(x2d, offsets)


# ---------------------------------------------------------------------------
# Scatter-accumulate (decompress + server mean over n workers)
# ---------------------------------------------------------------------------


def _scatter_accum_kernel(vals_ref, off_ref, out_ref, *, n: int):
    vals = vals_ref[...]      # (n, 1, kb)
    offs = off_ref[...]       # (n, 1, kb)
    kb = vals.shape[-1]
    B = out_ref.shape[-1]

    def body(w, acc):
        off_w = jax.lax.dynamic_index_in_dim(offs, w, 0, keepdims=False)  # (1, kb)
        val_w = jax.lax.dynamic_index_in_dim(vals, w, 0, keepdims=False)  # (1, kb)
        iota = jax.lax.broadcasted_iota(jnp.int32, (kb, B), 1)
        onehot = (iota == off_w.reshape(kb, 1)).astype(jnp.float32)
        # (1, kb) @ (kb, B) scatter-as-matmul; duplicates accumulate.
        return acc + jax.lax.dot_general(
            val_w.astype(jnp.float32), onehot, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    out_ref[...] = (acc / n).astype(out_ref.dtype)


def scatter_accum(
    values: jax.Array, offsets: jax.Array, block: int, *, interpret: bool = True
) -> jax.Array:
    """values/offsets (n, nblk, kb) → dense (nblk, block) mean over workers."""
    n, nblk, kb = values.shape
    return pl.pallas_call(
        functools.partial(_scatter_accum_kernel, n=n),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, kb), lambda i: (0, i, 0)),
            pl.BlockSpec((n, 1, kb), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, block), values.dtype),
        interpret=interpret,
    )(values, offsets)


# ---------------------------------------------------------------------------
# Seeded production sampler: indices from an on-chip counter-based PRNG
# ---------------------------------------------------------------------------
#
# We use the murmur3 finalizer as a counter-based hash RNG: pure uint32 vector
# arithmetic, so it lowers on the TPU VPU, runs in any interpreter, and is
# *bit-exactly* reproducible by the pure-jnp oracle (ref.murmur_bits_ref).
# (``pltpu.prng_random_bits`` would also work on hardware but is stubbed in the
# CPU interpreter, making it untestable here.)


def murmur_bits(seed: jax.Array, ctr: jax.Array) -> jax.Array:
    """murmur3 finalizer over (seed, counter): uint32 → uint32 hash."""
    x = ctr.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + seed.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _randk_seeded_kernel(seed_ref, x_ref, vals_ref, off_ref, *, scale: float):
    i = pl.program_id(0)
    x = x_ref[...]            # (1, B)
    B = x.shape[-1]
    kb = vals_ref.shape[-1]
    ctr = jax.lax.broadcasted_iota(jnp.uint32, (1, kb), 1) + jnp.uint32(i * kb)
    bits = murmur_bits(seed_ref[0].astype(jnp.uint32), ctr)
    # B is a power of two in production layouts; mask instead of mod.
    off = (bits & jnp.uint32(B - 1)).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (kb, B), 1)
    onehot = (iota == off.reshape(kb, 1)).astype(x.dtype)
    vals = jax.lax.dot_general(
        onehot, x.reshape(B, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    vals_ref[...] = (vals.reshape(1, kb) * scale).astype(vals_ref.dtype)
    off_ref[...] = off


def randk_seeded(
    x2d: jax.Array, seed: jax.Array, kb: int, scale: float, *, interpret: bool = True
):
    """Production path: sample kb indices per block on-chip (with replacement —
    unbiased with ω = B/kb, see DESIGN.md §5), gather, scale. Returns
    (values, offsets), both (nblk, kb). B must be a power of two."""
    nblk, B = x2d.shape
    assert B & (B - 1) == 0, "block width must be a power of two"
    return pl.pallas_call(
        functools.partial(_randk_seeded_kernel, scale=float(scale)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, kb), x2d.dtype),
            jax.ShapeDtypeStruct((nblk, kb), jnp.int32),
        ],
        interpret=interpret,
    )(seed.reshape(1).astype(jnp.int32), x2d)


# ---------------------------------------------------------------------------
# Worker-batched seeded sampler: the flat engine's uplink kernel
# ---------------------------------------------------------------------------


def _randk_seeded_workers_kernel(
    seed_ref, x_ref, vals_ref, off_ref, *, scale: float, nblk: int
):
    i = pl.program_id(0)          # global block id over n·nblk
    w = i // nblk                 # worker
    b = i % nblk                  # worker-local block
    x = x_ref[...]                # (1, B)
    B = x.shape[-1]
    kb = vals_ref.shape[-1]
    # worker-local counter stream: block b covers counters [b·kb, (b+1)·kb) —
    # the same stream tree_compress produces per worker, so the flat path is
    # bit-identical to the per-leaf path on block-aligned layouts.
    ctr = jax.lax.broadcasted_iota(jnp.uint32, (1, kb), 1) + jnp.uint32(b * kb)
    bits = murmur_bits(seed_ref[w].astype(jnp.uint32), ctr)
    off = (bits & jnp.uint32(B - 1)).astype(jnp.int32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (kb, B), 1)
    onehot = (iota == off.reshape(kb, 1)).astype(x.dtype)
    vals = jax.lax.dot_general(
        onehot, x.reshape(B, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    vals_ref[...] = (vals.reshape(1, kb) * scale).astype(vals_ref.dtype)
    off_ref[...] = off


def randk_seeded_workers(
    x3d: jax.Array, seeds: jax.Array, kb: int, scale: float, *,
    interpret: bool = True,
):
    """Per-worker seeded RandK: (n, nblk, B) + seeds (n,) → values/offsets
    (n, nblk, kb). Workers are folded into the grid (n·nblk steps) with
    per-worker seeds read from SMEM; each worker restarts its counter stream
    at 0, matching the tree path's per-worker key split (DESIGN.md §4.2)."""
    n, nblk, B = x3d.shape
    assert B & (B - 1) == 0, "block width must be a power of two"
    x2d = x3d.reshape(n * nblk, B)
    vals, offs = pl.pallas_call(
        functools.partial(
            _randk_seeded_workers_kernel, scale=float(scale), nblk=nblk
        ),
        grid=(n * nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
            pl.BlockSpec((1, kb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * nblk, kb), x3d.dtype),
            jax.ShapeDtypeStruct((n * nblk, kb), jnp.int32),
        ],
        interpret=interpret,
    )(seeds.astype(jnp.int32), x2d)
    return vals.reshape(n, nblk, kb), offs.reshape(n, nblk, kb)
