"""jit'd wrappers exposing the Pallas kernels on flat vectors.

Handles the flat → (nblk, B) blocked layout, zero padding, host-side index
sampling, and jittered-stratified offsets (one index per stride — unbiased with
the same ω = d/K − 1 as classic RandK, see DESIGN.md §5). These wrappers are
what the benchmarks and kernel tests call; the production compressed round
goes through repro.core.flat's fused engine instead. ``interpret=None``
resolves via the engine's backend switch: compiled on TPU, interpret mode on
this CPU container.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import randk as _randk
from . import quantize as _quant

DEFAULT_BLOCK = 1024  # lanes-aligned (8 × 128) VMEM tile width


def _interp(interpret) -> bool:
    if interpret is None:
        from repro.core.flat import resolve_backend

        return resolve_backend("auto") != "pallas"
    return bool(interpret)


def _quant_backend(interpret) -> str:
    """ops' legacy interpret flag → the quantize kernels' backend switch:
    None resolves like the engine ('pallas' on TPU, bit-exact 'ref' on CPU),
    an explicit bool forces the Pallas kernel in compiled/interpret mode."""
    if interpret is None:
        from repro.core.flat import resolve_backend

        return resolve_backend("auto")
    return "pallas_interpret" if interpret else "pallas"


def pad_to_blocks(x: jax.Array, block: int) -> jax.Array:
    """Flat (d,) → (nblk, block) with zero padding."""
    d = x.shape[0]
    nblk = max(1, -(-d // block))
    pad = nblk * block - d
    return jnp.pad(x, (0, pad)).reshape(nblk, block)


def jittered_offsets(key: jax.Array, nblk: int, block: int, kb: int) -> jax.Array:
    """Stratified sampling: one uniform index inside each of kb strides per block.

    Marginal inclusion probability of every coordinate is kb/block, so scaling by
    block/kb is unbiased; distinct strides ⇒ distinct indices (no replacement).
    """
    stride = block // kb
    base = jnp.arange(kb, dtype=jnp.int32) * stride
    jitter = jax.random.randint(key, (nblk, kb), 0, stride, dtype=jnp.int32)
    return base[None, :] + jitter


@partial(jax.jit, static_argnames=("kb", "block", "interpret"))
def randk_compress(
    x: jax.Array,
    key: jax.Array,
    kb: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
):
    """Blockwise jittered RandK of a flat vector. Returns (values, offsets, d).

    Effective K = nblk·kb, scale = block/kb = d_padded/K.
    """
    x2d = pad_to_blocks(x, block)
    nblk = x2d.shape[0]
    offsets = jittered_offsets(key, nblk, block, kb)
    scale = block / kb
    values = _randk.randk_gather(x2d, offsets, scale, interpret=_interp(interpret))
    return values, offsets


@partial(jax.jit, static_argnames=("d", "block", "interpret"))
def randk_decompress_mean(
    values: jax.Array,
    offsets: jax.Array,
    d: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    """Server aggregation of n worker payloads (n, nblk, kb) → dense (d,)."""
    dense = _randk.scatter_accum(values, offsets, block, interpret=_interp(interpret))
    return dense.reshape(-1)[:d]


@partial(jax.jit, static_argnames=("s", "block", "interpret"))
def qsgd_compress(
    x: jax.Array,
    key: jax.Array,
    s: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
):
    """Fused two-pass QSGD: (q int8 (d_padded,), norm scalar)."""
    x2d = pad_to_blocks(x, block)
    sumsq = _quant.block_sumsq(x2d, backend=_quant_backend(interpret))
    norm = jnp.sqrt(jnp.sum(sumsq))
    u2d = jax.random.uniform(key, x2d.shape)
    q = _quant.qsgd_quantize(
        x2d, u2d, norm, s, backend=_quant_backend(interpret)
    )
    return q, norm


@partial(jax.jit, static_argnames=("s", "d", "block", "interpret"))
def qsgd_decompress(
    q: jax.Array,
    norm: jax.Array,
    s: int,
    d: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool | None = None,
) -> jax.Array:
    dense = _quant.qsgd_dequantize(q, norm, s, backend=_quant_backend(interpret))
    return dense.reshape(-1)[:d]
