"""Pallas TPU kernel for paged-KV single-query attention (DESIGN.md §8).

The serving engine's decode hot path: each slot's query attends over the KV
pages its block-table row names. The kernel is a scalar-prefetch gather —
grid ``(n_slots, max_pages)``, with the block table and valid-length vector
prefetched into SMEM so the *index map itself* performs the page gather:
step ``(s, p)`` DMAs page ``tables[s, p]`` of the pool into VMEM, and the
last page step runs one masked softmax over the assembled per-slot cache.
No dense (S, max_len) cache is ever materialized; idle table entries point
at the null page and are masked by ``n_valid``.

Decode attention is memory-bound (every step streams the active KV pages
once, at arithmetic intensity ~1 FLOP/byte against the ~240 FLOP/byte
ridge), so the win is exactly the bytes the paging avoids: the pool holds
``Σ ceil(len_i / P)`` pages instead of ``n_slots × max_len`` rows.

Backend contract (like every kernel in this package): ``auto`` → compiled
Pallas on TPU, the bit-exact jnp oracle (kernels/ref.py) elsewhere;
``pallas_interpret`` validates the kernel body op-for-op against the
oracle. The int8 quantized-page mode routes through the jnp gather+dequant
path on every backend — int8 HBM traffic is already the win; a fused int8
kernel is future work (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref

_NEG_INF = -1e30


def _resolve(backend: str) -> str:
    from repro.core.flat import resolve_backend

    return resolve_backend(backend)


def _paged_attn_kernel(
    tbl_ref, nv_ref, q_ref, k_ref, v_ref, out_ref, k_scr, v_scr,
    *, page_size: int, max_pages: int,
):
    """Grid step (s, p): land page ``tables[s, p]`` in the per-slot scratch
    cache; on the slot's last page, attend. Mirrors ``paged_attend_ref``
    op for op (GQA repeat, f32 logits/softmax, v-dtype output)."""
    s = pl.program_id(0)
    p = pl.program_id(1)
    k_scr[pl.ds(p * page_size, page_size)] = k_ref[0]
    v_scr[pl.ds(p * page_size, page_size)] = v_ref[0]

    @pl.when(p == max_pages - 1)
    def _attend():
        q = q_ref[0]                                  # (H, hd)
        k = k_scr[...]                                # (L, KV, hd)
        v = v_scr[...]
        H, hd = q.shape
        KV = k.shape[1]
        rep = H // KV
        k_e = jnp.repeat(k, rep, axis=1) if rep > 1 else k
        v_e = jnp.repeat(v, rep, axis=1) if rep > 1 else v
        scale = 1.0 / jnp.sqrt(hd)
        logits = jnp.einsum("hd,khd->hk", q, k_e).astype(jnp.float32) * scale
        L = k.shape[0]
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, L), 1)
        logits = jnp.where(idx < nv_ref[s], logits, _NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out_ref[0] = jnp.einsum("hk,khd->hd", w.astype(v_e.dtype), v_e)


def paged_attn_decode(
    q: jax.Array,
    kpages: jax.Array,
    vpages: jax.Array,
    tables: jax.Array,
    n_valid: jax.Array,
    *,
    backend: str = "auto",
) -> jax.Array:
    """Block-table-gather single-query attention.

    q (S, H, hd); kpages/vpages (npage, P, KV, hd); tables (S, max_pages)
    int32 (page 0 = null); n_valid (S,) int32 — valid cache positions per
    slot INCLUDING the current token. Returns (S, H, hd) in v dtype.
    """
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.paged_attn_decode_ref(q, kpages, vpages, tables, n_valid)
    S, H, hd = q.shape
    _, P, KV, _ = kpages.shape
    maxp = tables.shape[1]
    L = maxp * P
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, maxp),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda s, p, tbl, nv: (s, 0, 0)),
            pl.BlockSpec(
                (1, P, KV, hd), lambda s, p, tbl, nv: (tbl[s, p], 0, 0, 0)
            ),
            pl.BlockSpec(
                (1, P, KV, hd), lambda s, p, tbl, nv: (tbl[s, p], 0, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda s, p, tbl, nv: (s, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((L, KV, hd), kpages.dtype),
            pltpu.VMEM((L, KV, hd), vpages.dtype),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=P, max_pages=maxp),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, H, hd), vpages.dtype),
        interpret=(backend == "pallas_interpret"),
    )(tables.astype(jnp.int32), n_valid.astype(jnp.int32), q, kpages, vpages)


def paged_attn_decode_q8(
    q: jax.Array,
    kq: jax.Array,
    vq: jax.Array,
    k_scale: jax.Array,
    v_scale: jax.Array,
    tables: jax.Array,
    n_valid: jax.Array,
    *,
    backend: str = "auto",
) -> jax.Array:
    """int8 quantized-page decode attention: every backend runs the jnp
    gather + dequantize-gathered-rows path (see module docstring); the
    ``backend`` arg is accepted for routing symmetry and validated."""
    _resolve(backend)
    return _ref.paged_attn_decode_q8_ref(
        q, kq, vq, k_scale, v_scale, tables, n_valid
    )
