"""Pure-jnp oracles for the compression kernels.

Every function here is the semantic ground truth for its Pallas counterpart;
tests assert_allclose kernel-vs-ref over shape/dtype sweeps in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def randk_block_compress_ref(x2d: jax.Array, offsets: jax.Array, scale: float) -> jax.Array:
    """Gather per-block coordinates and scale.

    x2d:     (nblk, B)   the flat gradient reshaped into VMEM-sized blocks
    offsets: (nblk, kb)  local indices in [0, B) chosen by the (host) sampler
    returns: (nblk, kb)  values · scale  (scale = d/K for unbiasedness)
    """
    gathered = jnp.take_along_axis(x2d, offsets, axis=1)
    return gathered * jnp.asarray(scale, x2d.dtype)


def scatter_accum_ref(
    values: jax.Array, offsets: jax.Array, block: int
) -> jax.Array:
    """Server-side aggregation: mean over n workers of scatter-add payloads.

    values:  (n, nblk, kb)
    offsets: (n, nblk, kb) local indices in [0, block)
    returns: (nblk, block) dense mean; duplicates within a worker accumulate
             (with-replacement sampling is allowed).
    """
    n, nblk, kb = values.shape
    out = jnp.zeros((nblk, block), values.dtype)

    def per_block(vals_b, offs_b):
        # vals_b, offs_b: (n, kb)
        dense = jnp.zeros((block,), values.dtype)
        return dense.at[offs_b.reshape(-1)].add(vals_b.reshape(-1))

    dense = jax.vmap(per_block, in_axes=(1, 1))(values, offsets)  # (nblk, block)
    return dense / n


#: counter offset separating the composition's dither stream from the index
#: stream of the same seed (index counters are < nblk·kb ≪ 2^30). Plain int:
#: a module-level jnp constant would capture a tracer if the module is first
#: imported inside a jit trace (the engine imports lazily).
DITHER_CTR_OFFSET = 0x40000000


def uniform_from_bits_ref(bits: jax.Array) -> jax.Array:
    """uint32 hash bits → f32 uniform in [0, 1), bit-exact on every backend.

    (bits >> 8) < 2^24 is exactly representable in f32, so the conversion and
    the 2^-24 scale are both exact — ref and kernel agree bit for bit."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def qsgd_quantize_ref(
    x2d: jax.Array, u2d: jax.Array, norm: jax.Array, s: int
) -> jax.Array:
    """Stochastic s-level quantization (QSGD): int8 levels with sign.

    x2d/u2d: (nblk, B);  u ~ U[0,1) supplied by the host sampler
    norm:    scalar ℓ2 norm of the full vector
    returns: (nblk, B) int8, value = sign(x)·⌊s|x|/‖x‖ + u⌋
    """
    safe = jnp.where(norm > 0, norm, 1.0).astype(jnp.float32)
    level = jnp.floor(s * jnp.abs(x2d.astype(jnp.float32)) / safe + u2d)
    return (jnp.sign(x2d.astype(jnp.float32)) * level).astype(jnp.int8)


def qsgd_dequantize_ref(q2d: jax.Array, norm: jax.Array, s: int) -> jax.Array:
    return q2d.astype(jnp.float32) * (norm / s)


def block_sumsq_ref(x2d: jax.Array) -> jax.Array:
    """Per-block Σx² (pass 1 of the two-pass fused QSGD norm)."""
    return jnp.sum(jnp.square(x2d.astype(jnp.float32)), axis=1)


def murmur_bits_ref(seed: jax.Array, ctr: jax.Array) -> jax.Array:
    """Bit-exact oracle for the kernel's counter-based RNG (murmur3 finalizer)."""
    x = ctr.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + seed.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def randk_seeded_ref(x2d: jax.Array, seed: jax.Array, kb: int, scale: float):
    """Oracle for randk_seeded: same hash, same masking, same gather."""
    nblk, B = x2d.shape
    ctr = (
        jnp.arange(kb, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * kb)[:, None]
    )
    bits = murmur_bits_ref(seed, ctr)
    off = (bits & jnp.uint32(B - 1)).astype(jnp.int32)
    vals = jnp.take_along_axis(x2d, off, axis=1) * jnp.asarray(scale, x2d.dtype)
    return vals, off


def randk_seeded_workers_ref(
    x3d: jax.Array, seeds: jax.Array, kb: int, scale: float
):
    """Oracle for randk_seeded_workers: per-worker seed, worker-local counters.

    x3d: (n, nblk, B);  seeds: (n,) uint32
    returns: values/offsets, both (n, nblk, kb)
    """
    return jax.vmap(
        lambda x2d, s: randk_seeded_ref(x2d, s.astype(jnp.uint32), kb, scale)
    )(x3d, seeds)


# ---------------------------------------------------------------------------
# PermK: seeded affine block permutations (disjoint worker supports)
# ---------------------------------------------------------------------------


def affine_perm_params_ref(seed: jax.Array, nblk: int, block: int):
    """Per-block affine bijection π_b(t) = (a_b·t + c_b) mod block.

    a_b is forced odd (a unit of Z_{2^k}, so π_b is a permutation of the
    block) and both coefficients come from the murmur3 counter RNG at
    counters (2b, 2b+1) — disjoint from the randk sampler's stream only by
    convention (different compressor, different seed).
    Returns a, c: (nblk,) uint32."""
    b = jnp.arange(nblk, dtype=jnp.uint32)
    mask = jnp.uint32(block - 1)
    a = (murmur_bits_ref(seed, 2 * b) | jnp.uint32(1)) & mask
    c = murmur_bits_ref(seed, 2 * b + 1) & mask
    return a, c


def odd_inverse_ref(a: jax.Array) -> jax.Array:
    """Multiplicative inverse of odd a modulo 2^32 (Newton iteration; exact
    after 5 steps). Masking to block−1 gives the inverse mod any 2^k."""
    a = a.astype(jnp.uint32)
    inv = a  # correct mod 2^3 already for odd a
    for _ in range(5):
        inv = inv * (jnp.uint32(2) - a * inv)
    return inv


def permk_offsets_ref(
    seed: jax.Array, nblk: int, block: int, n: int, wid: jax.Array
) -> jax.Array:
    """Worker wid's PermK support: offsets (nblk, block/n) int32 in [0, block).

    Worker w owns permuted slots [w·C, (w+1)·C), C = block/n; across the n
    workers the offsets partition every block exactly (π is a bijection)."""
    assert block % n == 0, "worker count must divide the block width"
    chunk = block // n
    a, c = affine_perm_params_ref(seed.astype(jnp.uint32), nblk, block)
    t = (
        jnp.arange(chunk, dtype=jnp.uint32)[None, :]
        + jnp.asarray(wid, jnp.uint32) * jnp.uint32(chunk)
    )
    off = (a[:, None] * t + c[:, None]) & jnp.uint32(block - 1)
    return off.astype(jnp.int32)


def permk_seeded_workers_ref(x3d: jax.Array, seed: jax.Array, n: int):
    """Oracle for the PermK uplink: one SHARED seed, per-worker disjoint chunk.

    x3d: (n, nblk, B); returns values/offsets, both (n, nblk, B/n); values are
    scaled by n (Perm-K's unbiasedness factor)."""
    nblk, B = x3d.shape[1], x3d.shape[2]
    wids = jnp.arange(n, dtype=jnp.int32)

    def one(x2d, w):
        off = permk_offsets_ref(seed.astype(jnp.uint32), nblk, B, n, w)
        vals = jnp.take_along_axis(x2d, off, axis=1) * jnp.asarray(n, x2d.dtype)
        return vals, off

    return jax.vmap(one)(x3d, wids)


def permk_concat_mean_ref(
    values: jax.Array, seed: jax.Array, block: int
) -> jax.Array:
    """Disjoint-support aggregation: mean over n PermK payloads WITHOUT scatter.

    values: (n, nblk, block/n) worker payloads (already scaled by n).
    The supports partition each block, so the mean is assembly, not
    accumulation: concatenate the chunks in slot order t = w·C+j and gather
    through the inverse permutation π⁻¹(s) = a⁻¹·(s − c) mod block.
    Returns (nblk, block) f32 — bit-compatible with scatter_accum_ref on the
    same payloads (collision-free ⇒ identical sums)."""
    n, nblk, chunk = values.shape
    a, c = affine_perm_params_ref(seed.astype(jnp.uint32), nblk, block)
    a_inv = odd_inverse_ref(a)
    s = jnp.arange(block, dtype=jnp.uint32)[None, :]
    slot = (a_inv[:, None] * (s - c[:, None])) & jnp.uint32(block - 1)
    # (nblk, block) values ordered by slot: slot t holds worker t//C's j-th value
    by_slot = jnp.moveaxis(values, 0, 1).reshape(nblk, n * chunk)
    dense = jnp.take_along_axis(by_slot, slot.astype(jnp.int32), axis=1)
    return dense.astype(jnp.float32) / n


# ---------------------------------------------------------------------------
# Packed quantization wire: block QSGD / natural compression (DESIGN.md §4.6)
# ---------------------------------------------------------------------------


def qsgd_block_ref(x2d: jax.Array, seed: jax.Array, s: int):
    """Blockwise s-level ℓ2 QSGD with seeded murmur3 dither.

    x2d: (nblk, B); each block quantized against its OWN ℓ2 norm (the
    per-block f32 norm rides the wire — DESIGN.md §4.6), dither counters
    [b·B, (b+1)·B) so the stream is a pure function of (seed, coordinate).
    Returns (levels int8 (nblk, B), norms f32 (nblk,)); |level| ≤ s, so
    levels fit a signed nibble for s ≤ 7 and int8 for s ≤ 127."""
    nblk, B = x2d.shape
    x = x2d.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x, axis=1))                    # (nblk,)
    safe = jnp.where(norm > 0, norm, 1.0)
    ctr = (
        jnp.arange(B, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * B)[:, None]
    )
    u = uniform_from_bits_ref(murmur_bits_ref(seed.astype(jnp.uint32), ctr))
    level = jnp.floor(s * jnp.abs(x) / safe[:, None] + u)
    return (jnp.sign(x) * level).astype(jnp.int8), norm


def qsgd_block_workers_ref(x3d: jax.Array, seeds: jax.Array, s: int):
    """Per-worker blockwise QSGD: (n, nblk, B) + (n,) seeds →
    (levels (n, nblk, B) int8, norms (n, nblk) f32). Worker counter streams
    restart at 0, mirroring the tree path's per-worker key split."""
    return jax.vmap(
        lambda x2d, sd: qsgd_block_ref(x2d, sd.astype(jnp.uint32), s)
    )(x3d, seeds)


def qsgd_dequant_mean_ref(
    levels: jax.Array, norms: jax.Array, s: int
) -> jax.Array:
    """Fused server aggregation: (n, nblk, B) int8 levels + (n, nblk) norms
    → (nblk, B) f32 mean. Accumulates worker by worker (fori_loop) so the
    only dense f32 buffer is the single (nblk, B) accumulator — the (n, d)
    dequantized trees are never materialized, and the input traffic stays at
    int8 bandwidth. Same accumulation order as the Pallas kernel (bit-exact
    float sums)."""
    n, nblk, B = levels.shape

    def body(w, acc):
        lw = jax.lax.dynamic_index_in_dim(levels, w, 0, keepdims=False)
        nw = jax.lax.dynamic_index_in_dim(norms, w, 0, keepdims=False)
        return acc + lw.astype(jnp.float32) * (nw / s)[:, None]

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((nblk, B), jnp.float32))
    return acc / n


def natural_block_ref(x2d: jax.Array, seed: jax.Array):
    """Blockwise natural compression (Horváth et al. 2019) on the packed wire.

    |x| is stochastically rounded to a power of two (E preserved, ω = 1/8);
    the wire code is the exponent *delta* from the block's reference scale
    ``2^(⌊log2 max|x_b|⌋ + 1)``: code = sign·(delta + 1) in int8, 0 for true
    zeros AND for magnitudes ≥ 2^126 below the block max (dropping those is a
    ≤ 2^-126·‖x_b‖_∞ perturbation — below f32 relative resolution).
    Returns (codes int8 (nblk, B), scales f32 (nblk,))."""
    nblk, B = x2d.shape
    x = x2d.astype(jnp.float32)
    ax = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.where(ax > 0, ax, 1.0)))
    lo = jnp.exp2(e)
    p_up = jnp.where(ax > 0, (ax - lo) / lo, 0.0)              # in [0, 1)
    ctr = (
        jnp.arange(B, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * B)[:, None]
    )
    u = uniform_from_bits_ref(murmur_bits_ref(seed.astype(jnp.uint32), ctr))
    e_q = e + (u < p_up).astype(jnp.float32)
    mx = jnp.max(ax, axis=1)                                   # (nblk,)
    e_ref = jnp.floor(jnp.log2(jnp.where(mx > 0, mx, 1.0))) + 1.0
    scale = jnp.exp2(e_ref)
    delta = e_ref[:, None] - e_q                               # ≥ 0
    keep = (ax > 0) & (delta <= 126.0)
    code = jnp.where(keep, jnp.sign(x) * (delta + 1.0), 0.0)
    return code.astype(jnp.int8), scale


def natural_block_workers_ref(x3d: jax.Array, seeds: jax.Array):
    """Per-worker blockwise natural compression: (n, nblk, B) + (n,) seeds →
    (codes (n, nblk, B) int8, scales (n, nblk) f32)."""
    return jax.vmap(
        lambda x2d, sd: natural_block_ref(x2d, sd.astype(jnp.uint32))
    )(x3d, seeds)


def natural_decode_ref(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """(nblk, B) int8 codes + (nblk,) f32 scales → dense f32 block buffer."""
    c = codes.astype(jnp.float32)
    mag = scales[:, None] * jnp.exp2(-(jnp.abs(c) - 1.0))
    return jnp.where(c != 0, jnp.sign(c) * mag, 0.0)


def natural_dequant_mean_ref(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """Fused server aggregation of natural payloads: (n, nblk, B) int8 +
    (n, nblk) f32 → (nblk, B) f32 mean; single dense accumulator."""
    n, nblk, B = codes.shape

    def body(w, acc):
        cw = jax.lax.dynamic_index_in_dim(codes, w, 0, keepdims=False)
        sw = jax.lax.dynamic_index_in_dim(scales, w, 0, keepdims=False)
        return acc + natural_decode_ref(cw, sw)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((nblk, B), jnp.float32))
    return acc / n


def nibble_pack_ref(q2d: jax.Array) -> jax.Array:
    """(nblk, B) int8 levels in [-8, 7] → (nblk, B/8) uint32 lane words.

    Level t of each 8-group occupies bits [4t, 4t+4) as a two's-complement
    nibble; this IS the 4-bit wire representation (half a byte per
    coordinate). Requires B % 8 == 0 (lane-aligned layouts always satisfy)."""
    nblk, B = q2d.shape
    assert B % 8 == 0, "block width must pack into whole uint32 words"
    nib = (q2d.astype(jnp.int32) & 0xF).astype(jnp.uint32).reshape(nblk, B // 8, 8)
    word = nib[..., 0]
    for t in range(1, 8):
        word = word | (nib[..., t] << jnp.uint32(4 * t))
    return word


def nibble_unpack_ref(words: jax.Array, block: int) -> jax.Array:
    """(nblk, B/8) uint32 lane words → (nblk, B) int8 (sign-extended nibbles).
    Exact inverse of :func:`nibble_pack_ref` on levels in [-8, 7]."""
    nblk, nw = words.shape
    assert nw * 8 == block
    nib = jnp.stack(
        [(words >> jnp.uint32(4 * t)) & jnp.uint32(0xF) for t in range(8)],
        axis=-1,
    ).astype(jnp.int8)                                         # values 0..15
    q = jnp.where(nib >= 8, nib - jnp.int8(16), nib)
    return q.reshape(nblk, block)


# ---------------------------------------------------------------------------
# Fused server epilogue (DESIGN.md §4.7): dequant/scatter-mean → g += δ →
# x −= γ·g in one (nblk, B)-tile sweep. Every oracle mirrors its Pallas twin
# in kernels/epilogue.py accumulation-order for accumulation-order, so integer
# payload handling is bit-exact and float sums agree to the same 1-ulp
# standard as the dequant-mean kernels (DESIGN.md §4.4).
# ---------------------------------------------------------------------------


def delta_epilogue_ref(delta2d, g2d, x2d, gamma: float):
    """Apply an already-dense round delta: g' = g + δ, x' = x − γ·g'.

    delta2d/g2d: (nblk, B) f32; x2d: (nblk, B) in the layout compute dtype.
    Returns (g_new f32, x_new x.dtype). The x update is evaluated exactly as
    the per-leaf path's ``tree_axpy(-γ, g', x)`` (IEEE sign-flip + commuted
    add are exact), so fused and unfused trajectories coincide bit for bit."""
    g_new = g2d.astype(jnp.float32) + delta2d.astype(jnp.float32)
    x_new = (-gamma) * g_new + x2d.astype(jnp.float32)
    return g_new, x_new.astype(x2d.dtype)


def mean_epilogue_ref(gbufs, x2d, gamma: float):
    """Sync-round epilogue: g' = mean over the worker axis of the packed
    gradient buffers (the ONE fused psum replacing the per-leaf tree mean),
    x' = x − γ·g'. gbufs: (n, nblk, B); returns (g_new f32, x_new x.dtype)."""
    g_new = jnp.mean(gbufs.astype(jnp.float32), axis=0)
    x_new = (-gamma) * g_new + x2d.astype(jnp.float32)
    return g_new, x_new.astype(x2d.dtype)


def scatter_epilogue_ref(values, offsets, g2d, x2d, gamma: float):
    """Seeded-RandK epilogue: scatter-accumulate the n worker payloads into
    the round delta and apply it, never materializing per-worker dense trees.
    values/offsets: (n, nblk, kb); returns (g_new f32, x_new x.dtype)."""
    delta = scatter_accum_ref(
        values.astype(jnp.float32), offsets, g2d.shape[-1]
    )
    return delta_epilogue_ref(delta, g2d, x2d, gamma)


def qsgd_epilogue_ref(levels, norms, g2d, x2d, gamma: float, s: int):
    """Packed-QSGD epilogue: fused dequantize-and-mean of the int8 payloads
    (same worker-indexed accumulation as ``qsgd_dequant_mean_ref``) + the
    g/x update. levels: (n, nblk, B) int8; norms: (n, nblk) f32."""
    delta = qsgd_dequant_mean_ref(levels, norms, s)
    return delta_epilogue_ref(delta, g2d, x2d, gamma)


def natural_epilogue_ref(codes, scales, g2d, x2d, gamma: float):
    """Natural-compression epilogue: fused decode-and-mean + g/x update."""
    delta = natural_dequant_mean_ref(codes, scales)
    return delta_epilogue_ref(delta, g2d, x2d, gamma)


def row_ranks_ref(rows: jax.Array) -> jax.Array:
    """Stable coordinate-wise ranks over the worker axis (sort-free).

    rows: (n, ...) — returns int32 ranks of the same shape where
    ``rank_i = #{j: v_j < v_i} + #{j < i: v_j == v_i}``. Ties break by worker
    index, so per coordinate the ranks are always a permutation of 0..n−1 —
    the k-th order statistic is the row with rank k, no sort needed. O(n²)
    compares per coordinate, accumulated worker by worker (fori_loop) in the
    exact order of the Pallas kernel; integer sums are order-free, so the
    ranks are bit-identical across backends."""
    n = rows.shape[0]
    x = rows.astype(jnp.float32)
    tail = (1,) * (x.ndim - 1)
    after_j = lambda j: (
        jnp.arange(n, dtype=jnp.int32) > j
    ).astype(jnp.int32).reshape((n,) + tail)

    def body(j, acc):
        vj = jax.lax.dynamic_index_in_dim(x, j, 0, keepdims=True)   # (1, ...)
        lt = (vj < x).astype(jnp.int32)
        tie = (vj == x).astype(jnp.int32) * after_j(j)
        return acc + lt + tie

    return jax.lax.fori_loop(0, n, body, jnp.zeros(x.shape, jnp.int32))


def trimmed_mean_rows_ref(rows: jax.Array, lo: int, hi: int) -> jax.Array:
    """Coordinate-wise trimmed mean over the worker axis.

    rows: (n, ...) → (...) f32: per coordinate, sort the n worker values and
    average the window ``[lo, hi)``. ``lo = f, hi = n−f`` is the f-trimmed
    mean; the coordinate-wise median is the trim-bound special case
    ``((n−1)//2, (n−1)//2+1)`` for odd n and ``(n//2−1, n//2+1)`` (mean of
    the two middle values) for even n.

    Implemented as an odd-even transposition sorting network over the
    (small) worker axis — ~n²/2 vectorized compare-exchanges, kept as a
    flat min/max DAG over per-row slices so XLA fuses it without buffer
    copies. That beats both the O(n²) sequential rank sweep of the Pallas
    kernel's formulation (``epilogue._trimmed_rows``) and ``jnp.sort``
    (whose CPU lowering is pathologically slow on a tiny sort axis with
    millions of batch columns) by an order of magnitude, and is
    *value-identical* to the kernel: the stable ranks are a permutation
    per coordinate, so the kept multiset is exactly the sorted window.
    NaN payloads are substituted with +inf before the network (min/max
    would propagate a NaN into BOTH lanes of a compare-exchange), sending
    them to the END, while under the rank semantics they rank 0 (every
    NaN comparison is false) — both land OUTSIDE every real trim window
    (``trim_bounds`` only emits lo ≥ 1 whenever hi < n), so the NaN
    exclusion matches; with f NaN rows the survivors are the honest
    values minus their f smallest. (More NaN rows than the trim width
    exceeds the rule's breakdown point — only the failure shape differs
    between the two formulations there.) Float sums may differ from the
    kernel by accumulation order — cross-backend tests compare with
    allclose, as for every other epilogue."""
    n = rows.shape[0]
    assert 0 <= lo < hi <= n, f"trim window [{lo}, {hi}) invalid for n={n}"
    x = rows.astype(jnp.float32)
    x = jnp.where(jnp.isnan(x), jnp.inf, x)
    r = [x[i] for i in range(n)]
    for stage in range(n):
        for i in range(stage % 2, n - 1, 2):
            a, b = r[i], r[i + 1]
            r[i] = jnp.minimum(a, b)
            r[i + 1] = jnp.maximum(a, b)
    acc = r[lo]
    for i in range(lo + 1, hi):
        acc = acc + r[i]
    return acc / (hi - lo)


def trimmed_delta_epilogue_ref(bufs, g2d, x2d, gamma: float, lo: int, hi: int):
    """Robust compressed-round epilogue: g' = g + trimmed_mean(worker rows),
    x' = x − γ·g'. bufs: (n, nblk, B) per-worker dense payload rows."""
    delta = trimmed_mean_rows_ref(bufs, lo, hi)
    return delta_epilogue_ref(delta, g2d, x2d, gamma)


def trimmed_sync_epilogue_ref(bufs, x2d, gamma: float, lo: int, hi: int):
    """Robust sync-round epilogue: g' = trimmed_mean of the packed worker
    gradient buffers (replacing the worker mean), x' = x − γ·g'."""
    g_new = trimmed_mean_rows_ref(bufs, lo, hi)
    x_new = (-gamma) * g_new + x2d.astype(jnp.float32)
    return g_new, x_new.astype(x2d.dtype)


def randk_qsgd_workers_ref(
    x3d: jax.Array, seeds: jax.Array, kb: int, scale: float, s: int
):
    """RandK∘QSGD composition uplink: seeded RandK keeps kb coords per block
    (scaled B/kb), then blockwise QSGD quantizes ONLY those K values against
    the per-block norm of the sampled vector. Dither counters live at
    DITHER_CTR_OFFSET so they never collide with the index stream of the same
    seed. Returns (levels (n, nblk, kb) int8, offsets (n, nblk, kb) int32,
    norms (n, nblk) f32). K-sized compute: no Pallas kernel needed — the
    quantization touches ζ ≪ d values (the gather/scatter stay on the fused
    kernels)."""
    vals, offs = randk_seeded_workers_ref(x3d, seeds, kb, scale)
    levels, norms = qsgd_sampled_quantize_ref(vals, seeds, s)
    return levels, offs, norms


def qsgd_sampled_quantize_ref(vals: jax.Array, seeds: jax.Array, s: int):
    """QSGD stage of the composition: quantize already-sampled values
    (n, nblk, kb) against per-block norms of the SAMPLED vector. Works on
    whatever the gather kernel produced (so the gather itself can stay on the
    backend-switched Pallas path). Returns (levels int8, norms f32)."""
    _, nblk, kb = vals.shape
    ctr = (
        jnp.arange(kb, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * kb)[:, None]
        + jnp.uint32(DITHER_CTR_OFFSET)
    )

    def quantize(v2d, sd):
        v = v2d.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(v * v, axis=1))
        safe = jnp.where(norm > 0, norm, 1.0)
        u = uniform_from_bits_ref(murmur_bits_ref(sd.astype(jnp.uint32), ctr))
        level = jnp.floor(s * jnp.abs(v) / safe[:, None] + u)
        return (jnp.sign(v) * level).astype(jnp.int8), norm

    return jax.vmap(quantize)(vals, seeds)


def randk_qsgd_dequant_ref(
    levels: jax.Array, norms: jax.Array, s: int
) -> jax.Array:
    """Composition payload → f32 values ready for scatter-accumulate:
    (n, nblk, kb) int8 + (n, nblk) f32 → (n, nblk, kb) f32. K-sized."""
    return levels.astype(jnp.float32) * (norms / s)[..., None]


# ---------------------------------------------------------------------------
# Paged KV cache: block-table-gather attention + int8 page rows (DESIGN.md §8)
# ---------------------------------------------------------------------------

#: masking sentinel, matching models/attention.py (exp(−1e30 − m) underflows
#: to exactly 0.0 in f32, so masked positions contribute exact zeros)
_NEG_INF = -1e30


def paged_gather_ref(pages: jax.Array, tables: jax.Array) -> jax.Array:
    """(npage, P, ...) pool + (S, max_pages) int32 tables →
    (S, max_pages·P, ...) per-slot flat cache views. Token t of slot s lands
    at flat index t (pages are gathered in block-table order), so position
    masks are plain ``arange(L) < n_valid`` — no indirection survives the
    gather."""
    g = pages[tables]                       # (S, maxp, P, ...)
    S, maxp, P = g.shape[:3]
    return g.reshape(S, maxp * P, *g.shape[3:])


def paged_attend_ref(
    q: jax.Array, k_flat: jax.Array, v_flat: jax.Array, n_valid: jax.Array
) -> jax.Array:
    """Single-query attention over gathered per-slot caches.

    q (S, H, hd); k_flat/v_flat (S, L, KV, hd); n_valid (S,) int32 — valid
    positions per slot INCLUDING the current token (callers write k_t/v_t
    before attending). Same op sequence as the dense ``attn_decode`` body
    (GQA repeat, f32 logits/softmax, v-dtype output) and, per slot, as the
    Pallas kernel in kernels/paged.py — the bit-exactness contract."""
    S, H, hd = q.shape
    KV = k_flat.shape[2]
    rep = H // KV
    k_e = jnp.repeat(k_flat, rep, axis=2) if rep > 1 else k_flat
    v_e = jnp.repeat(v_flat, rep, axis=2) if rep > 1 else v_flat
    scale = 1.0 / jnp.sqrt(hd)
    logits = jnp.einsum("shd,skhd->shk", q, k_e).astype(jnp.float32) * scale
    L = k_flat.shape[1]
    valid = jnp.arange(L)[None, :] < n_valid[:, None]
    logits = jnp.where(valid[:, None, :], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("shk,skhd->shd", w.astype(v_e.dtype), v_e)


def paged_attn_decode_ref(
    q: jax.Array,
    kpages: jax.Array,
    vpages: jax.Array,
    tables: jax.Array,
    n_valid: jax.Array,
) -> jax.Array:
    """Oracle for the paged-attention decode kernel: gather pages through the
    block tables, then one-shot masked attention. q (S, H, hd);
    kpages/vpages (npage, P, KV, hd); tables (S, max_pages) int32;
    n_valid (S,) int32. Returns (S, H, hd) in v dtype."""
    return paged_attend_ref(
        q, paged_gather_ref(kpages, tables), paged_gather_ref(vpages, tables),
        n_valid,
    )


def absmax_quant_rows_ref(x2d: jax.Array):
    """Symmetric absmax int8 quantization per row (the quantized-page wire).

    x2d (R, W) → (codes int8 (R, W), scales f32 (R,)): scale = max|x|/127,
    code = round-to-nearest-even(x / scale). Deterministic (no dither —
    KV entries are read many times, so unbiased-per-read stochastic noise
    would not average out the way a gradient's does). Error model:
    |x − x̂| ≤ scale/2 = max|x|/254 per element (DESIGN.md §8)."""
    x = x2d.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)
    # multiply by the f32 reciprocal instead of dividing: XLA rewrites x/127
    # into x * (1/127) in some lowerings but not others, and the kernel must
    # match this oracle bit-for-bit
    scale = amax * jnp.float32(1.0 / 127.0)
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.round(x / safe[:, None]).astype(jnp.int8)
    return codes, scale


def absmax_dequant_rows_ref(codes: jax.Array, scales: jax.Array) -> jax.Array:
    """(R, W) int8 codes + (R,) f32 scales → (R, W) f32 rows."""
    return codes.astype(jnp.float32) * scales[:, None]


def paged_attn_decode_q8_ref(
    q: jax.Array,
    kq: jax.Array,
    vq: jax.Array,
    k_scale: jax.Array,
    v_scale: jax.Array,
    tables: jax.Array,
    n_valid: jax.Array,
) -> jax.Array:
    """Quantized-page decode attention: gather int8 pages (kq/vq
    (npage, P, KV, hd) int8, scales (npage, P, KV) f32) through the block
    tables, dequantize ONLY the gathered rows, then the same attention body
    as the f32 path. HBM traffic for the cache read is int8 + one f32 scale
    per (row, kv-head) — the 2–4× KV-memory cut of the quantized-page mode."""
    kgf = paged_gather_ref(kq, tables).astype(jnp.float32)      # (S, L, KV, hd)
    vgf = paged_gather_ref(vq, tables).astype(jnp.float32)
    ks = paged_gather_ref(k_scale, tables)                      # (S, L, KV)
    vs = paged_gather_ref(v_scale, tables)
    return paged_attend_ref(q, kgf * ks[..., None], vgf * vs[..., None], n_valid)
