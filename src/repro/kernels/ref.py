"""Pure-jnp oracles for the compression kernels.

Every function here is the semantic ground truth for its Pallas counterpart;
tests assert_allclose kernel-vs-ref over shape/dtype sweeps in interpret mode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def randk_block_compress_ref(x2d: jax.Array, offsets: jax.Array, scale: float) -> jax.Array:
    """Gather per-block coordinates and scale.

    x2d:     (nblk, B)   the flat gradient reshaped into VMEM-sized blocks
    offsets: (nblk, kb)  local indices in [0, B) chosen by the (host) sampler
    returns: (nblk, kb)  values · scale  (scale = d/K for unbiasedness)
    """
    gathered = jnp.take_along_axis(x2d, offsets, axis=1)
    return gathered * jnp.asarray(scale, x2d.dtype)


def scatter_accum_ref(
    values: jax.Array, offsets: jax.Array, block: int
) -> jax.Array:
    """Server-side aggregation: mean over n workers of scatter-add payloads.

    values:  (n, nblk, kb)
    offsets: (n, nblk, kb) local indices in [0, block)
    returns: (nblk, block) dense mean; duplicates within a worker accumulate
             (with-replacement sampling is allowed).
    """
    n, nblk, kb = values.shape
    out = jnp.zeros((nblk, block), values.dtype)

    def per_block(vals_b, offs_b):
        # vals_b, offs_b: (n, kb)
        dense = jnp.zeros((block,), values.dtype)
        return dense.at[offs_b.reshape(-1)].add(vals_b.reshape(-1))

    dense = jax.vmap(per_block, in_axes=(1, 1))(values, offsets)  # (nblk, block)
    return dense / n


def qsgd_quantize_ref(
    x2d: jax.Array, u2d: jax.Array, norm: jax.Array, s: int
) -> jax.Array:
    """Stochastic s-level quantization (QSGD): int8 levels with sign.

    x2d/u2d: (nblk, B);  u ~ U[0,1) supplied by the host sampler
    norm:    scalar ℓ2 norm of the full vector
    returns: (nblk, B) int8, value = sign(x)·⌊s|x|/‖x‖ + u⌋
    """
    safe = jnp.where(norm > 0, norm, 1.0).astype(jnp.float32)
    level = jnp.floor(s * jnp.abs(x2d.astype(jnp.float32)) / safe + u2d)
    return (jnp.sign(x2d.astype(jnp.float32)) * level).astype(jnp.int8)


def qsgd_dequantize_ref(q2d: jax.Array, norm: jax.Array, s: int) -> jax.Array:
    return q2d.astype(jnp.float32) * (norm / s)


def block_sumsq_ref(x2d: jax.Array) -> jax.Array:
    """Per-block Σx² (pass 1 of the two-pass fused QSGD norm)."""
    return jnp.sum(jnp.square(x2d.astype(jnp.float32)), axis=1)


def murmur_bits_ref(seed: jax.Array, ctr: jax.Array) -> jax.Array:
    """Bit-exact oracle for the kernel's counter-based RNG (murmur3 finalizer)."""
    x = ctr.astype(jnp.uint32) * jnp.uint32(0x9E3779B9) + seed.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def randk_seeded_ref(x2d: jax.Array, seed: jax.Array, kb: int, scale: float):
    """Oracle for randk_seeded: same hash, same masking, same gather."""
    nblk, B = x2d.shape
    ctr = (
        jnp.arange(kb, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * kb)[:, None]
    )
    bits = murmur_bits_ref(seed, ctr)
    off = (bits & jnp.uint32(B - 1)).astype(jnp.int32)
    vals = jnp.take_along_axis(x2d, off, axis=1) * jnp.asarray(scale, x2d.dtype)
    return vals, off


def randk_seeded_workers_ref(
    x3d: jax.Array, seeds: jax.Array, kb: int, scale: float
):
    """Oracle for randk_seeded_workers: per-worker seed, worker-local counters.

    x3d: (n, nblk, B);  seeds: (n,) uint32
    returns: values/offsets, both (n, nblk, kb)
    """
    return jax.vmap(
        lambda x2d, s: randk_seeded_ref(x2d, s.astype(jnp.uint32), kb, scale)
    )(x3d, seeds)


# ---------------------------------------------------------------------------
# PermK: seeded affine block permutations (disjoint worker supports)
# ---------------------------------------------------------------------------


def affine_perm_params_ref(seed: jax.Array, nblk: int, block: int):
    """Per-block affine bijection π_b(t) = (a_b·t + c_b) mod block.

    a_b is forced odd (a unit of Z_{2^k}, so π_b is a permutation of the
    block) and both coefficients come from the murmur3 counter RNG at
    counters (2b, 2b+1) — disjoint from the randk sampler's stream only by
    convention (different compressor, different seed).
    Returns a, c: (nblk,) uint32."""
    b = jnp.arange(nblk, dtype=jnp.uint32)
    mask = jnp.uint32(block - 1)
    a = (murmur_bits_ref(seed, 2 * b) | jnp.uint32(1)) & mask
    c = murmur_bits_ref(seed, 2 * b + 1) & mask
    return a, c


def odd_inverse_ref(a: jax.Array) -> jax.Array:
    """Multiplicative inverse of odd a modulo 2^32 (Newton iteration; exact
    after 5 steps). Masking to block−1 gives the inverse mod any 2^k."""
    a = a.astype(jnp.uint32)
    inv = a  # correct mod 2^3 already for odd a
    for _ in range(5):
        inv = inv * (jnp.uint32(2) - a * inv)
    return inv


def permk_offsets_ref(
    seed: jax.Array, nblk: int, block: int, n: int, wid: jax.Array
) -> jax.Array:
    """Worker wid's PermK support: offsets (nblk, block/n) int32 in [0, block).

    Worker w owns permuted slots [w·C, (w+1)·C), C = block/n; across the n
    workers the offsets partition every block exactly (π is a bijection)."""
    assert block % n == 0, "worker count must divide the block width"
    chunk = block // n
    a, c = affine_perm_params_ref(seed.astype(jnp.uint32), nblk, block)
    t = (
        jnp.arange(chunk, dtype=jnp.uint32)[None, :]
        + jnp.asarray(wid, jnp.uint32) * jnp.uint32(chunk)
    )
    off = (a[:, None] * t + c[:, None]) & jnp.uint32(block - 1)
    return off.astype(jnp.int32)


def permk_seeded_workers_ref(x3d: jax.Array, seed: jax.Array, n: int):
    """Oracle for the PermK uplink: one SHARED seed, per-worker disjoint chunk.

    x3d: (n, nblk, B); returns values/offsets, both (n, nblk, B/n); values are
    scaled by n (Perm-K's unbiasedness factor)."""
    nblk, B = x3d.shape[1], x3d.shape[2]
    wids = jnp.arange(n, dtype=jnp.int32)

    def one(x2d, w):
        off = permk_offsets_ref(seed.astype(jnp.uint32), nblk, B, n, w)
        vals = jnp.take_along_axis(x2d, off, axis=1) * jnp.asarray(n, x2d.dtype)
        return vals, off

    return jax.vmap(one)(x3d, wids)


def permk_concat_mean_ref(
    values: jax.Array, seed: jax.Array, block: int
) -> jax.Array:
    """Disjoint-support aggregation: mean over n PermK payloads WITHOUT scatter.

    values: (n, nblk, block/n) worker payloads (already scaled by n).
    The supports partition each block, so the mean is assembly, not
    accumulation: concatenate the chunks in slot order t = w·C+j and gather
    through the inverse permutation π⁻¹(s) = a⁻¹·(s − c) mod block.
    Returns (nblk, block) f32 — bit-compatible with scatter_accum_ref on the
    same payloads (collision-free ⇒ identical sums)."""
    n, nblk, chunk = values.shape
    a, c = affine_perm_params_ref(seed.astype(jnp.uint32), nblk, block)
    a_inv = odd_inverse_ref(a)
    s = jnp.arange(block, dtype=jnp.uint32)[None, :]
    slot = (a_inv[:, None] * (s - c[:, None])) & jnp.uint32(block - 1)
    # (nblk, block) values ordered by slot: slot t holds worker t//C's j-th value
    by_slot = jnp.moveaxis(values, 0, 1).reshape(nblk, n * chunk)
    dense = jnp.take_along_axis(by_slot, slot.astype(jnp.int32), axis=1)
    return dense.astype(jnp.float32) / n
