"""Pallas TPU kernels for fused QSGD (s-level ℓ2) quantization.

Two-pass scheme sized for VMEM:
  pass 1 — ``block_sumsq``: per-(1,B)-tile Σx² partial reduction,
  pass 2 — ``qsgd_quantize``: sign/|·|/floor/int8-pack in one sweep using the
            combined norm. Fusing scale+round+cast keeps the quantize pass
            memory-bound at the int8 *output* bandwidth instead of three f32
            round trips (the GPU reference does this with a thrust transform;
            the TPU version is a single VPU pass per tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_sumsq_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)   # (1, B)
    out_ref[...] = jnp.sum(x * x, axis=-1, keepdims=True)  # (1, 1)


def block_sumsq(x2d: jax.Array, *, interpret: bool = True) -> jax.Array:
    nblk, B = x2d.shape
    return pl.pallas_call(
        _block_sumsq_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, B), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        interpret=interpret,
    )(x2d).reshape(nblk)


def _qsgd_kernel(x_ref, u_ref, norm_ref, out_ref, *, s: int):
    x = x_ref[...].astype(jnp.float32)   # (1, B)
    u = u_ref[...]                        # (1, B)
    norm = norm_ref[0, 0]
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.floor(s * jnp.abs(x) / safe + u)
    out_ref[...] = (jnp.sign(x) * level).astype(jnp.int8)


def qsgd_quantize(
    x2d: jax.Array, u2d: jax.Array, norm: jax.Array, s: int, *, interpret: bool = True
) -> jax.Array:
    """(nblk, B) f32/bf16 → (nblk, B) int8 levels; norm is the global ℓ2 norm."""
    nblk, B = x2d.shape
    return pl.pallas_call(
        functools.partial(_qsgd_kernel, s=int(s)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B), jnp.int8),
        interpret=interpret,
    )(x2d, u2d, norm.reshape(1, 1).astype(jnp.float32))


def _dequant_kernel(q_ref, norm_ref, out_ref, *, s: int):
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = (q * (norm_ref[0, 0] / s)).astype(out_ref.dtype)


def qsgd_dequantize(
    q2d: jax.Array, norm: jax.Array, s: int, *, interpret: bool = True
) -> jax.Array:
    nblk, B = q2d.shape
    return pl.pallas_call(
        functools.partial(_dequant_kernel, s=int(s)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B), jnp.float32),
        interpret=interpret,
    )(q2d, norm.reshape(1, 1).astype(jnp.float32))
