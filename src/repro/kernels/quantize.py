"""Pallas TPU kernels for the packed quantization wire (DESIGN.md §4.6/§5).

Every entry point takes ``backend="auto"`` and routes through
``repro.core.flat.resolve_backend`` exactly like the randk/permk primitives:
compiled Pallas on TPU, the bit-exact jnp oracle (kernels/ref.py) on CPU,
``pallas_interpret`` for interpreter-mode validation. (The v1 module
hardcoded ``interpret=True`` everywhere, so TPU ran these kernels in the
interpreter — the one backend that should never see interpret mode.)

Kernel inventory:

* ``block_sumsq`` / ``qsgd_quantize`` / ``qsgd_dequantize`` — the original
  two-pass global-norm QSGD (kept for the ops.py flat-vector wrappers).
* ``qsgd_block_workers`` — fused blockwise QSGD uplink: one (1, B) VMEM tile
  per grid step computes the block's ℓ2 norm, draws the murmur3 dither
  on-chip, and writes int8 levels + the per-block f32 norm in a single VPU
  sweep (memory-bound at the int8 *output* bandwidth). Workers fold into the
  grid (n·nblk steps) with per-worker seeds in SMEM, like
  ``randk_seeded_workers``.
* ``natural_block_workers`` — fused natural compression: stochastic
  power-of-two rounding, wire code = sign·(exponent-delta+1) int8 against the
  block's reference scale.
* ``qsgd_dequant_mean`` / ``natural_dequant_mean`` — the fused
  dequantize-and-mean server side: accumulates the n workers' int8 payloads
  into one (1, B) f32 tile per block; input traffic is int8, the (n, d)
  dequantized trees are never materialized.
* ``nibble_pack`` / ``nibble_unpack`` — the 4-bit wire: two's-complement
  nibbles, eight per uint32 lane word (half a byte per coordinate for
  s ≤ 7); pure uint32 shift/mask VPU ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref
from .randk import murmur_bits


def _resolve(backend: str) -> str:
    from repro.core.flat import resolve_backend

    return resolve_backend(backend)


def _uniform_from_bits(bits: jax.Array) -> jax.Array:
    """Kernel-side twin of ``ref.uniform_from_bits_ref`` (exact f32 convert)."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


# ---------------------------------------------------------------------------
# Two-pass global-norm QSGD (ops.py flat-vector path)
# ---------------------------------------------------------------------------


def _block_sumsq_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)   # (1, B)
    out_ref[...] = jnp.sum(x * x, axis=-1, keepdims=True)  # (1, 1)


def block_sumsq(x2d: jax.Array, *, backend: str = "auto") -> jax.Array:
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.block_sumsq_ref(x2d)
    nblk, B = x2d.shape
    return pl.pallas_call(
        _block_sumsq_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, B), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 1), jnp.float32),
        interpret=(backend == "pallas_interpret"),
    )(x2d).reshape(nblk)


def _qsgd_kernel(x_ref, u_ref, norm_ref, out_ref, *, s: int):
    x = x_ref[...].astype(jnp.float32)   # (1, B)
    u = u_ref[...]                        # (1, B)
    norm = norm_ref[0, 0]
    safe = jnp.where(norm > 0, norm, 1.0)
    level = jnp.floor(s * jnp.abs(x) / safe + u)
    out_ref[...] = (jnp.sign(x) * level).astype(jnp.int8)


def qsgd_quantize(
    x2d: jax.Array, u2d: jax.Array, norm: jax.Array, s: int, *,
    backend: str = "auto",
) -> jax.Array:
    """(nblk, B) f32/bf16 → (nblk, B) int8 levels; norm is the global ℓ2 norm."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.qsgd_quantize_ref(x2d, u2d, norm, s)
    nblk, B = x2d.shape
    return pl.pallas_call(
        functools.partial(_qsgd_kernel, s=int(s)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B), jnp.int8),
        interpret=(backend == "pallas_interpret"),
    )(x2d, u2d, norm.reshape(1, 1).astype(jnp.float32))


def _dequant_kernel(q_ref, norm_ref, out_ref, *, s: int):
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = (q * (norm_ref[0, 0] / s)).astype(out_ref.dtype)


def qsgd_dequantize(
    q2d: jax.Array, norm: jax.Array, s: int, *, backend: str = "auto"
) -> jax.Array:
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.qsgd_dequantize_ref(q2d, norm, s)
    nblk, B = q2d.shape
    return pl.pallas_call(
        functools.partial(_dequant_kernel, s=int(s)),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B), jnp.float32),
        interpret=(backend == "pallas_interpret"),
    )(q2d, norm.reshape(1, 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Fused blockwise QSGD uplink (per-block norms on the wire — DESIGN.md §4.6)
# ---------------------------------------------------------------------------


def _qsgd_block_workers_kernel(
    seed_ref, x_ref, q_ref, norm_ref, *, s: int, nblk: int
):
    i = pl.program_id(0)          # global block id over n·nblk
    w = i // nblk                 # worker
    b = i % nblk                  # worker-local block
    x = x_ref[...].astype(jnp.float32)   # (1, B)
    B = x.shape[-1]
    norm = jnp.sqrt(jnp.sum(x * x))
    safe = jnp.where(norm > 0, norm, 1.0)
    # worker-local dither stream: block b covers counters [b·B, (b+1)·B) —
    # the same stream BlockQSGD.compress draws, so tree/flat paths coincide.
    ctr = jax.lax.broadcasted_iota(jnp.uint32, (1, B), 1) + jnp.uint32(b * B)
    u = _uniform_from_bits(murmur_bits(seed_ref[w].astype(jnp.uint32), ctr))
    level = jnp.floor(s * jnp.abs(x) / safe + u)
    q_ref[...] = (jnp.sign(x) * level).astype(jnp.int8)
    norm_ref[...] = norm.reshape(1, 1)


def qsgd_block_workers(
    x3d: jax.Array, seeds: jax.Array, s: int, *, backend: str = "auto"
):
    """Fused per-worker blockwise QSGD: (n, nblk, B) + (n,) seeds →
    (levels (n, nblk, B) int8, norms (n, nblk) f32). One VPU sweep per
    (1, B) tile: norm, dither, scale, floor, int8 cast — the quantize pass
    writes at int8 bandwidth instead of three f32 round trips."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.qsgd_block_workers_ref(x3d, seeds.astype(jnp.uint32), s)
    n, nblk, B = x3d.shape
    x2d = x3d.reshape(n * nblk, B)
    q, norms = pl.pallas_call(
        functools.partial(_qsgd_block_workers_kernel, s=int(s), nblk=nblk),
        grid=(n * nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * nblk, B), jnp.int8),
            jax.ShapeDtypeStruct((n * nblk, 1), jnp.float32),
        ],
        interpret=(backend == "pallas_interpret"),
    )(seeds.astype(jnp.int32), x2d)
    return q.reshape(n, nblk, B), norms.reshape(n, nblk)


def _qsgd_dequant_mean_kernel(q_ref, norm_ref, out_ref, *, s: int, n: int):
    B = out_ref.shape[-1]

    def body(w, acc):
        qw = jax.lax.dynamic_index_in_dim(q_ref[...], w, 0, keepdims=False)
        nw = jax.lax.dynamic_index_in_dim(norm_ref[...], w, 0, keepdims=False)
        return acc + qw.astype(jnp.float32) * (nw[0] / s)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    out_ref[...] = acc / n


def qsgd_dequant_mean(
    levels: jax.Array, norms: jax.Array, s: int, *, backend: str = "auto"
) -> jax.Array:
    """Fused dequantize-and-mean: (n, nblk, B) int8 + (n, nblk) f32 →
    (nblk, B) f32 mean over workers. The grid owns one (1, B) output tile
    per block and streams the n int8 payloads through it — aggregation runs
    at int8 input bandwidth with a single dense f32 accumulator."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.qsgd_dequant_mean_ref(levels, norms, s)
    n, nblk, B = levels.shape
    return pl.pallas_call(
        functools.partial(_qsgd_dequant_mean_kernel, s=int(s), n=n),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B), jnp.float32),
        interpret=(backend == "pallas_interpret"),
    )(levels, norms)


# ---------------------------------------------------------------------------
# Fused blockwise natural compression (power-of-two stochastic rounding)
# ---------------------------------------------------------------------------


def _natural_block_workers_kernel(seed_ref, x_ref, code_ref, scale_ref, *, nblk: int):
    i = pl.program_id(0)
    w = i // nblk
    b = i % nblk
    x = x_ref[...].astype(jnp.float32)   # (1, B)
    B = x.shape[-1]
    ax = jnp.abs(x)
    e = jnp.floor(jnp.log2(jnp.where(ax > 0, ax, 1.0)))
    lo = jnp.exp2(e)
    p_up = jnp.where(ax > 0, (ax - lo) / lo, 0.0)
    ctr = jax.lax.broadcasted_iota(jnp.uint32, (1, B), 1) + jnp.uint32(b * B)
    u = _uniform_from_bits(murmur_bits(seed_ref[w].astype(jnp.uint32), ctr))
    e_q = e + (u < p_up).astype(jnp.float32)
    mx = jnp.max(ax)
    e_ref = jnp.floor(jnp.log2(jnp.where(mx > 0, mx, 1.0))) + 1.0
    delta = e_ref - e_q
    keep = (ax > 0) & (delta <= 126.0)
    code_ref[...] = jnp.where(
        keep, jnp.sign(x) * (delta + 1.0), 0.0
    ).astype(jnp.int8)
    scale_ref[...] = jnp.exp2(e_ref).reshape(1, 1)


def natural_block_workers(
    x3d: jax.Array, seeds: jax.Array, *, backend: str = "auto"
):
    """Fused per-worker natural compression: (n, nblk, B) + (n,) seeds →
    (codes (n, nblk, B) int8, scales (n, nblk) f32)."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.natural_block_workers_ref(x3d, seeds.astype(jnp.uint32))
    n, nblk, B = x3d.shape
    x2d = x3d.reshape(n * nblk, B)
    codes, scales = pl.pallas_call(
        functools.partial(_natural_block_workers_kernel, nblk=nblk),
        grid=(n * nblk,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, B), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, B), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n * nblk, B), jnp.int8),
            jax.ShapeDtypeStruct((n * nblk, 1), jnp.float32),
        ],
        interpret=(backend == "pallas_interpret"),
    )(seeds.astype(jnp.int32), x2d)
    return codes.reshape(n, nblk, B), scales.reshape(n, nblk)


def _natural_dequant_mean_kernel(code_ref, scale_ref, out_ref, *, n: int):
    B = out_ref.shape[-1]

    def body(w, acc):
        cw = jax.lax.dynamic_index_in_dim(code_ref[...], w, 0, keepdims=False)
        sw = jax.lax.dynamic_index_in_dim(scale_ref[...], w, 0, keepdims=False)
        c = cw.astype(jnp.float32)
        mag = sw[0] * jnp.exp2(-(jnp.abs(c) - 1.0))
        return acc + jnp.where(c != 0, jnp.sign(c) * mag, 0.0)

    acc = jax.lax.fori_loop(0, n, body, jnp.zeros((1, B), jnp.float32))
    out_ref[...] = acc / n


def natural_dequant_mean(
    codes: jax.Array, scales: jax.Array, *, backend: str = "auto"
) -> jax.Array:
    """Fused decode-and-mean of natural payloads: (n, nblk, B) int8 +
    (n, nblk) f32 → (nblk, B) f32; int8 input bandwidth."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.natural_dequant_mean_ref(codes, scales)
    n, nblk, B = codes.shape
    return pl.pallas_call(
        functools.partial(_natural_dequant_mean_kernel, n=n),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((n, 1, B), lambda i: (0, i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B), jnp.float32),
        interpret=(backend == "pallas_interpret"),
    )(codes, scales)


# ---------------------------------------------------------------------------
# 4-bit wire: nibble pack/unpack (two levels per byte, eight per uint32)
# ---------------------------------------------------------------------------


def _nibble_pack_kernel(q_ref, out_ref):
    q = q_ref[...]                       # (1, B) int8
    B = q.shape[-1]
    nib = (q.astype(jnp.int32) & 0xF).astype(jnp.uint32).reshape(B // 8, 8)
    word = nib[:, 0]
    for t in range(1, 8):
        word = word | (nib[:, t] << jnp.uint32(4 * t))
    out_ref[...] = word.reshape(1, B // 8)


def nibble_pack(q2d: jax.Array, *, backend: str = "auto") -> jax.Array:
    """(nblk, B) int8 levels in [-8, 7] → (nblk, B/8) uint32 lane words —
    the genuine 4-bit on-wire representation (DESIGN.md §4.6)."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.nibble_pack_ref(q2d)
    nblk, B = q2d.shape
    assert B % 8 == 0, "block width must pack into whole uint32 words"
    return pl.pallas_call(
        _nibble_pack_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, B), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, B // 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, B // 8), jnp.uint32),
        interpret=(backend == "pallas_interpret"),
    )(q2d)


def _nibble_unpack_kernel(w_ref, out_ref):
    words = w_ref[...]                   # (1, B/8) uint32
    nw = words.shape[-1]
    cols = [
        ((words >> jnp.uint32(4 * t)) & jnp.uint32(0xF)).reshape(nw, 1)
        for t in range(8)
    ]
    nib = jnp.concatenate(cols, axis=1).astype(jnp.int8)  # (B/8, 8) in 0..15
    q = jnp.where(nib >= 8, nib - jnp.int8(16), nib)
    out_ref[...] = q.reshape(1, nw * 8)


def nibble_unpack(
    words: jax.Array, block: int, *, backend: str = "auto"
) -> jax.Array:
    """(nblk, B/8) uint32 lane words → (nblk, B) int8; exact inverse of
    :func:`nibble_pack` on levels in [-8, 7]."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.nibble_unpack_ref(words, block)
    nblk, nw = words.shape
    assert nw * 8 == block
    return pl.pallas_call(
        _nibble_unpack_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1, nw), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, block), jnp.int8),
        interpret=(backend == "pallas_interpret"),
    )(words)


# ---------------------------------------------------------------------------
# int8 KV-page rows (serving engine quantized-page mode, DESIGN.md §8)
# ---------------------------------------------------------------------------


def _absmax_quant_rows_kernel(x_ref, code_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                       # (1, W)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)       # (1, 1)
    scale = amax * jnp.float32(1.0 / 127.0)  # reciprocal-multiply: see ref
    safe = jnp.where(scale > 0, scale, 1.0)
    code_ref[...] = jnp.round(x / safe).astype(jnp.int8)
    scale_ref[...] = scale


def absmax_quant_rows(x2d: jax.Array, *, backend: str = "auto"):
    """Symmetric absmax int8 quantization per row: (R, W) → (codes int8
    (R, W), scales f32 (R,)). The KV-page write path — deterministic
    round-to-nearest-even, no dither (cache rows are read many times, so
    per-read stochastic noise would not average out like a gradient's);
    error model |x − x̂| ≤ max|x|/254 per element (DESIGN.md §8)."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.absmax_quant_rows_ref(x2d)
    R, W = x2d.shape
    codes, scales = pl.pallas_call(
        _absmax_quant_rows_kernel,
        grid=(R,),
        in_specs=[pl.BlockSpec((1, W), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, W), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, W), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=(backend == "pallas_interpret"),
    )(x2d)
    return codes, scales.reshape(R)


def _absmax_dequant_rows_kernel(code_ref, scale_ref, out_ref):
    out_ref[...] = code_ref[...].astype(jnp.float32) * scale_ref[0, 0]


def absmax_dequant_rows(
    codes: jax.Array, scales: jax.Array, *, backend: str = "auto"
) -> jax.Array:
    """(R, W) int8 codes + (R,) f32 scales → (R, W) f32 rows; exact inverse
    of the representable points of :func:`absmax_quant_rows`."""
    backend = _resolve(backend)
    if backend == "ref":
        return _ref.absmax_dequant_rows_ref(codes, scales)
    R, W = codes.shape
    return pl.pallas_call(
        _absmax_dequant_rows_kernel,
        grid=(R,),
        in_specs=[
            pl.BlockSpec((1, W), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, W), jnp.float32),
        interpret=(backend == "pallas_interpret"),
    )(codes, scales.reshape(R, 1).astype(jnp.float32))
