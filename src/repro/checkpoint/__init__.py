from .store import (
    CheckpointCorruptionError,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)

__all__ = [
    "CheckpointCorruptionError",
    "load_checkpoint",
    "save_checkpoint",
    "latest_step",
]
