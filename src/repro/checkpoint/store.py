"""npz-based pytree checkpointing (orbax-free; the container is offline).

Trees are flattened with '/'-joined key paths; dataclass states (MarinaState
etc.) round-trip through their registered pytree flatten. Atomic via
write-to-temp + rename. Exact restore is covered by tests/test_checkpoint.py.

Every checkpoint carries a content checksum (CRC-32 over the sorted
(key, dtype, shape, bytes) stream, stored under the reserved ``__checksum__``
entry) that :func:`load_checkpoint` verifies before restoring: a truncated or
bit-flipped file raises :class:`CheckpointCorruptionError` instead of silently
resuming a half-written state. Pre-checksum checkpoints (no ``__checksum__``
entry) still load — the digest is only enforced when present.
"""

from __future__ import annotations

import os
import re
import tempfile
import zipfile
import zlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_SEP = "//"

_CHECKSUM_KEY = "__checksum__"


class CheckpointCorruptionError(RuntimeError):
    """The checkpoint file is corrupt (bad archive, or digest mismatch).

    Deliberately NOT a :class:`KeyError`/:class:`ValueError`: the trainer's
    format-compatibility fallbacks catch those to try older checkpoint
    layouts, and a corrupt file must fail loudly rather than degrade into a
    "pre-ledger checkpoint" guess.
    """


def _digest(arrays: dict) -> int:
    """CRC-32 over the sorted (key, dtype, shape, bytes) stream of the
    *encoded* arrays (bf16 et al. digest as their stored bit-views, so the
    digest is computable on load without decoding)."""
    crc = 0
    for key in sorted(arrays):
        if key == _CHECKSUM_KEY:
            continue
        arr = np.ascontiguousarray(arrays[key])
        for part in (key, arr.dtype.str, str(arr.shape)):
            crc = zlib.crc32(part.encode(), crc)
        crc = zlib.crc32(arr.tobytes(), crc)
    return crc


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8, "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """npz can't hold ml_dtypes (bf16 → void); store a bit-view + dtype tag."""
    name = arr.dtype.name
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, ""


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for path, leaf in flat:
        arr, tag = _encode(np.asarray(leaf))
        key = _path_str(path) + (f"::{tag}" if tag else "")
        arrays[key] = arr
    arrays[_CHECKSUM_KEY] = np.uint32(_digest(arrays))
    final = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := re.match(r"ckpt_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of `like` (shapes/dtypes preserved)."""
    import ml_dtypes

    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    try:
        with np.load(path) as data:
            raw = {k: data[k] for k in data.files}
    except FileNotFoundError:
        raise  # absent is absent, not corrupt
    except (zipfile.BadZipFile, zlib.error, ValueError, EOFError, OSError) as e:
        raise CheckpointCorruptionError(
            f"checkpoint {path} is corrupt (unreadable archive: {e})"
        ) from e
    if _CHECKSUM_KEY in raw:
        stored = int(raw[_CHECKSUM_KEY])
        actual = _digest(raw)
        if stored != actual:
            raise CheckpointCorruptionError(
                f"checkpoint {path} is corrupt: content checksum mismatch "
                f"(stored {stored:#010x}, computed {actual:#010x})"
            )
        del raw[_CHECKSUM_KEY]
    tagged = {}
    for k in raw:
        base, _, tag = k.partition("::")
        tagged[base] = (k, tag)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key not in tagged:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        fkey, tag = tagged[key]
        arr = raw[fkey]
        if tag:
            arr = arr.view(np.dtype(getattr(ml_dtypes, tag)))
        if arr.shape != leaf.shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {leaf.shape}"
            )
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
