"""Roofline-term extraction from compiled XLA artifacts (deliverable g).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` provides FLOPs / bytes-accessed; collective bytes are parsed
from the optimized HLO: we sum, over every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, the per-device wire bytes
under standard ring-algorithm accounting:

    all-gather       (g-1)/g · out_bytes
    reduce-scatter   (g-1)/g · in_bytes  (≈ out_bytes · (g-1))
    all-reduce       2(g-1)/g · bytes
    all-to-all       (g-1)/g · bytes
    collective-permute  bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

**Per-tier α–β collective model (ISSUE 7).** A flat ici bandwidth misprices
exactly the regime MARINA targets: the cross-pod (dcn) link is ~8× slower
than ici and adds ~25µs launch latency per collective. When a
``launch.topology.Topology`` is passed to :func:`analyze_compiled`, every
collective is classified by its replica groups — by the member device ids
when the HLO records them (explicit lists or the iota reshape-transpose
form: a group strided across pods is dcn no matter how narrow), by group
size otherwise (wider than one pod must cross the dcn; wider than one
process likewise on the local CPU cluster) — and the collective term
becomes the α–β cost

    collective_s = Σ_tier  counts_tier · α_tier  +  bytes_tier / β_tier

with (α = per-collective launch latency, β = link bandwidth) taken from the
topology's link table (``launch/topology.py::DEFAULT_LINKS`` documents the
default constants: loopback 0.5µs / 100 GB/s, ici 1µs / 50 GB/s, dcn 25µs /
6.25 GB/s). Without a topology the historical flat-ici model is used, so
pre-ISSUE-7 perf JSONs stay comparable. :func:`alpha_beta_disagreement` is
the REFUTED-style check: it flags recorded rooflines that disagree with the
α–β model by more than 2× (scripts/check_all.py sweeps experiments/perf/).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_TIER_ORDER = ("loopback", "ici", "dcn")  # mirrors core.wire.LINK_TIERS


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of an HLO shape string like 'bf16[2,16,8]' or a tuple."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _replica_group_ids(line: str):
    """Device ids of every replica group, when the HLO spells them out.

    Handles the explicit-list form (``replica_groups={{0,16},{1,17}}``) and
    the iota reshape-transpose form (``replica_groups=[16,32]<=[16,2,16]
    T(1,0,2)``). Returns a list of id-lists, or None when only the group
    size survives (caller falls back to size-based classification)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        n = 1
        for d in dims:
            n *= d
        if n != ng * gs or n > 1 << 20:
            return None
        import numpy as np

        ids = np.arange(n)
        if m.group(4) is not None:
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.reshape(dims).transpose(perm).reshape(-1)
        return [[int(i) for i in row] for row in ids.reshape(ng, gs)]
    key = "replica_groups={"
    i = line.find(key)
    if i < 0:
        return None
    j, depth = i + len(key) - 1, 0
    for j in range(i + len(key) - 1, len(line)):
        if line[j] == "{":
            depth += 1
        elif line[j] == "}":
            depth -= 1
            if depth == 0:
                break
    body = line[i + len(key): j]
    groups = []
    for part in body.split("},"):
        part = part.strip().strip("{}")
        if not part:
            continue
        try:
            groups.append([int(x) for x in part.split(",") if x.strip()])
        except ValueError:
            return None
    return groups or None


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:  # iota form [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [x for x in first.replace("{", "").split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


@dataclasses.dataclass
class CollectiveStats:
    per_device_bytes: float = 0.0
    counts: dict = dataclasses.field(default_factory=dict)
    by_kind_bytes: dict = dataclasses.field(default_factory=dict)
    # per-link-tier splits (empty when no topology classified the groups)
    by_tier_bytes: dict = dataclasses.field(default_factory=dict)
    by_tier_counts: dict = dataclasses.field(default_factory=dict)


def collective_bytes_from_hlo(
    hlo_text: str, n_devices: int, topology: Optional[Any] = None
) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # counted at -start
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)          # input = out × g; ring moves (g-1)/g · in
        elif kind == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = size
        stats.per_device_bytes += wire
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.by_kind_bytes[kind] = stats.by_kind_bytes.get(kind, 0.0) + wire
        if topology is not None:
            # the slowest link any replica group must cross: classify by the
            # actual member ids when the HLO records them (a 32-device group
            # strided across two pods is dcn even though it is far narrower
            # than a pod), by group size otherwise
            groups = _replica_group_ids(line)
            if groups:
                tier = max(
                    (topology.tier_for_ids(ids) for ids in groups),
                    key=_TIER_ORDER.index,
                )
            else:
                tier = topology.tier_for_group_size(g)
            stats.by_tier_bytes[tier] = stats.by_tier_bytes.get(tier, 0.0) + wire
            stats.by_tier_counts[tier] = stats.by_tier_counts.get(tier, 0) + 1
    return stats


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    n_devices: int
    hw: HW = dataclasses.field(default_factory=HW)
    model_flops_total: Optional[float] = None
    peak_memory_per_device: Optional[float] = None
    topology: Optional[Any] = None  # launch.topology.Topology (α–β model)

    @property
    def compute_s(self) -> float:
        """Compute term from HLO-counted FLOPs.

        Caveat: XLA's cost analysis counts while-loop bodies once (not ×
        trip-count), so scan-heavy programs under-report here. The dry-run
        therefore also records ``analytic_compute_s`` and uses the max of the
        two for the dominant-term call.
        """
        return self.flops_per_device / self.hw.peak_flops

    @property
    def analytic_compute_s(self) -> float:
        """MFU-style lower bound: MODEL_FLOPS / (chips × peak)."""
        if not self.model_flops_total:
            return 0.0
        return self.model_flops_total / self.n_devices / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def collective_s_flat(self) -> float:
        """The historical single-bandwidth model (bytes / flat ici bw)."""
        return self.collective.per_device_bytes / self.hw.ici_bw

    @property
    def collective_s(self) -> float:
        """Collective term: the per-tier α–β cost when a topology classified
        the replica groups, else the flat-ici fallback."""
        if self.topology is None or not self.collective.by_tier_bytes:
            return self.collective_s_flat
        total = 0.0
        for tier, byts in self.collective.by_tier_bytes.items():
            link = self.topology.link(tier)
            total += self.collective.by_tier_counts.get(tier, 0) * link.alpha_s
            total += byts / link.bw
        return total

    @property
    def dominant(self) -> str:
        terms = {
            "compute": max(self.compute_s, self.analytic_compute_s),
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        if not self.model_flops_total:
            return None
        total = self.flops_per_device * self.n_devices
        return self.model_flops_total / total if total else None

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective.per_device_bytes,
            "collective_counts": self.collective.counts,
            "collective_by_kind_bytes": self.collective.by_kind_bytes,
            **(
                {
                    "collective_by_tier_bytes": self.collective.by_tier_bytes,
                    "collective_by_tier_counts": self.collective.by_tier_counts,
                    "collective_s_flat": self.collective_s_flat,
                    "link_table": {
                        t: {"alpha_s": sp.alpha_s, "bw": sp.bw}
                        for t, sp in dict(self.topology.links).items()
                    },
                }
                if self.topology is not None
                else {}
            ),
            "analytic_compute_s": self.analytic_compute_s,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_ratio": self.useful_ratio,
            "peak_memory_per_device": self.peak_memory_per_device,
            "n_devices": self.n_devices,
        }


def alpha_beta_disagreement(
    recorded_s: float, modeled_s: float, factor: float = 2.0
) -> Optional[dict]:
    """REFUTED-style flag for recorded-vs-model roofline drift (ISSUE 7).

    ``recorded_s`` is the collective term a perf JSON recorded (typically
    the flat-ici model of its day); ``modeled_s`` the per-tier α–β cost of
    the same HLO. A >``factor``× ratio either way earns REFUTED — the
    recorded number can't be trusted as a cross-host prediction (the
    variant's collectives are dominated by a link tier the flat model
    mispriced). Returns None when either side is degenerate (zero-collective
    steps have nothing to disagree about)."""
    if recorded_s <= 0.0 or modeled_s <= 0.0:
        return None
    ratio = max(recorded_s / modeled_s, modeled_s / recorded_s)
    return {
        "ratio": ratio,
        "verdict": "REFUTED" if ratio > factor else "CONFIRMED",
    }


def decode_bandwidth_bound_s(
    kv_bytes: float,
    param_bytes: float,
    n_devices: int,
    hw: HW = HW(),
    topology: Optional[Any] = None,
    collective_bytes: float = 0.0,
    n_collectives: int = 0,
    tier: str = "ici",
) -> dict:
    """Analytic floor for one single-token decode step (DESIGN.md §8).

    A decode step touches every parameter byte and every LIVE KV byte
    exactly once per token with trivial arithmetic intensity, so its floor
    is pure streaming:

        hbm_s = (param_bytes + kv_bytes) / (n_devices · hbm_bw)

    ``kv_bytes`` is the point where paging pays: a dense cache streams
    ``n_slots × max_len`` rows regardless of occupancy, while the page pool
    streams only Σ ceil(len_i/P) occupied pages — pass the pool's actual
    byte footprint and the bound shrinks with it.

    The decode step's collectives (the per-token logit/activation
    all-reduces over the model axis) are priced under the launch-layer link
    tiers (``launch/topology.py::DEFAULT_LINKS``): ``n_collectives`` α
    launches plus ``collective_bytes`` wire over the named ``tier``'s β,
    falling back to the flat-ici constant of :class:`HW` when no topology
    is given — the same convention :meth:`RooflineReport.collective_s`
    uses, so the bound and the compiled-HLO term are comparable.

    Returns ``{"hbm_s", "collective_s", "bound_s"}`` with
    ``bound_s = hbm_s + collective_s`` (a decode step too small to overlap
    wire with streaming — the pessimistic additive floor).
    """
    hbm_s = (param_bytes + kv_bytes) / (n_devices * hw.hbm_bw)
    if topology is not None:
        link = topology.link(tier)
        coll_s = n_collectives * link.alpha_s + collective_bytes / link.bw
    else:
        coll_s = collective_bytes / hw.ici_bw
    return {
        "hbm_s": hbm_s,
        "collective_s": coll_s,
        "bound_s": hbm_s + coll_s,
    }


def prefill_sharing_savings(
    tokens_unshared: float,
    tokens_shared: float,
    flops_per_token: float,
    kv_bytes_per_token: float,
    n_devices: int,
    hw: HW = HW(),
) -> dict:
    """Analytic price of COW prefix sharing on the prefill bill (DESIGN.md §8).

    Prefix sharing removes prompt tokens from the prefill path entirely —
    a follower maps the donor's cached pages instead of recomputing them —
    so the saving is linear in tokens skipped:

        tokens_saved = tokens_unshared - tokens_shared

    Each skipped token saves its forward FLOPs (``flops_per_token``, ~2·N
    for an N-parameter model) and the KV write traffic it would have issued
    (``kv_bytes_per_token``, the per-token KV footprint across layers; the
    COW pages are written once by the donor and only re-read). Parameter
    streaming amortizes over the prefill chunk either way and is excluded.

    Returns the saved FLOPs/bytes plus the time each converts to on the
    ``hw`` roofline (compute at peak, KV writes at HBM bandwidth) — prefill
    is compute-bound at any realistic chunk, so ``saved_s`` takes the
    compute leg as the headline and keeps the HBM leg for reference.
    """
    tokens_saved = max(0.0, tokens_unshared - tokens_shared)
    flops_saved = tokens_saved * flops_per_token
    hbm_saved = tokens_saved * kv_bytes_per_token
    compute_s = flops_saved / (n_devices * hw.peak_flops)
    hbm_s = hbm_saved / (n_devices * hw.hbm_bw)
    return {
        "tokens_unshared": tokens_unshared,
        "tokens_shared": tokens_shared,
        "tokens_saved": tokens_saved,
        "prefill_token_reduction": (
            tokens_unshared / tokens_shared if tokens_shared > 0 else float("inf")
        ),
        "flops_saved": flops_saved,
        "kv_write_bytes_saved": hbm_saved,
        "compute_s_saved": compute_s,
        "hbm_s_saved": hbm_s,
        "saved_s": compute_s,
    }


def analyze_compiled(
    compiled,
    n_devices: int,
    model_flops_total: Optional[float] = None,
    topology: Optional[Any] = None,
) -> RooflineReport:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    # XLA reports whole-program numbers for the SPMD module (per-device view).
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo, n_devices, topology)

    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass

    return RooflineReport(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective=coll,
        n_devices=n_devices,
        model_flops_total=model_flops_total,
        peak_memory_per_device=peak,
        topology=topology,
    )
