"""Roofline-term extraction from compiled XLA artifacts (deliverable g).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` provides FLOPs / bytes-accessed; collective bytes are parsed
from the optimized HLO: we sum, over every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, the per-device wire bytes
under standard ring-algorithm accounting:

    all-gather       (g-1)/g · out_bytes
    reduce-scatter   (g-1)/g · in_bytes  (≈ out_bytes · (g-1))
    all-reduce       2(g-1)/g · bytes
    all-to-all       (g-1)/g · bytes
    collective-permute  bytes

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of an HLO shape string like 'bf16[2,16,8]' or a tuple."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACKET_RE.search(line)
    if m:  # iota form [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        ids = [x for x in first.replace("{", "").split(",") if x.strip() != ""]
        return max(1, len(ids))
    return default


@dataclasses.dataclass
class CollectiveStats:
    per_device_bytes: float = 0.0
    counts: dict = dataclasses.field(default_factory=dict)
    by_kind_bytes: dict = dataclasses.field(default_factory=dict)


def collective_bytes_from_hlo(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # counted at -start
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = _group_size(line, n_devices)
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = size * (g - 1)          # input = out × g; ring moves (g-1)/g · in
        elif kind == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:  # collective-permute
            wire = size
        stats.per_device_bytes += wire
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.by_kind_bytes[kind] = stats.by_kind_bytes.get(kind, 0.0) + wire
    return stats


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    n_devices: int
    hw: HW = dataclasses.field(default_factory=HW)
    model_flops_total: Optional[float] = None
    peak_memory_per_device: Optional[float] = None

    @property
    def compute_s(self) -> float:
        """Compute term from HLO-counted FLOPs.

        Caveat: XLA's cost analysis counts while-loop bodies once (not ×
        trip-count), so scan-heavy programs under-report here. The dry-run
        therefore also records ``analytic_compute_s`` and uses the max of the
        two for the dominant-term call.
        """
        return self.flops_per_device / self.hw.peak_flops

    @property
    def analytic_compute_s(self) -> float:
        """MFU-style lower bound: MODEL_FLOPS / (chips × peak)."""
        if not self.model_flops_total:
            return 0.0
        return self.model_flops_total / self.n_devices / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective.per_device_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": max(self.compute_s, self.analytic_compute_s),
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        if not self.model_flops_total:
            return None
        total = self.flops_per_device * self.n_devices
        return self.model_flops_total / total if total else None

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective.per_device_bytes,
            "collective_counts": self.collective.counts,
            "collective_by_kind_bytes": self.collective.by_kind_bytes,
            "analytic_compute_s": self.analytic_compute_s,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_total": self.model_flops_total,
            "useful_ratio": self.useful_ratio,
            "peak_memory_per_device": self.peak_memory_per_device,
            "n_devices": self.n_devices,
        }


def analyze_compiled(
    compiled, n_devices: int, model_flops_total: Optional[float] = None
) -> RooflineReport:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device
        cost = cost[0] if cost else {}
    # XLA reports whole-program numbers for the SPMD module (per-device view).
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo, n_devices)

    peak = None
    try:
        ma = compiled.memory_analysis()
        peak = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass

    return RooflineReport(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective=coll,
        n_devices=n_devices,
        model_flops_total=model_flops_total,
        peak_memory_per_device=peak,
    )
