from .analysis import (
    HW,
    CollectiveStats,
    RooflineReport,
    alpha_beta_disagreement,
    analyze_compiled,
    collective_bytes_from_hlo,
    decode_bandwidth_bound_s,
    prefill_sharing_savings,
)

__all__ = [
    "HW",
    "CollectiveStats",
    "RooflineReport",
    "alpha_beta_disagreement",
    "analyze_compiled",
    "collective_bytes_from_hlo",
    "decode_bandwidth_bound_s",
    "prefill_sharing_savings",
]
