from .analysis import (
    HW,
    CollectiveStats,
    RooflineReport,
    analyze_compiled,
    collective_bytes_from_hlo,
)

__all__ = [
    "HW",
    "CollectiveStats",
    "RooflineReport",
    "analyze_compiled",
    "collective_bytes_from_hlo",
]
