from .analysis import (
    HW,
    CollectiveStats,
    RooflineReport,
    alpha_beta_disagreement,
    analyze_compiled,
    collective_bytes_from_hlo,
)

__all__ = [
    "HW",
    "CollectiveStats",
    "RooflineReport",
    "alpha_beta_disagreement",
    "analyze_compiled",
    "collective_bytes_from_hlo",
]
