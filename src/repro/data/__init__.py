from .pipeline import (
    HeterogeneousLMData,
    lm_batch_iterator,
    make_lm_data,
    make_prefix_embeddings,
    worker_batches,
)

__all__ = [
    "HeterogeneousLMData",
    "lm_batch_iterator",
    "make_lm_data",
    "make_prefix_embeddings",
    "worker_batches",
]
