"""repro.data — deterministic synthetic heterogeneous data pipelines."""

from .pipeline import (
    HeterogeneousLMData,
    client_weights_from_counts,
    dirichlet_partition,
    dirichlet_proportions,
    lm_batch_iterator,
    make_lm_data,
    make_prefix_embeddings,
    worker_batches,
)

__all__ = [
    "HeterogeneousLMData",
    "client_weights_from_counts",
    "dirichlet_partition",
    "dirichlet_proportions",
    "lm_batch_iterator",
    "make_lm_data",
    "make_prefix_embeddings",
    "worker_batches",
]
