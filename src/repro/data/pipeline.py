"""Synthetic heterogeneous data pipeline.

The paper's setting is *arbitrarily heterogeneous* local datasets f_i. For LM
training we synthesize per-worker token streams from distinct Markov chains
(worker-specific transition tables biased toward different vocabulary regions),
so local gradients genuinely disagree — the regime where gradient-difference
compression (MARINA) beats direct gradient compression (QSGD/DIANA).

Deterministic: every (worker, step) batch is a pure function of the seed, so
data-parallel shards never need host-side coordination, checkpointed runs
resume bit-exactly, and the same stream can be regenerated on any mesh layout.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class HeterogeneousLMData:
    """Spec for per-worker synthetic token distributions."""

    n_workers: int
    vocab_size: int
    seq_len: int
    seed: int = 0
    heterogeneity: float = 1.0  # 0 → iid workers
    order: int = 8              # markov-ish context hash width


def make_lm_data(
    n_workers: int,
    vocab_size: int,
    seq_len: int,
    seed: int = 0,
    heterogeneity: float = 1.0,
) -> HeterogeneousLMData:
    return HeterogeneousLMData(
        n_workers=n_workers,
        vocab_size=vocab_size,
        seq_len=seq_len,
        seed=seed,
        heterogeneity=heterogeneity,
    )


def _worker_tokens(
    data: HeterogeneousLMData, key: jax.Array, worker: jax.Array, batch: int
) -> jax.Array:
    """Sample (batch, seq_len) tokens for one worker.

    Per-worker unigram tilt + a deterministic "grammar": token_{t+1} is a hash
    of token_t with worker-biased noise, giving learnable structure whose
    optimum differs across workers.
    """
    V = data.vocab_size
    k_bias, k_start, k_noise = jax.random.split(key, 3)
    # worker-specific preferred region of the vocabulary (→ V/2 when iid)
    het = data.heterogeneity
    offset = ((worker.astype(jnp.float32) + 0.5) / data.n_workers - 0.5) * V
    center = V / 2.0 + het * offset
    width = V * (1.0 - 0.7 * het) + 1.0

    start = jax.random.randint(k_start, (batch,), 0, V)

    def step(tok, k):
        k1, k2 = jax.random.split(k)
        # deterministic component: affine hash of current token
        nxt = (tok * 31 + 7) % V
        # worker-biased stochastic component
        noise = jax.random.normal(k1, tok.shape) * width * 0.1
        biased = jnp.clip(center + noise, 0, V - 1).astype(jnp.int32)
        use_hash = jax.random.bernoulli(k2, 0.7, tok.shape)
        return jnp.where(use_hash, nxt, biased), None

    def scan_fn(tok, k):
        nxt, _ = step(tok, k)
        return nxt, nxt

    keys = jax.random.split(k_noise, data.seq_len - 1)
    _, rest = jax.lax.scan(scan_fn, start, keys)
    return jnp.concatenate([start[None, :], rest], axis=0).T  # (batch, S)


def worker_batches(
    data: HeterogeneousLMData, step: int, batch_per_worker: int
) -> jax.Array:
    """(n_workers, batch, seq_len) tokens for a given global step."""
    base = jax.random.fold_in(jax.random.PRNGKey(data.seed), step)

    def one(worker):
        k = jax.random.fold_in(base, worker)
        return _worker_tokens(data, k, worker, batch_per_worker)

    return jax.vmap(one)(jnp.arange(data.n_workers))


def lm_batch_iterator(
    data: HeterogeneousLMData, batch_per_worker: int, start_step: int = 0
) -> Iterator[jax.Array]:
    step = start_step
    fn = jax.jit(lambda s: worker_batches(data, s, batch_per_worker))
    while True:
        yield fn(step)
        step += 1


def make_prefix_embeddings(
    key: jax.Array, n_workers: int, batch: int, prefix_len: int, d_model: int
) -> jax.Array:
    """Stub frontend output (vision patches / audio conditioning frames):
    (n_workers, batch, prefix_len, d_model), unit-scale."""
    return jax.random.normal(key, (n_workers, batch, prefix_len, d_model)) * 0.02
