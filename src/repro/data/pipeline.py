"""Synthetic heterogeneous data pipeline.

The paper's setting is *arbitrarily heterogeneous* local datasets f_i. For LM
training we synthesize per-worker token streams from distinct Markov chains
(worker-specific transition tables biased toward different vocabulary regions),
so local gradients genuinely disagree — the regime where gradient-difference
compression (MARINA) beats direct gradient compression (QSGD/DIANA).

Deterministic: every (worker, step) batch is a pure function of the seed, so
data-parallel shards never need host-side coordination, checkpointed runs
resume bit-exactly, and the same stream can be regenerated on any mesh layout.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class HeterogeneousLMData:
    """Spec for per-worker synthetic token distributions.

    Two heterogeneity dials (DESIGN.md §6): the legacy ``heterogeneity``
    scalar shifts each worker's preferred vocabulary region smoothly, and
    ``alpha`` switches to the federated Dirichlet protocol — each worker's
    mixture over ``n_regions`` vocabulary regions is drawn ~ Dir(α) from the
    seed (α → ∞ iid, α = 0.1 near-single-region clients), matching how
    federated benchmarks skew label distributions (Hsu et al. 2019).
    """

    n_workers: int
    vocab_size: int
    seq_len: int
    seed: int = 0
    heterogeneity: float = 1.0  # 0 → iid workers
    order: int = 8              # markov-ish context hash width
    alpha: Optional[float] = None  # Dirichlet non-IID dial (None → legacy)
    n_regions: int = 8             # vocab regions the Dirichlet mixes over


def make_lm_data(
    n_workers: int,
    vocab_size: int,
    seq_len: int,
    seed: int = 0,
    heterogeneity: float = 1.0,
    alpha: Optional[float] = None,
) -> HeterogeneousLMData:
    """Build a :class:`HeterogeneousLMData` spec (see its docstring for the
    heterogeneity vs Dirichlet-α dials)."""
    return HeterogeneousLMData(
        n_workers=n_workers,
        vocab_size=vocab_size,
        seq_len=seq_len,
        seed=seed,
        heterogeneity=heterogeneity,
        alpha=alpha,
    )


# ---------------------------------------------------------------------------
# Dirichlet(α) non-IID partitioning (the standard federated protocol)
# ---------------------------------------------------------------------------


def dirichlet_proportions(
    key: jax.Array, n_clients: int, n_classes: int, alpha: float
) -> jax.Array:
    """(n_clients, n_classes) class mixtures, one Dir(α) row per client.

    α → ∞ (or any non-finite value) degrades to the uniform mixture — iid
    clients; small α concentrates each client on few classes.
    """
    if alpha is None or not np.isfinite(alpha):
        return jnp.full((n_clients, n_classes), 1.0 / n_classes)
    return jax.random.dirichlet(
        key, jnp.full((n_classes,), float(alpha)), (n_clients,)
    )


def dirichlet_partition(
    key: jax.Array, labels: np.ndarray, n_clients: int, alpha: float
) -> list:
    """Partition sample indices across clients by Dirichlet label skew.

    Host-side (numpy): for each class, the class's sample indices are split
    across clients proportionally to the clients' Dir(α) mixture column.
    Returns a list of ``n_clients`` disjoint int arrays covering all
    indices — the standard federated non-IID split (Hsu et al. 2019).
    """
    labels = np.asarray(labels)
    classes = np.unique(labels)
    props = np.asarray(
        dirichlet_proportions(key, n_clients, len(classes), alpha)
    )
    rng = np.random.default_rng(int(np.asarray(jax.random.bits(key))))
    shards = [[] for _ in range(n_clients)]
    for c_idx, c in enumerate(classes):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        # split this class across clients ∝ their mixture weight on it
        w = props[:, c_idx]
        w = w / max(w.sum(), 1e-12)
        cuts = (np.cumsum(w)[:-1] * len(idx)).astype(int)
        for client, part in enumerate(np.split(idx, cuts)):
            shards[client].append(part)
    return [np.concatenate(s) if s else np.empty((0,), int) for s in shards]


def client_weights_from_counts(counts) -> jax.Array:
    """Normalized client weights w_i = m_i / Σm_j from per-client sample
    counts — the weights PPMarina uses for unbalanced local datasets."""
    c = jnp.asarray(counts, jnp.float32)
    return c / jnp.sum(c)


def _worker_tokens(
    data: HeterogeneousLMData, key: jax.Array, worker: jax.Array, batch: int
) -> jax.Array:
    """Sample (batch, seq_len) tokens for one worker.

    Per-worker unigram tilt + a deterministic "grammar": token_{t+1} is a hash
    of token_t with worker-biased noise, giving learnable structure whose
    optimum differs across workers.
    """
    V = data.vocab_size
    k_bias, k_start, k_noise = jax.random.split(key, 3)
    # worker-specific preferred region of the vocabulary (→ V/2 when iid)
    het = data.heterogeneity
    offset = ((worker.astype(jnp.float32) + 0.5) / data.n_workers - 0.5) * V
    center = V / 2.0 + het * offset
    width = V * (1.0 - 0.7 * het) + 1.0

    if data.alpha is not None:
        # federated Dirichlet skew: this worker's mixture over n_regions
        # vocab regions is a pure function of (seed, worker) — every step
        # draws tokens from the same per-client distribution.
        C = data.n_regions
        k_pi = jax.random.fold_in(jax.random.PRNGKey(data.seed + 101), worker)
        pi = dirichlet_proportions(k_pi, 1, C, data.alpha)[0]
        region_w = V // C

    start = jax.random.randint(k_start, (batch,), 0, V)

    def step(tok, k):
        k1, k2 = jax.random.split(k)
        # deterministic component: affine hash of current token
        nxt = (tok * 31 + 7) % V
        if data.alpha is not None:
            # stochastic component: region ~ Dir(α) mixture, uniform within
            kr, ku = jax.random.split(k1)
            region = jax.random.choice(kr, C, tok.shape, p=pi)
            within = jax.random.randint(ku, tok.shape, 0, region_w)
            biased = jnp.clip(region * region_w + within, 0, V - 1)
            biased = biased.astype(jnp.int32)
        else:
            # worker-biased stochastic component
            noise = jax.random.normal(k1, tok.shape) * width * 0.1
            biased = jnp.clip(center + noise, 0, V - 1).astype(jnp.int32)
        use_hash = jax.random.bernoulli(k2, 0.7, tok.shape)
        return jnp.where(use_hash, nxt, biased), None

    def scan_fn(tok, k):
        nxt, _ = step(tok, k)
        return nxt, nxt

    keys = jax.random.split(k_noise, data.seq_len - 1)
    _, rest = jax.lax.scan(scan_fn, start, keys)
    return jnp.concatenate([start[None, :], rest], axis=0).T  # (batch, S)


def worker_batches(
    data: HeterogeneousLMData, step: int, batch_per_worker: int
) -> jax.Array:
    """(n_workers, batch, seq_len) tokens for a given global step."""
    base = jax.random.fold_in(jax.random.PRNGKey(data.seed), step)

    def one(worker):
        k = jax.random.fold_in(base, worker)
        return _worker_tokens(data, k, worker, batch_per_worker)

    return jax.vmap(one)(jnp.arange(data.n_workers))


def lm_batch_iterator(
    data: HeterogeneousLMData, batch_per_worker: int, start_step: int = 0
) -> Iterator[jax.Array]:
    """Endless (n_workers, batch, seq_len) token stream, one jitted batch
    per optimizer step — a host-side convenience over worker_batches."""
    step = start_step
    fn = jax.jit(lambda s: worker_batches(data, s, batch_per_worker))
    while True:
        yield fn(step)
        step += 1


def make_prefix_embeddings(
    key: jax.Array, n_workers: int, batch: int, prefix_len: int, d_model: int
) -> jax.Array:
    """Stub frontend output (vision patches / audio conditioning frames):
    (n_workers, batch, prefix_len, d_model), unit-scale."""
    return jax.random.normal(key, (n_workers, batch, prefix_len, d_model)) * 0.02
