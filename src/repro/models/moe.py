"""Mixture-of-Experts FF layer: top-k routing, shared experts, capacity dispatch.

Dispatch strategy (TPU adaptation): tokens are sorted by expert id and packed
into an (E, C, d) capacity buffer via index scatter (only int32 indices are
scattered, never activations), then each expert runs a dense batched SwiGLU —
an MXU-friendly (E, C, d) × (E, d, f) contraction whose expert dimension shards
cleanly over the model axis (expert parallelism). Tokens beyond an expert's
capacity C = tokens·top_k/E · capacity_factor are dropped (standard
Switch-style behaviour; the router aux loss keeps drops rare).

Router flavours: softmax-over-top-k (llama4/mixtral style) and
sigmoid-with-normalization (deepseek-v3 style), plus the standard
load-balance auxiliary loss (Switch eq. 4).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import init_dense, init_mlp, mlp

PyTree = Any


def init_moe(key, cfg: ModelConfig, dtype):
    m: MoEConfig = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.num_experts
    ks = jax.random.split(key, 5)

    def stack(k, d_in, d_out):
        kk = jax.random.split(k, E)
        return jnp.stack([init_dense(ki, d_in, d_out, dtype) for ki in kk])

    p = {
        "router": init_dense(ks[0], d, E, dtype, scale=0.02),
        # moe_-prefixed names drive expert-parallel sharding rules
        "moe_gate": stack(ks[1], d, f),
        "moe_up": stack(ks[2], d, f),
        "moe_down": stack(ks[3], f, d),
    }
    if m.num_shared:
        p["shared"] = init_mlp(ks[4], d, f * m.num_shared, dtype)
    return p


def _route(p, m: MoEConfig, x_flat: jax.Array):
    """x_flat (T, d) → (expert_ids (T,k), combine_w (T,k), aux_loss)."""
    logits = (x_flat @ p["router"]).astype(jnp.float32)  # (T, E)
    if m.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        w, ids = jax.lax.top_k(scores, m.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        w, ids = jax.lax.top_k(probs, m.top_k)
        w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)

    # Switch load-balance loss: E · Σ_e fraction_e · router_prob_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(ids[:, 0], m.num_experts, dtype=jnp.float32), axis=0
    )
    aux = m.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return ids.astype(jnp.int32), w, aux * m.aux_loss_coef


def capacity(m: MoEConfig, T: int) -> int:
    c = int(T * m.top_k / m.num_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)  # multiple of 8 for TPU sublane alignment


def moe_ff(p, cfg: ModelConfig, x: jax.Array):
    """x (B, S, d) → (y (B, S, d), aux_loss scalar)."""
    m: MoEConfig = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    x_flat = x.reshape(T, d)

    ids, w, aux = _route(p, m, x_flat)          # (T,k)
    C = capacity(m, T)

    # --- pack: rank of each (token, slot) within its expert -----------------
    flat_e = ids.reshape(-1)                    # (T*k,)
    order = jnp.argsort(flat_e, stable=True)    # sorted by expert
    sorted_e = flat_e[order]
    # position within expert group = running index - group start
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank = jnp.arange(T * k) - group_start[sorted_e]
    keep = rank < C
    slot = sorted_e * C + jnp.where(keep, rank, 0)

    token_of_pair = order // k                  # original token index
    # slot tables (E*C,): token index feeding each slot, and its combine weight
    token_for_slot = jnp.full((E * C,), T, jnp.int32)  # T = dummy row
    token_for_slot = token_for_slot.at[slot].set(
        jnp.where(keep, token_of_pair, T).astype(jnp.int32)
    )
    w_flat = w.reshape(-1)[order]
    w_for_slot = jnp.zeros((E * C,), w.dtype)
    w_for_slot = w_for_slot.at[slot].set(jnp.where(keep, w_flat, 0.0))

    # --- expert compute: dense batched SwiGLU over (E, C, d) ---------------
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x.dtype)], axis=0)
    xg = x_pad[token_for_slot].reshape(E, C, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["moe_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xg, p["moe_up"]
    )
    yg = jnp.einsum("ecf,efd->ecd", h, p["moe_down"]).reshape(E * C, d)

    # --- combine: weighted scatter-add back to tokens -----------------------
    y = jnp.zeros((T + 1, d), x.dtype)
    y = y.at[token_for_slot].add(yg * w_for_slot[:, None].astype(x.dtype))
    y = y[:T].reshape(B, S, d)

    if m.num_shared:
        y = y + mlp(p["shared"], x)
    return y, aux
