"""Model configuration: one dataclass drives all 10 assigned architectures.

A model is a sequence of *segments*; each segment is a short period of
``LayerSpec``s repeated ``repeat`` times (params are stacked over the repeat
dimension and applied with ``lax.scan``). This expresses every assigned layout:

* uniform dense stacks          — one segment, period 1
* gemma3 5 local : 1 global     — period 6 × 10 + a trailing (local, local)
* recurrentgemma (rec,rec,attn) — period 3 × 8 + trailing (rec, rec)
* llama4 alternating dense/MoE  — period 2 × 24
* xLSTM 7 mLSTM : 1 sLSTM       — period 8 × 3
* deepseek-v3 3 dense + 58 MoE  — two segments
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Mixer = Literal["attn", "attn_local", "mla", "mlstm", "slstm", "rglru"]
FF = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ff: FF = "mlp"


@dataclasses.dataclass(frozen=True)
class Segment:
    period: tuple[LayerSpec, ...]
    repeat: int

    @property
    def num_layers(self) -> int:
        return len(self.period) * self.repeat


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 2048
    num_shared: int = 0            # shared (always-on) experts
    capacity_factor: float = 1.25  # per-expert slots = tokens*top_k/E * cf
    router_score: Literal["softmax", "sigmoid"] = "softmax"
    aux_loss_coef: float = 0.001   # load-balance loss


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    segments: tuple[Segment, ...]

    head_dim: Optional[int] = None       # default d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    window: int = 1024                   # sliding window for attn_local mixers
    rope_theta: float = 10_000.0
    pos_emb: Literal["rope", "sinusoidal", "none"] = "rope"
    tie_embeddings: bool = False

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None

    # SSM / hybrid
    lru_width: Optional[int] = None      # RG-LRU state width (default d_model)
    conv_width: int = 4                  # temporal conv in the recurrent block
    mlstm_proj_factor: float = 2.0       # mLSTM block up-projection
    slstm_proj_factor: float = 4.0 / 3.0

    # multi-token prediction (deepseek-v3); 0 = off
    mtp_depth: int = 0

    # modality frontend stub: model consumes precomputed embeddings
    frontend: Optional[Literal["vision", "audio"]] = None

    # norms
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0

    # chunk width of the online-softmax attention (perf knob; must be ≥ window)
    attn_chunk: int = 1024

    # per-layer rematerialization in the training forward (saves only the
    # residual stream between layers; recomputes attention/FF in the backward)
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.segments)

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def supports_long_context(self) -> bool:
        """True if decode state is O(window)/O(1) per layer for every mixer —
        the sub-quadratic criterion for the long_500k shape."""
        kinds = {l.mixer for s in self.segments for l in s.period}
        return "attn" not in kinds and "mla" not in kinds


def dense_stack(n: int, mixer: Mixer = "attn", ff: FF = "mlp") -> tuple[Segment, ...]:
    return (Segment(period=(LayerSpec(mixer=mixer, ff=ff),), repeat=n),)


def reduced(cfg: ModelConfig, layers: int = 2, d_model: int = 256) -> ModelConfig:
    """Build the CPU-smoke-test variant of the same family (≤4 experts, tiny d).

    Every segment's structure survives (the period is preserved; only repeats,
    widths and expert counts shrink) so the smoke test exercises the same block
    types as the full config.
    """
    scale = d_model / cfg.d_model
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, cfg.num_kv_heads if cfg.num_kv_heads < cfg.num_heads else heads))
    segs = []
    remaining = layers
    for s in cfg.segments:
        if remaining <= 0:
            break
        period = s.period[: max(1, min(len(s.period), remaining))]
        rep = max(1, min(s.repeat, -(-remaining // len(period))))
        rep = min(rep, max(1, remaining // len(period)) or 1)
        segs.append(Segment(period=period, repeat=rep))
        remaining -= len(period) * rep
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(4, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k),
            d_expert=max(32, int(cfg.moe.d_expert * scale)),
            num_shared=min(1, cfg.moe.num_shared),
            # generous capacity so CPU smoke/decode tests are drop-free
            # (capacity drops are legitimate train/serve skew at scale)
            capacity_factor=4.0,
        )
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
    return dataclasses.replace(
        cfg,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=None if cfg.head_dim is None else max(16, d_model // heads),
        d_ff=max(32, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        vocab_size=512,
        segments=tuple(segs),
        moe=moe,
        mla=mla,
        lru_width=None,
        window=16,
        mtp_depth=min(cfg.mtp_depth, 1),
    )
