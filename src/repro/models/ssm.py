"""Recurrent mixers: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM (xLSTM).

Training formulations are chosen for TPU shapes:

* RG-LRU — diagonal linear recurrence ⇒ ``jax.lax.associative_scan`` (log-depth,
  no sequential bottleneck).
* mLSTM — matrix-memory linear recurrence ⇒ chunkwise-parallel form: quadratic
  attention-like compute inside chunks (MXU), recurrent hand-off of the
  (dk × dv) state only at chunk boundaries. Exponential gates are stabilized by
  a running log-scale max, as in the xLSTM paper (App. A).
* sLSTM — non-linear recurrence (gates read h_{t−1}); inherently sequential ⇒
  ``lax.scan``; the state is O(d) so the scan carry is small.

Decode for all three is a single recurrent update — O(1) per token, which is
why the ssm/hybrid architectures run the long_500k shape.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_dense, init_rmsnorm, rmsnorm

PyTree = Any


# ---------------------------------------------------------------------------
# Temporal conv (shared by RG-LRU block)
# ---------------------------------------------------------------------------


def init_conv1d(key, width: int, channels: int, dtype):
    return {
        "w": (jax.random.normal(key, (width, channels)) / width).astype(dtype),
        "b": jnp.zeros((channels,), dtype),
    }


def causal_conv1d(p, x: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C); kernel (W,C)."""
    W = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * p["w"][i][None, None, :] for i in range(W)
    )
    return out + p["b"]


def conv1d_decode(p, state: jax.Array, x_t: jax.Array):
    """state (B, W-1, C) holds the last W-1 inputs; x_t (B,1,C)."""
    W = p["w"].shape[0]
    window = jnp.concatenate([state, x_t], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, p["w"]) + p["b"]
    return out[:, None, :], window[:, 1:, :]


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

_RG_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype):
    d, w = cfg.d_model, cfg.resolved_lru_width
    ks = jax.random.split(key, 7)
    return {
        "w_x": init_dense(ks[0], d, w, dtype),
        "w_y": init_dense(ks[1], d, w, dtype),
        "conv": init_conv1d(ks[2], cfg.conv_width, w, dtype),
        "w_a": init_dense(ks[3], w, w, dtype, scale=0.02),
        "w_i": init_dense(ks[4], w, w, dtype, scale=0.02),
        # Λ init so that a ∈ (0.9, 0.999) at r = 1 (Griffin §2.4)
        "lam": (jax.random.uniform(ks[5], (w,), minval=0.7, maxval=5.0)).astype(dtype),
        "w_out": init_dense(ks[6], w, d, dtype),
    }


def _rglru_gates(p, u: jax.Array):
    """u (B,S,w) (post-conv). Returns per-step decay a and input b."""
    r = jax.nn.sigmoid((u @ p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32))
    log_a = -_RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (
        i * u.astype(jnp.float32)
    )
    return a, gated


def rglru_train(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Griffin recurrent block: conv + RG-LRU gated by a GeLU branch."""
    y = jax.nn.gelu(x @ p["w_y"])
    u = causal_conv1d(p["conv"], x @ p["w_x"])
    a, b = _rglru_gates(p, u)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    return (h * y) @ p["w_out"]


def init_rglru_state(cfg: ModelConfig, B: int, dtype):
    w = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((B, w), jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode(p, cfg: ModelConfig, state, x_t: jax.Array):
    y = jax.nn.gelu(x_t @ p["w_y"])
    u, conv_state = conv1d_decode(p["conv"], state["conv"], x_t @ p["w_x"])
    a, b = _rglru_gates(p, u)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = (h[:, None, :].astype(x_t.dtype) * y) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM (matrix memory, exponential gating) — chunkwise-parallel
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    inner = int(cfg.mlstm_proj_factor * d)
    H = cfg.num_heads
    assert inner % H == 0
    ks = jax.random.split(key, 8)
    return {
        "w_up": init_dense(ks[0], d, 2 * inner, dtype),
        "conv": init_conv1d(ks[1], cfg.conv_width, inner, dtype),
        "w_q": init_dense(ks[2], inner, inner, dtype),
        "w_k": init_dense(ks[3], inner, inner, dtype),
        "w_v": init_dense(ks[4], inner, inner, dtype),
        "w_if": init_dense(ks[5], inner, 2 * H, dtype, scale=0.02),
        "out_norm": init_rmsnorm(inner, dtype),
        "w_down": init_dense(ks[6], inner, d, dtype),
    }


def _mlstm_proj(p, cfg: ModelConfig, x: jax.Array):
    B, S, _ = x.shape
    H = cfg.num_heads
    inner = p["w_q"].shape[0]
    hd = inner // H
    up = x @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    c = jax.nn.silu(causal_conv1d(p["conv"], xm))
    q = (c @ p["w_q"]).reshape(B, S, H, hd)
    k = (c @ p["w_k"]).reshape(B, S, H, hd) / jnp.sqrt(hd)
    v = (xm @ p["w_v"]).reshape(B, S, H, hd)
    gates = (c @ p["w_if"]).astype(jnp.float32).reshape(B, S, H, 2)
    log_i = gates[..., 0]                      # pre-activation of exp input gate
    log_f = -jax.nn.softplus(-gates[..., 1])   # log sigmoid forget gate
    return q, k, v, log_i, log_f, z


def mlstm_train(p, cfg: ModelConfig, x: jax.Array, *, chunk: int = 256) -> jax.Array:
    B, S, _ = x.shape
    H = cfg.num_heads
    q, k, v, log_i, log_f, z = _mlstm_proj(p, cfg, x)
    inner = q.shape[2] * q.shape[3]
    hd = q.shape[3]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nch = S // chunk

    def resh(t, extra=()):
        return t.reshape(B, nch, chunk, H, *extra).swapaxes(2, 3)

    qc = resh(q, (hd,))   # (B,nch,H,chunk,hd)
    kc = resh(k, (hd,))
    vc = resh(v, (hd,))
    lic = log_i.reshape(B, nch, chunk, H).swapaxes(2, 3)  # (B,nch,H,chunk)
    lfc = log_f.reshape(B, nch, chunk, H).swapaxes(2, 3)

    F = jnp.cumsum(lfc, axis=-1)              # within-chunk Σ log f
    Ftot = F[..., -1]                          # (B,nch,H)

    def step(carry, idx):
        C, n, m = carry  # (B,H,hd,hd), (B,H,hd), (B,H)
        qi = qc[:, idx]
        ki = kc[:, idx]
        vi = vc[:, idx]
        Fi = F[:, idx]                          # (B,H,chunk)
        li = lic[:, idx]
        ftot = Ftot[:, idx]

        # log weights: inter-chunk  q_t C:  F_t + m_prev
        #              intra-chunk  (s<=t): F_t − F_s + log i_s
        log_inter = Fi + m[..., None]                             # (B,H,chunk)
        log_intra = Fi[..., :, None] - Fi[..., None, :] + li[..., None, :]
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        log_intra = jnp.where(causal, log_intra, -jnp.inf)
        m_new = jnp.maximum(
            jnp.max(log_intra, axis=-1), log_inter
        )                                                          # (B,H,chunk)
        w_inter = jnp.exp(log_inter - m_new)
        w_intra = jnp.exp(log_intra - m_new[..., None])            # (B,H,chunk,chunk)

        h_inter = jnp.einsum("bhtd,bhde->bhte", qi, C) * w_inter[..., None]
        n_inter = jnp.einsum("bhtd,bhd->bht", qi, n) * w_inter

        scores = jnp.einsum("bhtd,bhsd->bhts", qi, ki) * w_intra.astype(qi.dtype)
        h_intra = jnp.einsum("bhts,bhse->bhte", scores, vi)
        n_intra = jnp.sum(scores, axis=-1)

        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_new))
        h = (h_inter + h_intra) / denom[..., None].astype(qi.dtype)

        # boundary state update (stabilized at scale m_run)
        m_run = jnp.maximum(ftot + m, jnp.max(Fi * 0 + li + (ftot[..., None] - Fi), axis=-1))
        decay = jnp.exp(ftot + m - m_run)
        w_in = jnp.exp(ftot[..., None] - Fi + li - m_run[..., None])  # (B,H,chunk)
        C_new = decay[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_in, ki, vi
        )
        n_new = decay[..., None] * n + jnp.einsum("bhs,bhsd->bhd", w_in, ki)
        return (C_new, n_new, m_run), h

    init = (
        jnp.zeros((B, H, hd, hd), jnp.float32),
        jnp.zeros((B, H, hd), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    qc32 = qc.astype(jnp.float32)
    kc32 = kc.astype(jnp.float32)
    vc32 = vc.astype(jnp.float32)
    qc, kc, vc = qc32, kc32, vc32
    _, hs = jax.lax.scan(step, init, jnp.arange(nch))  # (nch,B,H,chunk,hd)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, inner).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    return (h * jax.nn.silu(z)) @ p["w_down"]


def init_mlstm_state(cfg: ModelConfig, B: int, dtype):
    inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    H = cfg.num_heads
    hd = inner // H
    return {
        "C": jnp.zeros((B, H, hd, hd), jnp.float32),
        "n": jnp.zeros((B, H, hd), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
        "conv": jnp.zeros((B, cfg.conv_width - 1, inner), dtype),
    }


def mlstm_decode(p, cfg: ModelConfig, state, x_t: jax.Array):
    B = x_t.shape[0]
    H = cfg.num_heads
    inner = p["w_q"].shape[0]
    hd = inner // H
    up = x_t @ p["w_up"]
    xm, z = jnp.split(up, 2, axis=-1)
    c_t, conv_state = conv1d_decode(p["conv"], state["conv"], xm)
    c_t = jax.nn.silu(c_t)
    q = (c_t @ p["w_q"]).reshape(B, H, hd).astype(jnp.float32)
    k = ((c_t @ p["w_k"]).reshape(B, H, hd) / jnp.sqrt(hd)).astype(jnp.float32)
    v = (xm @ p["w_v"]).reshape(B, H, hd).astype(jnp.float32)
    gates = (c_t @ p["w_if"]).astype(jnp.float32).reshape(B, H, 2)
    log_i = gates[..., 0]
    log_f = -jax.nn.softplus(-gates[..., 1])

    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    C = f_s[..., None, None] * state["C"] + i_s[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_s[..., None] * state["n"] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, inner).astype(x_t.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    return out, {"C": C, "n": n, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, recurrent gates) — sequential scan
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ks = jax.random.split(key, 7)

    def rec(k):  # block-diagonal (head-wise) recurrent matrix
        return (jax.random.normal(k, (H, hd, hd)) * 0.02).astype(dtype)

    # lane-aligned FF width (…and divisible by the 16-way model axis)
    f = max(128, -(-int(cfg.slstm_proj_factor * d) // 128) * 128)
    return {
        "w_in": init_dense(ks[0], d, 4 * d, dtype),     # z, i, f, o pre-acts
        "r_z": rec(ks[1]),
        "r_i": rec(ks[2]),
        "r_f": rec(ks[3]),
        "r_o": rec(ks[4]),
        "out_norm": init_rmsnorm(d, dtype),
        # GeGLU feed-forward (proj factor 4/3) folded into the block
        "ff_up": init_dense(ks[5], d, 2 * f, dtype),
        "ff_down": init_dense(ks[6], f, d, dtype),
    }


def _slstm_cell(p, H, hd, carry, wx_t):
    """carry: (c, n, h, m) each (B,H,hd) fp32; wx_t (B,4d) pre-activations."""
    c, n, h, m = carry
    B = c.shape[0]

    def recur(r, hh):
        return jnp.einsum("bhd,hde->bhe", hh, r.astype(jnp.float32))

    z_x, i_x, f_x, o_x = jnp.split(wx_t.astype(jnp.float32), 4, axis=-1)
    resh = lambda t: t.reshape(B, H, hd)
    z = jnp.tanh(resh(z_x) + recur(p["r_z"], h))
    log_i = resh(i_x) + recur(p["r_i"], h)
    log_f = -jax.nn.softplus(-(resh(f_x) + recur(p["r_f"], h)))  # log σ(f̃)
    o = jax.nn.sigmoid(resh(o_x) + recur(p["r_o"], h))

    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = jnp.maximum(f_s * n + i_s, 1e-6)
    h_new = o * c_new / n_new
    return (c_new, n_new, h_new, m_new), h_new


def slstm_train(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    wx = x @ p["w_in"]  # (B,S,4d)

    def step(carry, wx_t):
        return _slstm_cell(p, H, hd, carry, wx_t)

    init = tuple(jnp.zeros((B, H, hd), jnp.float32) for _ in range(3)) + (
        jnp.full((B, H, hd), -1e30, jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, wx.swapaxes(0, 1))   # (S,B,H,hd)
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    up = h @ p["ff_up"]
    a, b = jnp.split(up, 2, axis=-1)
    return (jax.nn.gelu(a) * b) @ p["ff_down"]


def init_slstm_state(cfg: ModelConfig, B: int, dtype):
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((B, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((B, H, hd), -1e30, jnp.float32)}


def slstm_decode(p, cfg: ModelConfig, state, x_t: jax.Array):
    B = x_t.shape[0]
    H = cfg.num_heads
    hd = cfg.d_model // H
    wx = (x_t @ p["w_in"])[:, 0, :]
    carry = (state["c"], state["n"], state["h"], state["m"])
    (c, n, h, m), h_out = _slstm_cell(p, H, hd, carry, wx)
    y = h_out.reshape(B, 1, cfg.d_model).astype(x_t.dtype)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps)
    up = y @ p["ff_up"]
    a, b = jnp.split(up, 2, axis=-1)
    out = (jax.nn.gelu(a) * b) @ p["ff_down"]
    return out, {"c": c, "n": n, "h": h, "m": m}
