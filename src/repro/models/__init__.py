from .config import (
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    Segment,
    dense_stack,
    reduced,
)
from .model import (
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    init_params,
    lm_loss,
    paged_copy_pages,
    paged_decode_step,
    paged_gather_pages,
    paged_prefill_chunk,
    paged_scatter_pages,
    param_count,
    prefill,
)

__all__ = [
    "LayerSpec", "MLAConfig", "MoEConfig", "ModelConfig", "Segment",
    "dense_stack", "reduced", "decode_step", "forward", "init_cache",
    "init_paged_cache", "init_params", "lm_loss", "paged_copy_pages",
    "paged_decode_step", "paged_gather_pages", "paged_prefill_chunk",
    "paged_scatter_pages", "param_count", "prefill",
]
