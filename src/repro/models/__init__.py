from .config import (
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    Segment,
    dense_stack,
    reduced,
)
from .model import (
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    init_params,
    lm_loss,
    paged_decode_step,
    paged_prefill_chunk,
    param_count,
    prefill,
)

__all__ = [
    "LayerSpec", "MLAConfig", "MoEConfig", "ModelConfig", "Segment",
    "dense_stack", "reduced", "decode_step", "forward", "init_cache",
    "init_paged_cache", "init_params", "lm_loss", "paged_decode_step",
    "paged_prefill_chunk", "param_count", "prefill",
]
