"""Layer assembly: (pre-norm mixer + residual) ∘ (pre-norm FF + residual).

One ``LayerSpec`` describes a layer; segments stack layers of identical spec
and scan over them (model.py). All train entry points optionally return the
serving cache so prefill is a single forward pass.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .config import LayerSpec, ModelConfig
from .layers import init_mlp, init_rmsnorm, mlp, rmsnorm

PyTree = Any


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype) -> PyTree:
    k_mix, k_ff = jax.random.split(key)
    p: dict[str, Any] = {"ln1": init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = attn.init_attn(k_mix, cfg, dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn.init_mla(k_mix, cfg, dtype)
    elif spec.mixer == "rglru":
        p["mixer"] = ssm.init_rglru(k_mix, cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = ssm.init_mlstm(k_mix, cfg, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = ssm.init_slstm(k_mix, cfg, dtype)
    else:
        raise ValueError(spec.mixer)

    if spec.ff == "mlp":
        p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
        p["ff"] = init_mlp(k_ff, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ff == "moe":
        p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
        p["ff"] = moe_mod.init_moe(k_ff, cfg, dtype)
    return p


def layer_train(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    x: jax.Array,
    positions: jax.Array,
    *,
    want_cache: bool = False,
    cache_len: int | None = None,
):
    """→ (x', aux_loss, cache-or-None)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    cache = None
    cache_len = cache_len or x.shape[1]
    if spec.mixer in ("attn", "attn_local"):
        local = spec.mixer == "attn_local"
        y = attn.attn_train(p["mixer"], cfg, h, positions, local=local, chunk=cfg.attn_chunk)
        if want_cache:
            cache = _attn_cache_from_prefill(
                p["mixer"], cfg, h, positions, local, cache_len
            )
    elif spec.mixer == "mla":
        y = attn.mla_train(p["mixer"], cfg, h, positions, chunk=cfg.attn_chunk)
        if want_cache:
            cache = _mla_cache_from_prefill(p["mixer"], cfg, h, positions, cache_len)
    elif spec.mixer == "rglru":
        y, cache = _rglru_train(p["mixer"], cfg, h, want_cache)
    elif spec.mixer == "mlstm":
        y, cache = _mlstm_train(p["mixer"], cfg, h, want_cache)
    elif spec.mixer == "slstm":
        y, cache = _slstm_train(p["mixer"], cfg, h, want_cache)
    else:
        raise ValueError(spec.mixer)
    x = x + y

    aux = jnp.zeros((), jnp.float32)
    if spec.ff == "mlp":
        x = x + mlp(p["ff"], rmsnorm(x, p["ln2"], cfg.norm_eps))
    elif spec.ff == "moe":
        y, aux = moe_mod.moe_ff(p["ff"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps))
        x = x + y
    return x, aux, cache


def layer_decode(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    cache: PyTree,
    x_t: jax.Array,
    pos,
):
    h = rmsnorm(x_t, p["ln1"], cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        y, cache = attn.attn_decode(
            p["mixer"], cfg, cache, h, pos, local=spec.mixer == "attn_local"
        )
    elif spec.mixer == "mla":
        y, cache = attn.mla_decode(p["mixer"], cfg, cache, h, pos)
    elif spec.mixer == "rglru":
        y, cache = ssm.rglru_decode(p["mixer"], cfg, cache, h)
    elif spec.mixer == "mlstm":
        y, cache = ssm.mlstm_decode(p["mixer"], cfg, cache, h)
    elif spec.mixer == "slstm":
        y, cache = ssm.slstm_decode(p["mixer"], cfg, cache, h)
    else:
        raise ValueError(spec.mixer)
    x_t = x_t + y
    if spec.ff == "mlp":
        x_t = x_t + mlp(p["ff"], rmsnorm(x_t, p["ln2"], cfg.norm_eps))
    elif spec.ff == "moe":
        y, _ = moe_mod.moe_ff(p["ff"], cfg, rmsnorm(x_t, p["ln2"], cfg.norm_eps))
        x_t = x_t + y
    return x_t, cache


def _ff_decode(p: PyTree, cfg: ModelConfig, spec: LayerSpec, x_t: jax.Array):
    if spec.ff == "mlp":
        return x_t + mlp(p["ff"], rmsnorm(x_t, p["ln2"], cfg.norm_eps))
    if spec.ff == "moe":
        y, _ = moe_mod.moe_ff(p["ff"], cfg, rmsnorm(x_t, p["ln2"], cfg.norm_eps))
        return x_t + y
    return x_t


def layer_paged_decode(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    cache: PyTree,
    x_t: jax.Array,
    lengths: jax.Array,
    tables: jax.Array,
    *,
    backend: str = "auto",
):
    """Paged twin of :func:`layer_decode` — global-attention mixers only
    (paging a ring buffer or an O(1) recurrent state buys nothing)."""
    if spec.mixer != "attn":
        raise ValueError(
            f"paged serving supports global-attention mixers only, got {spec.mixer!r}"
        )
    h = rmsnorm(x_t, p["ln1"], cfg.norm_eps)
    y, cache = attn.paged_attn_decode(
        p["mixer"], cfg, cache, h, lengths, tables, backend=backend
    )
    return _ff_decode(p, cfg, spec, x_t + y), cache


def layer_paged_prefill(
    p: PyTree,
    cfg: ModelConfig,
    spec: LayerSpec,
    cache: PyTree,
    x: jax.Array,
    start,
    table_row: jax.Array,
    n_valid,
    *,
    backend: str = "auto",
):
    """Paged twin of :func:`layer_train` for one request's prompt chunk."""
    if spec.mixer != "attn":
        raise ValueError(
            f"paged serving supports global-attention mixers only, got {spec.mixer!r}"
        )
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    y, cache = attn.paged_attn_prefill_chunk(
        p["mixer"], cfg, cache, h, start, table_row, n_valid, backend=backend
    )
    return _ff_decode(p, cfg, spec, x + y), cache


def init_layer_paged_cache(
    cfg: ModelConfig, spec: LayerSpec, npage: int, page_size: int, dtype,
    *, quantized: bool = False,
):
    if spec.mixer != "attn":
        raise ValueError(
            f"paged serving supports global-attention mixers only, got {spec.mixer!r}"
        )
    return attn.init_paged_attn_cache(
        cfg, npage, page_size, dtype, quantized=quantized
    )


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, B: int, max_len: int, dtype):
    if spec.mixer in ("attn", "attn_local"):
        return attn.init_attn_cache(
            cfg, B, max_len, local=spec.mixer == "attn_local", dtype=dtype
        )
    if spec.mixer == "mla":
        return attn.init_mla_cache(cfg, B, max_len, dtype)
    if spec.mixer == "rglru":
        return ssm.init_rglru_state(cfg, B, dtype)
    if spec.mixer == "mlstm":
        return ssm.init_mlstm_state(cfg, B, dtype)
    if spec.mixer == "slstm":
        return ssm.init_slstm_state(cfg, B, dtype)
    raise ValueError(spec.mixer)


# ---------------------------------------------------------------------------
# prefill-cache helpers
# ---------------------------------------------------------------------------


def _pad_time(t: jax.Array, L: int) -> jax.Array:
    """Pad axis 1 (time) with zeros up to L."""
    S = t.shape[1]
    if S >= L:
        return t[:, :L]
    pad = [(0, 0)] * t.ndim
    pad[1] = (0, L - S)
    return jnp.pad(t, pad)


def _attn_cache_from_prefill(p, cfg, h, positions, local, cache_len):
    """Recompute k/v projections (cheap) and lay them out as the decode cache."""
    q, k, v = attn._qkv(p, cfg, h, positions)
    if not local:
        return {"k": _pad_time(k, cache_len), "v": _pad_time(v, cache_len)}
    L = min(cfg.window, cache_len)
    T = min(L, k.shape[1])
    k_tail, v_tail = k[:, -T:], v[:, -T:]
    slots = positions[:, -T:] % L  # ring layout
    B = k.shape[0]
    ring_k = jnp.zeros((B, L, *k.shape[2:]), k.dtype)
    ring_v = jnp.zeros((B, L, *v.shape[2:]), v.dtype)
    bidx = jnp.arange(B)[:, None]
    ring_k = ring_k.at[bidx, slots].set(k_tail)
    ring_v = ring_v.at[bidx, slots].set(v_tail)
    return {"k": ring_k, "v": ring_v}


def _mla_cache_from_prefill(p, cfg, h, positions, cache_len):
    _, _, ckv, k_rope = attn._mla_qkv(p, cfg, h, positions)
    return {
        "ckv": _pad_time(ckv, cache_len),
        "k_rope": _pad_time(k_rope[:, :, 0, :], cache_len),
    }


def _rglru_train(p, cfg, h, want_cache):
    y = ssm.rglru_train(p, cfg, h)
    if not want_cache:
        return y, None
    # final recurrent state: rerun the gate scan's last element cheaply
    u = ssm.causal_conv1d(p["conv"], h @ p["w_x"])
    a, b = ssm._rglru_gates(p, u)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    state = {
        "h": hs[:, -1],
        "conv": (h @ p["w_x"])[:, -(cfg.conv_width - 1):, :],
    }
    return y, state


def _mlstm_train(p, cfg, h, want_cache):
    y = ssm.mlstm_train(p, cfg, h)
    if not want_cache:
        return y, None
    # replay the chunk scan to get the final boundary state (compute-cheap
    # relative to the output pass; decode then continues from it)
    B = h.shape[0]
    state = ssm.init_mlstm_state(cfg, B, h.dtype)
    up = h @ p["w_up"]
    xm, _ = jnp.split(up, 2, axis=-1)
    state = dict(state)
    state["conv"] = xm[:, -(cfg.conv_width - 1):, :]
    # boundary (C, n, m) via decode-cell scan over the last chunk is exact but
    # sequential; we use the chunkwise final carry instead
    q, k, v, log_i, log_f, _ = ssm._mlstm_proj(p, cfg, h)
    Bq, S, H, hd = q.shape
    F = jnp.cumsum(log_f, axis=1)  # (B,S,H)
    ftot = F[:, -1]
    m_run = jnp.max(ftot[:, None, :] - F + log_i, axis=1)
    w_in = jnp.exp(ftot[:, None, :] - F + log_i - m_run[:, None, :])
    C = jnp.einsum("bsh,bshd,bshe->bhde", w_in, k.astype(jnp.float32), v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshd->bhd", w_in, k.astype(jnp.float32))
    state.update({"C": C, "n": n, "m": m_run})
    return y, state


def _slstm_train(p, cfg, h, want_cache):
    B, S, d = h.shape
    H = cfg.num_heads
    hd = d // H
    wx = h @ p["w_in"]

    def step(carry, wx_t):
        return ssm._slstm_cell(p, H, hd, carry, wx_t)

    init = tuple(jnp.zeros((B, H, hd), jnp.float32) for _ in range(3)) + (
        jnp.full((B, H, hd), -1e30, jnp.float32),
    )
    carry, hs = jax.lax.scan(step, init, wx.swapaxes(0, 1))
    hseq = hs.swapaxes(0, 1).reshape(B, S, d).astype(h.dtype)
    hseq = rmsnorm(hseq, p["out_norm"], cfg.norm_eps)
    up = hseq @ p["ff_up"]
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a) * b) @ p["ff_down"]
    if not want_cache:
        return y, None
    c, n, hh, m = carry
    return y, {"c": c, "n": n, "h": hh, "m": m}
