"""Attention mixers: GQA (full / sliding-window) and DeepSeek MLA.

Training/prefill use a chunked online-softmax formulation (pure JAX "flash"):
memory is O(S·chunk) instead of O(S²), which is what makes the 32k prefill
shape lowerable. Sliding-window layers ("attn_local") only visit the two kv
chunks that can intersect the window (requires window ≤ chunk), so their
compute is O(S·window) — the property that qualifies gemma3/recurrentgemma
for the long_500k shape.

Decode attends one query token against the cache:
* global attention — full (B, S, KV, hd) cache;
* local attention  — O(window) ring-buffer cache;
* MLA              — compressed (B, S, kv_lora + rope_dim) cache with the
  weight-absorption trick (queries projected into the latent space), which is
  the architecture's entire point and gives a 512+64 wide cache instead of
  2·128·128.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import MLAConfig, ModelConfig
from .layers import init_dense, init_rmsnorm, rmsnorm, rope

PyTree = Any

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked online-softmax attention core
# ---------------------------------------------------------------------------


def _attend_chunk(q, k, v, mask):
    """q (B,Cq,H,hd), k/v (B,Ck,H,hd), mask (B,Cq,Ck) → partial (logits-max, den, num)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[:, None, :, :], logits, _NEG_INF)
    m = jnp.max(logits, axis=-1)                      # (B,H,Cq)
    p = jnp.exp(logits - m[..., None])
    den = jnp.sum(p, axis=-1)                         # (B,H,Cq)
    num = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return m, den, num


def _merge(carry, m, den, num):
    m0, den0, num0 = carry
    m_new = jnp.maximum(m0, m)
    a0 = jnp.exp(m0 - m_new)
    a1 = jnp.exp(m - m_new)
    den_new = den0 * a0 + den * a1
    num_new = num0 * a0.transpose(0, 2, 1)[..., None].astype(num0.dtype) + \
        num * a1.transpose(0, 2, 1)[..., None].astype(num.dtype)
    return m_new, den_new, num_new


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    positions: jax.Array,
    *,
    window: int | None = None,
    chunk: int = 1024,
) -> jax.Array:
    """Causal (optionally banded) attention. q (B,S,H,hd), k/v (B,S,KV,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    hd_v = v.shape[3]
    chunk = min(chunk, S)
    S_orig = S
    pad = (-S) % chunk
    if pad:
        # self-pad to a chunk multiple; padded keys get sentinel positions so
        # no real query attends to them, padded query rows are sliced off
        zq = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q = jnp.pad(q, zq)
        k = jnp.pad(k, zq)
        v = jnp.pad(v, zq)
        positions = jnp.pad(positions, [(0, 0), (0, pad)], constant_values=2**30)
        S = S + pad
    nch = S // chunk
    if window is not None:
        # the banded path only visits chunks {qi-1, qi}; with a single chunk
        # plain causal masking already covers any window
        assert window <= chunk or nch == 1, "sliding window must fit one chunk"
    rep = H // KV

    qc = q.reshape(B, nch, chunk, H, hd)
    kc = k.reshape(B, nch, chunk, KV, hd)
    vc = v.reshape(B, nch, chunk, KV, hd_v)
    pc = positions.reshape(B, nch, chunk)

    def expand(x):  # GQA: repeat kv heads to H
        return jnp.repeat(x, rep, axis=2) if rep > 1 else x

    def mask_fn(pq, pk):
        m = pk[:, None, :] <= pq[:, :, None]
        if window is not None:
            m &= (pq[:, :, None] - pk[:, None, :]) < window
        return m

    def q_block(_, qi):
        q_i = jax.lax.dynamic_index_in_dim(qc, qi, 1, keepdims=False)
        p_i = jax.lax.dynamic_index_in_dim(pc, qi, 1, keepdims=False)
        init = (
            jnp.full((B, H, chunk), _NEG_INF, jnp.float32),
            jnp.zeros((B, H, chunk), jnp.float32),
            jnp.zeros((B, chunk, H, hd_v), v.dtype),
        )

        if window is not None:
            # banded: only chunks qi-1 and qi can intersect the window
            carry = init
            for delta in (1, 0):
                kj = jnp.maximum(qi - delta, 0)
                k_j = expand(jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False))
                v_j = expand(jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False))
                p_j = jax.lax.dynamic_index_in_dim(pc, kj, 1, keepdims=False)
                m = mask_fn(p_i, p_j) & (qi - delta >= 0)
                carry = _merge(carry, *_attend_chunk(q_i, k_j, v_j, m))
            m_f, den, num = carry
        else:
            def kv_block(carry, kj):
                k_j = expand(kc[:, kj])
                v_j = expand(vc[:, kj])
                m = mask_fn(p_i, pc[:, kj]) & (kj <= qi)
                return _merge(carry, *_attend_chunk(q_i, k_j, v_j, m)), None

            (m_f, den, num), _ = jax.lax.scan(kv_block, init, jnp.arange(nch))

        den = jnp.maximum(den, 1e-30)
        out = num / den.transpose(0, 2, 1)[..., None].astype(num.dtype)
        return None, out

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nch))  # (nch,B,chunk,H,hd_v)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd_v)
    return out[:, :S_orig]


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig, dtype):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": init_dense(ks[0], d, H * hd, dtype),
        "wk": init_dense(ks[1], d, KV * hd, dtype),
        "wv": init_dense(ks[2], d, KV * hd, dtype),
        "wo": init_dense(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_train(p, cfg: ModelConfig, x, positions, *, local: bool, chunk: int = 1024):
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    window = cfg.window if local else None
    chunk = max(chunk, window or 0)
    out = chunked_attention(q, k, v, positions, window=window, chunk=chunk)
    return out.reshape(B, S, -1) @ p["wo"]


def init_attn_cache(cfg: ModelConfig, B: int, max_len: int, *, local: bool, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = min(cfg.window, max_len) if local else max_len
    return {
        "k": jnp.zeros((B, L, KV, hd), dtype),
        "v": jnp.zeros((B, L, KV, hd), dtype),
    }


def attn_decode(p, cfg: ModelConfig, cache, x_t, pos, *, local: bool):
    """x_t (B,1,d); pos scalar int (current absolute position). Returns y, cache."""
    B = x_t.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_t, v_t = _qkv(p, cfg, x_t, positions)

    L = cache["k"].shape[1]
    slot = (pos % L) if local else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_t.astype(cache["k"].dtype), slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_t.astype(cache["v"].dtype), slot, 1)

    # key positions for masking
    idx = jnp.arange(L)
    if local:
        # ring buffer: slot s holds absolute position p with p % L == s, and the
        # newest write is at `slot`; valid if 0 <= pos - kpos < window
        kpos = pos - ((slot - idx) % L)
    else:
        kpos = idx
    valid = (kpos >= 0) & (kpos <= pos)
    if local:
        valid &= (pos - kpos) < cfg.window

    rep = H // KV
    k_e = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    v_e = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    scale = 1.0 / jnp.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_e).astype(jnp.float32) * scale
    logits = jnp.where(valid[None, None, None, :], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w.astype(v_e.dtype), v_e)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Paged-KV GQA (serving engine, DESIGN.md §8)
# ---------------------------------------------------------------------------


def init_paged_attn_cache(
    cfg: ModelConfig, npage: int, page_size: int, dtype, *, quantized: bool = False
):
    """One layer's KV page pool: (npage, P, KV, hd) with page 0 reserved as
    the null/trash page (core/paging.py). ``quantized`` stores int8 codes
    plus one f32 absmax scale per (page, row, kv-head)."""
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if quantized:
        return {
            "kq": jnp.zeros((npage, page_size, KV, hd), jnp.int8),
            "vq": jnp.zeros((npage, page_size, KV, hd), jnp.int8),
            "k_scale": jnp.zeros((npage, page_size, KV), jnp.float32),
            "v_scale": jnp.zeros((npage, page_size, KV), jnp.float32),
        }
    return {
        "k": jnp.zeros((npage, page_size, KV, hd), dtype),
        "v": jnp.zeros((npage, page_size, KV, hd), dtype),
    }


def _paged_write(cache, k_rows, v_rows, page, row, *, backend):
    """Scatter per-token k/v rows into the page pool at (page, row) — both
    (T,) int32. Idle/invalid tokens carry page 0 (the null page), so their
    writes are absorbed without masking. k_rows/v_rows: (T, KV, hd)."""
    if "kq" in cache:
        from repro.kernels import quantize as qz

        T, KV, hd = k_rows.shape
        kc, ks = qz.absmax_quant_rows(k_rows.reshape(T * KV, hd), backend=backend)
        vc, vs = qz.absmax_quant_rows(v_rows.reshape(T * KV, hd), backend=backend)
        return {
            "kq": cache["kq"].at[page, row].set(kc.reshape(T, KV, hd)),
            "vq": cache["vq"].at[page, row].set(vc.reshape(T, KV, hd)),
            "k_scale": cache["k_scale"].at[page, row].set(ks.reshape(T, KV)),
            "v_scale": cache["v_scale"].at[page, row].set(vs.reshape(T, KV)),
        }
    return {
        "k": cache["k"].at[page, row].set(k_rows.astype(cache["k"].dtype)),
        "v": cache["v"].at[page, row].set(v_rows.astype(cache["v"].dtype)),
    }


def _paged_attend_multi(cache, q, tables, key_mask):
    """Chunked-prefill attention against gathered pages (jnp — this path is
    compute-bound, the Pallas kernel covers the memory-bound decode).
    q (S, C, H, hd); tables (S, maxp); key_mask (S, C, L) True = visible.
    Returns (S, C, H, hd)."""
    from repro.kernels import ref as kref

    H, hd = q.shape[2], q.shape[3]
    if "kq" in cache:
        k_flat = kref.paged_gather_ref(cache["kq"], tables).astype(jnp.float32)
        v_flat = kref.paged_gather_ref(cache["vq"], tables).astype(jnp.float32)
        k_flat = k_flat * kref.paged_gather_ref(cache["k_scale"], tables)[..., None]
        v_flat = v_flat * kref.paged_gather_ref(cache["v_scale"], tables)[..., None]
    else:
        k_flat = kref.paged_gather_ref(cache["k"], tables)
        v_flat = kref.paged_gather_ref(cache["v"], tables)
    KV = k_flat.shape[2]
    rep = H // KV
    k_e = jnp.repeat(k_flat, rep, axis=2) if rep > 1 else k_flat
    v_e = jnp.repeat(v_flat, rep, axis=2) if rep > 1 else v_flat
    scale = 1.0 / jnp.sqrt(hd)
    logits = jnp.einsum("schd,slhd->shcl", q, k_e).astype(jnp.float32) * scale
    logits = jnp.where(key_mask[:, None, :, :], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("shcl,slhd->schd", w.astype(v_e.dtype), v_e)


def paged_attn_decode(
    p, cfg: ModelConfig, cache, x_t, lengths, tables, *, backend: str = "auto"
):
    """Paged decode: x_t (S,1,d); lengths (S,) tokens already cached per slot
    (= the rope position of x_t); tables (S, max_pages) int32. Writes k_t/v_t
    at page ``tables[s, lengths[s]//P]`` row ``lengths[s]%P`` (idle slots
    point at the null page), then attends over the gathered pages through
    the block-table-gather kernel. Returns (y (S,1,d), new cache)."""
    from repro.kernels import paged as paged_kernels

    S = x_t.shape[0]
    lengths = lengths.astype(jnp.int32)
    positions = lengths[:, None]
    q, k_t, v_t = _qkv(p, cfg, x_t, positions)
    P = (cache["kq"] if "kq" in cache else cache["k"]).shape[1]
    page = jnp.take_along_axis(tables, (lengths // P)[:, None], axis=1)[:, 0]
    row = lengths % P
    cache = _paged_write(cache, k_t[:, 0], v_t[:, 0], page, row, backend=backend)
    n_valid = lengths + 1
    if "kq" in cache:
        out = paged_kernels.paged_attn_decode_q8(
            q[:, 0], cache["kq"], cache["vq"], cache["k_scale"],
            cache["v_scale"], tables, n_valid, backend=backend,
        )
    else:
        out = paged_kernels.paged_attn_decode(
            q[:, 0], cache["k"], cache["v"], tables, n_valid, backend=backend
        )
    y = out.reshape(S, 1, -1) @ p["wo"]
    return y, cache


def paged_attn_prefill_chunk(
    p, cfg: ModelConfig, cache, x, start, table_row, n_valid, *,
    backend: str = "auto",
):
    """One request's prompt chunk: x (1, C, d) holds prompt tokens
    [start, start+C) with only the first ``n_valid`` real. Writes their k/v
    rows into the pages of ``table_row`` (max_pages,), then attends causally
    over everything this request has cached (earlier chunks included — the
    writes land before the gather). Returns (y (1, C, d), new cache)."""
    C = x.shape[1]
    offs = jnp.arange(C, dtype=jnp.int32)
    tok = start + offs
    positions = tok[None]
    q, k, v = _qkv(p, cfg, x, positions)
    P = (cache["kq"] if "kq" in cache else cache["k"]).shape[1]
    page = jnp.where(offs < n_valid, table_row[tok // P], 0)
    cache = _paged_write(cache, k[0], v[0], page, tok % P, backend=backend)
    L = table_row.shape[0] * P
    key_mask = (jnp.arange(L)[None, :] <= tok[:, None])[None]  # (1, C, L)
    out = _paged_attend_multi(cache, q, table_row[None], key_mask)
    y = out.reshape(1, C, -1) @ p["wo"]
    return y, cache


# ---------------------------------------------------------------------------
# DeepSeek MLA
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": init_dense(ks[0], d, m.q_lora_rank, dtype),
        "q_ln": init_rmsnorm(m.q_lora_rank, dtype),
        "w_uq": init_dense(ks[1], m.q_lora_rank, H * qd, dtype),
        "w_dkv": init_dense(ks[2], d, m.kv_lora_rank, dtype),
        "kv_ln": init_rmsnorm(m.kv_lora_rank, dtype),
        "w_kr": init_dense(ks[3], d, m.qk_rope_head_dim, dtype),
        "w_uk": init_dense(ks[4], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
        "w_uv": init_dense(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype),
        "wo": init_dense(ks[6], H * m.v_head_dim, d, dtype),
    }


def _mla_qkv(p, cfg: ModelConfig, x, positions):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    cq = rmsnorm(x @ p["w_dq"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, S, H, -1)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = rmsnorm(x @ p["w_dkv"], p["kv_ln"], cfg.norm_eps)          # (B,S,r)
    k_rope = rope(
        (x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta
    )  # (B,S,1,rd) shared across heads
    return q_nope, q_rope, ckv, k_rope


def mla_train(p, cfg: ModelConfig, x, positions, *, chunk: int = 1024):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1
    )
    out = chunked_attention(q, k, v, positions, window=None, chunk=chunk)
    return out.reshape(B, S, -1) @ p["wo"]


def init_mla_cache(cfg: ModelConfig, B: int, max_len: int, dtype):
    m: MLAConfig = cfg.mla
    return {
        "ckv": jnp.zeros((B, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((B, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p, cfg: ModelConfig, cache, x_t, pos):
    """Weight-absorbed MLA decode against the compressed latent cache."""
    m: MLAConfig = cfg.mla
    B = x_t.shape[0]
    H = cfg.num_heads
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope, ckv_t, kr_t = _mla_qkv(p, cfg, x_t, positions)

    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_t.astype(cache["ckv"].dtype), pos, 1
    )
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_t[:, :, 0, :].astype(cache["k_rope"].dtype), pos, 1
    )

    # Absorb W_uk into the query: q_eff (B,1,H,r)
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (
        jnp.einsum("bqhr,bkr->bhqk", q_eff, ckv)
        + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    valid = jnp.arange(ckv.shape[1]) <= pos
    logits = jnp.where(valid[None, None, None, :], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    # attend in latent space, then up-project once per head
    lat = jnp.einsum("bhqk,bkr->bqhr", w.astype(ckv.dtype), ckv)  # (B,1,H,r)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", lat, w_uv)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"ckv": ckv, "k_rope": k_rope}
