"""The language model: init / train forward / prefill / decode_step.

Segments scan over stacked layer params (config.py). Supports:
* token inputs, plus an optional continuous ``prefix_embed`` (the stub output
  of the vision/audio frontend for the vlm/audio architectures — the carve-out
  in the assignment);
* tied or untied unembedding;
* a deepseek-style multi-token-prediction (MTP) auxiliary head;
* MoE auxiliary load-balance losses accumulated across layers.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .blocks import (
    init_layer,
    init_layer_cache,
    init_layer_paged_cache,
    layer_decode,
    layer_paged_decode,
    layer_paged_prefill,
    layer_train,
)
from .config import LayerSpec, ModelConfig, Segment
from .layers import (
    embed,
    init_embedding,
    init_rmsnorm,
    rmsnorm,
    sinusoidal_pos,
    unembed,
)

PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    keys = jax.random.split(key, len(cfg.segments) + 3)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(keys[1], cfg.vocab_size, cfg.d_model, dtype)

    segs = []
    for si, seg in enumerate(cfg.segments):
        seg_key = keys[2 + si]
        pos_params = []
        for pi, spec in enumerate(seg.period):
            pk = jax.random.fold_in(seg_key, pi)
            stack = [
                init_layer(jax.random.fold_in(pk, r), cfg, spec, dtype)
                for r in range(seg.repeat)
            ]
            pos_params.append(jax.tree.map(lambda *xs: jnp.stack(xs), *stack))
        segs.append(pos_params)
    params["segments"] = segs

    if cfg.mtp_depth > 0:
        mtp_spec = cfg.segments[-1].period[-1]
        params["mtp"] = {
            "proj": (jax.random.normal(keys[-1], (2 * cfg.d_model, cfg.d_model)) * 0.02).astype(dtype),
            "layer": init_layer(jax.random.fold_in(keys[-1], 1), cfg, dataclasses.replace(mtp_spec), dtype),
            "norm": init_rmsnorm(cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, tokens, prefix_embed):
    x = embed(params["embed"], tokens)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)
    return x, positions


def forward(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    prefix_embed: Optional[jax.Array] = None,
    *,
    want_cache: bool = False,
    cache_len: int | None = None,
    last_logits_only: bool = False,
):
    """→ (logits (B,S,V) or (B,1,V), aux_loss, cache-or-None, hidden (B,S,d)).

    ``last_logits_only`` computes the unembedding for the final position only —
    the serving-prefill optimization (XLA does not push a slice through the
    (B,S,d)×(V,d) contraction on its own; §Perf `last_logits`)."""
    x, positions = _embed_inputs(params, cfg, tokens, prefix_embed)
    aux_total = jnp.zeros((), jnp.float32)
    caches = []

    # per-layer remat: only the residual stream survives between layers;
    # attention/FF internals are recomputed in the backward pass
    use_remat = cfg.remat and not want_cache

    def apply_layer(pp, spec, x_c, positions):
        def f(pp, x_c, positions):
            x_o, aux, cache = layer_train(
                pp, cfg, spec, x_c, positions,
                want_cache=want_cache, cache_len=cache_len,
            )
            return x_o, aux, cache

        if use_remat:
            f = jax.checkpoint(f)
        return f(pp, x_c, positions)

    for seg, pos_params in zip(cfg.segments, params["segments"]):
        if seg.repeat == 1:
            seg_caches = []
            for spec, pp in zip(seg.period, pos_params):
                p0 = jax.tree.map(lambda t: t[0], pp)
                x, aux, cache = apply_layer(p0, spec, x, positions)
                aux_total = aux_total + aux
                seg_caches.append(
                    jax.tree.map(lambda t: t[None], cache) if cache is not None else None
                )
            caches.append(seg_caches)
        else:
            def body(carry, slice_params, seg=seg):
                x_c, aux_c = carry
                step_caches = []
                for spec, pp in zip(seg.period, slice_params):
                    x_c, aux, cache = apply_layer(pp, spec, x_c, positions)
                    aux_c = aux_c + aux
                    step_caches.append(cache)
                return (x_c, aux_c), step_caches

            (x, aux_total), seg_caches = jax.lax.scan(
                body, (x, aux_total), pos_params
            )
            caches.append(seg_caches)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, x[:, -1:, :] if last_logits_only else x)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, aux_total, (caches if want_cache else None), x


def lm_loss(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    prefix_embed: Optional[jax.Array] = None,
) -> jax.Array:
    """Next-token cross-entropy over token positions (prefix excluded),
    + MoE aux loss + optional MTP auxiliary loss."""
    logits, aux, _, hidden = forward(params, cfg, tokens, prefix_embed)
    P = 0 if prefix_embed is None else prefix_embed.shape[1]
    tok_logits = logits[:, P:, :]
    pred = tok_logits[:, :-1]
    tgt = tokens[:, 1:]
    logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll) + aux

    if cfg.mtp_depth > 0 and tokens.shape[1] > 2:
        # DeepSeek-V3-style MTP: combine hidden_t with embed(token_{t+1}) to
        # predict token_{t+2} through one extra layer.
        h_tok = hidden[:, P:, :]
        h_in = h_tok[:, :-2, :]
        e_next = embed(params["embed"], tokens[:, 1:-1])
        z = jnp.concatenate([h_in, e_next], axis=-1) @ params["mtp"]["proj"]
        B, S2, _ = z.shape
        positions = jnp.broadcast_to(jnp.arange(S2, dtype=jnp.int32), (B, S2))
        spec = cfg.segments[-1].period[-1]
        z, mtp_aux, _ = layer_train(params["mtp"]["layer"], cfg, spec, z, positions)
        z = rmsnorm(z, params["mtp"]["norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        mtp_logits = unembed(table, z)
        mtp_tgt = tokens[:, 2:]
        mtp_logp = jax.nn.log_softmax(mtp_logits.astype(jnp.float32), axis=-1)
        mtp_nll = -jnp.take_along_axis(mtp_logp, mtp_tgt[..., None], axis=-1)[..., 0]
        loss = loss + 0.3 * jnp.mean(mtp_nll) + mtp_aux
    return loss


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, max_len: int, dtype=jnp.float32) -> PyTree:
    caches = []
    for seg in cfg.segments:
        seg_caches = []
        for spec in seg.period:
            one = init_layer_cache(cfg, spec, B, max_len, dtype)
            seg_caches.append(
                jax.tree.map(lambda t: jnp.broadcast_to(t[None], (seg.repeat, *t.shape)), one)
            )
        caches.append(seg_caches)
    return caches


def prefill(
    params: PyTree,
    cfg: ModelConfig,
    tokens: jax.Array,
    prefix_embed: Optional[jax.Array] = None,
    *,
    max_len: int | None = None,
    last_logits_only: bool = False,
):
    """Serve prefill: one forward pass that also lays out the decode cache,
    sized for ``max_len`` total positions. Returns (last logits (B,V), cache)."""
    logits, _, cache, _ = forward(
        params, cfg, tokens, prefix_embed, want_cache=True, cache_len=max_len,
        last_logits_only=last_logits_only,
    )
    return logits[:, -1, :], cache


def decode_step(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    token_t: jax.Array,  # (B,)
    pos,                 # scalar int32: absolute position of token_t
):
    """One serve step: token_t at position pos, attending to the cache.
    Returns (logits (B,V), new cache)."""
    x = embed(params["embed"], token_t[:, None])
    if cfg.pos_emb == "sinusoidal":
        B = x.shape[0]
        p = jnp.full((B, 1), pos, jnp.int32)
        x = x + sinusoidal_pos(p, cfg.d_model).astype(x.dtype)

    new_caches = []
    for seg, pos_params, seg_cache in zip(cfg.segments, params["segments"], cache):
        if seg.repeat == 1:
            new_seg = []
            for spec, pp, c in zip(seg.period, pos_params, seg_cache):
                p0 = jax.tree.map(lambda t: t[0], pp)
                c0 = jax.tree.map(lambda t: t[0], c)
                x, c_new = layer_decode(p0, cfg, spec, c0, x, pos)
                new_seg.append(jax.tree.map(lambda t: t[None], c_new))
            new_caches.append(new_seg)
        else:
            def body(x_c, slice_in, seg=seg):
                slice_params, slice_cache = slice_in
                new_slice = []
                for spec, pp, c in zip(seg.period, slice_params, slice_cache):
                    x_c, c_new = layer_decode(pp, cfg, spec, c, x_c, pos)
                    new_slice.append(c_new)
                return x_c, new_slice

            x, new_seg = jax.lax.scan(body, x, (pos_params, seg_cache))
            new_caches.append(new_seg)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, x)[:, 0, :]
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_caches


# ---------------------------------------------------------------------------
# paged serving (continuous batching, DESIGN.md §8)
# ---------------------------------------------------------------------------


def init_paged_cache(
    cfg: ModelConfig, npage: int, page_size: int, dtype=jnp.float32,
    *, quantized: bool = False,
) -> PyTree:
    """Per-layer KV page pools, same nesting as :func:`init_cache` but with
    (repeat, npage, P, KV, hd) leaves: every layer owns its pool, all layers
    share ONE block table (token t of slot s lives at the same (page, row)
    coordinate in every layer — core/paging.py). Global-attention mixers
    only; page 0 is the reserved null page."""
    caches = []
    for seg in cfg.segments:
        seg_caches = []
        for spec in seg.period:
            one = init_layer_paged_cache(
                cfg, spec, npage, page_size, dtype, quantized=quantized
            )
            seg_caches.append(
                jax.tree.map(lambda t: jnp.broadcast_to(t[None], (seg.repeat, *t.shape)), one)
            )
        caches.append(seg_caches)
    return caches


def paged_copy_pages(cache: PyTree, src: jax.Array, dst: jax.Array) -> PyTree:
    """Copy pool pages ``src[i] -> dst[i]`` in every layer's pool (the COW
    split: a shared page is duplicated before its new owner writes into it).
    ``src``/``dst`` are fixed-width (W,) int32 vectors padded with the null
    page — padded lanes copy page 0 onto itself, which is free garbage by
    design, so one compiled shape covers every split. Every pool leaf has
    the page axis at position 1 ((repeat, npage, ...) — init_paged_cache)."""
    return jax.tree.map(lambda leaf: leaf.at[:, dst].set(leaf[:, src]), cache)


def paged_gather_pages(cache: PyTree, ids: jax.Array) -> PyTree:
    """Snapshot pool pages ``ids`` (a (W,) int32 vector, null-padded) out of
    every layer's pool — the swap-out half of preemption. Returns a pytree
    of (repeat, W, ...) leaves the host parks until resume."""
    return jax.tree.map(lambda leaf: leaf[:, ids], cache)


def paged_scatter_pages(cache: PyTree, ids: jax.Array, snap: PyTree) -> PyTree:
    """Write a :func:`paged_gather_pages` snapshot back into pages ``ids`` —
    the resume half of preemption (fresh pages, identical content, so the
    resumed request's token stream is unchanged). Padded lanes write the
    null page."""
    return jax.tree.map(
        lambda leaf, s: leaf.at[:, ids].set(s.astype(leaf.dtype)), cache, snap
    )


def paged_decode_step(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    token_t: jax.Array,   # (S,)
    lengths: jax.Array,   # (S,) int32: tokens already cached per slot
    tables: jax.Array,    # (S, max_pages) int32 block tables
    *,
    backend: str = "auto",
):
    """One continuous-batching decode step: slot s's token at position
    ``lengths[s]`` (idle slots carry length 0 and null tables; their logits
    are garbage the scheduler ignores). Returns (logits (S,V), new cache)."""
    x = embed(params["embed"], token_t[:, None])
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos(lengths[:, None].astype(jnp.int32), cfg.d_model).astype(x.dtype)

    new_caches = []
    for seg, pos_params, seg_cache in zip(cfg.segments, params["segments"], cache):
        if seg.repeat == 1:
            new_seg = []
            for spec, pp, c in zip(seg.period, pos_params, seg_cache):
                p0 = jax.tree.map(lambda t: t[0], pp)
                c0 = jax.tree.map(lambda t: t[0], c)
                x, c_new = layer_paged_decode(
                    p0, cfg, spec, c0, x, lengths, tables, backend=backend
                )
                new_seg.append(jax.tree.map(lambda t: t[None], c_new))
            new_caches.append(new_seg)
        else:
            def body(x_c, slice_in, seg=seg):
                slice_params, slice_cache = slice_in
                new_slice = []
                for spec, pp, c in zip(seg.period, slice_params, slice_cache):
                    x_c, c_new = layer_paged_decode(
                        pp, cfg, spec, c, x_c, lengths, tables, backend=backend
                    )
                    new_slice.append(c_new)
                return x_c, new_slice

            x, new_seg = jax.lax.scan(body, x, (pos_params, seg_cache))
            new_caches.append(new_seg)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, x)[:, 0, :]
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_caches


def paged_prefill_chunk(
    params: PyTree,
    cfg: ModelConfig,
    cache: PyTree,
    tokens: jax.Array,    # (1, C): prompt tokens [start, start+C), padded
    start,                # scalar int32: first position of this chunk
    table_row: jax.Array, # (max_pages,) int32: the request's block-table row
    n_valid,              # scalar int32: real tokens in this chunk (≤ C)
    *,
    backend: str = "auto",
):
    """One chunked-prefill dispatch for ONE request: embeds the chunk, writes
    its k/v rows into the request's pages, and attends causally over the
    request's whole cached prefix. Returns (logits (V,) at the chunk's last
    valid position, new cache) — the logits matter only on the final chunk,
    where they seed the first generated token."""
    x = embed(params["embed"], tokens)
    C = tokens.shape[1]
    if cfg.pos_emb == "sinusoidal":
        pos = (start + jnp.arange(C, dtype=jnp.int32))[None]
        x = x + sinusoidal_pos(pos, cfg.d_model).astype(x.dtype)

    new_caches = []
    for seg, pos_params, seg_cache in zip(cfg.segments, params["segments"], cache):
        if seg.repeat == 1:
            new_seg = []
            for spec, pp, c in zip(seg.period, pos_params, seg_cache):
                p0 = jax.tree.map(lambda t: t[0], pp)
                c0 = jax.tree.map(lambda t: t[0], c)
                x, c_new = layer_paged_prefill(
                    p0, cfg, spec, c0, x, start, table_row, n_valid,
                    backend=backend,
                )
                new_seg.append(jax.tree.map(lambda t: t[None], c_new))
            new_caches.append(new_seg)
        else:
            def body(x_c, slice_in, seg=seg):
                slice_params, slice_cache = slice_in
                new_slice = []
                for spec, pp, c in zip(seg.period, slice_params, slice_cache):
                    x_c, c_new = layer_paged_prefill(
                        pp, cfg, spec, c, x_c, start, table_row, n_valid,
                        backend=backend,
                    )
                    new_slice.append(c_new)
                return x_c, new_slice

            x, new_seg = jax.lax.scan(body, x, (pos_params, seg_cache))
            new_caches.append(new_seg)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    h_last = jax.lax.dynamic_index_in_dim(x[0], n_valid - 1, 0, keepdims=True)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(table, h_last[None])[0, 0]
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_caches


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
