"""Primitive layers: norms, embeddings, positional encodings, dense MLPs.

Pure-functional: ``init_*`` builds param subtrees, ``apply`` functions consume
them. Parameter names follow the sharding-rule conventions in
launch/sharding.py (``w_in``-style names get their last dim model-sharded,
``w_out`` its first, embeddings shard the vocab dim).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return _normal(key, (d_in, d_out), scale, dtype)


def init_rmsnorm(d: int, dtype):
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def init_embedding(key, vocab: int, d: int, dtype):
    return _normal(key, (vocab, d), d**-0.5, dtype)


def embed(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """Logits via the (possibly tied) embedding table: x (…, d) → (…, V)."""
    return jnp.einsum("...d,vd->...v", x, table)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd) with hd even; positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jax.Array, d: int) -> jax.Array:
    """(…, S) → (…, S, d) classic transformer sinusoids (musicgen)."""
    half = d // 2
    freqs = 1.0 / (10_000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d, f, dtype),
        "w_up": init_dense(k2, d, f, dtype),
        "w_down": init_dense(k3, f, d, dtype),
    }


def mlp(params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]
