"""Federated round assembly: PP-MARINA cohort rounds on the mesh.

The partial-participation round path (Alg. 4, DESIGN.md §4.8) split out of
launch/distributed.py by the ISSUE 7 layering: ``build_train_steps`` calls
:func:`build_pp_steps` to override its compressed/train steps when
``participation=(r, scheme)`` is set. Sync rounds are untouched (all n
clients ship dense gradients); compressed rounds take the cohort row
``sel`` from :func:`pp_cohort_schedule`, respread the r sampled clients'
batch rows over all n worker shards, and put exactly r payload rows on the
wire through the transport interface (flat-PP engine bookings included).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import flat as flat_engine
from repro.core.marina import _FAULT_FOLD, _pp_carry_refresh, _uplink_faults
from repro.launch import sharding as shd
from repro.launch.topology import cohort_group_size


def pp_cohort_schedule(
    base_key: jax.Array, n_steps: int, n: int, r: int,
    scheme: str = "without",
) -> jax.Array:
    """Precompute the (n_steps, r) PP cohort table — the prefetch side of the
    participation wire (DESIGN.md §4.8).

    Row k is EXACTLY the cohort the core ``PPMarina`` step draws from the
    step key ``fold_in(base_key, k)`` (the same 3-way ``(bern, sel, q)``
    split), so a precomputed schedule keeps distributed rounds
    trajectory-equal to the single-process reference while hoisting the
    sampling off the round's critical path: the k+1 batch-row gather can be
    issued while round k's epilogue is still in flight.
    """
    from repro.core.marina import pp_sample_cohort

    assert scheme in ("with", "without"), scheme

    def one(step):
        k = jax.random.fold_in(base_key, step)
        _, k_sel, _ = jax.random.split(k, 3)
        return pp_sample_cohort(k_sel, n, r, replace=(scheme == "with"))

    return jax.vmap(one)(jnp.arange(n_steps, dtype=jnp.int32))


def build_pp_steps(
    participation,
    *,
    n: int,
    per_worker: int,
    p: float,
    block: int,
    kb: int,
    shared_mask: bool,
    compression: str,
    compression_backend: str,
    qsgd_s: int,
    replicate_params: bool,
    inner: tuple,
    param_shapes,
    p_shard,
    batch_shard,
    mesh,
    transport,
    downlink: str,
    robust: bool,
    aggregator,
    faults,
    grad_carry: bool,
    sync_step,
    worker_grads,
    descend,
    robust_delta,
):
    """Build the PP compressed/train steps over the shared round plumbing.

    Everything numeric is the caller's: ``sync_step`` / ``worker_grads`` /
    ``descend`` / ``robust_delta`` close over the model and transport built
    in ``build_train_steps``; this function only assembles the cohort
    compute and the r-row wire around them. Returns
    ``(compressed_step, train_step, meta)`` where ``meta`` records the
    participation mode, cohort-compute vs masked fallback, and flat-PP
    decisions.
    """
    r_part, scheme = participation
    assert scheme in ("with", "without"), scheme
    assert 1 <= r_part <= n, f"cohort r={r_part} vs n={n} workers"
    assert not shared_mask, (
        "participation composes with randk/permk/qsgd, not shared_mask "
        "(a shared mask already correlates the whole fleet)"
    )
    # cohort-mapped compute needs the r clients' rows to respread evenly
    # over the n worker shards in whole tokens-per-shard units
    grp = cohort_group_size(n, r_part)
    cohort_compute = grp is not None and (per_worker * r_part) % n == 0
    # flat-PP: where packing cannot force a reshard (same predicate as
    # flat_sync auto), the r-row payload pipeline IS the core engine —
    # pack → sampler → aggregate with the identical key/seed derivation,
    # which is what makes mesh rounds trajectory-equal to core PPMarina.
    flat_pp = replicate_params or not inner
    pp_eng = None
    if flat_pp and compression in ("randk", "permk", "qsgd"):
        if compression == "permk" and block % r_part != 0:
            flat_pp = False
        else:
            # seed_constraint pins the threefry seed derivation
            # replicated: the SPMD partitioner otherwise re-partitions
            # the split→bits chain and yields different seed VALUES
            # than one device — the silent killer of core↔mesh
            # trajectory equality (core/flat.py).
            pp_eng = flat_engine.make_engine(
                param_shapes, kb=kb, block=block,
                backend=compression_backend, sampler=compression,
                s=qsgd_s,
            )
            pp_eng = dataclasses.replace(
                pp_eng, seed_constraint=shd.replicated(mesh)
            )
    else:
        flat_pp = False

    def cohort_grads(x, batch, sel):
        """Per-client gradients of the r sampled clients.

        Cohort-mapped: gather the r clients' batch rows, respread them
        over all n shards (each shard backprops per_worker·r/n tokens —
        compute is r/n of a full round), then group-mean the n shard
        grads back to r client grads (equal sub-batch sizes make the
        mean of means exact). Masked fallback: every shard backprops its
        own full batch and only the r sampled rows are kept."""
        if cohort_compute:
            sub = (per_worker * r_part) // n
            sel_b = jax.tree.map(
                lambda t: t[sel].reshape(n, sub, *t.shape[2:]), batch
            )
            sel_b = jax.tree.map(
                jax.lax.with_sharding_constraint, sel_b, batch_shard
            )
            wg = worker_grads(x, sel_b)
            return jax.tree.map(
                lambda t: jnp.mean(
                    t.reshape(r_part, grp, *t.shape[1:]), axis=1
                ),
                wg,
            )
        wg = worker_grads(x, batch)
        return jax.tree.map(lambda t: t[sel], wg)

    def pp_delta(key, diffs):
        """(1/r)·Σ Q(Δ_i) over the r cohort payload rows (the GAR over
        the cohort's decoded rows when robust) + downlink."""
        k_up, k_down = jax.random.split(key)
        k_up = k_up if downlink != "none" else key
        if flat_pp:
            # the flat engine stages this exchange itself, so the
            # transport can't see it — book the r·ζ_Q uplink explicitly
            # from the engine's own wire accounting
            transport.book(
                "up",
                "all-to-all" if compression == "permk" else "all-gather",
                r_part * pp_eng.payload_bits(r_part) / n,
            )
            bufs = flat_engine.pack_stacked(pp_eng.layout, diffs)
            delta = flat_engine.unpack(
                pp_eng.layout,
                pp_eng.aggregate(k_up, bufs, r_part, aggregator),
            )
            delta = jax.tree.map(
                jax.lax.with_sharding_constraint, delta, p_shard
            )
        elif robust:
            delta = robust_delta(k_up, diffs, r_part)
        else:
            # sharded fallback: the per-leaf staged wire on the r-row
            # payload stack (cohort rows replicate — r·ζ, not n·ζ)
            delta = transport.uplink_mean(
                k_up, diffs, rows_n=r_part, rows_sharded=False,
                out_shardings=p_shard,
            )
        return transport.downlink(k_down, delta)

    if grad_carry:
        # h is the SERVER-SIDE CARRY TABLE: all n rows live on the mesh,
        # compressed rounds refresh only the sampled ones.
        def compressed_step(params, g, h, batch, key, sel):
            x_new = descend(params, g)
            cg = cohort_grads(x_new, batch, sel)
            h_sel = jax.tree.map(lambda t: t[sel], h)
            diffs = jax.tree.map(jnp.subtract, cg, h_sel)
            diffs = _uplink_faults(
                faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                sel, n,
            )
            g_new = jax.tree.map(jnp.add, g, pp_delta(key, diffs))
            # sampled rows refresh — except dropped clients, whose row
            # the server never received (core _pp_carry_refresh)
            h_new = _pp_carry_refresh(h, sel, cg, faults, n)
            return x_new, g_new, h_new

        def train_step(params, g, h, batch, key, sel):
            k_b, _, k_q = jax.random.split(key, 3)
            c_k = jax.random.bernoulli(k_b, p)
            return jax.lax.cond(
                c_k,
                lambda _: sync_step(params, g, h, batch),
                lambda _: compressed_step(params, g, h, batch, k_q, sel),
                None,
            )
    else:
        def compressed_step(params, g, batch, key, sel):
            x_new = descend(params, g)
            g_plus = cohort_grads(x_new, batch, sel)
            g_minus = cohort_grads(params, batch, sel)
            diffs = jax.tree.map(jnp.subtract, g_plus, g_minus)
            diffs = _uplink_faults(
                faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                sel, n,
            )
            g_new = jax.tree.map(jnp.add, g, pp_delta(key, diffs))
            return x_new, g_new

        def train_step(params, g, batch, key, sel):
            # the core PPMarina key discipline: (bern, sel, q) 3-way
            # split; the sel slot is consumed by pp_cohort_schedule.
            k_b, _, k_q = jax.random.split(key, 3)
            c_k = jax.random.bernoulli(k_b, p)
            return jax.lax.cond(
                c_k,
                lambda _: sync_step(params, g, batch),
                lambda _: compressed_step(params, g, batch, k_q, sel),
                None,
            )

    meta = {
        "participation": participation,
        "cohort_compute": cohort_compute,
        "flat_pp": flat_pp,
    }
    return compressed_step, train_step, meta
