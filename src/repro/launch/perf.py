import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing runner: re-lower a selected (arch × shape × mesh) pair
with one named variant applied, and record the roofline delta vs baseline.

Each variant encodes one hypothesis from EXPERIMENTS.md §Perf. Results land in
experiments/perf/<arch>__<shape>__<mesh>__<variant>.json and are rendered into
the §Perf log by scripts/update_perf.py.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --arch xlstm-350m \
      --shape train_4k --mesh single --variant replicate_params
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.launch import param_math
from repro.launch.dryrun import SHAPES, OUT_DIR
from repro.launch.topology import make_production_mesh, production_topology
from repro.roofline import (
    analyze_compiled,
    decode_bandwidth_bound_s,
    prefill_sharing_savings,
)

PERF_DIR = os.path.join(os.path.dirname(OUT_DIR), "perf")

# variant name -> (builder overrides, model-config replaces, arch replaces)
VARIANTS = {
    "baseline": ({}, {}, {}),
    # compression / collective schedule
    "shared_mask": ({"shared_mask": True}, {}, {}),
    "packed_payload": ({"packed_payload": True}, {}, {}),
    "shared_and_packed": ({"shared_mask": True, "packed_payload": True}, {}, {}),
    # correlated Perm-K: disjoint d/n shards, values-only exchange, γ = 1/L
    "permk_payload": ({"compression": "permk"}, {}, {}),
    "permk_packed": ({"compression": "permk", "packed_payload": True}, {}, {}),
    # packed quantization wire: dense s-level QSGD, int8 levels + f32 norms
    # (1 B/coord); qsgd4_packed ships 4-bit nibbles in uint32 (0.5 B/coord)
    "qsgd_payload": ({"compression": "qsgd"}, {}, {}),
    "qsgd4_packed": (
        {"compression": "qsgd", "packed_payload": True, "qsgd_s": 7}, {}, {},
    ),
    # round-pipeline overhaul (DESIGN.md §4.7)
    "grad_carry": ({"grad_carry": True}, {}, {}),
    "downlink_qsgd": ({"downlink": "qsgd", "downlink_s": 7}, {}, {}),
    "carry_down_qsgd": (
        {"grad_carry": True, "downlink": "qsgd", "downlink_s": 7}, {}, {},
    ),
    # sync-exchange A/B: force the packed flat-psum exchange on (it
    # auto-enables only for worker-pure/replicated meshes) vs force the
    # per-leaf exchange off
    "flat_sync": ({"flat_sync": True}, {}, {}),
    "tree_sync": ({"flat_sync": False}, {}, {}),
    # memory/compute policy
    "no_remat": ({"remat": False}, {}, {}),
    "f32_params": ({"dtype": jnp.float32}, {}, {}),
    # small-model distribution: model axis → within-worker data parallelism
    "replicate_params": ({"replicate_params": True}, {}, {}),
    # attention chunking
    "chunk_2048": ({}, {"attn_chunk": 2048}, {}),
    "chunk_512": ({}, {"attn_chunk": 512}, {}),
    # MoE capacity
    "cap_1.0": ({}, {}, {"moe_cap": 1.0}),
    # giant models: worker = pod+data (more workers, thinner shards)
    "workers_pod_data": ({}, {}, {"worker_axes": "pod_data"}),
    # serving: unembed only the final position during prefill
    "last_logits": ({"last_logits": True}, {}, {}),
    # serving: paged KV decode — continuous-batching pool sized at 50% mean
    # occupancy vs the dense n_slots × max_len cache (decode shapes only)
    "paged_decode": ({"paged": True}, {}, {}),
    # staged payload constraints (new default; variant isolates the delta
    # against the v1 baselines which lowered without staging)
    "staged_payload": ({}, {}, {}),
    "unstaged_payload": ({"staged_payload": False}, {}, {}),
    "staged_shared": ({"shared_mask": True}, {}, {}),
}


def run_variant(arch_name, shape_name, mesh_name, variant):
    from repro.launch.distributed import build_serve_steps, build_train_steps

    overrides, model_repl, arch_repl = VARIANTS[variant]
    arch = get_arch(arch_name)
    if model_repl:
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(arch.model, **model_repl)
        )
    if "moe_cap" in arch_repl and arch.model.moe is not None:
        moe = dataclasses.replace(arch.model.moe, capacity_factor=arch_repl["moe_cap"])
        arch = dataclasses.replace(arch, model=dataclasses.replace(arch.model, moe=moe))
    if "worker_axes" in arch_repl:
        arch = dataclasses.replace(arch, worker_axes=arch_repl["worker_axes"])

    spec = SHAPES[shape_name]
    multi_pod = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    topo = production_topology(multi_pod=multi_pod)
    n_dev = topo.n_devices

    if spec["kind"] == "train":
        bundle = build_train_steps(
            arch, mesh, multi_pod,
            global_batch=spec["global_batch"], seq_len=spec["seq_len"],
            topology=topo,   # book wire bits under the MODELED fabric's tiers
            **overrides,
        )
        tokens = spec["global_batch"] * spec["seq_len"]
        mf = param_math.model_flops(arch.model, tokens)
    elif overrides.get("paged"):
        from repro.launch.serve_steps import build_paged_serve_steps

        if spec["kind"] != "decode":
            raise ValueError("paged_decode variant requires a decode shape")
        n_slots, page_size = spec["global_batch"], 64
        max_pages = -(-spec["seq_len"] // page_size)
        # 50% mean occupancy (+ the reserved null page): the dense cache
        # streams n_slots × max_len KV rows per decode step regardless of
        # how full each slot is; the pool holds half that
        npage = 1 + (n_slots * max_pages) // 2
        bundle = build_paged_serve_steps(
            arch, mesh, multi_pod, n_slots=n_slots, npage=npage,
            page_size=page_size, max_pages=max_pages, chunk=page_size,
        )
        tokens = n_slots
        mf = param_math.model_flops(arch.model, tokens) / 3.0
        paged_pool = (npage, page_size, max_pages, n_slots)
    else:
        serve_over = {
            k: v for k, v in overrides.items() if k in ("dtype", "last_logits")
        }
        bundle = build_serve_steps(
            arch, mesh, multi_pod,
            batch=spec["global_batch"], seq_len=spec["seq_len"],
            mode=spec["kind"], **serve_over,
        )
        tokens = (
            spec["global_batch"] * spec["seq_len"]
            if spec["kind"] == "prefill" else spec["global_batch"]
        )
        mf = param_math.model_flops(arch.model, tokens) / 3.0

    result = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "steps": {},
    }

    kv_bytes = dense_kv_bytes = param_bytes = 0.0
    if overrides.get("paged"):
        from repro.models import init_cache, init_paged_cache

        def tree_bytes(shapes):
            return float(sum(
                l.size * l.dtype.itemsize for l in jax.tree.leaves(shapes)
            ))

        npage, page_size, max_pages, n_slots = paged_pool
        kv_bytes = tree_bytes(jax.eval_shape(
            lambda: init_paged_cache(arch.model, npage, page_size, jnp.bfloat16)
        ))
        dense_kv_bytes = tree_bytes(jax.eval_shape(
            lambda: init_cache(arch.model, n_slots, spec["seq_len"], jnp.bfloat16)
        ))
        param_bytes = float(param_math.count_params(arch.model)) * 2.0
    with bundle.mesh:
        for name, (fn, args) in bundle.fns.items():
            entry = {}
            try:
                t0 = time.time()
                compiled = fn.lower(*args).compile()
                entry["compile_s"] = time.time() - t0
                step_mf = mf * (2.0 if name == "compressed_step" else 1.0) \
                    if name != "train_step" else mf
                rep = analyze_compiled(
                    compiled, n_dev, model_flops_total=step_mf, topology=topo
                )
                entry.update(rep.to_dict())
                try:
                    ma = compiled.memory_analysis()
                    entry["memory_analysis"] = {
                        k: float(getattr(ma, k))
                        for k in (
                            "argument_size_in_bytes", "output_size_in_bytes",
                            "temp_size_in_bytes", "alias_size_in_bytes",
                        ) if hasattr(ma, k)
                    }
                except Exception:
                    pass
                if overrides.get("paged") and name == "paged_decode_step":
                    # analytic streaming floor for the step: the paged pool's
                    # live bytes vs the dense cache it replaces, collectives
                    # priced on the dominant-by-bytes link tier
                    stats = rep.collective
                    tier = (
                        max(stats.by_tier_bytes, key=stats.by_tier_bytes.get)
                        if stats.by_tier_bytes else "ici"
                    )
                    bound = decode_bandwidth_bound_s(
                        kv_bytes, param_bytes, n_dev, topology=topo,
                        collective_bytes=stats.per_device_bytes,
                        n_collectives=sum(stats.counts.values()), tier=tier,
                    )
                    dense = decode_bandwidth_bound_s(
                        dense_kv_bytes, param_bytes, n_dev, topology=topo,
                        collective_bytes=stats.per_device_bytes,
                        n_collectives=sum(stats.counts.values()), tier=tier,
                    )
                    bound["kv_bytes"] = kv_bytes
                    bound["dense_kv_bytes"] = dense_kv_bytes
                    bound["dense_bound_s"] = dense["bound_s"]
                    entry["decode_bound"] = bound
                    # COW prefix-sharing price for the shared-system-prompt
                    # regime on this pool: all n_slots residents share one
                    # seq_len prompt, so followers map the donor's pages
                    # instead of re-prefilling (DESIGN.md §8)
                    entry["prefix_sharing"] = prefill_sharing_savings(
                        tokens_unshared=float(n_slots * spec["seq_len"]),
                        tokens_shared=float(spec["seq_len"]),
                        flops_per_token=(
                            param_math.model_flops(arch.model, 1) / 3.0
                        ),
                        kv_bytes_per_token=kv_bytes / (npage * page_size),
                        n_devices=n_dev,
                    )
                entry["ok"] = True
            except Exception as e:
                entry["ok"] = False
                entry["error"] = f"{type(e).__name__}: {e}"
                entry["traceback"] = traceback.format_exc()[-3000:]
            result["steps"][name] = entry
    tr = getattr(bundle, "transport", None)
    if tr is not None and tr.ledger.bits:
        # the bytes-by-link-tier ledger of whatever the loop above traced
        result["wire_by_tier"] = tr.ledger.to_dict()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", required=True, choices=["single", "multi"])
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(PERF_DIR, exist_ok=True)
    path = os.path.join(
        PERF_DIR, f"{args.arch}__{args.shape}__{args.mesh}__{args.variant}.json"
    )
    if os.path.exists(path) and not args.force:
        print(f"skip {path}")
        return
    res = run_variant(args.arch, args.shape, args.mesh, args.variant)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    for sname, s in res["steps"].items():
        if s.get("ok"):
            print(
                f"{sname}: comp={s['compute_s']*1e3:.1f}ms mem={s['memory_s']*1e3:.1f}ms "
                f"coll={s['collective_s']*1e3:.1f}ms dom={s['dominant']}",
                flush=True,
            )
        else:
            print(f"{sname}: FAIL {s['error'][:300]}")


if __name__ == "__main__":
    main()
