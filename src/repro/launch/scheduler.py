"""Continuous-batching scheduler over the paged KV pool (DESIGN.md §8).

The scheduler owns the host-side bookkeeping: a FIFO admission queue, the
slot table, and the page pool / block tables / prefix index from
core/paging.py. Two admission policies:

* ``admission="reserve"`` — the PR-9 policy: a request is admitted only
  when a slot is free AND the pool can hand over every page it could ever
  touch (``ceil((prompt + max_new)/P)``), so an admitted request never
  hits mid-stream pool pressure. Safe, but a pool full of reservations
  for tokens that do not exist yet caps concurrency far below what the
  memory supports.
* ``admission="expected"`` (default) — admission is against the pages the
  request needs *now* (its unshared prompt pages); generation pages are
  allocated lazily as decode crosses page boundaries, and pool pressure
  is resolved by **preemption**: a victim's pages are swapped to a
  host-side store, released, and the victim re-queued at the head to
  resume later by re-mapping fresh pages. The victim policy never
  preempts the lowest-index occupied slot, so that request always runs
  to completion and frees its pages — no deadlock by construction (its
  worst-case demand is bounded by ``submit``'s checks).

**Prefix sharing (COW).** With ``share_prefix=True`` (requires
``admission="expected"``), admission consults the PrefixIndex: prompt
pages whose content is already resident are *forked* into the new row
(refcount++) instead of re-prefilled — aliasing is purely block-table
content, so the device path is untouched and bit-exact. Every write
(prefill chunk or decode token) first runs ``prepare_write``: a target
page that is still NULL is allocated lazily, and a target page with
refcount > 1 is **COW-split** — a fresh page is allocated, the engine
copies the old page's content on device, the row entry is repointed, and
the old page's refcount drops. The final prompt position is never mapped
from the index (``match`` is capped at ``prompt_len - 1``) because its
prefill logits seed the first generated token.

The engine turns the bookkeeping into dispatches: per iteration it joins
at most one prefill chunk (the longest-admitted unfinished prompt) into
the running batch and then runs ONE decode step over all slots — a single
jitted donated-cache dispatch regardless of how many requests are in
flight. Slots that are idle or still prefilling ride along with a nulled
block-table row: their decode write lands in the reserved null page
(page 0) and their logits are ignored, so no masking is needed on the
device path.

Completion releases the request's pages (refcount--, freeing the
exclusive ones) and clears its slot, making room for the next admission —
requests join and leave the batch every step, which is exactly the
continuous-vs-static tokens/s win BENCH_serve measures.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.paging import (
    NULL_PAGE,
    BlockTables,
    PagePool,
    PagedLayout,
    PoolExhausted,
    PrefixIndex,
)


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a generation budget."""

    rid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int                # tokens to generate (including the first)

    # engine bookkeeping (filled in as the request moves through the system)
    slot: int = -1
    prefill_done: int = 0       # prompt tokens already written to the cache
    shared_tokens: int = 0      # prompt tokens mapped from the prefix index
    generated: list = dataclasses.field(default_factory=list)
    registered: bool = False    # prompt pages published to the prefix index
    preemptions: int = 0
    # swap-out state: (row page-indices, physical ids at swap time, snapshot)
    swap: Optional[tuple] = None
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0        # first generated token
    t_done: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefilling(self) -> bool:
        return self.prefill_done < self.prompt_len

    @property
    def decoding(self) -> bool:
        return not self.prefilling and len(self.generated) < self.max_new


class ContinuousScheduler:
    """Slot/pool/prefix bookkeeping behind the continuous-batching engine.

    ``admission`` picks "reserve" (full up-front reservation, PR-9) or
    "expected" (immediate-need admission + lazy allocation + preemption);
    ``share_prefix`` turns on COW prefix sharing (expected admission only —
    a COW split transiently needs one extra page, which a fully-reserved
    pool cannot promise).
    """

    def __init__(
        self,
        layout: PagedLayout,
        *,
        admission: str = "expected",
        share_prefix: bool = False,
    ):
        if admission not in ("reserve", "expected"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if share_prefix and admission == "reserve":
            raise ValueError(
                "share_prefix requires admission='expected': a COW split "
                "transiently needs one extra free page, which full "
                "reservation cannot guarantee"
            )
        self.layout = layout
        self.admission = admission
        self.share_prefix = share_prefix
        self.pool = PagePool(layout)
        self.tables = BlockTables(layout)
        self.prefix_index = PrefixIndex(layout)
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * layout.n_slots
        self.finished: list[Request] = []
        self.shared_tokens_total = 0
        self.preemptions = 0
        self.cow_splits = 0

    def submit(self, req: Request, now: float = 0.0) -> None:
        need = self.layout.pages_for(req.prompt_len + req.max_new)
        if need > self.layout.usable_pages:
            raise ValueError(
                f"request {req.rid} needs {need} pages; pool has "
                f"{self.layout.usable_pages} total"
            )
        if need > self.layout.max_pages:
            raise ValueError(
                f"request {req.rid} needs {need} pages; block-table rows hold "
                f"{self.layout.max_pages}"
            )
        req.t_submit = now
        self.queue.append(req)

    # -- admission ----------------------------------------------------------

    def _row_pages(self, slot: int) -> List[Tuple[int, int]]:
        """Non-null (page-index, physical id) entries of a slot's row."""
        row = self.tables.row(slot)
        return [(i, int(p)) for i, p in enumerate(row) if int(p) != NULL_PAGE]

    def _admit_fresh(self, req: Request, slot: int) -> bool:
        """Map/allocate the request's prompt pages; False when short on pages."""
        shared_pages: List[int] = []
        shared_tokens = 0
        if self.share_prefix and req.prompt_len > 1:
            # cap at prompt_len - 1: the last prompt position must go through
            # prefill so its logits seed the first generated token
            shared_pages, shared_tokens = self.prefix_index.match(
                self.pool, req.prompt, req.prompt_len - 1
            )
        prompt_pages = self.layout.pages_for(req.prompt_len)
        fresh = prompt_pages - len(shared_pages)
        if self.admission == "reserve":
            need = self.layout.pages_for(req.prompt_len + req.max_new)
        else:
            need = fresh
        if self.pool.n_free < need:
            return False
        for p in shared_pages:
            self.pool.fork(p)
        new_pages = self.pool.alloc(need)
        self.tables.assign(slot, list(shared_pages) + new_pages)
        req.slot = slot
        req.shared_tokens = shared_tokens
        req.prefill_done = shared_tokens
        self.shared_tokens_total += shared_tokens
        self.slots[slot] = req
        return True

    def _admit_resume(self, req: Request, slot: int) -> bool:
        """Re-map a preempted request: fresh pages for its swapped snapshot
        (the engine scatters the saved content back before the next step)."""
        idxs, _old_ids, _snap = req.swap
        if self.pool.n_free < len(idxs):
            return False
        new_ids = self.pool.alloc(len(idxs))
        self.tables.clear(slot)
        for i, p in zip(idxs, new_ids):
            self.tables.set_entry(slot, i, p)
        req.slot = slot
        req.swap = (idxs, new_ids, req.swap[2])
        self.slots[slot] = req
        return True

    def admit(self, now: float = 0.0) -> list[Request]:
        """Admit queued requests while a slot is free and the pool covers the
        policy's page demand. FIFO: the head of the queue blocks admission
        (no starvation by smaller requests jumping ahead); preempted
        requests re-queue at the head, so they resume first."""
        admitted = []
        while self.queue:
            req = self.queue[0]
            slot = next(
                (i for i, s in enumerate(self.slots) if s is None), None
            )
            if slot is None:
                break
            ok = (
                self._admit_resume(req, slot)
                if req.swap is not None
                else self._admit_fresh(req, slot)
            )
            if not ok:
                break
            self.queue.popleft()
            req.t_admit = now
            admitted.append(req)
        return admitted

    def rematch_prefix(self, req: Request) -> None:
        """Retry the prefix match right before a request's FIRST prefill
        chunk. A follower admitted while its donor was still prefilling saw
        an empty index at admission; by the time the engine gets to the
        follower's first chunk the donor has registered (prefill is FIFO by
        admission time), and since the follower has written nothing yet,
        swapping its fresh prompt pages for shared ones is free."""
        if not self.share_prefix or req.prefill_done != req.shared_tokens:
            return
        if req.prompt_len <= 1:
            return
        pages, n = self.prefix_index.match(
            self.pool, req.prompt, req.prompt_len - 1
        )
        if n <= req.shared_tokens:
            return
        # fork the new mapping BEFORE releasing the old one: the old row may
        # itself be the last holder keeping some matched page alive
        for p in pages:
            self.pool.fork(p)
        for _, p in self._row_pages(req.slot):
            self.pool.release(p)
        self.tables.clear(req.slot)
        # cannot exhaust: the releases above returned at least as many
        # exclusive pages as the (smaller) fresh remainder needs
        fresh = self.pool.alloc(self.layout.pages_for(req.prompt_len) - len(pages))
        self.tables.assign(req.slot, list(pages) + fresh)
        self.shared_tokens_total += n - req.shared_tokens
        req.shared_tokens = n
        req.prefill_done = n

    # -- writes: lazy allocation + COW --------------------------------------

    def prepare_write(
        self, req: Request, start: int, n_tokens: int
    ) -> List[Tuple[int, int]]:
        """Make every page covering token positions ``[start, start+n)`` of
        ``req`` privately writable. NULL entries are allocated lazily;
        entries with refcount > 1 are COW-split: a fresh page is allocated
        and the row repointed, and the returned ``(src, dst)`` pairs tell
        the engine which device-side page copies to issue BEFORE the write
        dispatch. Raises PoolExhausted when the pool cannot cover it (the
        engine resolves that with a preemption and retries)."""
        if n_tokens <= 0:
            return []
        P = self.layout.page_size
        copies: List[Tuple[int, int]] = []
        first = start // P
        last = (start + n_tokens - 1) // P
        row = self.tables.row(req.slot)
        for idx in range(first, last + 1):
            cur = int(row[idx])
            if cur == NULL_PAGE:
                (new,) = self.pool.alloc(1)
                self.tables.set_entry(req.slot, idx, new)
            elif self.pool.refcount(cur) > 1:
                (new,) = self.pool.alloc(1)
                copies.append((cur, new))
                self.tables.set_entry(req.slot, idx, new)
                self.pool.release(cur)
                self.cow_splits += 1
        return copies

    # -- preemption / swap ---------------------------------------------------

    def pick_victim(self, requester: Request) -> Optional[Request]:
        """Victim for a preemption: the request in the HIGHEST-index occupied
        slot, excluding the requester and the lowest-index occupied slot.
        The lowest occupied slot is never preempted — it always runs to
        completion, so the pool always drains and admission always resumes
        (liveness by induction). Returns None when no candidate exists
        (the engine then self-preempts the requester, unless the requester
        itself is the protected slot)."""
        occupied = [i for i, s in enumerate(self.slots) if s is not None]
        if not occupied:
            return None
        protected = occupied[0]
        for i in reversed(occupied):
            if i == protected or self.slots[i] is requester:
                continue
            return self.slots[i]
        return None

    def swap_out(self, victim: Request, snapshot=None, now: float = 0.0) -> None:
        """Release the victim's pages and slot; park its (page-index,
        physical-id, snapshot) triple for resume. The engine gathers the
        snapshot from the device BEFORE calling this (released exclusive
        pages go straight back on the free list)."""
        entries = self._row_pages(victim.slot)
        idxs = [i for i, _ in entries]
        ids = [p for _, p in entries]
        for p in ids:
            self.pool.release(p)
        self.tables.clear(victim.slot)
        self.slots[victim.slot] = None
        victim.slot = -1
        victim.swap = (idxs, ids, snapshot)
        victim.preemptions += 1
        self.preemptions += 1
        # resume FIRST: FIFO head blocks, so a preempted request can never
        # be starved by fresh arrivals
        self.queue.appendleft(victim)

    def resume_ids(self, req: Request) -> tuple:
        """(fresh ids mapped at re-admission, host snapshot) for the engine's
        scatter; clears the swap state."""
        idxs, new_ids, snapshot = req.swap
        req.swap = None
        return new_ids, snapshot

    # -- completion ----------------------------------------------------------

    def register_prefix(self, req: Request) -> None:
        """Publish a fully-prefilled prompt's pages to the prefix index (a
        later identical/extending prompt forks them instead of re-running
        prefill)."""
        if not self.share_prefix or req.registered or req.prefilling:
            return
        n = self.layout.pages_for(req.prompt_len)
        row = self.tables.row(req.slot)
        self.prefix_index.register(self.pool, req.prompt, [int(p) for p in row[:n]])
        req.registered = True

    def complete(self, req: Request, now: float = 0.0) -> None:
        """Release every page the request holds and free its slot (shared
        pages survive under their other holders' references)."""
        req.t_done = now
        for _, p in self._row_pages(req.slot):
            self.pool.release(p)
        self.tables.clear(req.slot)
        self.slots[req.slot] = None
        self.finished.append(req)

    @property
    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def decode_view(self):
        """(tokens, lengths, tables) device-ready arrays for one decode step.

        Only slots in the decode phase expose their real block-table row and
        length; idle and still-prefilling slots are nulled so their write
        lands in the trash page and their (garbage) logits cost nothing to
        ignore."""
        S = self.layout.n_slots
        toks = np.zeros((S,), np.int32)
        lengths = np.zeros((S,), np.int32)
        tables = np.full(
            (S, self.layout.max_pages), NULL_PAGE, np.int32
        )
        for s, req in enumerate(self.slots):
            if req is not None and req.decoding:
                toks[s] = req.generated[-1]
                lengths[s] = req.prompt_len + len(req.generated) - 1
                tables[s] = self.tables.row(s)
        return toks, lengths, tables


@dataclasses.dataclass
class ServeReport:
    """What BENCH_serve records for one run."""

    n_requests: int
    total_new_tokens: int
    wall_s: float
    tokens_per_s: float
    first_token_p50_ms: float
    first_token_p99_ms: float
    completion_p50_ms: float
    completion_p99_ms: float
    decode_steps: int
    prefill_chunks: int
    # prefix-sharing / preemption telemetry (zero on the plain path)
    prefill_tokens: int = 0
    shared_tokens: int = 0
    cow_splits: int = 0
    preemptions: int = 0
    swapped_pages: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _pct(xs: list, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


class ContinuousEngine:
    """Drives jitted paged steps from the scheduler's bookkeeping.

    ``prefill_fn(cache, tokens (1,C), start, table_row, n_valid)`` and
    ``decode_fn(cache, tokens (S,), lengths (S,), tables (S,maxp))`` both
    return ``(sampled_tokens, new_cache)`` with the cache donated — the
    engine threads one live cache value through every dispatch.

    The sharing/preemption machinery needs three more device hooks, all
    over fixed ``(W,)`` id vectors (W = max_pages) padded with the null
    page so one compiled shape covers every call — padded lanes write the
    trash page by design:

    * ``copy_fn(cache, src, dst)`` — COW split: copy pages src[i] → dst[i];
    * ``gather_fn(cache, ids)`` — swap-out: snapshot pages to host;
    * ``scatter_fn(cache, ids, snap)`` — resume: write a snapshot back.

    Without them the engine still runs (reserve admission, no sharing);
    a preemption that needs a missing hook degrades to dropping the
    victim's cache content, which only the fake-model tests do.
    """

    def __init__(
        self,
        scheduler: ContinuousScheduler,
        cache,
        prefill_fn: Callable,
        decode_fn: Callable,
        *,
        chunk: int,
        eos_id: Optional[int] = None,
        clock: Callable[[], float] = time.perf_counter,
        copy_fn: Optional[Callable] = None,
        gather_fn: Optional[Callable] = None,
        scatter_fn: Optional[Callable] = None,
    ):
        self.sched = scheduler
        self.cache = cache
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.chunk = chunk
        self.eos_id = eos_id
        self.clock = clock
        self.copy_fn = copy_fn
        self.gather_fn = gather_fn
        self.scatter_fn = scatter_fn
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.prefill_tokens = 0
        self.swapped_pages = 0

    # -- page pressure -------------------------------------------------------

    def _pad_ids(self, ids: list) -> np.ndarray:
        W = self.sched.layout.max_pages
        out = np.full((W,), NULL_PAGE, np.int32)
        out[:len(ids)] = np.asarray(ids, np.int32)
        return out

    def _apply_copies(self, copies: list) -> None:
        if not copies:
            return
        if self.copy_fn is None:
            raise RuntimeError(
                "COW split required but the engine has no copy_fn "
                "(share_prefix engines must pass one)"
            )
        src = self._pad_ids([s for s, _ in copies])
        dst = self._pad_ids([d for _, d in copies])
        self.cache = self.copy_fn(self.cache, src, dst)

    def _swap_out(self, victim: Request) -> None:
        ids = [p for _, p in self.sched._row_pages(victim.slot)]
        snapshot = None
        if self.gather_fn is not None:
            snapshot = self.gather_fn(self.cache, self._pad_ids(ids))
        self.swapped_pages += len(ids)
        self.sched.swap_out(victim, snapshot, self.clock())

    def _ensure_writable(self, req: Request, start: int, n_tokens: int) -> bool:
        """prepare_write with preemption on pool pressure; returns False when
        the REQUESTER itself was self-preempted (skip its dispatch)."""
        while True:
            try:
                copies = self.sched.prepare_write(req, start, n_tokens)
            except PoolExhausted:
                victim = self.sched.pick_victim(req)
                if victim is None:
                    occupied = [
                        i for i, s in enumerate(self.sched.slots) if s is not None
                    ]
                    if occupied and self.sched.slots[occupied[0]] is req:
                        # the protected slot itself cannot be satisfied: the
                        # pool is genuinely too small for one request, which
                        # submit() rejects — this is unreachable by contract
                        raise
                    self._swap_out(req)
                    return False
                self._swap_out(victim)
                continue
            self._apply_copies(copies)
            return True

    def _resume_if_swapped(self, req: Request) -> None:
        if req.swap is None or req.slot < 0:
            return
        new_ids, snapshot = self.sched.resume_ids(req)
        if snapshot is not None and self.scatter_fn is not None:
            self.cache = self.scatter_fn(
                self.cache, self._pad_ids(new_ids), snapshot
            )

    # -- dispatches ----------------------------------------------------------

    def _prefill_one(self) -> None:
        """One chunk of the longest-admitted request still prefilling."""
        cands = [r for r in self.sched.active if r.prefilling]
        if not cands:
            return
        req = min(cands, key=lambda r: r.t_admit)
        self.sched.rematch_prefix(req)
        start = req.prefill_done
        nv = min(self.chunk, req.prompt_len - start)
        if not self._ensure_writable(req, start, nv):
            return
        toks = np.zeros((1, self.chunk), np.int32)
        toks[0, :nv] = req.prompt[start:start + nv]
        row = self.sched.tables.row(req.slot)
        tok, self.cache = self.prefill_fn(
            self.cache, toks, np.int32(start), row.astype(np.int32),
            np.int32(nv),
        )
        self.prefill_chunks += 1
        self.prefill_tokens += nv
        req.prefill_done = start + nv
        if not req.prefilling:
            self.sched.register_prefix(req)
            req.generated.append(int(tok))
            req.t_first = self.clock()
            self._maybe_complete(req)

    def _decode_all(self) -> None:
        # every decoding slot writes its last token's k/v at position
        # lengths[s] = prompt_len + n_generated - 1: make that page private
        # (lazy-alloc or COW) before the batched dispatch
        for req in list(self.sched.active):
            # a request visited earlier in this loop may have preempted this
            # one (slot cleared) — skip it, it re-queued for resume
            if req is not None and req.decoding and req.slot >= 0:
                pos = req.prompt_len + len(req.generated) - 1
                self._ensure_writable(req, pos, 1)
        toks, lengths, tables = self.sched.decode_view()
        if not int((lengths > 0).sum()):
            return
        out, self.cache = self.decode_fn(self.cache, toks, lengths, tables)
        self.decode_steps += 1
        out = np.asarray(out)
        now = self.clock()
        for s, req in enumerate(list(self.sched.slots)):
            if req is not None and req.decoding and lengths[s] > 0:
                req.generated.append(int(out[s]))
                self._maybe_complete(req, now)

    def _maybe_complete(self, req: Request, now: Optional[float] = None) -> None:
        done = len(req.generated) >= req.max_new or (
            self.eos_id is not None and req.generated[-1] == self.eos_id
        )
        if done:
            self.sched.complete(req, now if now is not None else self.clock())

    def run(self, requests: list[Request]) -> ServeReport:
        """Serve every request to completion; return the latency report."""
        t0 = self.clock()
        for req in requests:
            self.sched.submit(req, t0)
        while self.sched.busy:
            for req in self.sched.admit(self.clock()):
                self._resume_if_swapped(req)
            self._prefill_one()
            self._decode_all()
        wall = self.clock() - t0
        done = self.sched.finished
        total = sum(len(r.generated) for r in done)
        first = [(r.t_first - r.t_submit) * 1e3 for r in done]
        comp = [(r.t_done - r.t_submit) * 1e3 for r in done]
        return ServeReport(
            n_requests=len(done),
            total_new_tokens=total,
            wall_s=wall,
            tokens_per_s=total / wall if wall > 0 else 0.0,
            first_token_p50_ms=_pct(first, 50),
            first_token_p99_ms=_pct(first, 99),
            completion_p50_ms=_pct(comp, 50),
            completion_p99_ms=_pct(comp, 99),
            decode_steps=self.decode_steps,
            prefill_chunks=self.prefill_chunks,
            prefill_tokens=self.prefill_tokens,
            shared_tokens=self.sched.shared_tokens_total,
            cow_splits=self.sched.cow_splits,
            preemptions=self.sched.preemptions,
            swapped_pages=self.swapped_pages,
        )
