"""Continuous-batching scheduler over the paged KV pool (DESIGN.md §8).

The scheduler owns the host-side bookkeeping: a FIFO admission queue, the
slot table, and the page pool / block tables from core/paging.py. Admission
is by *reservation* — a request is admitted only when a slot is free AND the
pool can hand over every page the request could ever touch
(``ceil((prompt + max_new) / P)``), so an admitted request never hits a
mid-stream pool-exhausted preemption.

The engine turns that bookkeeping into dispatches: per iteration it joins at
most one prefill chunk (the longest-admitted unfinished prompt) into the
running batch and then runs ONE decode step over all slots — a single jitted
donated-cache dispatch regardless of how many requests are in flight. Slots
that are idle or still prefilling ride along with a nulled block-table row:
their decode write lands in the reserved null page (page 0) and their logits
are ignored, so no masking is needed on the device path.

Completion (``n_generated == max_new`` or EOS) frees the request's pages
back to the pool and clears its slot, making room for the next admission —
requests join and leave the batch every step, which is exactly the
continuous-vs-static tokens/s win BENCH_serve measures.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np

from repro.core.paging import NULL_PAGE, BlockTables, PagePool, PagedLayout


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a generation budget."""

    rid: int
    prompt: np.ndarray          # (prompt_len,) int32
    max_new: int                # tokens to generate (including the first)

    # engine bookkeeping (filled in as the request moves through the system)
    slot: int = -1
    pages: tuple = ()
    prefill_done: int = 0       # prompt tokens already written to the cache
    generated: list = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0        # first generated token
    t_done: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefilling(self) -> bool:
        return self.prefill_done < self.prompt_len

    @property
    def decoding(self) -> bool:
        return not self.prefilling and len(self.generated) < self.max_new


class ContinuousScheduler:
    """FIFO admission with up-front page reservation; slot/pool bookkeeping."""

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self.pool = PagePool(layout)
        self.tables = BlockTables(layout)
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * layout.n_slots
        self.finished: list[Request] = []

    def submit(self, req: Request, now: float = 0.0) -> None:
        need = self.layout.pages_for(req.prompt_len + req.max_new)
        if need > self.layout.usable_pages:
            raise ValueError(
                f"request {req.rid} needs {need} pages; pool has "
                f"{self.layout.usable_pages} total"
            )
        if need > self.layout.max_pages:
            raise ValueError(
                f"request {req.rid} needs {need} pages; block-table rows hold "
                f"{self.layout.max_pages}"
            )
        req.t_submit = now
        self.queue.append(req)

    def admit(self, now: float = 0.0) -> list[Request]:
        """Admit queued requests while a slot is free and the pool can cover
        the full reservation. FIFO: the head of the queue blocks admission
        (no starvation by smaller requests jumping ahead)."""
        admitted = []
        while self.queue:
            req = self.queue[0]
            slot = next(
                (i for i, s in enumerate(self.slots) if s is None), None
            )
            if slot is None:
                break
            need = self.layout.pages_for(req.prompt_len + req.max_new)
            if self.pool.n_free < need:
                break
            self.queue.popleft()
            req.pages = tuple(self.pool.alloc(need))
            req.slot = slot
            req.t_admit = now
            self.tables.assign(slot, req.pages)
            self.slots[slot] = req
            admitted.append(req)
        return admitted

    def complete(self, req: Request, now: float = 0.0) -> None:
        """Release every page the request reserved and free its slot."""
        req.t_done = now
        self.pool.free(req.pages)
        self.tables.clear(req.slot)
        self.slots[req.slot] = None
        req.pages = ()
        self.finished.append(req)

    @property
    def active(self) -> list[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def decode_view(self):
        """(tokens, lengths, tables) device-ready arrays for one decode step.

        Only slots in the decode phase expose their real block-table row and
        length; idle and still-prefilling slots are nulled so their write
        lands in the trash page and their (garbage) logits cost nothing to
        ignore."""
        S = self.layout.n_slots
        toks = np.zeros((S,), np.int32)
        lengths = np.zeros((S,), np.int32)
        tables = np.full(
            (S, self.layout.max_pages), NULL_PAGE, np.int32
        )
        for s, req in enumerate(self.slots):
            if req is not None and req.decoding:
                toks[s] = req.generated[-1]
                lengths[s] = req.prompt_len + len(req.generated) - 1
                tables[s] = self.tables.row(s)
        return toks, lengths, tables


@dataclasses.dataclass
class ServeReport:
    """What BENCH_serve records for one run."""

    n_requests: int
    total_new_tokens: int
    wall_s: float
    tokens_per_s: float
    first_token_p50_ms: float
    first_token_p99_ms: float
    completion_p50_ms: float
    completion_p99_ms: float
    decode_steps: int
    prefill_chunks: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _pct(xs: list, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


class ContinuousEngine:
    """Drives jitted paged steps from the scheduler's bookkeeping.

    ``prefill_fn(cache, tokens (1,C), start, table_row, n_valid)`` and
    ``decode_fn(cache, tokens (S,), lengths (S,), tables (S,maxp))`` both
    return ``(sampled_tokens, new_cache)`` with the cache donated — the
    engine threads one live cache value through every dispatch.
    """

    def __init__(
        self,
        scheduler: ContinuousScheduler,
        cache,
        prefill_fn: Callable,
        decode_fn: Callable,
        *,
        chunk: int,
        eos_id: Optional[int] = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.sched = scheduler
        self.cache = cache
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.chunk = chunk
        self.eos_id = eos_id
        self.clock = clock
        self.decode_steps = 0
        self.prefill_chunks = 0

    def _prefill_one(self) -> None:
        """One chunk of the longest-admitted request still prefilling."""
        cands = [r for r in self.sched.active if r.prefilling]
        if not cands:
            return
        req = min(cands, key=lambda r: r.t_admit)
        start = req.prefill_done
        nv = min(self.chunk, req.prompt_len - start)
        toks = np.zeros((1, self.chunk), np.int32)
        toks[0, :nv] = req.prompt[start:start + nv]
        row = self.sched.tables.row(req.slot)
        tok, self.cache = self.prefill_fn(
            self.cache, toks, np.int32(start), row.astype(np.int32),
            np.int32(nv),
        )
        self.prefill_chunks += 1
        req.prefill_done = start + nv
        if not req.prefilling:
            req.generated.append(int(tok))
            req.t_first = self.clock()
            self._maybe_complete(req)

    def _decode_all(self) -> None:
        toks, lengths, tables = self.sched.decode_view()
        if not int((lengths > 0).sum()):
            return
        out, self.cache = self.decode_fn(self.cache, toks, lengths, tables)
        self.decode_steps += 1
        out = np.asarray(out)
        now = self.clock()
        for s, req in enumerate(list(self.sched.slots)):
            if req is not None and req.decoding and lengths[s] > 0:
                req.generated.append(int(out[s]))
                self._maybe_complete(req, now)

    def _maybe_complete(self, req: Request, now: Optional[float] = None) -> None:
        done = len(req.generated) >= req.max_new or (
            self.eos_id is not None and req.generated[-1] == self.eos_id
        )
        if done:
            self.sched.complete(req, now if now is not None else self.clock())

    def run(self, requests: list[Request]) -> ServeReport:
        """Serve every request to completion; return the latency report."""
        t0 = self.clock()
        for req in requests:
            self.sched.submit(req, t0)
        while self.sched.busy:
            self.sched.admit(self.clock())
            self._prefill_one()
            self._decode_all()
        wall = self.clock() - t0
        done = self.sched.finished
        total = sum(len(r.generated) for r in done)
        first = [(r.t_first - r.t_submit) * 1e3 for r in done]
        comp = [(r.t_done - r.t_submit) * 1e3 for r in done]
        return ServeReport(
            n_requests=len(done),
            total_new_tokens=total,
            wall_s=wall,
            tokens_per_s=total / wall if wall > 0 else 0.0,
            first_token_p50_ms=_pct(first, 50),
            first_token_p99_ms=_pct(first, 99),
            completion_p50_ms=_pct(comp, 50),
            completion_p99_ms=_pct(comp, 99),
            decode_steps=self.decode_steps,
            prefill_chunks=self.prefill_chunks,
        )
