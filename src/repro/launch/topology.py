"""Topology layer — the device fabric under every launch-layer round.

This module answers three questions the round-assembly code used to answer
implicitly (or not at all):

1. **What does the fabric look like?** :class:`Topology` describes hosts,
   pods, and the link tier every mesh axis crosses — ``loopback`` (devices
   inside one process: the fake-device CPU simulation), ``ici`` (intra-pod
   chip interconnect), ``dcn`` (the cross-pod / cross-host data-center
   network — the bandwidth cliff MARINA's compressed wires were built
   for). Each tier carries an α–β cost model (:class:`LinkSpec`:
   per-collective-step latency α, bandwidth β) with a documented default
   table (:data:`DEFAULT_LINKS`).

2. **How do I get a mesh on it?** The mesh constructors (folded in from
   the old ``launch/mesh.py``) stay functions — importing this module never
   touches jax device state — and :func:`detect_topology` classifies any
   mesh's axes against the *runtime* process layout (an axis whose devices
   span OS processes on CPU is a dcn axis: cross-process is exactly the
   slow link the local cluster simulates).

3. **How do multiple processes come up?** :func:`initialize_multiprocess`
   wraps ``jax.distributed.initialize`` (gloo CPU collectives included),
   :func:`init_from_env` reads the ``MARINA_MP_*`` contract, and
   :func:`spawn_local_cluster` stands up an N-process local cluster in
   subprocesses — the bring-up path tests/CI and the multiproc benchmark
   share (``tests/test_multiproc.py``, ``benchmarks.run --only
   roundstep_mp``).

The transport layer (`launch/transport.py`) consumes the topology to book
every payload collective's bits under the right tier; `roofline/analysis.py`
consumes it to price collectives α–β per tier instead of one flat ICI
bandwidth. DESIGN.md §7 is the contract.

Demo (2-process local cluster, one psum + topology report per process):

    PYTHONPATH=src python -m repro.launch.topology --processes 2
"""

from __future__ import annotations

import dataclasses
import os
import socket
import subprocess
import sys
import time
from typing import Optional

PROCESS_ENV = "MARINA_MP_PROCESS"       # "<process_id>/<num_processes>"
COORD_ENV = "MARINA_MP_COORDINATOR"     # "host:port"

# crash/recovery contract (DESIGN.md §4.10): the resilient runner and the
# worker programs communicate through these —
CRASH_ENV = "MARINA_MP_CRASH"           # "<rank>@<round>": hard-exit there
DEAD_ENV = "MARINA_MP_DEAD"             # "2,3": client ids lost to a crash
RESUME_ENV = "MARINA_MP_RESUME"         # first round the dead set applies

#: per-round liveness marker worker programs print (rank 0 AND every other
#: rank) after completing each round; the resilient runner reads the stream
#: back to locate the last fleet-wide completed round after a crash.
HEARTBEAT = "MARINA_HB"

#: link-tier names, fastest to slowest (mirrors repro.core.wire.LINK_TIERS)
TIERS = ("loopback", "ici", "dcn")


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """α–β cost model of one link tier: a collective over the tier costs
    ``steps·alpha_s + wire_bytes/bw`` (ring accounting supplies the wire
    bytes and the step count — roofline/analysis.py)."""

    alpha_s: float          # latency per collective step (seconds)
    bw: float               # bandwidth per device (bytes/s)


#: Default α–β table (DESIGN.md §7). Sources: loopback ≈ one HBM-speed
#: memcpy between fake devices in one address space; ici = TPU v5e ~50 GB/s
#: per link, ~1 µs hop latency; dcn = commodity 50 Gbit/s NIC per host
#: (6.25 GB/s) with ~25 µs round-trip software latency. These are modeling
#: constants, not measurements — the REFUTED-style check in
#: roofline/analysis.py flags any recorded variant that disagrees with the
#: model by more than 2×.
DEFAULT_LINKS: dict = {
    "loopback": LinkSpec(alpha_s=5e-7, bw=100e9),
    "ici": LinkSpec(alpha_s=1e-6, bw=50e9),
    "dcn": LinkSpec(alpha_s=25e-6, bw=6.25e9),
}


@dataclasses.dataclass(frozen=True)
class Topology:
    """The device fabric: process/pod extents plus a link tier per mesh axis.

    ``axis_tiers`` maps every mesh axis name to the SLOWEST link a
    collective over that axis crosses. ``devices_per_pod`` bounds the
    ici domain for group-size classification (collectives spanning more
    devices than one pod must cross the dcn); ``devices_per_process``
    bounds the loopback domain the same way.
    """

    axis_tiers: tuple            # ((axis, tier), ...) — frozen mapping
    n_devices: int
    n_processes: int = 1
    devices_per_pod: Optional[int] = None   # None: single-pod fabric
    links: tuple = tuple(sorted(DEFAULT_LINKS.items()))

    @property
    def devices_per_process(self) -> int:
        """Addressable devices per OS process (the loopback domain)."""
        return self.n_devices // max(1, self.n_processes)

    def tier_of_axis(self, axis: str) -> str:
        """Link tier of a collective over one mesh axis."""
        for a, t in self.axis_tiers:
            if a == axis:
                return t
        raise KeyError(f"axis {axis!r} not in topology {self.axis_tiers}")

    def tier_for_axes(self, axes) -> str:
        """Slowest tier among the given mesh axes (a collective spanning
        several axes is priced at its worst link). Empty axes (a
        device-local exchange) price as loopback."""
        if not axes:
            return "loopback"
        if isinstance(axes, str):
            axes = (axes,)
        tiers = [self.tier_of_axis(a) for a in axes]
        return max(tiers, key=TIERS.index)

    def tier_for_group_size(self, g: int) -> str:
        """Classify a collective by its replica-group extent: groups wider
        than one pod cross the dcn; wider than one process cross the ici;
        anything inside one process is loopback. This is how the roofline
        tiers HLO collectives, where only the group size survives
        compilation."""
        if self.devices_per_pod is not None and g > self.devices_per_pod:
            return "dcn"
        if g > self.devices_per_process:
            return "ici"
        # single-process fabrics distinguish modeled-ici from loopback via
        # the axis table: if any axis is ici the fabric models real chips
        if any(t != "loopback" for _a, t in self.axis_tiers):
            return "ici"
        return "loopback"

    def tier_for_ids(self, ids) -> str:
        """Classify a replica group by its member device ids — sharper than
        :meth:`tier_for_group_size` when the HLO spells the ids out. A group
        narrower than one pod can still cross the dcn if its members sit in
        different pods (e.g. a psum over the ("pod", "data") worker axes of
        a 2-pod mesh: 32 devices, strided across the pod boundary); likewise
        a group whose ids span OS processes crosses the simulated slow link
        (the same convention :func:`detect_topology` applies to axes)."""
        ids = [int(i) for i in ids]
        if len(ids) <= 1:
            return "loopback"
        if self.devices_per_pod is not None and len(
            {i // self.devices_per_pod for i in ids}
        ) > 1:
            return "dcn"
        if self.n_processes > 1 and len(
            {i // self.devices_per_process for i in ids}
        ) > 1:
            return "dcn"
        return self.tier_for_group_size(len(ids))

    def link(self, tier: str) -> LinkSpec:
        """The α–β constants of one tier."""
        return dict(self.links)[tier]


# ---------------------------------------------------------------------------
# production / test meshes (folded in from the old launch/mesh.py)
#
# Defined as functions (never module-level constants) so importing this
# module does not touch jax device state — the dry-run sets
# ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
# import; tests and benches see the real single device.
# ---------------------------------------------------------------------------


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def production_topology(*, multi_pod: bool = False) -> Topology:
    """The fabric the production meshes MODEL (the dry-run runs them on
    fake devices, but §Perf prices them as real chips): every intra-pod
    axis is ici, the pod axis is dcn, one pod = 256 chips."""
    if multi_pod:
        return Topology(
            axis_tiers=(("pod", "dcn"), ("data", "ici"), ("model", "ici")),
            n_devices=512, n_processes=1, devices_per_pod=256,
        )
    return Topology(
        axis_tiers=(("data", "ici"), ("model", "ici")),
        n_devices=256, n_processes=1, devices_per_pod=256,
    )


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU sharding tests (requires ≥ data·model host
    devices)."""
    import jax

    return jax.make_mesh((data, model), ("data", "model"))


def make_federated_mesh(clients: int, model: int = 1):
    """Mesh for the federated PP scenario: the worker ("data") axis is the
    client fleet, the model axis carries within-client parallelism (1 for
    cross-device clients). Requires ≥ clients·model host devices — pair
    with XLA_FLAGS=--xla_force_host_platform_device_count for CPU tests."""
    import jax

    return jax.make_mesh((clients, model), ("data", "model"))


def worker_axis_names(multi_pod: bool, worker_axes: str) -> tuple:
    """Which mesh axes form the MARINA worker dimension (DESIGN.md §3)."""
    if not multi_pod:
        return ("data",)
    return ("pod",) if worker_axes == "pod" else ("pod", "data")


def num_workers(mesh, multi_pod: bool, worker_axes: str) -> int:
    """Worker-fleet size n: product of the worker mesh axes' extents."""
    n = 1
    for ax in worker_axis_names(multi_pod, worker_axes):
        n *= mesh.shape[ax]
    return n


def cohort_group_size(n: int, r: int) -> Optional[int]:
    """Mesh slots per sampled client when a PP cohort of r is respread over
    all n worker shards (DESIGN.md §4.8): n/r when r divides n, else None.
    None means cohort-mapped compute is impossible and the builder falls
    back to masked dense compute; a non-None group is necessary but not
    sufficient — build_train_steps additionally requires the per-worker
    batch to split evenly ((per_worker·r) % n == 0)."""
    return n // r if (r > 0 and n % r == 0) else None


def detect_topology(mesh, *, multi_pod: bool = False) -> Topology:
    """Classify a RUNTIME mesh's axes against the actual process layout.

    Per axis: devices varying along it that live in different OS processes
    make it a cross-process axis — "dcn" on CPU (the local cluster's
    process boundary IS its simulated slow link) and "ici" on real
    accelerators inside one pod; an axis named "pod" is always "dcn".
    Axes local to one process are "loopback" on CPU fake devices, "ici"
    on real chips."""
    import jax
    import numpy as np

    dev = np.asarray(mesh.devices)
    procs = np.vectorize(lambda d: d.process_index)(dev)
    cpu = jax.default_backend() == "cpu"
    tiers = []
    for i, axis in enumerate(mesh.axis_names):
        if axis == "pod":
            tiers.append((axis, "dcn"))
            continue
        along = np.moveaxis(procs, i, 0)
        spans = bool((along != along[0]).any())
        if spans:
            tiers.append((axis, "dcn" if cpu else "ici"))
        else:
            tiers.append((axis, "loopback" if cpu else "ici"))
    pod_devs = None
    if "pod" in mesh.axis_names:
        pod_devs = dev.size // mesh.shape["pod"]
    return Topology(
        axis_tiers=tuple(tiers),
        n_devices=int(dev.size),
        n_processes=int(jax.process_count()),
        devices_per_pod=pod_devs,
    )


# ---------------------------------------------------------------------------
# multi-process bring-up (jax.distributed)
# ---------------------------------------------------------------------------


def initialize_multiprocess(
    coordinator_address: str,
    num_processes: int,
    process_id: int,
) -> None:
    """``jax.distributed.initialize`` with CPU cross-process collectives.

    Must run before the first jax computation touches the backend. On CPU
    the gloo collectives implementation is selected so worker-axis psums /
    all-gathers genuinely cross the process boundary (the transport's dcn
    tier) instead of failing at dispatch."""
    import jax

    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # non-CPU backends / older configs: the default is fine
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )


def init_from_env() -> tuple:
    """Bring up this process from the ``MARINA_MP_*`` contract set by
    :func:`spawn_local_cluster` (no-op single-process bring-up when the
    variables are absent). Returns ``(process_id, num_processes)``."""
    spec = os.environ.get(PROCESS_ENV)
    coord = os.environ.get(COORD_ENV)
    if not spec or not coord:
        return (0, 1)
    pid_s, nproc_s = spec.split("/")
    pid, nproc = int(pid_s), int(nproc_s)
    initialize_multiprocess(coord, nproc, pid)
    return (pid, nproc)


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _launch_procs(
    prog: str,
    num_processes: int,
    devices_per_process: int,
    extra_env: Optional[dict],
) -> list:
    """Start the cluster's subprocesses (rank order) on a fresh coordinator
    port — the shared bring-up of :func:`spawn_local_cluster` and
    :func:`run_resilient_cluster`."""
    port = _free_port()
    env_base = dict(os.environ)
    env_base["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices_per_process} "
        + env_base.get("XLA_FLAGS", "")
    )
    env_base[COORD_ENV] = f"127.0.0.1:{port}"
    env_base.setdefault(
        "PYTHONPATH",
        os.path.join(os.path.dirname(__file__), "..", ".."),
    )
    if extra_env:
        env_base.update(extra_env)
    procs = []
    for pid in range(num_processes):
        env = dict(env_base)
        env[PROCESS_ENV] = f"{pid}/{num_processes}"
        procs.append(
            subprocess.Popen(
                [sys.executable, "-c", prog],
                stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                text=True, env=env,
            )
        )
    return procs


class ClusterBringupError(RuntimeError):
    """A local-cluster attempt came back with failed children. Carries the
    per-rank ``CompletedProcess`` list so the retry wrapper can surface the
    LAST attempt's stderr when the budget runs out."""

    def __init__(self, message: str, results: Optional[list] = None):
        super().__init__(message)
        self.results = results


def spawn_local_cluster(
    prog: str,
    *,
    num_processes: int = 2,
    devices_per_process: int = 2,
    timeout: float = 560.0,
    extra_env: Optional[dict] = None,
    retry=None,
) -> list:
    """Run ``prog`` (python source) in ``num_processes`` subprocesses wired
    into one jax.distributed cluster; each child sees
    ``devices_per_process`` fake CPU devices and must call
    :func:`init_from_env` before computing. Returns the per-process
    ``CompletedProcess`` list (rank order) — callers assert on
    returncode/stdout.

    This is the CI-sized stand-in for real multi-host bring-up: same
    initialize path, same global meshes, same cross-process collectives
    (gloo), just on localhost.

    ``retry`` (a :class:`repro.launch.transport.RetryPolicy`) hardens the
    flaky bring-up: the whole attempt is torn down and relaunched — fresh
    port, fresh children — when it times out or any child exits nonzero
    (gloo rendezvous races ARE whole-cluster failures; a half-alive fleet
    cannot be patched). Each attempt gets ``retry.timeout_s``; backoff
    sleeps between attempts; the last attempt's failure propagates
    (``TimeoutExpired``) or returns its failed results for the caller's
    returncode asserts."""

    def one_attempt(attempt_timeout: float) -> list:
        procs = _launch_procs(
            prog, num_processes, devices_per_process, extra_env
        )
        done = []
        for p in procs:
            try:
                out, err = p.communicate(timeout=attempt_timeout)
            except subprocess.TimeoutExpired:
                for q in procs:
                    q.kill()
                raise
            done.append(
                subprocess.CompletedProcess(p.args, p.returncode, out, err)
            )
        return done

    if retry is None:
        return one_attempt(timeout)

    from repro.launch.transport import retry_call  # deferred: transport imports topology

    def attempt() -> list:
        results = one_attempt(retry.timeout_s)
        bad = [i for i, r in enumerate(results) if r.returncode != 0]
        if bad:
            raise ClusterBringupError(
                f"cluster ranks {bad} exited nonzero", results=results
            )
        return results

    try:
        return retry_call(
            attempt, retry,
            retryable=(ClusterBringupError, subprocess.TimeoutExpired),
        )
    except ClusterBringupError as exc:
        return exc.results


# ---------------------------------------------------------------------------
# crash detection + recovery (DESIGN.md §4.10)
#
# A killed worker process on the real gloo cluster takes its device rows
# with it, and every survivor then hangs in the next collective — there is
# no in-band signal. The resilient runner therefore watches LIVENESS from
# outside: it polls the children, and the moment any rank dies it kills the
# survivors (they are blocked, not recoverable), reads the buffered stdout
# back, and locates the last fleet-wide completed round from the heartbeat
# lines every rank prints. Recovery is a relaunch with the dead clients
# mapped to the static ``drop`` fault (FaultSpec ids) from the first
# incomplete round onward — deterministic replay makes the recovered
# trajectory equal the run where those clients had simply missed every
# deadline from the crash round (tests/test_multiproc.py proves it).
# ---------------------------------------------------------------------------


def clients_of_rank(rank: int, devices_per_process: int) -> tuple:
    """Client ids a crashed rank takes down: the local-cluster convention
    maps worker/client i to global device i, and rank r owns the contiguous
    device block [r·dpp, (r+1)·dpp)."""
    lo = rank * devices_per_process
    return tuple(range(lo, lo + devices_per_process))


def crash_spec_from_env() -> Optional[tuple]:
    """Worker side of the crash-fault contract: ``(rank, round)`` parsed
    from ``MARINA_MP_CRASH="<rank>@<round>"``; None when unset/empty."""
    spec = os.environ.get(CRASH_ENV, "")
    if not spec:
        return None
    rank_s, round_s = spec.split("@")
    return (int(rank_s), int(round_s))


def maybe_crash(rank: int, round_k: int) -> None:
    """Process-crash fault injection: hard-exit via ``os._exit`` — no
    atexit, no flushed collectives, the closest a test gets to a SIGKILL'd
    worker — when the env names this rank and round. Call at the TOP of the
    round body, before any collective: the round never completes anywhere."""
    if crash_spec_from_env() == (rank, round_k):
        sys.stdout.flush()
        sys.stderr.flush()
        os._exit(17)


def recovery_from_env() -> tuple:
    """Worker side of the recovery contract: ``(dead_client_ids,
    resume_round)`` from ``MARINA_MP_DEAD``/``MARINA_MP_RESUME``. Rounds
    before ``resume_round`` replay fault-free (the fleet completed them);
    from it onward the dead ids are a static ``drop`` set. ``((), 0)``
    when unset — a plain run."""
    dead_s = os.environ.get(DEAD_ENV, "")
    dead = tuple(
        int(x) for x in dead_s.split(",") if x.strip()
    ) if dead_s else ()
    resume = int(os.environ.get(RESUME_ENV, "") or 0)
    return dead, resume


def last_heartbeat(text: str) -> int:
    """Last round a rank reported complete (``MARINA_HB <k>`` lines in its
    stdout); −1 when it never finished one."""
    last = -1
    for line in text.splitlines():
        parts = line.strip().split()
        if len(parts) == 2 and parts[0] == HEARTBEAT:
            try:
                last = int(parts[1])
            except ValueError:
                pass
    return last


@dataclasses.dataclass
class ClusterOutcome:
    """What :func:`run_resilient_cluster` observed: per-rank results (rank
    order; survivors killed after a crash carry their buffered output),
    the ranks that died on their own, and the last round EVERY rank had
    completed (min over heartbeats — the resume point)."""

    results: list
    dead_ranks: tuple
    last_round: int

    @property
    def crashed(self) -> bool:
        return bool(self.dead_ranks)


def run_resilient_cluster(
    prog: str,
    *,
    num_processes: int = 2,
    devices_per_process: int = 2,
    timeout: float = 560.0,
    extra_env: Optional[dict] = None,
    poll_s: float = 0.2,
) -> ClusterOutcome:
    """Like :func:`spawn_local_cluster`, but crash-aware: polls child
    liveness instead of blocking on rank 0. When a rank exits while others
    run, the survivors (hung in their next gloo collective) are killed
    immediately — the cluster does NOT stall for ``timeout`` — and the
    heartbeat streams locate the last fleet-wide completed round. A clean
    fleet-wide exit returns with ``dead_ranks=()``. The overall ``timeout``
    is the hang backstop (everything killed, whatever heartbeats were seen
    are reported)."""
    procs = _launch_procs(
        prog, num_processes, devices_per_process, extra_env
    )
    deadline = time.monotonic() + timeout
    dead = ()
    while time.monotonic() < deadline:
        codes = [p.poll() for p in procs]
        dead = tuple(
            i for i, c in enumerate(codes) if c is not None and c != 0
        )
        if dead or all(c is not None for c in codes):
            break
        time.sleep(poll_s)
    for p in procs:
        if p.poll() is None:
            p.kill()
    results = []
    for p in procs:
        out, err = p.communicate()
        results.append(
            subprocess.CompletedProcess(p.args, p.returncode, out, err)
        )
    beats = [last_heartbeat(r.stdout or "") for r in results]
    return ClusterOutcome(
        results=results,
        dead_ranks=dead,
        last_round=min(beats) if beats else -1,
    )


def run_with_recovery(
    prog: str,
    *,
    num_processes: int = 2,
    devices_per_process: int = 2,
    timeout: float = 560.0,
    extra_env: Optional[dict] = None,
    retry=None,
) -> tuple:
    """The full straggler-tolerance loop: run ``prog`` on the local cluster
    crash-aware; if a rank dies, relaunch ``prog`` single-process (the
    survivors' devices fold into one process) with the crashed rank's
    clients exported as the dead set from the first incomplete round —
    rounds the fleet completed replay fault-free, everything after treats
    the dead clients as permanent deadline-missers (the carry/drop
    substitution). Returns ``(outcome, recovery)`` where ``recovery`` is
    the recovery run's ``CompletedProcess`` (None when nothing crashed).
    ``retry`` hardens the recovery relaunch's bring-up."""
    outcome = run_resilient_cluster(
        prog,
        num_processes=num_processes,
        devices_per_process=devices_per_process,
        timeout=timeout,
        extra_env=extra_env,
    )
    if not outcome.crashed:
        return outcome, None
    dead_clients = ()
    for r in outcome.dead_ranks:
        dead_clients += clients_of_rank(r, devices_per_process)
    recovery_env = dict(extra_env or {})
    recovery_env[CRASH_ENV] = ""          # the ghost must not die twice
    recovery_env[DEAD_ENV] = ",".join(str(c) for c in sorted(dead_clients))
    recovery_env[RESUME_ENV] = str(outcome.last_round + 1)
    results = spawn_local_cluster(
        prog,
        num_processes=1,
        devices_per_process=num_processes * devices_per_process,
        timeout=timeout,
        extra_env=recovery_env,
        retry=retry,
    )
    return outcome, results[0]


_DEMO_PROG = r"""
from repro.launch import topology as topo
pid, nproc = topo.init_from_env()
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
mesh = jax.make_mesh((jax.device_count(),), ("data",))
t = topo.detect_topology(mesh)
sh = NamedSharding(mesh, P("data"))
x = jax.make_array_from_callback(
    (jax.device_count(),), sh, lambda i: np.arange(jax.device_count(), dtype=np.float32)[i]
)
total = jax.jit(jnp.sum, out_shardings=NamedSharding(mesh, P()))(x)
print(f"process {pid}/{nproc}: {jax.local_device_count()} local of "
      f"{jax.device_count()} global devices; worker-axis tier = "
      f"{t.tier_for_axes(('data',))}; psum(arange) = {float(total):.0f}",
      flush=True)
"""


def main():
    """CLI demo: spawn an N-process local cluster, run one cross-process
    psum, and print each process's view of the topology."""
    import argparse

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=2)
    args = ap.parse_args()
    results = spawn_local_cluster(
        _DEMO_PROG,
        num_processes=args.processes,
        devices_per_process=args.devices_per_process,
    )
    ok = True
    for r in results:
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            ok = False
            sys.stderr.write(r.stderr[-2000:])
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
