"""Path-based GSPMD sharding rules for parameters, batches, and caches.

Every rule is a *preference*; ``_fit`` drops any axis that does not divide the
corresponding dimension (e.g. 10 attention heads over a 16-way model axis →
replicated, while the flattened H·hd projection column still shards). This is
what lets one rule table drive all 10 architectures on both meshes.

Roles:
* ``M`` — prefer the model axis (tensor/expert parallelism)
* ``F`` — prefer the fsdp axis ("data") when the arch runs worker-per-pod
* ``None`` — replicate
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

M, F = "M", "F"

# name → right-aligned dim roles (extra leading dims, e.g. scan stacks, replicate)
_RULES: dict[str, tuple] = {
    # embeddings: (V, d) — vocab-parallel
    "embed": (M, F),
    "lm_head": (M, F),
    # in-projections (d_in, d_out): column-parallel
    **{k: (F, M) for k in (
        "wq", "wk", "wv", "w_uq", "w_uk", "w_uv", "w_dq", "w_dkv", "w_kr",
        "w_in", "ff_up", "w_x", "w_y", "w_a", "w_i", "w_q", "w_k", "w_v",
        "w_up_mlp", "proj",
    )},
    "w_gate": (F, M),
    "w_up": (F, M),
    # MoE expert stacks (E, d_in, d_out) / (E, d_out, d_in): experts → model (EP)
    "moe_gate": (M, F, None),
    "moe_up": (M, F, None),
    "moe_down": (M, None, F),
    # out-projections (d_out, d_in): row-parallel
    **{k: (M, F) for k in ("wo", "w_down", "ff_down", "w_out")},
    # gates with tiny output dims
    "w_if": (F, None),
    # conv (W, C)
    "w": (None, M),
    "b": (M,),
    # small / replicated
    **{k: () for k in ("lam", "r_z", "r_i", "r_f", "r_o")},
    # router (d, E): replicate E (small), fsdp the input dim
    "router": (F, None),
}

def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def _fit(roles: tuple, shape: tuple, mesh: Mesh, fsdp: bool) -> P:
    """Right-align roles to shape, drop non-dividing axes, map roles to axes."""
    axes: list[Optional[str]] = [None] * len(shape)
    used = set()
    for i, role in enumerate(roles):
        dim = len(shape) - len(roles) + i
        if dim < 0 or role is None:
            continue
        ax = "model" if role == M else ("data" if fsdp else None)
        if ax is None or ax in used or ax not in mesh.shape:
            continue
        if shape[dim] % mesh.shape[ax] == 0 and shape[dim] > 0:
            axes[dim] = ax
            used.add(ax)
    return P(*axes)


def param_spec(path, leaf, mesh: Mesh, fsdp: bool) -> P:
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    roles = _RULES.get(name)
    if roles is None:
        roles = (F, M) if len(shape) >= 2 else ()
    spec = _fit(roles, shape, mesh, fsdp)
    # fallback: a large leaf whose preferred dim didn't divide (e.g. an odd
    # vocab) still gets the model axis on any dividing dim, rightmost first
    if (
        all(s is None for s in spec)
        and int(np.prod(shape)) > 1_000_000
        and "model" in mesh.shape
    ):
        axes: list[Optional[str]] = [None] * len(shape)
        for dim in range(len(shape) - 1, -1, -1):
            if shape[dim] % mesh.shape["model"] == 0:
                axes[dim] = "model"
                break
        spec = P(*axes)
    return spec


def param_sharding_tree(shapes: PyTree, mesh: Mesh, fsdp: bool) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [NamedSharding(mesh, param_spec(p, l, mesh, fsdp)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------


def batch_spec(worker_axes: tuple[str, ...], inner_batch_axis: Optional[str], ndim: int) -> P:
    """(n_workers, per_worker_batch, ...) — workers on dim 0; optionally shard
    the per-worker batch over the fsdp axis (worker-per-pod archs)."""
    axes: list = [worker_axes if len(worker_axes) > 1 else worker_axes[0]]
    axes.append(inner_batch_axis)
    axes += [None] * (ndim - 2)
    return P(*axes)


def serve_batch_axes(mesh: Mesh, B: int) -> Optional[tuple]:
    """Best axes to shard a serving batch dim of size B over."""
    cands = [ax for ax in ("pod", "data") if ax in mesh.shape]
    chosen = []
    size = 1
    for ax in cands:
        if B % (size * mesh.shape[ax]) == 0:
            chosen.append(ax)
            size *= mesh.shape[ax]
    if not chosen:
        return None
    return tuple(chosen)


def serve_batch_spec(batch_axes, ndim: int) -> P:
    """Spec for a serving array with the batch on dim 0: batch axes (or their
    tuple) on dim 0, everything else replicated. The single shared spelling of
    ``P(baxes if not baxes or len(baxes) > 1 else baxes[0], None, ...)`` that
    serve_steps.py used to repeat inline."""
    if not batch_axes:
        lead = None
    elif len(batch_axes) > 1:
        lead = batch_axes
    else:
        lead = batch_axes[0]
    return P(lead, *([None] * (ndim - 1)))


def serve_batch_sharding(mesh: Mesh, batch_axes, ndim: int) -> NamedSharding:
    """NamedSharding form of :func:`serve_batch_spec`."""
    return NamedSharding(mesh, serve_batch_spec(batch_axes, ndim))


def cache_leaf_spec(path, leaf, mesh: Mesh, batch_axes) -> P:
    """Decode-cache leaves: (repeat, B, ...). Shard B over batch axes, then try
    the model axis on head-ish dims, then the unused data axes on the time dim
    (sequence-parallel KV for long contexts)."""
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    axes: list = [None] * len(shape)
    used = set()
    if len(shape) >= 2 and batch_axes:
        bsz = int(np.prod([mesh.shape[a] for a in batch_axes]))
        if shape[1] % bsz == 0:
            axes[1] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
            used.update(batch_axes)
    # trailing feature dims: try model axis once, rightmost-but-one first
    if "model" in mesh.shape:
        for dim in range(len(shape) - 2, 1, -1):
            if shape[dim] % mesh.shape["model"] == 0 and "model" not in used:
                axes[dim] = "model"
                used.add("model")
                break
        else:
            if (
                len(shape) >= 3
                and "model" not in used
                and shape[-1] % mesh.shape["model"] == 0
            ):
                axes[-1] = "model"
                used.add("model")
    # time dim (dim 2 for (repeat,B,S,...) caches): spread over leftover axes
    if name in ("k", "v", "ckv", "k_rope") and len(shape) >= 4:
        leftover = [a for a in ("pod", "data") if a in mesh.shape and a not in used]
        if leftover:
            size = int(np.prod([mesh.shape[a] for a in leftover]))
            if shape[2] % size == 0:
                axes[2] = tuple(leftover) if len(leftover) > 1 else leftover[0]
                used.update(leftover)
    return P(*axes)


def cache_sharding_tree(cache_shapes: PyTree, mesh: Mesh, batch_axes) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = [
        NamedSharding(mesh, cache_leaf_spec(p, l, mesh, batch_axes))
        for p, l in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
