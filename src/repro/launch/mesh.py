"""Production meshes.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU sharding tests (requires ≥ data·model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def worker_axis_names(multi_pod: bool, worker_axes: str) -> tuple[str, ...]:
    """Which mesh axes form the MARINA worker dimension (DESIGN.md §3)."""
    if not multi_pod:
        return ("data",)
    return ("pod",) if worker_axes == "pod" else ("pod", "data")


def num_workers(mesh, multi_pod: bool, worker_axes: str) -> int:
    """Worker-fleet size n: product of the worker mesh axes' extents."""
    n = 1
    for ax in worker_axis_names(multi_pod, worker_axes):
        n *= mesh.shape[ax]
    return n


def make_federated_mesh(clients: int, model: int = 1):
    """Mesh for the federated PP scenario: the worker ("data") axis is the
    client fleet, the model axis carries within-client parallelism (1 for
    cross-device clients). Requires ≥ clients·model host devices — pair
    with XLA_FLAGS=--xla_force_host_platform_device_count for CPU tests."""
    return jax.make_mesh((clients, model), ("data", "model"))


def cohort_group_size(n: int, r: int) -> "int | None":
    """Mesh slots per sampled client when a PP cohort of r is respread over
    all n worker shards (DESIGN.md §4.8): n/r when r divides n, else None.
    None means cohort-mapped compute is impossible and the builder falls
    back to masked dense compute; a non-None group is necessary but not
    sufficient — build_train_steps additionally requires the per-worker
    batch to split evenly ((per_worker·r) % n == 0)."""
    return n // r if (r > 0 and n % r == 0) else None
