import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input-shape × mesh) combination and record memory analysis,
cost analysis, and the roofline terms.

MUST be run as a script/module so the XLA_FLAGS above land before jax
initializes devices (do not import this module from tests).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all            # full grid, resumable
    PYTHONPATH=src python -m repro.launch.dryrun --table          # print result table
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import PUBLIC_TO_MODULE, get_arch
from repro.launch.topology import make_production_mesh, production_topology
from repro.launch import param_math
from repro.roofline import analyze_compiled

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


# cheap-to-expensive order so a long grid run banks results early
_ORDER = [
    "qwen1.5-0.5b", "internvl2-1b", "xlstm-350m", "musicgen-medium",
    "recurrentgemma-2b", "gemma3-27b", "qwen3-32b", "deepseek-coder-33b",
    "llama4-scout-17b-a16e", "deepseek-v3-671b",
]


def combos():
    for arch_name in _ORDER:
        arch = get_arch(arch_name)
        for shape_name in SHAPES:
            if shape_name == "long_500k" and not arch.runs_long_context:
                continue
            for mesh_name in ("single", "multi"):
                yield arch_name, shape_name, mesh_name


def out_path(arch_name, shape_name, mesh_name):
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, f"{arch_name}__{shape_name}__{mesh_name}.json")


def run_one(arch_name: str, shape_name: str, mesh_name: str, overrides=None) -> dict:
    from repro.launch.distributed import build_serve_steps, build_train_steps

    arch = get_arch(arch_name)
    spec = SHAPES[shape_name]
    multi_pod = mesh_name == "multi"
    # one mesh + one modeled fabric per production shape — the topology layer
    # is the single source for both (the old duplicate n_dev constants drifted
    # from the mesh construction by design pressure alone)
    mesh = make_production_mesh(multi_pod=multi_pod)
    topo = production_topology(multi_pod=multi_pod)
    n_dev = topo.n_devices
    overrides = overrides or {}

    t0 = time.time()
    if spec["kind"] == "train":
        bundle = build_train_steps(
            arch, mesh, multi_pod,
            global_batch=spec["global_batch"], seq_len=spec["seq_len"],
            topology=topo,   # book wire bits under the MODELED fabric's tiers
            **overrides,
        )
        tokens = spec["global_batch"] * spec["seq_len"]
    else:
        bundle = build_serve_steps(
            arch, mesh, multi_pod,
            batch=spec["global_batch"], seq_len=spec["seq_len"],
            mode=spec["kind"], **overrides,
        )
        tokens = (
            spec["global_batch"] * spec["seq_len"]
            if spec["kind"] == "prefill"
            else spec["global_batch"]
        )
    # forward-only steps do ~2·N·D per token; train ~6·N·D (fwd+bwd)
    mf = param_math.model_flops(arch.model, tokens)
    if spec["kind"] != "train":
        mf /= 3.0

    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "n_workers": bundle.n_workers,
        "params": param_math.count_params(arch.model),
        "active_params": param_math.count_active_params(arch.model),
        "steps": {},
    }
    with bundle.mesh:
        for name, (fn, args) in bundle.fns.items():
            entry = {}
            try:
                t1 = time.time()
                lowered = fn.lower(*args)
                entry["lower_s"] = time.time() - t1
                t1 = time.time()
                compiled = lowered.compile()
                entry["compile_s"] = time.time() - t1
                # MODEL_FLOPS accounting: compressed rounds re-evaluate the
                # old point (2× oracle), sync rounds evaluate once
                step_mf = mf * (2.0 if name == "compressed_step" else 1.0) \
                    if name != "train_step" else mf
                rep = analyze_compiled(
                    compiled, n_dev, model_flops_total=step_mf, topology=topo
                )
                entry.update(rep.to_dict())
                try:
                    ma = compiled.memory_analysis()
                    entry["memory_analysis"] = {
                        k: float(getattr(ma, k))
                        for k in (
                            "argument_size_in_bytes",
                            "output_size_in_bytes",
                            "temp_size_in_bytes",
                            "alias_size_in_bytes",
                            "generated_code_size_in_bytes",
                        )
                        if hasattr(ma, k)
                    }
                except Exception as e:  # pragma: no cover
                    entry["memory_analysis_error"] = str(e)
                entry["ok"] = True
            except Exception as e:
                entry["ok"] = False
                entry["error"] = f"{type(e).__name__}: {e}"
                entry["traceback"] = traceback.format_exc()[-4000:]
            result["steps"][name] = entry
    result["wall_s"] = time.time() - t0
    return result


def print_table():
    import glob

    rows = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        for sname, s in r["steps"].items():
            if not s.get("ok"):
                rows.append((r["arch"], r["shape"], r["mesh"], sname, "FAIL", "", "", "", ""))
                continue
            rows.append(
                (
                    r["arch"], r["shape"], r["mesh"], sname,
                    r.get("dominant", s.get("dominant", "")),
                    f"{s['compute_s']*1e3:9.2f}",
                    f"{s['memory_s']*1e3:9.2f}",
                    f"{s['collective_s']*1e3:9.2f}",
                    f"{(s.get('useful_ratio') or 0):5.2f}",
                )
            )
    hdr = ("arch", "shape", "mesh", "step", "dom", "comp_ms", "mem_ms", "coll_ms", "useful")
    print(("{:<24}{:<12}{:<7}{:<17}{:<11}{:>10}{:>10}{:>10}{:>7}").format(*hdr))
    for row in rows:
        dom = row[4] if len(row) > 4 else ""
        print(
            "{:<24}{:<12}{:<7}{:<17}{:<11}{:>10}{:>10}{:>10}{:>7}".format(
                *row[:4], row[4] if row[4] else "", *row[5:]
            )
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--table", action="store_true")
    args = ap.parse_args()

    if args.table:
        print_table()
        return

    if args.all:
        todo = list(combos())
    else:
        assert args.arch and args.shape and args.mesh
        todo = [(args.arch, args.shape, args.mesh)]

    for arch_name, shape_name, mesh_name in todo:
        path = out_path(arch_name, shape_name, mesh_name)
        if os.path.exists(path) and not args.force:
            print(f"skip {path}")
            continue
        print(f"=== {arch_name} × {shape_name} × {mesh_name} ===", flush=True)
        res = run_one(arch_name, shape_name, mesh_name)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        for sname, s in res["steps"].items():
            status = "ok" if s.get("ok") else "FAIL " + s.get("error", "")[:200]
            extra = ""
            if s.get("ok"):
                extra = (
                    f" dom={s['dominant']} comp={s['compute_s']*1e3:.1f}ms"
                    f" mem={s['memory_s']*1e3:.1f}ms coll={s['collective_s']*1e3:.1f}ms"
                )
            print(f"  {sname}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
