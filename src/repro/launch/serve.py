"""Production serving driver: batched prefill + decode for any assigned
architecture (reduced on CPU; the full configs are exercised by dryrun.py).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --batch 4 --prompt 32 --gen 16 --reduced
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import PUBLIC_TO_MODULE, get_arch
from repro.models import decode_step, init_params, prefill, reduced as reduce_cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(PUBLIC_TO_MODULE))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = reduce_cfg(arch.model, layers=2, d_model=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, Pr, G = args.batch, args.prompt, args.gen
    total = Pr + G + 8
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (B, Pr), 0, cfg.vocab_size)
    prefix = (
        jax.random.normal(key, (B, 8, cfg.d_model)) * 0.02
        if arch.prefix_len else None
    )
    off = 0 if prefix is None else prefix.shape[1]

    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t, pe: prefill(p, cfg, t, pe, max_len=total)
    )(params, prompts, prefix)
    logits.block_until_ready()
    print(f"prefill {B}×{Pr}: {time.time()-t0:.2f}s")

    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    tok = jnp.argmax(logits, -1)
    t0 = time.time()
    toks = [tok]
    for i in range(G - 1):
        key, sub = jax.random.split(key)
        logits, cache = dec(params, cache, tok, off + Pr + i)
        if args.temperature > 0:
            tok = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, -1)
        toks.append(tok)
    jax.block_until_ready(toks[-1])
    dt = time.time() - t0
    print(f"decode {G-1} steps: {dt:.2f}s ({B*(G-1)/dt:.1f} tok/s)")
    print("ids[0]:", jnp.stack(toks, 1)[0].tolist())


if __name__ == "__main__":
    main()
