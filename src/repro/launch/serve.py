"""Production serving driver: continuous batching over the paged KV cache
(DESIGN.md §8), with the legacy static-batch path kept for A/B comparison.

Continuous mode threads one donated page-pool cache through a single jitted
decode step per iteration, joining prefill chunks into the running batch as
slots and pages free up. Static mode is the old serve loop: pad every
request to the longest prompt, prefill once, decode until the longest
generation finishes. BENCH_serve (benchmarks/bench_serve.py) runs both over
the same mixed-length workload and reports the tokens/s ratio.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b \
      --requests 32:24,32:4,8:4,8:4 --slots 4 --mode continuous
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --mode static --batch 4 --prompt 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PUBLIC_TO_MODULE, get_arch
from repro.core.paging import PagedLayout
from repro.launch.scheduler import ContinuousEngine, ContinuousScheduler, Request
from repro.models import (
    decode_step,
    init_paged_cache,
    init_params,
    paged_copy_pages,
    paged_decode_step,
    paged_gather_pages,
    paged_prefill_chunk,
    paged_scatter_pages,
    prefill,
    reduced as reduce_cfg,
)


def parse_requests(spec: str) -> list[tuple[int, int]]:
    """``"32:24,8:4"`` → [(prompt_len, gen_len), ...]."""
    out = []
    for part in spec.split(","):
        p, g = part.split(":")
        out.append((int(p), int(g)))
    return out


def make_workload(cfg, pairs, seed: int = 1) -> list[Request]:
    key = jax.random.PRNGKey(seed)
    reqs = []
    for rid, (p, g) in enumerate(pairs):
        key, sub = jax.random.split(key)
        prompt = np.asarray(
            jax.random.randint(sub, (p,), 0, cfg.vocab_size), np.int32
        )
        reqs.append(Request(rid=rid, prompt=prompt, max_new=g))
    return reqs


def build_paged_steps(
    params, cfg, *, temperature: float = 0.0, seed: int = 0,
) -> dict:
    """Jitted paged step + COW/swap page-op closures, reusable across
    engines. Build these ONCE and pass them to every :func:`build_engine` /
    :func:`run_continuous` call that shares the params — a fresh closure
    per run re-pays ~0.7 s of XLA compilation, which poisons benchmark
    ratios. One set serves both f32 and quantized caches (jit re-traces
    per cache pytree structure).

    Sampling is fused into the jitted step; the PRNG key is threaded (and
    split) only when ``temperature > 0`` — greedy decoding never touches
    the key. The page ops run over fixed-width null-padded id vectors, so
    each compiles exactly once per cache structure.
    """
    state = {"key": jax.random.PRNGKey(seed)}

    def sample(logits, key):
        if temperature > 0:
            return jax.random.categorical(key, logits / temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    @jax.jit
    def _prefill(cache, toks, start, row, nv, key=None):
        logits, cache = paged_prefill_chunk(params, cfg, cache, toks, start, row, nv)
        return sample(logits, key).astype(jnp.int32), cache

    @jax.jit
    def _decode(cache, toks, lengths, tables, key=None):
        logits, cache = paged_decode_step(params, cfg, cache, toks, lengths, tables)
        return sample(logits, key).astype(jnp.int32), cache

    def next_key():
        state["key"], sub = jax.random.split(state["key"])
        return sub

    def prefill_fn(cache, toks, start, row, nv):
        if temperature > 0:
            return _prefill(cache, toks, start, row, nv, next_key())
        return _prefill(cache, toks, start, row, nv)

    def decode_fn(cache, toks, lengths, tables):
        if temperature > 0:
            return _decode(cache, toks, lengths, tables, next_key())
        return _decode(cache, toks, lengths, tables)

    _copy = jax.jit(paged_copy_pages, donate_argnums=(0,))
    _gather = jax.jit(paged_gather_pages)
    _scatter = jax.jit(paged_scatter_pages, donate_argnums=(0,))

    return {
        "prefill": prefill_fn,
        "decode": decode_fn,
        "copy": lambda c, s, d: _copy(c, jnp.asarray(s), jnp.asarray(d)),
        # snapshots live host-side while the request is swapped out
        "gather": lambda c, i: jax.tree.map(
            np.asarray, _gather(c, jnp.asarray(i))
        ),
        "scatter": lambda c, i, sn: _scatter(c, jnp.asarray(i), sn),
    }


def build_engine(
    params, cfg, layout: PagedLayout, *, chunk: int,
    temperature: float = 0.0, quantized: bool = False, seed: int = 0,
    share_prefix: bool = False, admission: str = "expected",
    steps: dict | None = None,
) -> ContinuousEngine:
    """Single-process engine over jitted paged steps and a donated cache.

    ``share_prefix`` maps cached prompt pages via the prefix index (COW on
    first write); ``admission`` picks the scheduler policy ("expected" =
    lazy pages + preemption, "reserve" = PR-9 full reservation). Pass a
    :func:`build_paged_steps` dict via ``steps`` to share compiled code
    across engines.
    """
    if steps is None:
        steps = build_paged_steps(
            params, cfg, temperature=temperature, seed=seed
        )
    cache = init_paged_cache(
        cfg, layout.npage, layout.page_size, quantized=quantized
    )
    sched = ContinuousScheduler(
        layout, admission=admission, share_prefix=share_prefix
    )
    return ContinuousEngine(
        sched, cache, steps["prefill"], steps["decode"], chunk=chunk,
        copy_fn=steps["copy"], gather_fn=steps["gather"],
        scatter_fn=steps["scatter"],
    )


def run_continuous(
    params, cfg, reqs: list[Request], *, slots: int, page_size: int,
    npage: int | None = None, chunk: int = 16, temperature: float = 0.0,
    quantized: bool = False, share_prefix: bool = False,
    admission: str = "expected", steps: dict | None = None,
):
    """Serve ``reqs`` with continuous batching; returns the ServeReport."""
    need = max(r.prompt_len + r.max_new for r in reqs)
    max_pages = -(-need // page_size)
    if npage is None:
        # enough for every slot to hold a worst-case request, plus the null page
        npage = 1 + slots * max_pages
    layout = PagedLayout(
        npage=npage, page_size=page_size, max_pages=max_pages, n_slots=slots
    )
    engine = build_engine(
        params, cfg, layout, chunk=chunk, temperature=temperature,
        quantized=quantized, share_prefix=share_prefix, admission=admission,
        steps=steps,
    )
    report = engine.run(reqs)
    engine.sched.pool.check_conservation(engine.sched.tables)
    return report


def run_static(
    params, cfg, reqs: list[Request], *, batch: int, temperature: float = 0.0,
    seed: int = 0, jit_cache: dict | None = None,
):
    """Legacy static batching: pad each batch of ``batch`` requests to the
    longest prompt, prefill, decode until the longest generation finishes.
    tokens/s counts USEFUL tokens only (what each request asked for), so
    padding and overrun show up as lost throughput. Pass (and reuse) a
    ``jit_cache`` dict to keep compiled steps across calls — benchmarks
    must not re-pay compilation inside the measured run."""
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(seed)
    total_new = 0
    firsts, comps = [], []

    jc = jit_cache if jit_cache is not None else {}
    if "dec" not in jc:
        jc["dec"] = jax.jit(lambda c, t, pos: decode_step(params, cfg, c, t, pos))
    dec = jc["dec"]
    for i in range(0, len(reqs), batch):
        group = reqs[i:i + batch]
        pmax = max(r.prompt_len for r in group)
        gmax = max(r.max_new for r in group)
        toks = np.zeros((len(group), pmax), np.int32)
        for j, r in enumerate(group):
            toks[j, pmax - r.prompt_len:] = r.prompt  # left-pad
        if ("prefill", pmax + gmax) not in jc:
            jc[("prefill", pmax + gmax)] = jax.jit(
                lambda t, ml=pmax + gmax: prefill(params, cfg, t, max_len=ml)
            )
        logits, cache = jc[("prefill", pmax + gmax)](jnp.asarray(toks))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, -1)
        t_first = time.perf_counter()
        for j, r in enumerate(group):
            firsts.append((t_first - t0) * 1e3)
        done_at = [None] * len(group)
        for step in range(1, gmax):
            lg, cache = dec(cache, tok, pmax + step - 1)
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, lg / temperature, axis=-1)
            else:
                tok = jnp.argmax(lg, -1)
            jax.block_until_ready(tok)
            now = time.perf_counter()
            for j, r in enumerate(group):
                if done_at[j] is None and step + 1 >= r.max_new:
                    done_at[j] = now
        now = time.perf_counter()
        for j, r in enumerate(group):
            total_new += r.max_new
            comps.append(((done_at[j] or now) - t0) * 1e3)
    wall = time.perf_counter() - t0
    return {
        "n_requests": len(reqs),
        "total_new_tokens": total_new,
        "wall_s": wall,
        "tokens_per_s": total_new / wall if wall > 0 else 0.0,
        "first_token_p50_ms": float(np.percentile(firsts, 50)),
        "first_token_p99_ms": float(np.percentile(firsts, 99)),
        "completion_p50_ms": float(np.percentile(comps, 50)),
        "completion_p99_ms": float(np.percentile(comps, 99)),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(PUBLIC_TO_MODULE))
    ap.add_argument("--mode", choices=["continuous", "static"], default="continuous")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--requests", default=None,
        help="mixed workload 'prompt:gen,prompt:gen,...' (overrides --batch/--prompt/--gen)",
    )
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument(
        "--reduced", action=argparse.BooleanOptionalAction, default=True,
        help="CPU-sized config (--no-reduced lowers the full arch)",
    )
    ap.add_argument("--quantized", action="store_true", help="int8 KV pages")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--share-prefix", action="store_true",
        help="map cached prompt pages via the prefix index (COW on write)",
    )
    ap.add_argument(
        "--admission", choices=["expected", "reserve"], default="expected",
        help="'expected' admits on fresh prompt pages and preempts under "
             "pressure; 'reserve' requires the full worst-case reservation",
    )
    ap.add_argument(
        "--npage", type=int, default=None,
        help="pool size override (default: worst-case fit for --slots)",
    )
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = reduce_cfg(arch.model, layers=2, d_model=128) if args.reduced else arch.model
    params = init_params(jax.random.PRNGKey(0), cfg)

    pairs = (
        parse_requests(args.requests)
        if args.requests
        else [(args.prompt, args.gen)] * args.batch
    )
    reqs = make_workload(cfg, pairs)

    if args.mode == "continuous":
        rep = run_continuous(
            params, cfg, reqs, slots=args.slots, page_size=args.page_size,
            npage=args.npage, chunk=args.chunk, temperature=args.temperature,
            quantized=args.quantized, share_prefix=args.share_prefix,
            admission=args.admission,
        ).to_dict()
    else:
        rep = run_static(
            params, cfg, reqs, batch=args.batch, temperature=args.temperature
        )
    print(json.dumps(rep, indent=1))


if __name__ == "__main__":
    main()
