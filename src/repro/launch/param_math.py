"""Analytic parameter/FLOP accounting for full-size configs (no allocation).

Uses ``jax.eval_shape`` over ``init_params`` so the count is exactly what the
dry-run will lower, and provides MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D
(MoE) for the §Roofline usefulness ratio.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_params
from repro.models.config import ModelConfig


@functools.lru_cache(maxsize=None)
def _shapes(cfg: ModelConfig, dtype_str: str):
    dtype = jnp.dtype(dtype_str)
    return jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    return _shapes(cfg, jnp.dtype(dtype).name)


def count_params(cfg: ModelConfig) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(param_shapes(cfg)))


def count_active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token: MoE experts count at top_k/E (+ shared)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(param_shapes(cfg))[0]:
        n = int(np.prod(leaf.shape))
        keys = "/".join(str(p) for p in path)
        if cfg.moe is not None and any(
            f"'{w}'" in keys for w in ("moe_gate", "moe_up", "moe_down")
        ) and leaf.ndim >= 3:
            # stacked expert weights: (repeat?, E, d, f)
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        total += n
    return total


def model_flops(cfg: ModelConfig, tokens: int) -> float:
    """6·N_active·D — the §Roofline 'useful compute' yardstick."""
    return 6.0 * count_active_params(cfg) * tokens
