"""Transport layer — the collective primitives every mesh round rides.

Round-assembly code (`launch/distributed.py`) never calls raw collectives
or stages payload shardings itself: it composes a :class:`Transport`,
which owns

* the **sync exchange** — the dense worker-axis mean (one fused psum over
  the packed (nblk, B) flat buffer where packing cannot force a reshard,
  the per-leaf tree exchange otherwise — the PR-4 `flat_sync` policy), and
  the robust GAR variant on the worker gradient stack;
* the **compressed uplink** — per-leaf Block-RandK / Perm-K / QSGD payload
  staging and exchange across the worker axes (`uplink_mean`), plus the
  per-worker dense decode robust GARs aggregate (`worker_rows`);
* the **compressed downlink** — the Q_down(g^{k+1} − g^k) broadcast
  roundtrip (`downlink`);

and a **bytes-by-link-tier ledger** (`repro.core.wire.TierLedger`): every
exchange books its per-worker wire bits under (jit scope, direction, link
tier, collective kind) AT TRACE TIME — the booking is a Python-side effect
of staging the payload, so whatever a step actually lowers is exactly what
the ledger prices, tier-classified by the topology layer
(`launch/topology.py`). Ledger semantics (DESIGN.md §7):

* values are bits per worker per round — the fleet-total divided by the
  worker count, matching the `StepMetrics.bits_per_worker` convention the
  trainer and benchmarks already use (PP rounds with r < n uploaders book
  r·ζ_Q/n);
* a jit step books once per TRACE, not per call (re-executions of the
  compiled step do not re-book); `train_step` traces both `lax.cond`
  branches, so its scope holds sync + compressed bits together — read the
  per-round-type numbers from the dedicated `sync_step`/`compressed_step`
  scopes;
* the tier is the slowest link the exchange's worker axes cross
  (`Topology.tier_for_axes`) — ici inside a pod, dcn across pods or
  across the processes of a local cluster, loopback on single-process
  fake devices.

The numeric semantics of every method are bit-identical to the pre-split
`distributed.py` monolith (the subprocess trajectory tests in
tests/test_sharding.py, tests/test_pp.py and tests/test_multiproc.py are
the safety net).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import flat as flat_engine
from repro.core import wire
from repro.kernels import ref as kref
from repro.launch.topology import Topology

PyTree = Any


def _qsgd_quantize_rows(key: jax.Array, x, s: int):
    """Per-row ℓ2-norm s-level stochastic quantization over the LAST axis:
    levels = sign(x)·⌊s|x|/‖row‖ + u⌋ as int8, norms f32 (kept-dims). The
    one quantize formula both wire directions share — uplink and downlink
    must never drift apart."""
    assert 1 <= s <= 127, f"s={s} does not fit the int8 wire"
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(xf * xf, axis=-1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    u = jax.random.uniform(key, x.shape)
    q = (jnp.sign(xf) * jnp.floor(s * jnp.abs(xf) / safe + u)).astype(jnp.int8)
    return q, norm.astype(jnp.float32)


def _nibble_roundtrip_rows(q: jax.Array) -> jax.Array:
    """Push int8 levels through the genuine 4-bit wire (|level| ≤ 7): pack
    eight two's-complement nibbles per uint32 lane word, unpack back."""
    L = q.shape[-1]
    lead = q.shape[:-1]
    flat = q.reshape(-1, L)
    return kref.nibble_unpack_ref(kref.nibble_pack_ref(flat), L).reshape(
        *lead, L
    )


def _gather_along_last(x3d, idx3d, scale, backend):
    """(n, R, L) gather via the backend-switched flat primitive."""
    n_, R, L = x3d.shape
    kb = idx3d.shape[-1]
    out = flat_engine.block_gather(
        x3d.reshape(n_ * R, L), idx3d.reshape(n_ * R, kb), scale, backend
    )
    return out.reshape(n_, R, kb)


def _scatter_mean_last(vals3d, idx3d, L, backend):
    """(n_eff, R, kb) scatter-accumulate mean over workers → (R, L) f32."""
    return flat_engine.block_scatter_mean(
        vals3d.astype(jnp.float32), idx3d, L, backend
    )


def _arr_bits(*arrays) -> float:
    """Total wire bits of the staged payload arrays (dtype-exact)."""
    return float(sum(a.size * a.dtype.itemsize * 8 for a in arrays))


# -- retry/timeout/backoff (DESIGN.md §4.10) ---------------------------------
#
# Real-cluster transport operations — gloo bring-up, worker spawn, the
# coordinator rendezvous — fail transiently (port races, slow container
# start). The policy below is the one knob both the launch layer
# (topology.spawn_local_cluster / run_resilient_cluster) and CI share:
# bounded attempts, exponential backoff, a per-attempt timeout the caller
# threads into whatever blocking call it wraps.


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Timeout/retry-with-backoff dial for flaky transport operations.

    ``timeout_s`` bounds a single attempt (callers pass it to their
    blocking primitive — ``Popen.communicate``, socket connect, …);
    ``retries`` is the number of RE-tries after the first attempt (0 =
    fail fast); the sleep before retry ``i`` (0-based) is
    ``backoff_s · backoff_mult**i``. Frozen/hashable: safe as static
    config on step bundles and CI env."""

    timeout_s: float = 120.0
    retries: int = 1
    backoff_s: float = 1.0
    backoff_mult: float = 2.0

    def __post_init__(self):
        if self.timeout_s <= 0.0:
            raise ValueError("timeout_s must be positive")
        if self.retries < 0:
            raise ValueError("retries must be non-negative")
        if self.backoff_s < 0.0:
            raise ValueError("backoff_s must be non-negative")
        if self.backoff_mult < 1.0:
            raise ValueError("backoff_mult must be >= 1 (backoff never shrinks)")

    def backoff(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (0-based): backoff_s·mult^attempt."""
        return self.backoff_s * self.backoff_mult ** attempt


def retry_call(
    fn: Callable,
    policy: RetryPolicy,
    *,
    retryable: tuple = (Exception,),
    on_retry: Optional[Callable] = None,
    sleep: Callable = time.sleep,
):
    """Run ``fn()`` under ``policy``: up to ``1 + policy.retries`` attempts,
    exponential backoff between them, re-raising the last error when the
    budget is spent. Only ``retryable`` exception types trigger a retry —
    anything else propagates immediately (a config error must not burn the
    backoff budget). ``on_retry(attempt, exc)`` observes each failure
    before the sleep; ``sleep`` is injectable for tests."""
    for attempt in range(policy.retries + 1):
        try:
            return fn()
        except retryable as exc:
            if attempt >= policy.retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            sleep(policy.backoff(attempt))


@dataclasses.dataclass
class Transport:
    """Worker-axis collective interface + bytes-by-tier ledger (module doc).

    Built once per step bundle by :func:`make_transport`; frozen wire
    policy (compression family, quantization levels, payload packing,
    staging, downlink mode) lives here so round assembly passes trees and
    keys, never wire flags.
    """

    mesh: Any
    topology: Topology
    waxes: tuple
    n: int
    backend: str = "auto"
    compression: str = "randk"
    qsgd_s: int = 15
    packed_payload: bool = False
    staged_payload: bool = True
    shared_mask: bool = False
    downlink_mode: str = "none"
    downlink_s: int = 7
    # sync-exchange policy (configured by make_transport)
    flat_sync: bool = False
    sync_layout: Any = None
    sync_buf_shard: Any = None
    param_shardings: Any = None
    ledger: wire.TierLedger = dataclasses.field(
        default_factory=wire.TierLedger
    )
    _scope: str = "unscoped"

    # -- ledger -------------------------------------------------------------

    @contextlib.contextmanager
    def scope(self, name: str):
        """Tag ledger bookings with the jit step being traced. Round
        assembly wraps each step body so one shared transport attributes
        collectives to sync_step / compressed_step / train_step."""
        prev = self._scope
        self._scope = name
        try:
            yield
        finally:
            self._scope = prev

    def book(self, direction: str, kind: str, bits: float,
             axes: Optional[tuple] = None) -> None:
        """Book per-worker wire bits under the current scope, tiered by the
        worker axes the exchange crosses (defaults to this transport's).
        Public so round assembly can account exchanges the transport does
        not stage itself (the flat-PP engine aggregate)."""
        t = self.topology.tier_for_axes(
            self.waxes if axes is None else axes
        )
        self.ledger.book(self._scope, direction, t, kind, bits)

    def wire_by_tier(self) -> dict:
        """{scope: {tier: {direction: bits}}} ledger summary (JSON-ready)."""
        scopes = {s for (s, _d, _t, _k) in self.ledger.bits}
        return {s: self.ledger.by_tier(s) for s in sorted(scopes)}

    # -- shardings ----------------------------------------------------------

    @property
    def worker_sharding(self) -> NamedSharding:
        """Payload rows sharded across the worker axes."""
        wspec = (
            P(self.waxes if len(self.waxes) != 1 else self.waxes[0])
            if self.waxes else P()
        )
        return NamedSharding(self.mesh, wspec)

    @property
    def replicated(self) -> NamedSharding:
        """Replicated across the whole mesh (the payload collective's
        destination layout)."""
        return NamedSharding(self.mesh, P())

    # -- sync exchange ------------------------------------------------------

    def sync_mean(self, grads: PyTree) -> PyTree:
        """Dense worker-axis mean of the stacked gradients: one fused psum
        over the packed (nblk, B) flat buffer when ``flat_sync`` (packing
        cannot force a reshard), else the per-leaf tree exchange. Books the
        n dense f32 uploads (32d/worker up) + the dense estimator broadcast
        (32d down)."""
        d = sum(
            int(np.prod(t.shape[1:])) for t in jax.tree.leaves(grads)
        )
        self.book("up", "psum", wire.dense_f32_bits(d))
        self.book("down", "broadcast", wire.downlink_dense_bits(d))
        if self.flat_sync:
            lay = self.sync_layout
            bufs = jax.vmap(lambda t: flat_engine.pack(lay, t))(grads)
            bufs = jax.lax.with_sharding_constraint(bufs, self.sync_buf_shard)
            g_new = flat_engine.unpack(lay, jnp.mean(bufs, axis=0))
            return jax.tree.map(
                jax.lax.with_sharding_constraint, g_new, self.param_shardings
            )
        return jax.tree.map(lambda t: jnp.mean(t, axis=0), grads)

    def sync_aggregate(self, grads: PyTree, aggregator=None) -> PyTree:
        """Sync-round server aggregation: the robust GAR on the worker
        gradient stack when one is configured (combine_stacked, pinned back
        to the parameter shardings), else :meth:`sync_mean`. The wire cost
        is identical either way — n dense uploads — and is booked here."""
        if aggregator is not None and aggregator.robust:
            d = sum(
                int(np.prod(t.shape[1:])) for t in jax.tree.leaves(grads)
            )
            self.book("up", "psum", wire.dense_f32_bits(d))
            self.book("down", "broadcast", wire.downlink_dense_bits(d))
            g_new = aggregator.combine_stacked(grads)
            return jax.tree.map(
                jax.lax.with_sharding_constraint, g_new, self.param_shardings
            )
        return self.sync_mean(grads)

    # -- compressed uplink --------------------------------------------------

    def uplink_mean(
        self,
        key: jax.Array,
        diffs: PyTree,
        *,
        rows_n: Optional[int] = None,
        out_shardings: Optional[PyTree] = None,
        rows_sharded: bool = True,
        uploaded_rows: Optional[int] = None,
    ) -> PyTree:
        """Per-leaf compressed exchange across workers → dense mean update.

        Layout: each leaf (rows, *shape) is treated as (rows, R, L) with L
        its last dimension — gathers and scatters act along L only, so they
        stay local to whatever sharding the leaf has on its leading dims,
        and scatter indices never exceed L (no int64 pressure at
        10^10-parameter scale).

        Families (policy fixed at construction — DESIGN.md §4):

        * ``randk`` independent masks (paper-faithful): kb ≈ L/128 indices
          per row with replacement (unbiased, ω ≈ L/kb); the n·K payload
          replicates across the mesh — the all-gather the paper prices at
          ζ_Q. ``packed_payload`` ships bf16 values + int16 indices (int32
          when L > 32767).
        * ``shared_mask`` (beyond-paper MARINA-SM): all workers share one
          mask, so the worker mean commutes with the gather — a ζ-sized
          psum replaces the n·ζ all-gather; forfeits the 1/n variance
          averaging (ω instead of ω/√n in Thm 2.1).
        * ``permk`` (Szlendak et al. 2021): one shared permutation
          partitions each leaf's lane dimension; the exchange is an exact
          all-to-all of disjoint d/n shards — values only, the permutation
          regenerates from the replicated round key; inverse-perm gather,
          no scatter. Leaves with L % n != 0 fall back to independent
          masks.
        * ``qsgd`` (the packed quantization wire — DESIGN.md §4.6):
          workers quantize dense diff rows against per-row ℓ2 norms under
          worker-local staged constraints; the collective carries int8
          levels (4-bit nibbles in uint32 with ``packed_payload`` and
          s ≤ 7) + f32 norms, and every device runs the worker-indexed
          dequantize-and-mean — no (n, d) f32 buffer materializes.

        ``rows_n`` overrides the row count (PP cohorts upload r < n rows);
        ``rows_sharded=False`` marks a row stack that is NOT worker-sharded
        (cohort rows replicate — the staging constraints are skipped).
        Books the staged payload's dtype-exact bits: fleet-total / n per
        round under the worker-axis tier. ``uploaded_rows`` scales the
        booking when some of the staged rows never crossed the wire —
        dropped/crashed clients ride the collective as zero rows for shape
        stability, but only the surviving uploads bill (DESIGN.md §4.10:
        booked uplink == arrived·ζ_Q, mirroring the PP r·ζ_Q convention).
        """
        n = self.n if rows_n is None else rows_n
        if uploaded_rows is not None and not 0 <= uploaded_rows <= n:
            raise ValueError(
                f"uploaded_rows={uploaded_rows} outside [0, {n}] staged rows"
            )
        up_frac = 1.0 if uploaded_rows is None else uploaded_rows / n

        def book_up(kind: str, bits: float) -> None:
            self.book("up", kind, bits * up_frac)
        waxes = self.waxes if rows_sharded else ()
        staged = self.staged_payload if rows_sharded else False
        backend = self.backend
        packed = self.packed_payload

        leaves, treedef = jax.tree.flatten(diffs)
        out_shard_leaves = (
            jax.tree.leaves(out_shardings) if out_shardings is not None
            else [None] * len(leaves)
        )
        keys = jax.random.split(key, len(leaves))
        outs = []
        for lk, leaf, osh in zip(keys, leaves, out_shard_leaves):
            shape = leaf.shape[1:]
            L = int(shape[-1])
            R = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
            kb = max(1, L // 128)
            scale = L / kb
            x = leaf.reshape(n, R, L)

            wspec = P(waxes if len(waxes) != 1 else waxes[0]) if waxes else P()
            worker_sharded = NamedSharding(self.mesh, wspec)
            repl = self.replicated

            if self.compression == "permk" and L % n == 0:
                C = L // n
                perm = jax.random.permutation(lk, L)  # shared across workers
                idx = jnp.broadcast_to(perm.reshape(n, 1, C), (n, R, C))
                vals = _gather_along_last(x, idx, float(n), backend)
                if staged:
                    vals = jax.lax.with_sharding_constraint(
                        vals, worker_sharded
                    )
                # the exact all-to-all of d/n shards: VALUES ONLY ride the
                # wire (bf16 when packed); the permutation regenerates from
                # the replicated round key on every device — no index
                # payload, no scatter on arrival.
                sent = vals.astype(jnp.bfloat16) if packed else vals
                book_up("all-to-all", _arr_bits(sent) / self.n)
                sent = jax.lax.with_sharding_constraint(sent, repl)
                by_slot = jnp.moveaxis(
                    sent.astype(jnp.float32), 0, 1
                ).reshape(R, L)
                inv = jnp.argsort(perm)
                dense = (jnp.take(by_slot, inv, axis=1) / n).astype(leaf.dtype)
            elif self.compression == "qsgd":
                # shared row-quantize formula (int8-wire bound asserted
                # inside); norm is (n, R, 1) f32
                q, norm = _qsgd_quantize_rows(lk, x, int(self.qsgd_s))
                s = int(self.qsgd_s)
                if staged:
                    # quantize under the worker-sharded layout: the dense
                    # f32 diffs never leave their worker
                    q = jax.lax.with_sharding_constraint(q, worker_sharded)
                    norm = jax.lax.with_sharding_constraint(
                        norm, worker_sharded
                    )
                if packed and s <= 7 and L % 8 == 0:
                    # genuine 4-bit wire: eight signed nibbles per uint32
                    # lane word cross the collective (0.5 B/coord)
                    words = kref.nibble_pack_ref(q.reshape(n * R, L))
                    words = words.reshape(n, R, L // 8)
                    book_up("all-gather", _arr_bits(words, norm) / self.n)
                    words = jax.lax.with_sharding_constraint(words, repl)
                    q = kref.nibble_unpack_ref(
                        words.reshape(n * R, L // 8), L
                    ).reshape(n, R, L)
                else:
                    book_up("all-gather", _arr_bits(q, norm) / self.n)
                    q = jax.lax.with_sharding_constraint(q, repl)
                norm = jax.lax.with_sharding_constraint(norm, repl)

                # fused dequantize-and-mean: worker-indexed accumulation
                # into one (R, L) f32 buffer — input bandwidth stays int8
                def dq_body(w, acc):
                    qw = jax.lax.dynamic_index_in_dim(q, w, 0, keepdims=False)
                    nw = jax.lax.dynamic_index_in_dim(
                        norm, w, 0, keepdims=False
                    )
                    return acc + qw.astype(jnp.float32) * (nw / s)

                acc = jax.lax.fori_loop(
                    0, n, dq_body, jnp.zeros((R, L), jnp.float32)
                )
                dense = (acc / n).astype(leaf.dtype)
            elif self.shared_mask:
                idx = jax.random.randint(lk, (R, kb), 0, L, jnp.int32)
                vals = _gather_along_last(
                    x, jnp.broadcast_to(idx, (n, R, kb)), scale, backend
                )
                if staged:
                    # pin the gather to the worker-sharded layout so the
                    # partitioner cannot replicate the dense diffs instead
                    vals = jax.lax.with_sharding_constraint(
                        vals, worker_sharded
                    )
                # ζ-sized psum over the worker axis; stays sharded on R
                book_up("psum", _arr_bits(vals) / self.n)
                vals_mean = jnp.mean(vals, axis=0)                # (R, kb)
                dense = _scatter_mean_last(
                    vals_mean[None], idx[None], L, backend
                ).astype(leaf.dtype)
            else:
                idx = jax.random.randint(lk, (n, R, kb), 0, L, jnp.int32)
                vals = _gather_along_last(x, idx, scale, backend)
                if staged:
                    # stage 1: gather under the worker-sharded layout
                    # (local); stage 2 (below): all-gather only the K-sized
                    # payload
                    vals = jax.lax.with_sharding_constraint(
                        vals, worker_sharded
                    )
                if packed:
                    # §Perf: bf16 values + int16 indices on the wire — 8 →
                    # 4 B/coord, degrading to int32 indices (8 → 6 B/coord)
                    # when L > 32767 (int16 can't address the lane)
                    idx_wire = idx if L > 32767 else idx.astype(jnp.int16)
                    book_up(
                        "all-gather",
                        _arr_bits(vals.astype(jnp.bfloat16), idx_wire)
                        / self.n,
                    )
                    vals = jax.lax.with_sharding_constraint(
                        vals.astype(jnp.bfloat16), repl
                    ).astype(leaf.dtype)
                    idx = jax.lax.with_sharding_constraint(
                        idx_wire, repl
                    ).astype(jnp.int32)
                else:
                    book_up("all-gather", _arr_bits(vals, idx) / self.n)
                    vals = jax.lax.with_sharding_constraint(vals, repl)
                    idx = jax.lax.with_sharding_constraint(idx, repl)
                dense = _scatter_mean_last(
                    vals, idx, L, backend
                ).astype(leaf.dtype)

            out = dense.reshape(shape)
            if osh is not None and staged:
                # pin the decompressed accumulator to the destination
                # leaf's sharding — otherwise the partitioner may
                # materialize the scatter replicated (a 435 GB buffer for
                # the 671B expert stack)
                out = jax.lax.with_sharding_constraint(out, osh)
            outs.append(out)
        return jax.tree.unflatten(treedef, outs)

    def worker_rows(
        self,
        key: jax.Array,
        diffs: PyTree,
        rows_n: int,
        *,
        uploaded_rows: Optional[int] = None,
    ) -> PyTree:
        """Per-worker DENSE payload rows — what the server actually
        received from each client, before any aggregation (DESIGN.md §4.9).

        Robust GARs cannot ride the fused dequantize-and-mean of
        :meth:`uplink_mean` (trim/median/Krum/clip don't commute with the
        mean), so the robust wire decodes every worker's payload to a dense
        (n, *leaf) row stack for ``ServerAggregator.combine_stacked``. Key
        discipline is IDENTICAL to the mean path (one split per leaf, same
        per-leaf draw shapes), so the honest rows carry exactly the values
        the fused path would have averaged. The wire cost is unchanged —
        the same payloads cross the same link — and books identically;
        the dense row stack costs the fused path's memory saving.
        ``permk`` is refused upstream (coordinates partition across
        workers; nothing to aggregate robustly). ``uploaded_rows`` scales
        the booking exactly like :meth:`uplink_mean` — rows that never
        arrived ride as zeros for shape stability but do not bill."""
        n = rows_n
        if uploaded_rows is not None and not 0 <= uploaded_rows <= n:
            raise ValueError(
                f"uploaded_rows={uploaded_rows} outside [0, {n}] staged rows"
            )
        up_frac = 1.0 if uploaded_rows is None else uploaded_rows / n

        def book_up(kind: str, bits: float) -> None:
            self.book("up", kind, bits * up_frac)
        leaves, treedef = jax.tree.flatten(diffs)
        keys = jax.random.split(key, len(leaves))
        rows = []
        for lk, leaf in zip(keys, leaves):
            shape = leaf.shape[1:]
            L = int(shape[-1])
            R = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
            kb = max(1, L // 128)
            scale = L / kb
            x = leaf.reshape(n, R, L)
            if self.compression == "qsgd":
                q, norm = _qsgd_quantize_rows(lk, x, int(self.qsgd_s))
                s = int(self.qsgd_s)
                if self.packed_payload and s <= 7 and L % 8 == 0:
                    book_up(
                        "all-gather",
                        (_arr_bits(norm) + _arr_bits(q) / 2) / self.n,
                    )
                    q = _nibble_roundtrip_rows(q)
                else:
                    book_up("all-gather", _arr_bits(q, norm) / self.n)
                dense = q.astype(jnp.float32) * (norm / s)
            else:  # independent Block-RandK masks
                idx = jax.random.randint(lk, (n, R, kb), 0, L, jnp.int32)
                vals = _gather_along_last(x, idx, scale, self.backend)
                book_up("all-gather", _arr_bits(vals, idx) / self.n)
                dense = jax.vmap(
                    lambda v, i: _scatter_mean_last(
                        v[None], i[None], L, self.backend
                    )
                )(vals, idx)
            rows.append(dense.reshape((n,) + tuple(shape)))
        return jax.tree.unflatten(treedef, rows)

    # -- compressed downlink ------------------------------------------------

    def downlink(self, key: jax.Array, delta: PyTree) -> PyTree:
        """Compressed downlink on the aggregated round delta (DESIGN.md
        §4.7). The server broadcasts Q_down(g^{k+1} − g^k) = Q_down(δ_up);
        since δ_up is replicated after aggregation, every device compresses
        with the SHARED round key (one payload, one broadcast) and
        decompress-accumulates — the estimator recursion runs on the
        broadcast sequence, so worker replicas stay bitwise in sync.
        "qsgd": per-row ℓ2-norm s-level quantization, int8 (4-bit nibbles
        with ``packed_payload`` and s ≤ 7). "randk": seeded K-subsample
        (K = L/128 per row), indices regenerate from the key. "none"
        passes the dense delta through and books the dense f32 broadcast
        the ledger used to silently ignore."""
        mode, s = self.downlink_mode, self.downlink_s
        if mode == "none":
            d = sum(int(np.prod(t.shape)) for t in jax.tree.leaves(delta))
            self.book("down", "broadcast", wire.downlink_dense_bits(d))
            return delta
        leaves, treedef = jax.tree.flatten(delta)
        keys = jax.random.split(key, len(leaves))
        outs = []
        for lk, leaf in zip(keys, leaves):
            shape = leaf.shape
            L = int(shape[-1])
            R = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
            x = leaf.reshape(R, L).astype(jnp.float32)
            if mode == "qsgd":
                # the same shared row-quantize formula as the uplink
                q, norm = _qsgd_quantize_rows(lk, x, s)
                if self.packed_payload and s <= 7 and L % 8 == 0:
                    # the broadcast genuinely crosses the 4-bit wire
                    self.book(
                        "down", "broadcast",
                        _arr_bits(norm) + _arr_bits(q) / 2,
                    )
                    q = _nibble_roundtrip_rows(q)
                else:
                    self.book("down", "broadcast", _arr_bits(q, norm))
                y = q.astype(jnp.float32) * (norm / s)
            elif mode == "randk":
                kb = max(1, L // 128)
                idx = jax.random.randint(lk, (R, kb), 0, L, jnp.int32)
                vals = jnp.take_along_axis(x, idx, axis=1) * (L / kb)
                # seeded subsample: values only, indices regenerate
                self.book("down", "broadcast", _arr_bits(vals))
                y = jnp.zeros((R, L), jnp.float32).at[
                    jnp.arange(R)[:, None], idx
                ].add(vals)
            else:
                raise ValueError(f"unknown downlink {mode!r}")
            outs.append(y.reshape(shape).astype(leaf.dtype))
        return jax.tree.unflatten(treedef, outs)


def make_transport(
    mesh,
    topology: Topology,
    waxes: tuple,
    n: int,
    *,
    backend: str = "auto",
    compression: str = "randk",
    qsgd_s: int = 15,
    packed_payload: bool = False,
    staged_payload: bool = True,
    shared_mask: bool = False,
    downlink: str = "none",
    downlink_s: int = 7,
    flat_sync: bool = False,
    sync_layout=None,
    sync_buf_shard=None,
    param_shardings=None,
) -> Transport:
    """Build the per-bundle :class:`Transport` (wire policy + sync-exchange
    layout + a fresh tier ledger). One transport per step bundle: the
    ledger's scopes separate the bundle's jitted entries."""
    return Transport(
        mesh=mesh, topology=topology, waxes=tuple(waxes), n=n,
        backend=backend, compression=compression, qsgd_s=qsgd_s,
        packed_payload=packed_payload, staged_payload=staged_payload,
        shared_mask=shared_mask, downlink_mode=downlink,
        downlink_s=downlink_s, flat_sync=flat_sync, sync_layout=sync_layout,
        sync_buf_shard=sync_buf_shard, param_shardings=param_shardings,
    )
