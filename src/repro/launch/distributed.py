"""Round assembly: MARINA train rounds composed on the mesh.

This is the mesh instantiation of the algorithm in core/marina.py (the
simulation backend and this file share the update equations; the difference
is explicit GSPMD shardings and payload collectives — DESIGN.md §3). Since
ISSUE 7 the launch stack is three layers (DESIGN.md §7):

* **topology** (`launch/topology.py`) — the device fabric: mesh
  construction, worker axes, link tiers (loopback / ici / dcn), and
  multi-process bring-up;
* **transport** (`launch/transport.py`) — the collective primitives: the
  dense sync exchange, the compressed uplink (randk / shared-mask / permk /
  qsgd), the per-worker robust decode, and the compressed downlink, each
  booking its wire bits into the bytes-by-link-tier ledger
  (`core/wire.TierLedger`);
* **round assembly** (this file) — composition only: step bodies wire
  gradients, carries, cohorts and faults through the transport interface,
  and never call raw collectives or stage payload shardings themselves.

Steps built here: ``sync_step`` (the probability-p dense round —
``Transport.sync_aggregate``), ``compressed_step`` (the probability-(1−p)
round: two-point gradient differences through ``Transport.uplink_mean`` +
``Transport.downlink``), and ``train_step`` (Bernoulli(p) `lax.cond` over
the two; the dry-run lowers sync/compressed separately so §Roofline can
attribute costs per round type). Serving assembly lives in
launch/serve_steps.py. The exchange semantics of every wire family and the
round-pipeline overrides (grad_carry, flat_sync, downlink, participation,
aggregator, faults) are documented on the transport methods and the
``build_train_steps`` flags below.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.core import flat as flat_engine
from repro.core.marina import (
    _FAULT_FOLD,
    _carry_refresh,
    _sync_faults,
    _uplink_faults,
)
from repro.models import init_params, lm_loss
from repro.launch import sharding as shd
from repro.launch.participation import build_pp_steps, pp_cohort_schedule  # noqa: F401
from repro.launch.topology import detect_topology, num_workers, worker_axis_names
from repro.launch.transport import make_transport

PyTree = Any

BLOCK = 1024   # compression block width (8×128 VMEM tile)
KB = 8         # retained coords per block → ζ/d = 1/128, ω = 127


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything the dry-run needs for one (arch × mesh) combination."""

    mesh: Any
    n_workers: int
    param_shapes: PyTree
    param_shardings: PyTree
    fns: dict  # name -> (jitted fn, example abstract args)
    meta: dict = dataclasses.field(default_factory=dict)  # builder decisions
    # (participation mode, cohort-compute vs masked fallback, flat-PP path)
    transport: Any = None  # the Transport whose ledger priced this bundle


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_steps(
    arch: ArchConfig,
    mesh,
    multi_pod: bool,
    *,
    global_batch: int,
    seq_len: int,
    gamma: float = 1e-3,
    p: float = KB / BLOCK,
    dtype=jnp.bfloat16,
    shared_mask: bool = False,
    remat: bool = True,
    packed_payload: bool = False,
    replicate_params: bool = False,
    staged_payload: bool = True,
    compression_backend: str = "auto",
    compression: str = "randk",
    qsgd_s: int = 15,
    grad_carry: bool = False,
    flat_sync: "bool | None" = None,
    downlink: str = "none",
    downlink_s: int = 7,
    participation: "tuple[int, str] | None" = None,
    aggregator: "Any | None" = None,
    faults: "Any | None" = None,
    topology: "Any | None" = None,
):
    """Returns (fns, abstract_args) for sync_step / compressed_step / train_step.

    §Perf overrides (wire policy freezes into the transport —
    launch/transport.py documents each family's exchange semantics):
    * shared_mask      — SharedRandK: K-value psum instead of n·K all-gather
    * packed_payload   — bf16 values + int16 indices on the wire; with
      compression="qsgd" and s ≤ 7, 4-bit nibble packing instead
    * compression      — "randk" | "permk" | "qsgd" (DESIGN.md §4.2–§4.6)
    * qsgd_s           — quantization levels for compression="qsgd"
    * topology         — modeled fabric for the wire ledger (default: the
      runtime fabric via detect_topology; perf/dryrun pass the production
      topology so bits book under the tiers the mesh MODELS)
    * replicate_params — small-model mode: no tensor parallelism; the model
      axis becomes within-worker data parallelism
    * grad_carry       — single-backprop compressed rounds: the step carry
      grows per-worker h_i^k = ∇f_i(x^k) (sharded like the grads, donated);
      signatures become (params, g, h, batch[, key]) → (params, g, h)
    * flat_sync        — sync rounds exchange ONE packed (n, nblk, B) buffer
      instead of one collective per leaf. Default (None) auto-enables it
      only when packing cannot force a reshard of model-parallel leaves
      (replicated params, or a mesh whose axes are all worker axes) —
      otherwise GSPMD must all-gather the dense grads to assemble the flat
      buffer (~4× sync-step memory on the qwen 0.5B dryrun)
    * downlink         — "none" (dense estimator broadcast) or
      "qsgd"/"randk": broadcast Q_down(g^{k+1} − g^k) and
      decompress-accumulate worker-side (downlink_s levels)
    * participation    — (r, "with"|"without"): PP-MARINA on the mesh
      (DESIGN.md §4.8). Compressed rounds sample a cohort of r clients from
      ``pp_cohort_schedule`` (steps gain a trailing (r,) int32 ``sel``
      argument), respread the r clients' batch rows over ALL n shards (the
      genuine r/n compute saving) and put exactly r payload rows on the
      wire; falls back to masked dense compute when r doesn't divide
      n·per_worker evenly (recorded in ``bundle.meta``). With ``grad_carry``
      the step's h becomes the server-side carry table: only sampled rows
      refresh. Composes with randk/permk/qsgd but not shared_mask. On
      packing-legal meshes PP rounds are trajectory-equal to core
      ``PPMarina`` for ``downlink="none"`` — see DESIGN.md §4.8
    * aggregator       — a ``repro.core.ServerAggregator``: swap the server
      mean for a robust GAR on decoded per-worker rows
      (``Transport.worker_rows``; DESIGN.md §4.9). Refused with permk and
      shared_mask (payloads aren't per-coordinate comparable)
    * faults           — a ``repro.core.FaultSpec``: per-round client fault
      injection on the uplinked payloads (repro.core.faults); ``drop``
      requires ``grad_carry`` (the carried h row substitutes the missing
      upload)
    """
    cfg = dataclasses.replace(arch.model, remat=remat)
    robust = aggregator is not None and aggregator.robust
    if robust:
        if compression == "permk":
            raise ValueError(
                f"robust rule {aggregator.rule!r} is undefined on the permk "
                "wire: workers partition the coordinates (DESIGN.md §4.9)"
            )
        if shared_mask:
            raise ValueError(
                f"robust rule {aggregator.rule!r} is undefined with "
                "shared_mask: one correlated mask spans the whole fleet "
                "(DESIGN.md §4.9)"
            )
    if faults is not None and faults.attack == "drop" and not grad_carry:
        raise ValueError(
            "faults='drop' substitutes the carried h row for the missing "
            "upload — grad_carry=True is required (DESIGN.md §4.9)"
        )
    waxes = worker_axis_names(multi_pod, arch.worker_axes)
    fsdp = arch.fsdp and not any(a in waxes for a in ("data",))
    n = num_workers(mesh, multi_pod, arch.worker_axes)
    per_worker = global_batch // n
    inner_axis = "data" if (fsdp and "data" not in waxes) else None
    if replicate_params:
        inner_axis = "model"

    param_shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )
    if replicate_params:
        p_shard = jax.tree.map(lambda _: shd.replicated(mesh), param_shapes)
    else:
        p_shard = shd.param_sharding_tree(param_shapes, mesh, fsdp)

    # total positions = seq_len; frontend archs spend prefix_len of them on
    # stub embeddings so S stays chunk-aligned
    tok_len = seq_len - arch.prefix_len
    tok_shape = (n, per_worker, tok_len)
    batch = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    batch_shard = {
        "tokens": NamedSharding(mesh, shd.batch_spec(waxes, inner_axis, 3))
    }
    if arch.prefix_len:
        pshape = (n, per_worker, arch.prefix_len, cfg.d_model)
        batch["prefix"] = jax.ShapeDtypeStruct(pshape, dtype)
        batch_shard["prefix"] = NamedSharding(
            mesh, shd.batch_spec(waxes, inner_axis, 4)
        )

    def loss_fn(params, one_batch):
        return lm_loss(
            params, cfg, one_batch["tokens"], one_batch.get("prefix")
        )

    # remat is per-layer inside the model (cfg.remat above)
    grad_one = jax.grad(loss_fn)

    def worker_grads(params, batch):
        return jax.vmap(grad_one, in_axes=(None, 0))(params, batch)

    # sync rounds ride the flat buffer: one fused mean over the packed
    # (n, nblk, B) buffer — a single worker-axis psum of d — instead of one
    # collective per leaf. The buffer's block dim is pinned to the
    # non-worker mesh axes (when they divide nblk) so the dense grads never
    # replicate, and the unpacked mean is pinned back to the parameter
    # shardings.
    lay = flat_engine.make_layout(param_shapes, block=BLOCK)
    wlead = waxes if len(waxes) > 1 else (waxes[0] if waxes else None)
    # size-1 axes cannot shard anything, so they neither disqualify the
    # packed exchange nor are worth pinning block rows to
    inner = tuple(
        a for a in mesh.shape
        if a not in set(waxes) and mesh.shape[a] > 1
    )
    if flat_sync is None:
        flat_sync = replicate_params or not inner
    blk_axes = inner if (
        inner and lay.nblk % int(np.prod([mesh.shape[a] for a in inner])) == 0
    ) else None
    buf_shard = NamedSharding(
        mesh,
        P(wlead, blk_axes if blk_axes and len(blk_axes) > 1
          else (blk_axes[0] if blk_axes else None), None),
    )

    # the transport owns all wire policy + the bytes-by-tier ledger; the
    # topology classifies which link tier the worker axes cross. Callers
    # modeling a production fabric on fake devices (perf/dryrun) pass the
    # modeled topology; by default the RUNTIME fabric is detected.
    topo = topology if topology is not None else detect_topology(
        mesh, multi_pod=multi_pod
    )
    transport = make_transport(
        mesh, topo, waxes, n,
        backend=compression_backend, compression=compression, qsgd_s=qsgd_s,
        packed_payload=packed_payload, staged_payload=staged_payload,
        shared_mask=shared_mask, downlink=downlink, downlink_s=downlink_s,
        flat_sync=flat_sync, sync_layout=lay, sync_buf_shard=buf_shard,
        param_shardings=p_shard,
    )

    # mesh sync steps are keyless by design, so the (rare) sync-round
    # garbage noise draws from a fixed key — every other attack is
    # deterministic and unaffected
    sync_fault_key = jax.random.PRNGKey(_FAULT_FOLD)

    def sync_uplink(grads):
        return _sync_faults(faults, sync_fault_key, grads, jnp.arange(n), n)

    def descend(params, g):
        return jax.tree.map(
            lambda w, gg: w - gamma * gg.astype(w.dtype), params, g
        )

    def robust_delta(key, diffs, rows_n):
        """Robust compressed-round delta: per-worker dense payload rows →
        GAR → parameter-sharding pins (replaces the fused mean)."""
        rows = transport.worker_rows(key, diffs, rows_n)
        delta = aggregator.combine_stacked(rows)
        return jax.tree.map(
            jax.lax.with_sharding_constraint, delta, p_shard
        )

    # dropped/crashed clients ride the collective as zero rows (shape
    # stability across the fleet), but only the surviving uploads bill:
    # booked uplink == (n − f)·ζ_Q, mirroring the PP r·ζ_Q convention
    # (DESIGN.md §4.10). drop+GAR is refused at construction, so the
    # robust path never sees dropped rows.
    drop_uploaded = (
        n - faults.n_faulty(n)
        if faults is not None and faults.attack == "drop" else None
    )

    def compressed_delta(key, diffs):
        k_up, k_down = jax.random.split(key)
        k_up = k_up if downlink != "none" else key
        if robust:
            delta = robust_delta(k_up, diffs, n)
        else:
            delta = transport.uplink_mean(
                k_up, diffs, out_shardings=p_shard,
                uploaded_rows=drop_uploaded,
            )
        return transport.downlink(k_down, delta)

    if grad_carry:
        # single-backprop rounds: the carry holds h_i^k = ∇f_i(x^k), so the
        # compressed round differences against it instead of re-running the
        # second vmapped backprop at the old point.
        def sync_step(params, g, h, batch):
            x_new = descend(params, g)
            grads = worker_grads(x_new, batch)
            # h keeps the HONEST gradients: liars lie on the wire, the
            # simulated clients still know their own state
            return (
                x_new,
                transport.sync_aggregate(sync_uplink(grads), aggregator),
                grads,
            )

        def compressed_step(params, g, h, batch, key):
            x_new = descend(params, g)
            g_plus = worker_grads(x_new, batch)
            diffs = jax.tree.map(jnp.subtract, g_plus, h)
            diffs = _uplink_faults(
                faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                jnp.arange(n), n,
            )
            g_new = jax.tree.map(jnp.add, g, compressed_delta(key, diffs))
            # dropped rows keep their old h (the server never heard from
            # them); c_k=False — this IS the compressed branch
            h_new = _carry_refresh(h, g_plus, faults, jnp.asarray(False), n)
            return x_new, g_new, h_new

        def train_step(params, g, h, batch, key):
            k_b, k_q = jax.random.split(key)
            c_k = jax.random.bernoulli(k_b, p)
            return jax.lax.cond(
                c_k,
                lambda _: sync_step(params, g, h, batch),
                lambda _: compressed_step(params, g, h, batch, k_q),
                None,
            )
    else:
        def sync_step(params, g, batch):
            x_new = descend(params, g)
            grads = worker_grads(x_new, batch)
            return x_new, transport.sync_aggregate(
                sync_uplink(grads), aggregator
            )

        def compressed_step(params, g, batch, key):
            x_new = descend(params, g)
            g_plus = worker_grads(x_new, batch)
            g_minus = worker_grads(params, batch)
            diffs = jax.tree.map(jnp.subtract, g_plus, g_minus)
            diffs = _uplink_faults(
                faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                jnp.arange(n), n,
            )
            g_new = jax.tree.map(jnp.add, g, compressed_delta(key, diffs))
            return x_new, g_new

        def train_step(params, g, batch, key):
            k_b, k_q = jax.random.split(key)
            c_k = jax.random.bernoulli(k_b, p)
            return jax.lax.cond(
                c_k,
                lambda _: sync_step(params, g, batch),
                lambda _: compressed_step(params, g, batch, k_q),
                None,
            )

    pp_meta = {}
    if participation is not None:
        # federated PP-MARINA cohort rounds override compressed/train
        # (launch/participation.py — sync rounds stay as built above)
        compressed_step, train_step, pp_meta = build_pp_steps(
            participation, n=n, per_worker=per_worker, p=p, block=BLOCK,
            kb=KB, shared_mask=shared_mask, compression=compression,
            compression_backend=compression_backend, qsgd_s=qsgd_s,
            replicate_params=replicate_params, inner=inner,
            param_shapes=param_shapes, p_shard=p_shard,
            batch_shard=batch_shard, mesh=mesh, transport=transport,
            downlink=downlink, robust=robust, aggregator=aggregator,
            faults=faults, grad_carry=grad_carry, sync_step=sync_step,
            worker_grads=worker_grads, descend=descend,
            robust_delta=robust_delta,
        )

    g_shard = p_shard  # estimator g^k lives like the params
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    repl = shd.replicated(mesh)

    # one fns construction for both carries: grad_carry threads the h slot
    # (worker axes on the leading dim, the leaf's own parameter sharding
    # behind it; donated with params/g) through every entry.
    if grad_carry:
        h_in = (jax.tree.map(
            lambda ns: NamedSharding(mesh, P(wlead, *ns.spec)), p_shard
        ),)
        h_args = (jax.tree.map(
            lambda sh: jax.ShapeDtypeStruct((n, *sh.shape), sh.dtype),
            param_shapes,
        ),)
    else:
        h_in = h_args = ()
    state_out = (p_shard, g_shard, *h_in)
    donate = tuple(range(2 + len(h_in)))

    pp = participation is not None
    sel_spec = (
        jax.ShapeDtypeStruct((participation[0],), jnp.int32) if pp else None
    )

    def entry(name, fn, needs_key, needs_sel=False):
        key_in = (repl,) if needs_key else ()
        key_arg = (key_spec,) if needs_key else ()
        sel_in = (repl,) if needs_sel else ()
        sel_arg = (sel_spec,) if needs_sel else ()

        def scoped(*step_args):
            # ledger bookings from this trace land under the entry's name
            # (train_step traces both cond branches → books sync +
            # compressed together; read per-round-type numbers from the
            # dedicated sync/compressed scopes)
            with transport.scope(name):
                return fn(*step_args)

        return (
            jax.jit(
                scoped,
                in_shardings=(
                    p_shard, g_shard, *h_in, batch_shard, *key_in, *sel_in
                ),
                out_shardings=state_out,
                donate_argnums=donate,
            ),
            (param_shapes, param_shapes, *h_args, batch, *key_arg, *sel_arg),
        )

    fns = {
        "sync_step": entry("sync_step", sync_step, needs_key=False),
        "compressed_step": entry(
            "compressed_step", compressed_step, needs_key=True, needs_sel=pp
        ),
        "train_step": entry(
            "train_step", train_step, needs_key=True, needs_sel=pp
        ),
    }
    return StepBundle(
        mesh=mesh,
        n_workers=n,
        param_shapes=param_shapes,
        param_shardings=p_shard,
        fns=fns,
        meta={
            **pp_meta,
            **({"aggregator": aggregator.rule} if robust else {}),
            **({"faults": faults.attack} if faults is not None else {}),
        },
        transport=transport,
    )


# Serving assembly moved to launch/serve_steps.py (ISSUE 7 split); re-export
# so existing callers (dryrun, perf, check_api_docs) keep one import site.
from repro.launch.serve_steps import build_serve_steps  # noqa: E402,F401
