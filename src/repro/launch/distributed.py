"""Sharded production steps: MARINA train rounds + serve prefill/decode.

This is the mesh instantiation of the algorithm in core/marina.py (the
simulation backend and this file share the update equations; the difference is
explicit GSPMD shardings and payload collectives — DESIGN.md §3):

* ``sync_step``       — the probability-p dense round: per-worker gradients
  averaged across the worker axis (an all-reduce of d, exactly the paper's
  "send dense ∇f_i" cost).
* ``compressed_step`` — the probability-(1−p) round: per-worker two-point
  gradient differences, Block-RandK compressed; payloads are *replicated across
  the worker axes* (the HLO all-gather whose bytes are the paper's ζ_Q), then
  scatter-decompressed and averaged locally by every device. With
  ``compression="permk"`` the round uses the correlated Perm-K compressor
  (Szlendak et al. 2021): one shared permutation partitions each leaf's lane
  dimension across workers, every worker's payload is a disjoint L/n shard,
  and the exchange is an exact all-to-all of those shards — values only, no
  indices on the wire (the permutation regenerates from the replicated round
  key), and the mean assembles by inverse-perm gather with zero scatter
  collisions.
  With ``compression="qsgd"`` the round ships the packed quantization wire
  (DESIGN.md §4.6): workers quantize dense diff rows against per-row ℓ2
  norms under worker-local sharding constraints, the collective carries int8
  levels (or 4-bit nibbles in uint32 with ``packed_payload`` and s ≤ 7) +
  f32 norms — 1 (or 0.5) B/coord instead of 4 — and every device runs the
  worker-indexed dequantize-and-mean.
* ``train_step``      — production step: Bernoulli(p) `lax.cond` over the two.
  The dry-run lowers sync/compressed separately so §Roofline can attribute
  costs per round type.

Round-pipeline overrides (DESIGN.md §4.7):

* ``grad_carry=True`` — the step carry grows the per-worker gradients
  ``h_i^k = ∇f_i(x^k)`` (worker-stacked tree, sharded like the grads,
  donated): a compressed round evaluates ONE vmapped backprop (at x^{k+1})
  and differences against the carried h instead of recomputing at x^k —
  legal whenever each worker's oracle is deterministic in the iterate (fixed
  local shards). Step signatures become (params, g, h, batch[, key]) →
  (params, g, h).
* ``flat_sync=True`` — sync rounds ride the flat buffer: the per-leaf dense
  tree exchange is replaced by ONE fused mean over the packed (nblk, B)
  buffer (a single worker-axis psum of d instead of one collective per
  leaf); the unpacked mean is pinned back to the parameter shardings.
* ``downlink=`` — compressed downlink mirroring ``compression=``: the server
  side broadcasts Q_down(g^{k+1} − g^k) = Q_down(δ_up) instead of the dense
  estimator ("qsgd" quantizes the aggregated delta rows against per-row ℓ2
  norms, int8 — or 4-bit nibbles with ``packed_payload`` — and every worker
  decompress-accumulates; "randk" broadcasts a seeded K-subsample). The
  recursion runs on the broadcast estimator, so worker replicas stay exact.
* ``participation=(r, scheme)`` — federated PP-MARINA (Alg. 4, DESIGN.md
  §4.8): compressed rounds take a cohort row from ``pp_cohort_schedule``,
  respread the r sampled clients' batch rows over all n worker shards (each
  shard backprops r/n of its full-round tokens) and put exactly r payload
  rows on the wire; with ``grad_carry`` the carried h becomes the
  server-side per-client table, refreshed only for sampled clients.

The inner gather/scatter run through the backend-switched block primitives in
repro.core.flat (``block_gather`` / ``block_scatter_mean``): the pure-jnp ref
path (bit-identical to kernels/ref.py) on CPU simulation, the Pallas kernels
in repro.kernels on real TPU hardware (DESIGN.md §4/§5).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.core import flat as flat_engine
from repro.core.marina import (
    _FAULT_FOLD,
    _carry_refresh,
    _pp_carry_refresh,
    _sync_faults,
    _uplink_faults,
)
from repro.kernels import ref as kref
from repro.models import init_cache, init_params, lm_loss, decode_step as model_decode, prefill as model_prefill
from repro.launch import sharding as shd
from repro.launch.mesh import cohort_group_size, num_workers, worker_axis_names

PyTree = Any

BLOCK = 1024   # compression block width (8×128 VMEM tile)
KB = 8         # retained coords per block → ζ/d = 1/128, ω = 127


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything the dry-run needs for one (arch × mesh) combination."""

    mesh: Any
    n_workers: int
    param_shapes: PyTree
    param_shardings: PyTree
    fns: dict  # name -> (jitted fn, example abstract args)
    meta: dict = dataclasses.field(default_factory=dict)  # builder decisions
    # (participation mode, cohort-compute vs masked fallback, flat-PP path)


# ---------------------------------------------------------------------------
# Block-RandK on worker-stacked leaves (pure jnp; ref semantics of kernels/)
# ---------------------------------------------------------------------------


def _qsgd_quantize_rows(key: jax.Array, x, s: int):
    """Per-row ℓ2-norm s-level stochastic quantization over the LAST axis:
    levels = sign(x)·⌊s|x|/‖row‖ + u⌋ as int8, norms f32 (kept-dims). The
    one quantize formula both wire directions share — uplink
    (``compression="qsgd"``, worker-stacked rows) and downlink
    (:func:`_downlink_roundtrip`) must never drift apart."""
    assert 1 <= s <= 127, f"s={s} does not fit the int8 wire"
    xf = x.astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(xf * xf, axis=-1, keepdims=True))
    safe = jnp.where(norm > 0, norm, 1.0)
    u = jax.random.uniform(key, x.shape)
    q = (jnp.sign(xf) * jnp.floor(s * jnp.abs(xf) / safe + u)).astype(jnp.int8)
    return q, norm.astype(jnp.float32)


def _nibble_roundtrip_rows(q: jax.Array) -> jax.Array:
    """Push int8 levels through the genuine 4-bit wire (|level| ≤ 7): pack
    eight two's-complement nibbles per uint32 lane word, unpack back."""
    L = q.shape[-1]
    lead = q.shape[:-1]
    flat = q.reshape(-1, L)
    return kref.nibble_unpack_ref(kref.nibble_pack_ref(flat), L).reshape(
        *lead, L
    )


def _gather_along_last(x3d, idx3d, scale, backend):
    """(n, R, L) gather via the backend-switched flat primitive."""
    n_, R, L = x3d.shape
    kb = idx3d.shape[-1]
    out = flat_engine.block_gather(
        x3d.reshape(n_ * R, L), idx3d.reshape(n_ * R, kb), scale, backend
    )
    return out.reshape(n_, R, kb)


def _scatter_mean_last(vals3d, idx3d, L, backend):
    """(n_eff, R, kb) scatter-accumulate mean over workers → (R, L) f32."""
    return flat_engine.block_scatter_mean(
        vals3d.astype(jnp.float32), idx3d, L, backend
    )


def _compress_decompress_mean(
    key: jax.Array,
    diffs: PyTree,
    n: int,
    mesh,
    waxes: tuple = (),
    shared_mask: bool = False,
    packed_payload: bool = False,
    staged_payload: bool = True,
    out_shardings: "PyTree | None" = None,
    backend: str = "auto",
    compression: str = "randk",
    qsgd_s: int = 15,
) -> PyTree:
    """Per-leaf Block-RandK across workers → dense mean update.

    Layout: each leaf (n, *shape) is treated as (n, R, L) with L = its last
    dimension — gathers and scatters act along L only, so they stay local to
    whatever sharding the leaf has on its leading dims, and scatter indices
    never exceed L (no int64 pressure at 10^10-parameter scale). Sampling is
    kb ≈ L/128 indices per row with replacement (unbiased, ω ≈ L/kb — same
    class as kernels/randk.py's seeded sampler).

    independent masks (paper-faithful): the n·K payload is replicated across
    the mesh — the all-gather the paper prices at ζ_Q. Feasible for the
    small/mid models; for ≥27B models the replicated payload itself exceeds
    HBM, which the baseline records and §Perf resolves via:

    shared_mask=True (beyond-paper, MARINA-SM): all workers share one mask, so
    the worker mean commutes with the gather — a ζ-sized *psum* over the
    worker axis replaces the n·ζ all-gather, payload and dense accumulator
    both stay sharded, and the scheme scales to 671B. Theory cost: the
    cross-worker error correlation forfeits the 1/n variance averaging
    (ω instead of ω/√n in Thm 2.1).

    compression="qsgd" (the packed quantization wire — DESIGN.md §4.6): each
    worker quantizes its dense diff rows against per-row ℓ2 norms (s levels,
    stochastic dither) and the payload collective carries int8 levels + f32
    norms — 1 B/coord instead of 4. With ``packed_payload`` and s ≤ 7 the
    levels ship as signed 4-bit nibbles packed eight-per-uint32 (0.5 B/coord).
    The dense f32 diffs stay worker-local (staged constraints); every device
    dequantize-and-means the replicated int8 payload with a worker-indexed
    accumulation loop, so no (n, d) f32 buffer is ever materialized.

    compression="permk" (Szlendak et al. 2021): one permutation of each
    leaf's lane dimension, SHARED across workers, partitions the coordinates;
    worker i's payload is its disjoint (R, L/n) shard ×n. Because supports
    are disjoint, the exchange is an exact all-to-all of d/n shards — values
    only, no indices (every device regenerates the permutation from the
    replicated round key) — and the mean assembles by inverse-permutation
    *gather*: no scatter, no collisions, and no (A − B) > 0 variance premium
    in the stepsize (core/stepsize.py::marina_gamma_permk). Leaves whose lane
    width L is not divisible by n fall back to the independent-mask path.
    """
    leaves, treedef = jax.tree.flatten(diffs)
    out_shard_leaves = (
        jax.tree.leaves(out_shardings) if out_shardings is not None
        else [None] * len(leaves)
    )
    keys = jax.random.split(key, len(leaves))
    outs = []
    for lk, leaf, osh in zip(keys, leaves, out_shard_leaves):
        shape = leaf.shape[1:]
        L = int(shape[-1])
        R = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        kb = max(1, L // 128)
        scale = L / kb
        x = leaf.reshape(n, R, L)

        wspec = P(waxes if len(waxes) != 1 else waxes[0]) if waxes else P()
        worker_sharded = NamedSharding(mesh, wspec)

        if compression == "permk" and L % n == 0:
            C = L // n
            perm = jax.random.permutation(lk, L)  # shared across workers
            idx = jnp.broadcast_to(perm.reshape(n, 1, C), (n, R, C))
            vals = _gather_along_last(x, idx, float(n), backend)  # Q_i nonzeros
            if staged_payload:
                vals = jax.lax.with_sharding_constraint(vals, worker_sharded)
            repl = NamedSharding(mesh, P())
            # the exact all-to-all of d/n shards: VALUES ONLY ride the wire
            # (bf16 when packed); the permutation regenerates from the
            # replicated round key on every device, so there is no index
            # payload and no scatter on arrival.
            wire = vals.astype(jnp.bfloat16) if packed_payload else vals
            wire = jax.lax.with_sharding_constraint(wire, repl)
            by_slot = jnp.moveaxis(wire.astype(jnp.float32), 0, 1).reshape(R, L)
            inv = jnp.argsort(perm)
            dense = (jnp.take(by_slot, inv, axis=1) / n).astype(leaf.dtype)
        elif compression == "qsgd":
            # shared row-quantize formula (int8-wire bound asserted inside);
            # norm is (n, R, 1) f32
            q, norm = _qsgd_quantize_rows(lk, x, int(qsgd_s))
            s = int(qsgd_s)
            if staged_payload:
                # quantize under the worker-sharded layout: the dense f32
                # diffs never leave their worker
                q = jax.lax.with_sharding_constraint(q, worker_sharded)
                norm = jax.lax.with_sharding_constraint(norm, worker_sharded)
            repl = NamedSharding(mesh, P())
            if packed_payload and s <= 7 and L % 8 == 0:
                # genuine 4-bit wire: eight signed nibbles per uint32 lane
                # word cross the collective (0.5 B/coord)
                words = kref.nibble_pack_ref(q.reshape(n * R, L))
                words = jax.lax.with_sharding_constraint(
                    words.reshape(n, R, L // 8), repl
                )
                q = kref.nibble_unpack_ref(
                    words.reshape(n * R, L // 8), L
                ).reshape(n, R, L)
            else:
                q = jax.lax.with_sharding_constraint(q, repl)
            norm = jax.lax.with_sharding_constraint(norm, repl)

            # fused dequantize-and-mean: worker-indexed accumulation into one
            # (R, L) f32 buffer — input bandwidth stays int8
            def dq_body(w, acc):
                qw = jax.lax.dynamic_index_in_dim(q, w, 0, keepdims=False)
                nw = jax.lax.dynamic_index_in_dim(norm, w, 0, keepdims=False)
                return acc + qw.astype(jnp.float32) * (nw / s)

            acc = jax.lax.fori_loop(
                0, n, dq_body, jnp.zeros((R, L), jnp.float32)
            )
            dense = (acc / n).astype(leaf.dtype)
        elif shared_mask:
            idx = jax.random.randint(lk, (R, kb), 0, L, jnp.int32)
            vals = _gather_along_last(
                x, jnp.broadcast_to(idx, (n, R, kb)), scale, backend
            )
            if staged_payload:
                # pin the gather to the worker-sharded layout so the
                # partitioner cannot replicate the dense diffs instead
                vals = jax.lax.with_sharding_constraint(vals, worker_sharded)
            # ζ-sized psum over the worker axis; stays sharded on R
            vals_mean = jnp.mean(vals, axis=0)                     # (R, kb)
            dense = _scatter_mean_last(
                vals_mean[None], idx[None], L, backend
            ).astype(leaf.dtype)
        else:
            idx = jax.random.randint(lk, (n, R, kb), 0, L, jnp.int32)
            vals = _gather_along_last(x, idx, scale, backend)
            if staged_payload:
                # stage 1: gather under the worker-sharded layout (local);
                # stage 2 (below): all-gather only the K-sized payload
                vals = jax.lax.with_sharding_constraint(vals, worker_sharded)
            repl = NamedSharding(mesh, P())
            if packed_payload:
                # §Perf: bf16 values + int16 indices on the wire — 8 → 4
                # B/coord, degrading to int32 indices (8 → 6 B/coord) when
                # L > 32767 (int16 can't address the lane)
                vals = jax.lax.with_sharding_constraint(
                    vals.astype(jnp.bfloat16), repl
                ).astype(leaf.dtype)
                idx_wire = jax.lax.with_sharding_constraint(
                    (idx if L > 32767 else idx.astype(jnp.int16)), repl
                )
                idx = idx_wire.astype(jnp.int32)
            else:
                vals = jax.lax.with_sharding_constraint(vals, repl)
                idx = jax.lax.with_sharding_constraint(idx, repl)
            dense = _scatter_mean_last(vals, idx, L, backend).astype(leaf.dtype)

        out = dense.reshape(shape)
        if osh is not None and staged_payload:
            # pin the decompressed accumulator to the destination leaf's
            # sharding — otherwise the partitioner may materialize the scatter
            # replicated (a 435 GB buffer for the 671B expert stack)
            out = jax.lax.with_sharding_constraint(out, osh)
        outs.append(out)
    return jax.tree.unflatten(treedef, outs)


def _decompress_worker_rows(
    key: jax.Array,
    diffs: PyTree,
    n: int,
    packed_payload: bool = False,
    backend: str = "auto",
    compression: str = "randk",
    qsgd_s: int = 15,
) -> PyTree:
    """Per-worker DENSE payload rows — what the server actually received
    from each client, before any aggregation (DESIGN.md §4.9).

    Robust GARs cannot ride the fused dequantize-and-mean of
    :func:`_compress_decompress_mean` (trim/median/Krum/clip don't commute
    with the mean), so the robust wire decodes every worker's payload to a
    dense (n, *leaf) row stack and hands it to
    ``ServerAggregator.combine_stacked``. Key discipline is IDENTICAL to the
    mean path (one split per leaf, same per-leaf draw shapes), so the honest
    rows carry exactly the values the fused path would have averaged. The
    dense row stack costs the fused path's memory saving — the price of
    robustness, recorded in DESIGN.md §4.9. ``permk`` is refused upstream
    (coordinates partition across workers; nothing to aggregate robustly)."""
    leaves, treedef = jax.tree.flatten(diffs)
    keys = jax.random.split(key, len(leaves))
    rows = []
    for lk, leaf in zip(keys, leaves):
        shape = leaf.shape[1:]
        L = int(shape[-1])
        R = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        kb = max(1, L // 128)
        scale = L / kb
        x = leaf.reshape(n, R, L)
        if compression == "qsgd":
            q, norm = _qsgd_quantize_rows(lk, x, int(qsgd_s))
            s = int(qsgd_s)
            if packed_payload and s <= 7 and L % 8 == 0:
                q = _nibble_roundtrip_rows(q)
            dense = q.astype(jnp.float32) * (norm / s)
        else:  # independent Block-RandK masks
            idx = jax.random.randint(lk, (n, R, kb), 0, L, jnp.int32)
            vals = _gather_along_last(x, idx, scale, backend)
            dense = jax.vmap(
                lambda v, i: _scatter_mean_last(v[None], i[None], L, backend)
            )(vals, idx)
        rows.append(dense.reshape((n,) + tuple(shape)))
    return jax.tree.unflatten(treedef, rows)


def _downlink_roundtrip(
    key: jax.Array,
    delta: PyTree,
    mode: str,
    s: int,
    packed_payload: bool,
) -> PyTree:
    """Compressed downlink on the aggregated round delta (DESIGN.md §4.7).

    The server broadcasts Q_down(g^{k+1} − g^k) = Q_down(δ_up); since δ_up is
    replicated after aggregation, every device compresses with the SHARED
    round key (one payload, one broadcast) and decompress-accumulates — the
    estimator recursion runs on the broadcast sequence, so worker replicas
    stay bitwise in sync. "qsgd": per-row ℓ2-norm s-level quantization, int8
    (4-bit nibbles with ``packed_payload`` and s ≤ 7). "randk": seeded
    K-subsample (K = L/128 per row), indices regenerate from the key.
    """
    if mode == "none":
        return delta
    leaves, treedef = jax.tree.flatten(delta)
    keys = jax.random.split(key, len(leaves))
    outs = []
    for lk, leaf in zip(keys, leaves):
        shape = leaf.shape
        L = int(shape[-1])
        R = int(np.prod(shape[:-1])) if len(shape) > 1 else 1
        x = leaf.reshape(R, L).astype(jnp.float32)
        if mode == "qsgd":
            # the same shared row-quantize formula as the uplink
            q, norm = _qsgd_quantize_rows(lk, x, s)
            if packed_payload and s <= 7 and L % 8 == 0:
                # the broadcast genuinely crosses the 4-bit wire
                q = _nibble_roundtrip_rows(q)
            y = q.astype(jnp.float32) * (norm / s)
        elif mode == "randk":
            kb = max(1, L // 128)
            idx = jax.random.randint(lk, (R, kb), 0, L, jnp.int32)
            vals = jnp.take_along_axis(x, idx, axis=1) * (L / kb)
            y = jnp.zeros((R, L), jnp.float32).at[
                jnp.arange(R)[:, None], idx
            ].add(vals)
        else:
            raise ValueError(f"unknown downlink {mode!r}")
        outs.append(y.reshape(shape).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, outs)


def pp_cohort_schedule(
    base_key: jax.Array, n_steps: int, n: int, r: int,
    scheme: str = "without",
) -> jax.Array:
    """Precompute the (n_steps, r) PP cohort table — the prefetch side of the
    participation wire (DESIGN.md §4.8).

    Row k is EXACTLY the cohort the core ``PPMarina`` step draws from the
    step key ``fold_in(base_key, k)`` (the same 3-way ``(bern, sel, q)``
    split), so a precomputed schedule keeps distributed rounds
    trajectory-equal to the single-process reference while hoisting the
    sampling off the round's critical path: the k+1 batch-row gather can be
    issued while round k's epilogue is still in flight.
    """
    from repro.core.marina import pp_sample_cohort

    assert scheme in ("with", "without"), scheme

    def one(step):
        k = jax.random.fold_in(base_key, step)
        _, k_sel, _ = jax.random.split(k, 3)
        return pp_sample_cohort(k_sel, n, r, replace=(scheme == "with"))

    return jax.vmap(one)(jnp.arange(n_steps, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_steps(
    arch: ArchConfig,
    mesh,
    multi_pod: bool,
    *,
    global_batch: int,
    seq_len: int,
    gamma: float = 1e-3,
    p: float = KB / BLOCK,
    dtype=jnp.bfloat16,
    shared_mask: bool = False,
    remat: bool = True,
    packed_payload: bool = False,
    replicate_params: bool = False,
    staged_payload: bool = True,
    compression_backend: str = "auto",
    compression: str = "randk",
    qsgd_s: int = 15,
    grad_carry: bool = False,
    flat_sync: "bool | None" = None,
    downlink: str = "none",
    downlink_s: int = 7,
    participation: "tuple[int, str] | None" = None,
    aggregator: "Any | None" = None,
    faults: "Any | None" = None,
):
    """Returns (fns, abstract_args) for sync_step / compressed_step / train_step.

    §Perf overrides:
    * shared_mask      — SharedRandK: K-value psum instead of n·K all-gather
    * packed_payload   — bf16 values + int16 indices on the wire (8 → 4
      B/coord; indices fall back to int32 when L > 32767, 8 → 6 B/coord);
      with compression="qsgd" and s ≤ 7 it instead packs the int8 levels
      into 4-bit nibbles (1 → 0.5 B/coord)
    * compression      — "randk" (independent masks, n·K all-gather),
      "permk" (correlated Perm-K: disjoint d/n shards, values-only exchange)
      or "qsgd" (dense s-level quantization: int8 levels + f32 row norms on
      the wire — the packed quantization wire of DESIGN.md §4.6)
    * qsgd_s           — quantization levels for compression="qsgd"
    * replicate_params — small-model mode: no tensor parallelism; the model
      axis becomes within-worker data parallelism (per-worker batch sharded
      over "model", params replicated)
    * grad_carry       — single-backprop compressed rounds: the step carry
      grows per-worker h_i^k = ∇f_i(x^k) (sharded like the grads, donated);
      signatures become (params, g, h, batch[, key]) → (params, g, h)
    * flat_sync        — sync rounds exchange ONE packed (n, nblk, B) buffer
      (a single worker-axis psum) instead of one collective per leaf.
      Default (None) auto-enables it only when packing cannot force a
      reshard of model-parallel leaves (replicated params, or a mesh whose
      axes are all worker axes) — on tensor/FSDP-sharded params GSPMD must
      all-gather the dense grads to assemble the flat buffer (involuntary
      full remat, ~4× sync-step memory on the qwen 0.5B dryrun), so the
      per-leaf exchange stays the sharded default
    * downlink         — "none" (dense estimator broadcast) or "qsgd"/"randk":
      broadcast Q_down(g^{k+1} − g^k) and decompress-accumulate worker-side
      (downlink_s levels; packed_payload packs the downlink nibbles too)
    * participation    — (r, "with"|"without"): PP-MARINA on the mesh
      (DESIGN.md §4.8). Compressed rounds sample a cohort of r clients from
      the schedule (``pp_cohort_schedule``; steps gain a trailing (r,) int32
      ``sel`` argument) and map it onto the worker axis: the r clients'
      batch rows are respread over ALL n shards (each backprops r/n of its
      full-round tokens — the genuine r/n compute saving) and the wire
      carries exactly r payload rows through the configured compression
      (permk re-keys its partition to the cohort, tiling d/r). When r does
      not divide n·per_worker evenly the builder falls back to masked dense
      compute (all n backprop, only r rows compressed — wire saving kept,
      compute saving lost; recorded in ``bundle.meta``). With ``grad_carry``
      the step's h becomes the server-side carry table: only sampled rows
      refresh. Composes with randk/permk/qsgd but not shared_mask. On
      packing-legal meshes PP rounds are trajectory-equal to core
      ``PPMarina`` for ``downlink="none"``; with a downlink the key
      discipline follows the mesh convention (split from k_q), not core's
      step-key fold — see DESIGN.md §4.8.
    * aggregator       — a ``repro.core.ServerAggregator``: swap the server
      mean for a robust GAR (DESIGN.md §4.9). Sync rounds aggregate the
      worker gradient stack with ``combine_stacked``; compressed rounds
      decode per-worker dense payload rows (``_decompress_worker_rows``, or
      the flat engine's ``worker_dense`` on the flat-PP path) and aggregate
      those. Refused with compression="permk" and with shared_mask (the
      payloads aren't per-coordinate comparable across workers).
    * faults           — a ``repro.core.FaultSpec``: per-round client fault
      injection on the uplinked payloads (sign_flip / mean_shift / nan /
      garbage / drop — see repro.core.faults). ``drop`` requires
      ``grad_carry`` (the carried h row substitutes the missing upload, and
      dropped rows skip their h refresh). Sync-round garbage noise draws
      from a fixed key (the mesh sync steps are keyless by design).
    """
    cfg = dataclasses.replace(arch.model, remat=remat)
    robust = aggregator is not None and aggregator.robust
    if robust:
        if compression == "permk":
            raise ValueError(
                f"robust rule {aggregator.rule!r} is undefined on the permk "
                "wire: workers partition the coordinates (DESIGN.md §4.9)"
            )
        if shared_mask:
            raise ValueError(
                f"robust rule {aggregator.rule!r} is undefined with "
                "shared_mask: one correlated mask spans the whole fleet "
                "(DESIGN.md §4.9)"
            )
    if faults is not None and faults.attack == "drop" and not grad_carry:
        raise ValueError(
            "faults='drop' substitutes the carried h row for the missing "
            "upload — grad_carry=True is required (DESIGN.md §4.9)"
        )
    waxes = worker_axis_names(multi_pod, arch.worker_axes)
    fsdp = arch.fsdp and not any(a in waxes for a in ("data",))
    n = num_workers(mesh, multi_pod, arch.worker_axes)
    per_worker = global_batch // n
    inner_axis = "data" if (fsdp and "data" not in waxes) else None
    if replicate_params:
        inner_axis = "model"

    param_shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )
    if replicate_params:
        p_shard = jax.tree.map(lambda _: shd.replicated(mesh), param_shapes)
    else:
        p_shard = shd.param_sharding_tree(param_shapes, mesh, fsdp)

    # total positions = seq_len; frontend archs spend prefix_len of them on
    # stub embeddings so S stays chunk-aligned
    tok_len = seq_len - arch.prefix_len
    tok_shape = (n, per_worker, tok_len)
    batch = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    batch_shard = {
        "tokens": NamedSharding(mesh, shd.batch_spec(waxes, inner_axis, 3))
    }
    if arch.prefix_len:
        pshape = (n, per_worker, arch.prefix_len, cfg.d_model)
        batch["prefix"] = jax.ShapeDtypeStruct(pshape, dtype)
        batch_shard["prefix"] = NamedSharding(
            mesh, shd.batch_spec(waxes, inner_axis, 4)
        )

    def loss_fn(params, one_batch):
        return lm_loss(
            params, cfg, one_batch["tokens"], one_batch.get("prefix")
        )

    # remat is per-layer inside the model (cfg.remat above)
    grad_one = jax.grad(loss_fn)

    def worker_grads(params, batch):
        return jax.vmap(grad_one, in_axes=(None, 0))(params, batch)

    # sync rounds ride the flat buffer: one fused mean over the packed
    # (n, nblk, B) buffer — a single worker-axis psum of d — instead of one
    # collective per leaf. The buffer's block dim is pinned to the non-worker
    # mesh axes (when they divide nblk) so the dense grads never replicate,
    # and the unpacked mean is pinned back to the parameter shardings.
    lay = flat_engine.make_layout(param_shapes, block=BLOCK)
    wlead = waxes if len(waxes) > 1 else (waxes[0] if waxes else None)
    # size-1 axes cannot shard anything, so they neither disqualify the
    # packed exchange nor are worth pinning block rows to
    inner = tuple(
        a for a in mesh.shape
        if a not in set(waxes) and mesh.shape[a] > 1
    )
    if flat_sync is None:
        flat_sync = replicate_params or not inner
    blk_axes = inner if (
        inner and lay.nblk % int(np.prod([mesh.shape[a] for a in inner])) == 0
    ) else None
    buf_shard = NamedSharding(
        mesh,
        P(wlead, blk_axes if blk_axes and len(blk_axes) > 1
          else (blk_axes[0] if blk_axes else None), None),
    )

    def flat_worker_mean(grads):
        bufs = jax.vmap(lambda t: flat_engine.pack(lay, t))(grads)
        bufs = jax.lax.with_sharding_constraint(bufs, buf_shard)
        g_new = flat_engine.unpack(lay, jnp.mean(bufs, axis=0))
        return jax.tree.map(
            jax.lax.with_sharding_constraint, g_new, p_shard
        )

    def worker_mean(grads):
        if flat_sync:
            return flat_worker_mean(grads)
        return jax.tree.map(lambda t: jnp.mean(t, axis=0), grads)

    def worker_aggregate(grads):
        """Sync-round server aggregation: the GAR on the worker gradient
        stack when a robust aggregator is configured, else the mean."""
        if robust:
            g_new = aggregator.combine_stacked(grads)
            return jax.tree.map(
                jax.lax.with_sharding_constraint, g_new, p_shard
            )
        return worker_mean(grads)

    # mesh sync steps are keyless by design, so the (rare) sync-round
    # garbage noise draws from a fixed key — every other attack is
    # deterministic and unaffected
    sync_fault_key = jax.random.PRNGKey(_FAULT_FOLD)

    def sync_uplink(grads):
        return _sync_faults(faults, sync_fault_key, grads, jnp.arange(n), n)

    def descend(params, g):
        return jax.tree.map(
            lambda w, gg: w - gamma * gg.astype(w.dtype), params, g
        )

    def robust_delta(key, diffs, rows_n):
        """Robust compressed-round delta: per-worker dense payload rows →
        GAR → parameter-sharding pins (replaces the fused mean)."""
        rows = _decompress_worker_rows(
            key, diffs, rows_n, packed_payload=packed_payload,
            backend=compression_backend, compression=compression,
            qsgd_s=qsgd_s,
        )
        delta = aggregator.combine_stacked(rows)
        return jax.tree.map(
            jax.lax.with_sharding_constraint, delta, p_shard
        )

    def compressed_delta(key, diffs):
        k_up, k_down = jax.random.split(key)
        k_up = k_up if downlink != "none" else key
        if robust:
            delta = robust_delta(k_up, diffs, n)
        else:
            delta = _compress_decompress_mean(
                k_up, diffs, n, mesh, waxes,
                shared_mask, packed_payload, staged_payload,
                out_shardings=p_shard, backend=compression_backend,
                compression=compression, qsgd_s=qsgd_s,
            )
        return _downlink_roundtrip(
            k_down, delta, downlink, downlink_s, packed_payload
        )

    if grad_carry:
        # single-backprop rounds: the carry holds h_i^k = ∇f_i(x^k), so the
        # compressed round differences against it instead of re-running the
        # second vmapped backprop at the old point.
        def sync_step(params, g, h, batch):
            x_new = descend(params, g)
            grads = worker_grads(x_new, batch)
            # h keeps the HONEST gradients: liars lie on the wire, the
            # simulated clients still know their own state
            return x_new, worker_aggregate(sync_uplink(grads)), grads

        def compressed_step(params, g, h, batch, key):
            x_new = descend(params, g)
            g_plus = worker_grads(x_new, batch)
            diffs = jax.tree.map(jnp.subtract, g_plus, h)
            diffs = _uplink_faults(
                faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                jnp.arange(n), n,
            )
            g_new = jax.tree.map(jnp.add, g, compressed_delta(key, diffs))
            # dropped rows keep their old h (the server never heard from
            # them); c_k=False — this IS the compressed branch
            h_new = _carry_refresh(h, g_plus, faults, jnp.asarray(False), n)
            return x_new, g_new, h_new

        def train_step(params, g, h, batch, key):
            k_b, k_q = jax.random.split(key)
            c_k = jax.random.bernoulli(k_b, p)
            return jax.lax.cond(
                c_k,
                lambda _: sync_step(params, g, h, batch),
                lambda _: compressed_step(params, g, h, batch, k_q),
                None,
            )
    else:
        def sync_step(params, g, batch):
            x_new = descend(params, g)
            grads = worker_grads(x_new, batch)
            return x_new, worker_aggregate(sync_uplink(grads))

        def compressed_step(params, g, batch, key):
            x_new = descend(params, g)
            g_plus = worker_grads(x_new, batch)
            g_minus = worker_grads(params, batch)
            diffs = jax.tree.map(jnp.subtract, g_plus, g_minus)
            diffs = _uplink_faults(
                faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                jnp.arange(n), n,
            )
            g_new = jax.tree.map(jnp.add, g, compressed_delta(key, diffs))
            return x_new, g_new

        def train_step(params, g, batch, key):
            k_b, k_q = jax.random.split(key)
            c_k = jax.random.bernoulli(k_b, p)
            return jax.lax.cond(
                c_k,
                lambda _: sync_step(params, g, batch),
                lambda _: compressed_step(params, g, batch, k_q),
                None,
            )

    if participation is not None:
        # -- PP-MARINA on the mesh (DESIGN.md §4.8) -------------------------
        # sync rounds are unchanged (all n clients ship dense gradients —
        # the sync_step above); compressed rounds take the cohort row `sel`
        # from pp_cohort_schedule and override compressed/train below.
        r_part, scheme = participation
        assert scheme in ("with", "without"), scheme
        assert 1 <= r_part <= n, f"cohort r={r_part} vs n={n} workers"
        assert not shared_mask, (
            "participation composes with randk/permk/qsgd, not shared_mask "
            "(a shared mask already correlates the whole fleet)"
        )
        # cohort-mapped compute needs the r clients' rows to respread evenly
        # over the n worker shards in whole tokens-per-shard units
        grp = cohort_group_size(n, r_part)
        cohort_compute = grp is not None and (per_worker * r_part) % n == 0
        # flat-PP: where packing cannot force a reshard (same predicate as
        # flat_sync auto), the r-row payload pipeline IS the core engine —
        # pack → sampler → aggregate with the identical key/seed derivation,
        # which is what makes mesh rounds trajectory-equal to core PPMarina.
        flat_pp = replicate_params or not inner
        pp_eng = None
        if flat_pp and compression in ("randk", "permk", "qsgd"):
            if compression == "permk" and BLOCK % r_part != 0:
                flat_pp = False
            else:
                # seed_constraint pins the threefry seed derivation
                # replicated: the SPMD partitioner otherwise re-partitions
                # the split→bits chain and yields different seed VALUES
                # than one device — the silent killer of core↔mesh
                # trajectory equality (core/flat.py).
                pp_eng = flat_engine.make_engine(
                    param_shapes, kb=KB, block=BLOCK,
                    backend=compression_backend, sampler=compression,
                    s=qsgd_s,
                )
                pp_eng = dataclasses.replace(
                    pp_eng, seed_constraint=shd.replicated(mesh)
                )
        else:
            flat_pp = False

        def cohort_grads(x, batch, sel):
            """Per-client gradients of the r sampled clients.

            Cohort-mapped: gather the r clients' batch rows, respread them
            over all n shards (each shard backprops per_worker·r/n tokens —
            compute is r/n of a full round), then group-mean the n shard
            grads back to r client grads (equal sub-batch sizes make the
            mean of means exact). Masked fallback: every shard backprops its
            own full batch and only the r sampled rows are kept."""
            if cohort_compute:
                sub = (per_worker * r_part) // n
                sel_b = jax.tree.map(
                    lambda t: t[sel].reshape(n, sub, *t.shape[2:]), batch
                )
                sel_b = jax.tree.map(
                    jax.lax.with_sharding_constraint, sel_b, batch_shard
                )
                wg = worker_grads(x, sel_b)
                return jax.tree.map(
                    lambda t: jnp.mean(
                        t.reshape(r_part, grp, *t.shape[1:]), axis=1
                    ),
                    wg,
                )
            wg = worker_grads(x, batch)
            return jax.tree.map(lambda t: t[sel], wg)

        def pp_delta(key, diffs):
            """(1/r)·Σ Q(Δ_i) over the r cohort payload rows (the GAR over
            the cohort's decoded rows when robust) + downlink."""
            k_up, k_down = jax.random.split(key)
            k_up = k_up if downlink != "none" else key
            if flat_pp:
                bufs = flat_engine.pack_stacked(pp_eng.layout, diffs)
                delta = flat_engine.unpack(
                    pp_eng.layout,
                    pp_eng.aggregate(k_up, bufs, r_part, aggregator),
                )
                delta = jax.tree.map(
                    jax.lax.with_sharding_constraint, delta, p_shard
                )
            elif robust:
                delta = robust_delta(k_up, diffs, r_part)
            else:
                # sharded fallback: the per-leaf staged wire on the r-row
                # payload stack (cohort rows replicate — r·ζ, not n·ζ)
                delta = _compress_decompress_mean(
                    k_up, diffs, r_part, mesh, (), False,
                    packed_payload, False,
                    out_shardings=p_shard, backend=compression_backend,
                    compression=compression, qsgd_s=qsgd_s,
                )
            return _downlink_roundtrip(
                k_down, delta, downlink, downlink_s, packed_payload
            )

        if grad_carry:
            # h is the SERVER-SIDE CARRY TABLE: all n rows live on the mesh,
            # compressed rounds refresh only the sampled ones.
            def compressed_step(params, g, h, batch, key, sel):
                x_new = descend(params, g)
                cg = cohort_grads(x_new, batch, sel)
                h_sel = jax.tree.map(lambda t: t[sel], h)
                diffs = jax.tree.map(jnp.subtract, cg, h_sel)
                diffs = _uplink_faults(
                    faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                    sel, n,
                )
                g_new = jax.tree.map(jnp.add, g, pp_delta(key, diffs))
                # sampled rows refresh — except dropped clients, whose row
                # the server never received (core _pp_carry_refresh)
                h_new = _pp_carry_refresh(h, sel, cg, faults, n)
                return x_new, g_new, h_new

            def train_step(params, g, h, batch, key, sel):
                k_b, _, k_q = jax.random.split(key, 3)
                c_k = jax.random.bernoulli(k_b, p)
                return jax.lax.cond(
                    c_k,
                    lambda _: sync_step(params, g, h, batch),
                    lambda _: compressed_step(params, g, h, batch, k_q, sel),
                    None,
                )
        else:
            def compressed_step(params, g, batch, key, sel):
                x_new = descend(params, g)
                g_plus = cohort_grads(x_new, batch, sel)
                g_minus = cohort_grads(params, batch, sel)
                diffs = jax.tree.map(jnp.subtract, g_plus, g_minus)
                diffs = _uplink_faults(
                    faults, jax.random.fold_in(key, _FAULT_FOLD), diffs,
                    sel, n,
                )
                g_new = jax.tree.map(jnp.add, g, pp_delta(key, diffs))
                return x_new, g_new

            def train_step(params, g, batch, key, sel):
                # the core PPMarina key discipline: (bern, sel, q) 3-way
                # split; the sel slot is consumed by pp_cohort_schedule.
                k_b, _, k_q = jax.random.split(key, 3)
                c_k = jax.random.bernoulli(k_b, p)
                return jax.lax.cond(
                    c_k,
                    lambda _: sync_step(params, g, batch),
                    lambda _: compressed_step(params, g, batch, k_q, sel),
                    None,
                )

    g_shard = p_shard  # estimator g^k lives like the params
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    repl = shd.replicated(mesh)

    # one fns construction for both carries: grad_carry threads the h slot
    # (worker axes on the leading dim, the leaf's own parameter sharding
    # behind it; donated with params/g) through every entry.
    if grad_carry:
        h_in = (jax.tree.map(
            lambda ns: NamedSharding(mesh, P(wlead, *ns.spec)), p_shard
        ),)
        h_args = (jax.tree.map(
            lambda sh: jax.ShapeDtypeStruct((n, *sh.shape), sh.dtype),
            param_shapes,
        ),)
    else:
        h_in = h_args = ()
    state_out = (p_shard, g_shard, *h_in)
    donate = tuple(range(2 + len(h_in)))

    pp = participation is not None
    sel_spec = (
        jax.ShapeDtypeStruct((participation[0],), jnp.int32) if pp else None
    )

    def entry(fn, needs_key, needs_sel=False):
        key_in = (repl,) if needs_key else ()
        key_arg = (key_spec,) if needs_key else ()
        sel_in = (repl,) if needs_sel else ()
        sel_arg = (sel_spec,) if needs_sel else ()
        return (
            jax.jit(
                fn,
                in_shardings=(
                    p_shard, g_shard, *h_in, batch_shard, *key_in, *sel_in
                ),
                out_shardings=state_out,
                donate_argnums=donate,
            ),
            (param_shapes, param_shapes, *h_args, batch, *key_arg, *sel_arg),
        )

    fns = {
        "sync_step": entry(sync_step, needs_key=False),
        "compressed_step": entry(compressed_step, needs_key=True, needs_sel=pp),
        "train_step": entry(train_step, needs_key=True, needs_sel=pp),
    }
    return StepBundle(
        mesh=mesh,
        n_workers=n,
        param_shapes=param_shapes,
        param_shardings=p_shard,
        fns=fns,
        meta={
            **(
                {
                    "participation": participation,
                    "cohort_compute": cohort_compute,
                    "flat_pp": flat_pp,
                }
                if pp
                else {}
            ),
            **({"aggregator": aggregator.rule} if robust else {}),
            **({"faults": faults.attack} if faults is not None else {}),
        },
    )


def build_serve_steps(
    arch: ArchConfig,
    mesh,
    multi_pod: bool,
    *,
    batch: int,
    seq_len: int,
    mode: str,  # "prefill" | "decode"
    dtype=jnp.bfloat16,
    last_logits: bool = False,
):
    """Jitted serving steps for MARINA-trained checkpoints: "prefill" (full
    attention over the prompt, cache build) or "decode" (one token, donated
    cache) under the arch's GSPMD shardings — see launch/serve.py."""
    cfg = arch.model
    param_shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )
    p_shard = shd.param_sharding_tree(param_shapes, mesh, arch.fsdp)
    baxes = shd.serve_batch_axes(mesh, batch)
    repl = shd.replicated(mesh)

    fns = {}
    if mode == "prefill":
        P_len = arch.prefix_len
        tok_len = seq_len - P_len
        toks = jax.ShapeDtypeStruct((batch, tok_len), jnp.int32)
        tok_shard = NamedSharding(
            mesh, P(baxes if not baxes or len(baxes) > 1 else baxes[0], None)
        )
        args = [toks]
        shards = [tok_shard]
        if P_len:
            pre = jax.ShapeDtypeStruct((batch, P_len, cfg.d_model), dtype)
            args.append(pre)
            shards.append(
                NamedSharding(
                    mesh,
                    P(baxes if not baxes or len(baxes) > 1 else baxes[0], None, None),
                )
            )

        def prefill_step(params, tokens, prefix=None):
            return model_prefill(
                params, cfg, tokens, prefix, max_len=seq_len,
                last_logits_only=last_logits,
            )

        fns["prefill_step"] = (
            jax.jit(
                prefill_step,
                in_shardings=(p_shard, *shards),
                out_shardings=None,
            ),
            (param_shapes, *args),
        )
    else:
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, batch, seq_len, dtype)
        )
        c_shard = shd.cache_sharding_tree(cache_shapes, mesh, baxes)
        tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, token, pos):
            return model_decode(params, cfg, cache, token, pos)

        fns["decode_step"] = (
            jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, repl, repl),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ),
            (param_shapes, cache_shapes, tok, pos),
        )
    return StepBundle(
        mesh=mesh,
        n_workers=1,
        param_shapes=param_shapes,
        param_shardings=p_shard,
        fns=fns,
    )
