"""Serving round assembly: jitted prefill/decode steps for MARINA-trained
checkpoints under the arch's GSPMD shardings (launch/serve.py drives them;
the train-side assembly lives in launch/distributed.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig
from repro.models import init_cache, init_params, decode_step as model_decode, prefill as model_prefill
from repro.launch import sharding as shd


def build_serve_steps(
    arch: ArchConfig,
    mesh,
    multi_pod: bool,
    *,
    batch: int,
    seq_len: int,
    mode: str,  # "prefill" | "decode"
    dtype=jnp.bfloat16,
    last_logits: bool = False,
):
    """Jitted serving steps for MARINA-trained checkpoints: "prefill" (full
    attention over the prompt, cache build) or "decode" (one token, donated
    cache) under the arch's GSPMD shardings — see launch/serve.py."""
    from repro.launch.distributed import StepBundle

    cfg = arch.model
    param_shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )
    p_shard = shd.param_sharding_tree(param_shapes, mesh, arch.fsdp)
    baxes = shd.serve_batch_axes(mesh, batch)
    repl = shd.replicated(mesh)

    fns = {}
    if mode == "prefill":
        P_len = arch.prefix_len
        tok_len = seq_len - P_len
        toks = jax.ShapeDtypeStruct((batch, tok_len), jnp.int32)
        tok_shard = NamedSharding(
            mesh, P(baxes if not baxes or len(baxes) > 1 else baxes[0], None)
        )
        args = [toks]
        shards = [tok_shard]
        if P_len:
            pre = jax.ShapeDtypeStruct((batch, P_len, cfg.d_model), dtype)
            args.append(pre)
            shards.append(
                NamedSharding(
                    mesh,
                    P(baxes if not baxes or len(baxes) > 1 else baxes[0], None, None),
                )
            )

        def prefill_step(params, tokens, prefix=None):
            return model_prefill(
                params, cfg, tokens, prefix, max_len=seq_len,
                last_logits_only=last_logits,
            )

        fns["prefill_step"] = (
            jax.jit(
                prefill_step,
                in_shardings=(p_shard, *shards),
                out_shardings=None,
            ),
            (param_shapes, *args),
        )
    else:
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, batch, seq_len, dtype)
        )
        c_shard = shd.cache_sharding_tree(cache_shapes, mesh, baxes)
        tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, token, pos):
            return model_decode(params, cfg, cache, token, pos)

        fns["decode_step"] = (
            jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, repl, repl),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ),
            (param_shapes, cache_shapes, tok, pos),
        )
    return StepBundle(
        mesh=mesh,
        n_workers=1,
        param_shapes=param_shapes,
        param_shardings=p_shard,
        fns=fns,
    )
