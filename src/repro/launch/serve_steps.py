"""Serving round assembly: jitted prefill/decode steps for MARINA-trained
checkpoints under the arch's GSPMD shardings (launch/serve.py drives them;
the train-side assembly lives in launch/distributed.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import (
    init_cache,
    init_paged_cache,
    init_params,
    decode_step as model_decode,
    paged_decode_step,
    paged_prefill_chunk,
    prefill as model_prefill,
)
from repro.launch import sharding as shd


def build_serve_steps(
    arch: ArchConfig,
    mesh,
    multi_pod: bool,
    *,
    batch: int,
    seq_len: int,
    mode: str,  # "prefill" | "decode"
    dtype=jnp.bfloat16,
    last_logits: bool = False,
):
    """Jitted serving steps for MARINA-trained checkpoints: "prefill" (full
    attention over the prompt, cache build) or "decode" (one token, donated
    cache) under the arch's GSPMD shardings — see launch/serve.py."""
    from repro.launch.distributed import StepBundle

    cfg = arch.model
    param_shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )
    p_shard = shd.param_sharding_tree(param_shapes, mesh, arch.fsdp)
    baxes = shd.serve_batch_axes(mesh, batch)
    repl = shd.replicated(mesh)

    fns = {}
    if mode == "prefill":
        P_len = arch.prefix_len
        tok_len = seq_len - P_len
        toks = jax.ShapeDtypeStruct((batch, tok_len), jnp.int32)
        args = [toks]
        shards = [shd.serve_batch_sharding(mesh, baxes, 2)]
        if P_len:
            pre = jax.ShapeDtypeStruct((batch, P_len, cfg.d_model), dtype)
            args.append(pre)
            shards.append(shd.serve_batch_sharding(mesh, baxes, 3))

        def prefill_step(params, tokens, prefix=None):
            return model_prefill(
                params, cfg, tokens, prefix, max_len=seq_len,
                last_logits_only=last_logits,
            )

        fns["prefill_step"] = (
            jax.jit(
                prefill_step,
                in_shardings=(p_shard, *shards),
                out_shardings=None,
            ),
            (param_shapes, *args),
        )
    else:
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, batch, seq_len, dtype)
        )
        c_shard = shd.cache_sharding_tree(cache_shapes, mesh, baxes)
        tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, cache, token, pos):
            return model_decode(params, cfg, cache, token, pos)

        fns["decode_step"] = (
            jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, repl, repl),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ),
            (param_shapes, cache_shapes, tok, pos),
        )
    return StepBundle(
        mesh=mesh,
        n_workers=1,
        param_shapes=param_shapes,
        param_shardings=p_shard,
        fns=fns,
    )


def build_paged_serve_steps(
    arch: ArchConfig,
    mesh,
    multi_pod: bool,
    *,
    n_slots: int,
    npage: int,
    page_size: int,
    max_pages: int,
    chunk: int,
    dtype=jnp.bfloat16,
    quantized: bool = False,
    temperature: float = 0.0,
):
    """Jitted continuous-batching steps over a paged KV cache (DESIGN.md §8):

    * ``paged_decode_step`` — one token for every slot against the page pool
      (donated), sampling fused in: argmax when ``temperature == 0``, else a
      categorical draw from the passed key. One dispatch per engine step.
    * ``paged_prefill_chunk`` — one chunk of one request's prompt written into
      its block-table row (pool donated), returning the would-be first
      generated token (only the final chunk's matters).

    Global-attention archs only — models.init_paged_cache raises otherwise.
    """
    from repro.launch.distributed import StepBundle

    cfg = arch.model
    param_shapes = jax.eval_shape(
        lambda k: init_params(k, cfg, dtype), jax.random.PRNGKey(0)
    )
    p_shard = shd.param_sharding_tree(param_shapes, mesh, arch.fsdp)
    baxes = shd.serve_batch_axes(mesh, n_slots)
    repl = shd.replicated(mesh)

    cache_shapes = jax.eval_shape(
        lambda: init_paged_cache(cfg, npage, page_size, dtype, quantized=quantized)
    )
    c_shard = shd.cache_sharding_tree(cache_shapes, mesh, None)
    tok = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    lengths = jax.ShapeDtypeStruct((n_slots,), jnp.int32)
    tables = jax.ShapeDtypeStruct((n_slots, max_pages), jnp.int32)
    vec_shard = shd.serve_batch_sharding(mesh, baxes, 1)
    tbl_shard = shd.serve_batch_sharding(mesh, baxes, 2)

    def sample(logits, key):
        if temperature > 0:
            return jax.random.categorical(key, logits / temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    fns = {}

    def decode_fn(params, cache, token, lens, tbl, key=None):
        logits, cache = paged_decode_step(params, cfg, cache, token, lens, tbl)
        return sample(logits, key).astype(jnp.int32), cache

    dec_args = [param_shapes, cache_shapes, tok, lengths, tables]
    dec_shards = [p_shard, c_shard, vec_shard, vec_shard, tbl_shard]
    if temperature > 0:
        dec_args.append(jax.random.PRNGKey(0))
        dec_shards.append(repl)
    fns["paged_decode_step"] = (
        jax.jit(
            decode_fn,
            in_shardings=tuple(dec_shards),
            out_shardings=(vec_shard, c_shard),
            donate_argnums=(1,),
        ),
        tuple(dec_args),
    )

    chunk_toks = jax.ShapeDtypeStruct((1, chunk), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    row = jax.ShapeDtypeStruct((max_pages,), jnp.int32)

    def prefill_fn(params, cache, tokens, start, table_row, n_valid, key=None):
        logits, cache = paged_prefill_chunk(
            params, cfg, cache, tokens, start, table_row, n_valid
        )
        return sample(logits, key).astype(jnp.int32), cache

    pre_args = [param_shapes, cache_shapes, chunk_toks, scalar, row, scalar]
    pre_shards = [p_shard, c_shard, repl, repl, repl, repl]
    if temperature > 0:
        pre_args.append(jax.random.PRNGKey(0))
        pre_shards.append(repl)
    fns["paged_prefill_chunk"] = (
        jax.jit(
            prefill_fn,
            in_shardings=tuple(pre_shards),
            out_shardings=(repl, c_shard),
            donate_argnums=(1,),
        ),
        tuple(pre_args),
    )

    return StepBundle(
        mesh=mesh,
        n_workers=1,
        param_shapes=param_shapes,
        param_shardings=p_shard,
        fns=fns,
    )
