"""Production training driver.

Selects an assigned architecture (``--arch``), a MARINA-family method and a
compressor, and runs either:

* ``--backend sim``  — the CPU simulation backend (reduced model; the default
  here since this container has one device), or
* ``--backend mesh`` — the sharded GSPMD step on the production mesh
  (requires real devices, or --dry-compile to stop after compilation).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --steps 20 \
      --method vr_marina --compressor randk --k 0.02 --reduced
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import PUBLIC_TO_MODULE, get_arch
from repro.models import init_params, param_count, reduced as reduce_cfg
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(PUBLIC_TO_MODULE))
    ap.add_argument("--method", default="vr_marina")
    ap.add_argument("--compressor", default="randk")
    ap.add_argument("--k", type=float, default=0.02)
    ap.add_argument("--gamma", type=float, default=0.2)
    ap.add_argument("--p", type=float, default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mb", type=int, default=2)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced variant (CPU-feasible)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = (
        reduce_cfg(arch.model, layers=args.layers, d_model=args.d_model)
        if args.reduced
        else arch.model
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={args.arch} ({'reduced' if args.reduced else 'FULL'}) "
          f"params={param_count(params):,} method={args.method}")

    comp_kwargs = {"k": args.k} if args.compressor in ("randk", "shared_randk", "topk") else {}
    tcfg = TrainConfig(
        method=args.method,
        compressor=args.compressor,
        comp_kwargs=comp_kwargs,
        gamma=args.gamma,
        p=args.p,
        n_workers=args.workers,
        batch_per_worker=args.batch,
        mb_per_worker=args.mb,
        steps=args.steps,
        log_every=max(1, args.steps // 10),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(1, args.steps // 3) if args.ckpt_dir else 0,
    )
    trainer = Trainer(cfg, tcfg, params, prefix_len=8 if arch.prefix_len else 0)
    _, hist = trainer.run()
    print(f"\n{'step':>6} {'loss':>9} {'Mbits/worker':>13} {'oracle':>9}")
    for s, l, b, o in zip(hist.step, hist.loss, hist.bits_cum, hist.oracle_cum):
        print(f"{s:>6} {l:>9.4f} {b/1e6:>13.2f} {o:>9.0f}")


if __name__ == "__main__":
    main()
