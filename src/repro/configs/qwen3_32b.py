"""qwen3-32b [dense] — 64L d_model=5120 64H (kv=8) d_ff=25600
vocab=151936, qk-norm, head_dim=128. [hf:Qwen/Qwen3-8B family card]"""

from repro.configs import ArchConfig
from repro.models.config import ModelConfig, dense_stack


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="qwen3-32b",
        arch_type="dense",
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        segments=dense_stack(64),
        qk_norm=True,
        rope_theta=1_000_000.0,
    )
    return ArchConfig(model=model)
