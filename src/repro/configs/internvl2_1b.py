"""internvl2-1b [vlm] — language backbone 24L d_model=896 14H (kv=2)
d_ff=4864 vocab=151655 (Qwen2-0.5B-style, QKV bias); the InternViT vision
encoder + MLP projector are stubbed — input_specs() provides projected patch
embeddings as a prefix. [arXiv:2404.16821]"""

from repro.configs import ArchConfig
from repro.models.config import ModelConfig, dense_stack


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="internvl2-1b",
        arch_type="vlm",
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        segments=dense_stack(24),
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        frontend="vision",
    )
    # 256 visual tokens per image (InternVL2 pixel-unshuffled 448px tiles)
    return ArchConfig(model=model, prefix_len=256)
