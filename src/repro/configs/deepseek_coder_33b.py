"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (kv=8) d_ff=19200
vocab=32256, llama architecture (RMSNorm + SwiGLU + RoPE).
[arXiv:2401.14196]"""

from repro.configs import ArchConfig
from repro.models.config import ModelConfig, dense_stack


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="deepseek-coder-33b",
        arch_type="dense",
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        segments=dense_stack(62),
        rope_theta=100_000.0,
    )
    return ArchConfig(model=model)
