"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (kv=16) d_ff=2816
vocab=151936, QKV bias, tied embeddings. [hf:Qwen/Qwen1.5-0.5B]"""

from repro.configs import ArchConfig
from repro.models.config import ModelConfig, dense_stack


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="qwen1.5-0.5b",
        arch_type="dense",
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        segments=dense_stack(24),
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
    )
    return ArchConfig(model=model)
