"""Assigned-architecture registry.

Each ``<arch>.py`` exposes ``get_config() -> ArchConfig`` binding the exact
published dimensions ([citation] per file) plus the distribution policy the
launcher uses (worker axes, parameter sharding flavour, long-context support).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.models.config import ModelConfig

ARCH_IDS = [
    "deepseek_v3_671b",
    "qwen15_0_5b",
    "xlstm_350m",
    "recurrentgemma_2b",
    "llama4_scout_17b_a16e",
    "musicgen_medium",
    "qwen3_32b",
    "internvl2_1b",
    "deepseek_coder_33b",
    "gemma3_27b",
]

# public ids (with dashes) map to module names
PUBLIC_TO_MODULE = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen1.5-0.5b": "qwen15_0_5b",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "musicgen-medium": "musicgen_medium",
    "qwen3-32b": "qwen3_32b",
    "internvl2-1b": "internvl2_1b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "gemma3-27b": "gemma3_27b",
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    #: how to split the MARINA worker axis on the multi-pod mesh:
    #: "pod_data" → n = pods × data (small/mid models);
    #: "pod"      → n = pods, data axis becomes intra-worker FSDP (giant MoE).
    worker_axes: str = "pod_data"
    #: shard params over the data axis too (FSDP/ZeRO-3 within a worker)
    fsdp: bool = False
    #: prefix length of stub frontend embeddings (vlm/audio); 0 = none
    prefix_len: int = 0

    @property
    def runs_long_context(self) -> bool:
        return self.model.supports_long_context() or self._windowed_dense()

    def _windowed_dense(self) -> bool:
        kinds = [l.mixer for s in self.model.segments for l in s.period]
        # dense archs qualify if *global* attention is a bounded fraction and
        # the rest is sliding-window (gemma3 5:1)
        return "attn_local" in kinds and kinds.count("attn") <= len(kinds) // 4


def get_arch(name: str) -> ArchConfig:
    mod_name = PUBLIC_TO_MODULE.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.get_config()


def all_archs() -> dict[str, ArchConfig]:
    return {pub: get_arch(pub) for pub in PUBLIC_TO_MODULE}
