"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (kv=8) d_ff=8192,
vocab=202048; MoE 16 routed top-1 + 1 shared expert on every layer
(Scout interleave step 1 → 109B total / 17B active); early-fusion multimodal
in the published model — the text backbone is built here (frontend carve-out).
[hf:meta-llama/Llama-4-Scout-17B-16E]"""

from repro.configs import ArchConfig
from repro.models.config import LayerSpec, MoEConfig, ModelConfig, Segment


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="llama4-scout-17b-a16e",
        arch_type="moe",
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        segments=(
            Segment(period=(LayerSpec(mixer="attn", ff="moe"),), repeat=48),
        ),
        moe=MoEConfig(
            num_experts=16,
            top_k=1,
            d_expert=8192,
            num_shared=1,
            router_score="softmax",
            capacity_factor=1.25,
        ),
        rope_theta=500_000.0,
        qk_norm=True,
    )
    # 109B total params — worker = pod on the multi-pod mesh, FSDP inside.
    return ArchConfig(model=model, worker_axes="pod", fsdp=True)
