"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680,
vocab=256000; period (RG-LRU, RG-LRU, local-attn) ×8 + (RG-LRU, RG-LRU),
window 2048, lru_width=2560. [arXiv:2402.19427]"""

from repro.configs import ArchConfig
from repro.models.config import LayerSpec, ModelConfig, Segment


def get_config() -> ArchConfig:
    rec = LayerSpec(mixer="rglru", ff="mlp")
    att = LayerSpec(mixer="attn_local", ff="mlp")
    model = ModelConfig(
        name="recurrentgemma-2b",
        arch_type="hybrid",
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        segments=(
            Segment(period=(rec, rec, att), repeat=8),
            Segment(period=(rec, rec), repeat=1),
        ),
        window=2048,
        lru_width=2560,
        conv_width=4,
        tie_embeddings=True,
    )
    return ArchConfig(model=model)
