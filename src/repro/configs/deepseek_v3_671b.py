"""deepseek-v3-671b [moe] — 61L d_model=7168 128H (MLA) vocab=129280,
MoE: 1 shared + 256 routed top-8, d_expert=2048, first 3 layers dense,
MTP depth 1. [arXiv:2412.19437]"""

from repro.configs import ArchConfig
from repro.models.config import LayerSpec, MLAConfig, MoEConfig, ModelConfig, Segment


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,       # MLA is effectively MHA over latent KV
        head_dim=128,
        d_ff=18432,             # dense layers' FFN (first 3 layers)
        vocab_size=129280,
        segments=(
            Segment(period=(LayerSpec(mixer="mla", ff="mlp"),), repeat=3),
            Segment(period=(LayerSpec(mixer="mla", ff="moe"),), repeat=58),
        ),
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=256,
            top_k=8,
            d_expert=2048,
            num_shared=1,
            router_score="sigmoid",
            capacity_factor=1.0,
        ),
        mtp_depth=1,
        rope_theta=10_000.0,
    )
    # 671B params: a single MARINA worker must span a full pod (DESIGN.md §3).
    return ArchConfig(model=model, worker_axes="pod", fsdp=True)
