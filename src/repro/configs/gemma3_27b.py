"""gemma3-27b [dense] — 62L d_model=5376 32H (kv=16) d_ff=21504
vocab=262144; 5 local (window 1024) : 1 global attention, qk-norm,
head_dim=128. 62 = 6×10 + 2 → trailing (local, local) segment.
[hf:google/gemma-3 family card]"""

from repro.configs import ArchConfig
from repro.models.config import LayerSpec, ModelConfig, Segment


def get_config() -> ArchConfig:
    loc = LayerSpec(mixer="attn_local", ff="mlp")
    glb = LayerSpec(mixer="attn", ff="mlp")
    model = ModelConfig(
        name="gemma3-27b",
        arch_type="dense",
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        segments=(
            Segment(period=(loc, loc, loc, loc, loc, glb), repeat=10),
            Segment(period=(loc, loc), repeat=1),
        ),
        window=1024,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
    return ArchConfig(model=model)
