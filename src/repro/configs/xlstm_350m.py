"""xlstm-350m [ssm] — 24L d_model=1024 4H vocab=50304; xLSTM[7:1]
block ratio (7 mLSTM : 1 sLSTM), no separate FFN (d_ff=0: the blocks carry
their own projections). [arXiv:2405.04517]"""

from repro.configs import ArchConfig
from repro.models.config import LayerSpec, ModelConfig, Segment


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="xlstm-350m",
        arch_type="ssm",
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        segments=(
            Segment(
                period=tuple(
                    [LayerSpec(mixer="mlstm", ff="none")] * 7
                    + [LayerSpec(mixer="slstm", ff="none")]
                ),
                repeat=3,
            ),
        ),
        pos_emb="none",
        mlstm_proj_factor=2.0,
        slstm_proj_factor=4.0 / 3.0,
        conv_width=4,
    )
    return ArchConfig(model=model)
