"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens, sinusoidal positions.
The EnCodec/conditioning frontend is a stub: input_specs() provides the
conditioning prefix embeddings; the token stream is EnCodec codes.
[arXiv:2306.05284]"""

from repro.configs import ArchConfig
from repro.models.config import ModelConfig, dense_stack


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        segments=dense_stack(48),
        pos_emb="sinusoidal",
        frontend="audio",
    )
    # 64-frame conditioning prefix from the stubbed frontend
    return ArchConfig(model=model, prefix_len=64)
