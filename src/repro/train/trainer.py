"""Training loop wiring the MARINA family into LM training.

The trainer runs the *simulation backend* (worker-stacked trees on one device;
the same algorithm code as the mesh path — see launch/distributed.py for the
sharded production step). It owns:

* method construction (MARINA / VR-MARINA / PP-MARINA / DIANA / DCGD / EC-SGD /
  GD) with compressor + stepsize policy — ``block_randk``/``flat_randk`` and
  ``permk`` compressors additionally get the fused flat-buffer engine
  (DESIGN.md §4; correlated collections are sized to ``n_workers``),
* the per-step data plumbing (full-round batches vs b′ minibatches — the
  Alg. 3 online case), generated *inside the jitted scan* from the step index
  (the synthetic pipeline is a pure function of (seed, step)),
* a communication ledger in *bits actually uplinked* (the paper's x-axis in
  Figs. 1–2), accumulated on device,
* periodic eval loss, checkpointing, metrics history.

Hot-path discipline: the loop is a ``jax.lax.scan`` over chunks of
``log_every`` steps with the carry donated (``donate_argnums``), so the host
dispatches one fused computation — and syncs exactly once — per log interval
instead of every step.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.core import (
    DCGD,
    Diana,
    ECSGD,
    BlockNatural,
    BlockQSGD,
    BlockRandK,
    CorrelatedCompressor,
    FaultSpec,
    Marina,
    PermK,
    PPMarina,
    ServerAggregator,
    VRMarina,
    diana_alpha,
    make_compressor,
    make_downlink,
    make_engine,
    tree_dim,
    tree_omega,
)
from repro.data import HeterogeneousLMData, make_prefix_embeddings, worker_batches
from repro.models import lm_loss
from repro.models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    method: str = "vr_marina"          # marina|vr_marina|pp_marina|diana|dcgd|ec_sgd|gd
    compressor: str = "randk"
    comp_kwargs: dict = dataclasses.field(default_factory=lambda: {"k": 0.01})
    gamma: float = 0.05
    p: Optional[float] = None          # None → ζ_Q/d (Cor. 2.1)
    n_workers: int = 4
    batch_per_worker: int = 8          # b  (sync rounds / full batches)
    mb_per_worker: int = 2             # b' (compressed rounds)
    r_participating: int = 2           # PP-MARINA cohort size r
    # PP-MARINA federated dials (DESIGN.md §4.8): cohort scheme (Alg. 4
    # samples with replacement; False = the experiments' distinct-client
    # variant) and optional client weights for unbalanced local datasets
    # (array-like of length n_workers; raw sample counts are fine —
    # PPMarina normalizes to Σw_i = 1 at construction).
    pp_replace: bool = True
    pp_weights: Optional[Any] = None
    # Dirichlet non-IID dial for the LM data (None → legacy heterogeneity
    # scalar): alpha=0.1 gives near-single-region clients, np.inf iid —
    # so any config can run the federated scenario, e.g.
    # TrainConfig(method="pp_marina", n_workers=64, r_participating=8,
    # alpha=0.1).
    alpha: Optional[float] = None
    steps: int = 100
    seed: int = 0
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    diana_alpha: Optional[float] = None
    flat_backend: str = "auto"         # kernel backend for the flat engine
    # gradient-carry rounds (DESIGN.md §4.7): one backprop per round; with a
    # flat engine the round ends in the fused epilogue kernel. marina /
    # vr_marina only.
    carry_grads: bool = False
    # compressed downlink: compressor/sampler name for Q_down(g^{k+1} − g^k)
    # ("qsgd" | "randk" | "natural" | None = dense broadcast). With a flat
    # engine the name selects the downlink engine's sampler; on the per-leaf
    # tree path it is a make_compressor name.
    downlink: Optional[str] = None
    downlink_kwargs: dict = dataclasses.field(default_factory=dict)
    # Byzantine-robust server aggregation + client fault injection
    # (DESIGN.md §4.9). aggregator is a GAR name (repro.core.aggregators.RULES)
    # with aggregator_f the assumed Byzantine count; faults/faults_frac/
    # faults_scale build a FaultSpec. marina-family only; "mean"/"none" keep
    # the seed trajectory bit-identical.
    aggregator: str = "mean"
    aggregator_f: int = 0
    faults: str = "none"
    faults_frac: float = 0.0
    faults_scale: float = 1.0
    # Non-finite round guard: when a step produces any NaN/inf in the new
    # state (params or estimator), revert the whole state to the pre-step
    # value and count the round in TrainMetrics.skipped_cum. Bits are still
    # booked (the wire traffic happened; the server just refused the update).
    nonfinite_guard: bool = True


@dataclasses.dataclass
class TrainMetrics:
    step: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    grad_est_norm: list = dataclasses.field(default_factory=list)
    bits_cum: list = dataclasses.field(default_factory=list)
    down_cum: list = dataclasses.field(default_factory=list)
    oracle_cum: list = dataclasses.field(default_factory=list)
    wall: list = dataclasses.field(default_factory=list)
    skipped_cum: list = dataclasses.field(default_factory=list)


def _state_finite(state: PyTree) -> jax.Array:
    """Scalar bool: every floating leaf of the optimizer state (params,
    estimator g, carried h, …) is all-finite. The non-finite round guard's
    predicate — one traced reduction, no host sync."""
    checks = [
        jnp.all(jnp.isfinite(leaf))
        for leaf in jax.tree.leaves(state)
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)
    ]
    if not checks:
        return jnp.asarray(True)
    return jnp.all(jnp.stack(checks))


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        train_cfg: TrainConfig,
        init_params: PyTree,
        prefix_len: int = 0,
    ):
        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.prefix_len = prefix_len
        self.data = HeterogeneousLMData(
            n_workers=train_cfg.n_workers,
            vocab_size=model_cfg.vocab_size,
            seq_len=128 if model_cfg.num_layers <= 4 else 256,
            seed=train_cfg.seed,
            alpha=train_cfg.alpha,
        )
        self._prefix_key = jax.random.PRNGKey(train_cfg.seed + 7)

        def loss_fn(params, batch):
            tokens = batch["tokens"]
            prefix = batch.get("prefix")
            return lm_loss(params, model_cfg, tokens, prefix)

        self.loss_fn = loss_fn
        grad_fn = jax.grad(loss_fn)

        d = tree_dim(init_params)
        comp = make_compressor(train_cfg.compressor, **train_cfg.comp_kwargs)
        if isinstance(comp, CorrelatedCompressor) and comp.n == 0:
            # correlated collections are sized by the worker fleet
            comp = dataclasses.replace(comp, n=train_cfg.n_workers)
        p = train_cfg.p if train_cfg.p is not None else comp.default_p(d)
        self.p = p
        self.comp = comp
        # block_randk / permk / block_qsgd / block_natural rounds run fused
        # over the packed flat buffer (the quantized ones on the bit-packed
        # wire, so the bits ledger books the packed accounting — wire.py);
        # every other compressor keeps the per-leaf tree path.
        if isinstance(comp, BlockRandK):
            self.engine = make_engine(
                init_params, kb=comp.kb, block=comp.block,
                backend=train_cfg.flat_backend,
            )
        elif isinstance(comp, PermK):
            self.engine = make_engine(
                init_params, block=comp.block,
                backend=train_cfg.flat_backend, sampler="permk",
            )
        elif isinstance(comp, BlockQSGD):
            self.engine = make_engine(
                init_params, block=comp.block,
                backend=train_cfg.flat_backend, sampler="qsgd", s=comp.s,
            )
        elif isinstance(comp, BlockNatural):
            self.engine = make_engine(
                init_params, block=comp.block,
                backend=train_cfg.flat_backend, sampler="natural",
            )
        else:
            self.engine = None

        # compressed downlink (DESIGN.md §4.7): with a flat engine the
        # downlink is a second engine sharing the uplink layout (the name is
        # the sampler); on the per-leaf path it is a tree compressor. Either
        # way the ledger books wire.py accounting for the broadcast.
        self.down_engine = None
        self.down_comp = None
        if train_cfg.downlink is not None:
            dkw = dict(train_cfg.downlink_kwargs)
            if self.engine is not None:
                name = train_cfg.downlink.removeprefix("block_")
                assert name in ("randk", "qsgd", "natural"), (
                    f"downlink {train_cfg.downlink!r} is not broadcastable "
                    "(permk partitions across receivers)"
                )
                self.down_engine = make_downlink(
                    self.engine, sampler=name,
                    kb=dkw.get("kb"), s=dkw.get("s"),
                )
            else:
                self.down_comp = make_compressor(train_cfg.downlink, **dkw)

        m = train_cfg.method
        # robust aggregation / fault dials (DESIGN.md §4.9): None when the
        # config is the honest default so the seed trajectory stays
        # bit-identical (the optimizers also guarantee this for the explicit
        # "mean"/"none" instances, but None skips the dial entirely).
        agg = (
            ServerAggregator(train_cfg.aggregator, f=train_cfg.aggregator_f)
            if train_cfg.aggregator != "mean"
            else None
        )
        fspec = (
            FaultSpec(
                train_cfg.faults,
                frac=train_cfg.faults_frac,
                scale=train_cfg.faults_scale,
            )
            if train_cfg.faults != "none"
            else None
        )
        if (agg is not None or fspec is not None) and m not in (
            "marina", "vr_marina", "pp_marina"
        ):
            raise ValueError(
                f"aggregator/faults are marina-family dials, not {m!r}"
            )
        if train_cfg.carry_grads and m not in (
            "marina", "vr_marina", "pp_marina"
        ):
            raise ValueError(f"carry_grads is a marina-family mode, not {m!r}")
        if train_cfg.downlink is not None and m not in (
            "marina", "vr_marina", "pp_marina"
        ):
            # refuse rather than silently broadcast dense while the user
            # believes the downlink is compressed
            raise ValueError(
                f"downlink is a marina-family mode, not {m!r}"
            )
        if m == "marina":
            self.method = Marina(
                grad_fn, comp, train_cfg.gamma, p, self.engine,
                carry=train_cfg.carry_grads,
                down_compressor=self.down_comp, down_engine=self.down_engine,
                aggregator=agg, faults=fspec,
            )
        elif m == "gd":
            from repro.core import make_gd

            self.method = make_gd(grad_fn, train_cfg.gamma)
        elif m == "vr_marina":
            self.method = VRMarina(
                grad_fn, grad_fn, comp, train_cfg.gamma, p, self.engine,
                carry=train_cfg.carry_grads,
                down_compressor=self.down_comp, down_engine=self.down_engine,
                aggregator=agg, faults=fspec,
            )
        elif m == "pp_marina":
            self.method = PPMarina(
                grad_fn, comp, train_cfg.gamma, p, train_cfg.r_participating,
                self.engine,
                down_compressor=self.down_comp, down_engine=self.down_engine,
                replace=train_cfg.pp_replace,
                weights=(
                    None if train_cfg.pp_weights is None
                    else jnp.asarray(train_cfg.pp_weights, jnp.float32)
                ),
                carry=train_cfg.carry_grads,
                aggregator=agg, faults=fspec,
            )
        elif m == "diana":
            alpha = train_cfg.diana_alpha
            if alpha is None:
                # the per-leaf lifted compressor's worst-leaf ω, NOT ω of the
                # total tree dimension: for absolute-k compressors (RandK(64))
                # the true per-leaf ω is far below d/k − 1, and an α from the
                # inflated ω would be needlessly tiny (slow shift learning).
                alpha = (
                    diana_alpha(max(tree_omega(comp, init_params), 1e-9))
                    if comp.unbiased
                    else 0.5
                )
            self.method = Diana(
                grad_fn, comp, train_cfg.gamma, alpha, train_cfg.n_workers
            )
        elif m == "dcgd":
            self.method = DCGD(grad_fn, comp, train_cfg.gamma, train_cfg.n_workers)
        elif m == "ec_sgd":
            self.method = ECSGD(grad_fn, comp, train_cfg.gamma, train_cfg.n_workers)
        else:
            raise ValueError(f"unknown method {m!r}")

        self.params0 = init_params
        self._jitted_step = jax.jit(self._step)
        # chunked hot loop: one dispatch + one host sync per log interval.
        # carry = (state, bits, down, oracle); donated so params/g (and the
        # carried h) update in place.
        self._jitted_chunk = jax.jit(self._chunk, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _batches(self, step: int, per_worker: int):
        toks = worker_batches(self.data, step, per_worker)
        batch = {"tokens": toks}
        if self.prefix_len:
            batch["prefix"] = make_prefix_embeddings(
                jax.random.fold_in(self._prefix_key, step),
                self.tcfg.n_workers,
                per_worker,
                self.prefix_len,
                self.mcfg.d_model,
            )
        return batch

    def _step(self, state, key, full_b, mb_b):
        m = self.tcfg.method
        if m in ("marina", "gd", "pp_marina", "diana", "dcgd", "ec_sgd"):
            return self.method.step(state, key, full_b)
        return self.method.step(state, key, full_b, mb_b)

    def _chunk(self, carry, steps):
        """Scan `len(steps)` optimizer steps on device.

        Batches are regenerated inside the trace from the step index (the
        data pipeline is a pure function of (seed, step)), and the bits /
        down-bits / oracle ledgers accumulate in the carry — no per-step host
        sync. Returns the final carry and the last step's metrics.

        With ``nonfinite_guard`` (the default), a step whose new state holds
        any NaN/inf — e.g. a ``nan``-attack round hitting a mean aggregator —
        is *skipped*: the whole state reverts to its pre-step value (one bad
        round must not poison the MARINA recursion forever) and the skipped
        ledger increments. Bits/oracle still accumulate: the traffic and the
        compute happened; only the server-side update was refused.
        """
        base_key = jax.random.PRNGKey(self.tcfg.seed)

        def body(c, step):
            state, bits, down, oracle, skipped = c
            key = jax.random.fold_in(base_key, step)
            full_b = self._batches(step, self.tcfg.batch_per_worker)
            mb_b = self._batches(10**7 + step, self.tcfg.mb_per_worker)
            new_state, met = self._step(state, key, full_b, mb_b)
            if self.tcfg.nonfinite_guard:
                ok = _state_finite(new_state)
                # revert the ENTIRE state on a bad round — a finite-looking
                # h/g paired with reverted params would desynchronize the
                # estimator recursion.
                new_state = jax.tree.map(
                    lambda new, old: jnp.where(ok, new, old), new_state, state
                )
                met = met._replace(
                    grad_est_norm=jnp.where(ok, met.grad_est_norm, 0.0)
                )
                skipped = skipped + jnp.where(ok, 0.0, 1.0)
            return (
                new_state,
                bits + met.bits_per_worker,
                down + met.down_bits,
                oracle + met.oracle_calls,
                skipped,
            ), met

        carry, mets = jax.lax.scan(body, carry, steps)
        last_met = jax.tree.map(lambda a: a[-1], mets)
        return carry, last_met

    def eval_loss(self, params, step: int = 10**6) -> float:
        b = self._batches(step, self.tcfg.batch_per_worker)
        losses = jax.vmap(self.loss_fn, in_axes=(None, 0))(params, b)
        return float(jnp.mean(losses))

    # ------------------------------------------------------------------
    def _boundaries(self, start: int) -> list:
        """Host-sync points: steps after which we must look at the state
        (log/eval) or serialize it (checkpoint). The device runs free
        between consecutive boundaries."""
        tc = self.tcfg
        # log after every log_every-th step and always after the final step.
        # Chunks between consecutive log points are uniform (log_every steps)
        # so the scan compiles once for them; a ragged final chunk — and any
        # ckpt point not aligned to the log grid — adds one extra compile per
        # distinct length.
        log_pts = {
            s for s in range(start, tc.steps) if (s + 1) % tc.log_every == 0
        }
        log_pts.add(tc.steps - 1)
        ckpt_pts = set()
        if tc.ckpt_dir and tc.ckpt_every:
            ckpt_pts = {
                s for s in range(start, tc.steps) if (s + 1) % tc.ckpt_every == 0
            }
        pts = sorted(p for p in log_pts | ckpt_pts if start <= p < tc.steps)
        return [(p, p in log_pts, p in ckpt_pts) for p in pts]

    def run(self) -> tuple[PyTree, TrainMetrics]:
        tc = self.tcfg
        b0 = self._batches(0, tc.batch_per_worker)
        if tc.method in ("diana", "dcgd", "ec_sgd"):
            state = self.method.init(self.params0)
        else:
            state = self.method.init(self.params0, b0)

        start = 0
        bits = 0.0
        down = 0.0
        oracle = 0.0
        skipped = 0.0
        if tc.ckpt_dir:
            s = latest_step(tc.ckpt_dir)
            if s is not None:
                # the communication/oracle ledgers resume WITH the state
                # (which includes the carried h_i^k in carry mode): a restart
                # that zeroes them silently shifts every resumed loss-vs-bits
                # curve (the Fig. 1/2 x-axis) left. A corrupt file raises
                # CheckpointCorruptionError from load_checkpoint — NOT caught
                # by the KeyError format tiers below.
                like = {
                    "state": state,
                    "bits": np.zeros((), np.float32),
                    "down": np.zeros((), np.float32),
                    "oracle": np.zeros((), np.float32),
                    "skipped": np.zeros((), np.float32),
                }
                try:
                    ck = load_checkpoint(tc.ckpt_dir, s, like)
                    state = ck["state"]
                    bits = float(ck["bits"])
                    down = float(ck["down"])
                    oracle = float(ck["oracle"])
                    skipped = float(ck["skipped"])
                except KeyError:
                    try:
                        # pre-guard checkpoint: no skipped-rounds ledger.
                        del like["skipped"]
                        ck = load_checkpoint(tc.ckpt_dir, s, like)
                        state = ck["state"]
                        bits = float(ck["bits"])
                        down = float(ck["down"])
                        oracle = float(ck["oracle"])
                    except KeyError:
                        try:
                            # pre-downlink checkpoint: bits/oracle only.
                            del like["down"]
                            ck = load_checkpoint(tc.ckpt_dir, s, like)
                            state = ck["state"]
                            bits = float(ck["bits"])
                            oracle = float(ck["oracle"])
                        except KeyError:
                            # pre-ledger checkpoint (bare state tree): resume
                            # the iterates and accept zeroed ledgers rather
                            # than refuse the directory outright.
                            state = load_checkpoint(tc.ckpt_dir, s, state)
                start = s + 1

        # the chunk carry is donated; copy so self.params0 (aliased into the
        # initial state) survives for eval or a second run().
        state = jax.tree.map(jnp.array, state)

        hist = TrainMetrics()
        t0 = time.time()

        # anchor the loss-vs-bits curve at the pre-training state (step
        # start−1, 0 bits uplinked): the uniform chunking below only logs
        # after full log intervals, and the Fig. 1/2-style curves need the
        # initial point.
        from repro.core.tree_util import tree_norm

        hist.step.append(start - 1)
        hist.loss.append(self.eval_loss(state.params, start))
        hist.grad_est_norm.append(
            float(tree_norm(state.g)) if hasattr(state, "g") else 0.0
        )
        hist.bits_cum.append(bits)
        hist.down_cum.append(down)
        hist.oracle_cum.append(oracle)
        hist.wall.append(time.time() - t0)
        hist.skipped_cum.append(skipped)

        prev = start
        for bound, is_log, is_ckpt in self._boundaries(start):
            # one fused device dispatch for steps [prev, bound]; the bits /
            # down-bits / oracle / skipped ledgers accumulate on device, read
            # back once per chunk.
            steps_arr = jnp.arange(prev, bound + 1, dtype=jnp.int32)
            # four distinct zero buffers: the chunk carry is donated, and
            # donating one buffer several times is an XLA error
            zeros = [jnp.zeros((), jnp.float32) for _ in range(4)]
            (state, chunk_bits, chunk_down, chunk_oracle, chunk_skip), met = (
                self._jitted_chunk((state, *zeros), steps_arr)
            )
            bits += float(chunk_bits)
            down += float(chunk_down)
            oracle += float(chunk_oracle)
            skipped += float(chunk_skip)
            prev = bound + 1

            if is_log:
                loss = self.eval_loss(state.params, bound)
                hist.step.append(bound)
                hist.loss.append(loss)
                hist.grad_est_norm.append(float(met.grad_est_norm))
                hist.bits_cum.append(bits)
                hist.down_cum.append(down)
                hist.oracle_cum.append(oracle)
                hist.wall.append(time.time() - t0)
                hist.skipped_cum.append(skipped)
            if is_ckpt:
                save_checkpoint(
                    tc.ckpt_dir,
                    bound,
                    {
                        "state": state,
                        "bits": np.float32(bits),
                        "down": np.float32(down),
                        "oracle": np.float32(oracle),
                        "skipped": np.float32(skipped),
                    },
                )
        return state, hist
