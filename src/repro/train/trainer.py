"""Training loop wiring the MARINA family into LM training.

The trainer runs the *simulation backend* (worker-stacked trees on one device;
the same algorithm code as the mesh path — see launch/distributed.py for the
sharded production step). It owns:

* method construction (MARINA / VR-MARINA / PP-MARINA / DIANA / DCGD / EC-SGD /
  GD) with compressor + stepsize policy,
* the per-step data plumbing (full-round batches vs b′ minibatches — the
  Alg. 3 online case),
* a communication ledger in *bits actually uplinked* (the paper's x-axis in
  Figs. 1–2),
* periodic eval loss, checkpointing, metrics history.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, latest_step, save_checkpoint
from repro.core import (
    DCGD,
    Diana,
    ECSGD,
    Marina,
    PPMarina,
    VRMarina,
    diana_alpha,
    make_compressor,
    tree_dim,
)
from repro.data import HeterogeneousLMData, make_prefix_embeddings, worker_batches
from repro.models import lm_loss
from repro.models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass
class TrainConfig:
    method: str = "vr_marina"          # marina|vr_marina|pp_marina|diana|dcgd|ec_sgd|gd
    compressor: str = "randk"
    comp_kwargs: dict = dataclasses.field(default_factory=lambda: {"k": 0.01})
    gamma: float = 0.05
    p: Optional[float] = None          # None → ζ_Q/d (Cor. 2.1)
    n_workers: int = 4
    batch_per_worker: int = 8          # b  (sync rounds / full batches)
    mb_per_worker: int = 2             # b' (compressed rounds)
    r_participating: int = 2           # PP-MARINA
    steps: int = 100
    seed: int = 0
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    diana_alpha: Optional[float] = None


@dataclasses.dataclass
class TrainMetrics:
    step: list = dataclasses.field(default_factory=list)
    loss: list = dataclasses.field(default_factory=list)
    grad_est_norm: list = dataclasses.field(default_factory=list)
    bits_cum: list = dataclasses.field(default_factory=list)
    oracle_cum: list = dataclasses.field(default_factory=list)
    wall: list = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        train_cfg: TrainConfig,
        init_params: PyTree,
        prefix_len: int = 0,
    ):
        self.mcfg = model_cfg
        self.tcfg = train_cfg
        self.prefix_len = prefix_len
        self.data = HeterogeneousLMData(
            n_workers=train_cfg.n_workers,
            vocab_size=model_cfg.vocab_size,
            seq_len=128 if model_cfg.num_layers <= 4 else 256,
            seed=train_cfg.seed,
        )
        self._prefix_key = jax.random.PRNGKey(train_cfg.seed + 7)

        def loss_fn(params, batch):
            tokens = batch["tokens"]
            prefix = batch.get("prefix")
            return lm_loss(params, model_cfg, tokens, prefix)

        self.loss_fn = loss_fn
        grad_fn = jax.grad(loss_fn)

        d = tree_dim(init_params)
        comp = make_compressor(train_cfg.compressor, **train_cfg.comp_kwargs)
        p = train_cfg.p if train_cfg.p is not None else comp.default_p(d)
        self.p = p
        self.comp = comp

        m = train_cfg.method
        if m == "marina":
            self.method = Marina(grad_fn, comp, train_cfg.gamma, p)
        elif m == "gd":
            from repro.core import make_gd

            self.method = make_gd(grad_fn, train_cfg.gamma)
        elif m == "vr_marina":
            self.method = VRMarina(grad_fn, grad_fn, comp, train_cfg.gamma, p)
        elif m == "pp_marina":
            self.method = PPMarina(
                grad_fn, comp, train_cfg.gamma, p, train_cfg.r_participating
            )
        elif m == "diana":
            alpha = train_cfg.diana_alpha
            if alpha is None:
                from repro.core import tree_omega

                alpha = diana_alpha(max(comp.omega(d), 1e-9)) if comp.unbiased else 0.5
            self.method = Diana(
                grad_fn, comp, train_cfg.gamma, alpha, train_cfg.n_workers
            )
        elif m == "dcgd":
            self.method = DCGD(grad_fn, comp, train_cfg.gamma, train_cfg.n_workers)
        elif m == "ec_sgd":
            self.method = ECSGD(grad_fn, comp, train_cfg.gamma, train_cfg.n_workers)
        else:
            raise ValueError(f"unknown method {m!r}")

        self.params0 = init_params
        self._jitted_step = jax.jit(self._step)

    # ------------------------------------------------------------------
    def _batches(self, step: int, per_worker: int):
        toks = worker_batches(self.data, step, per_worker)
        batch = {"tokens": toks}
        if self.prefix_len:
            batch["prefix"] = make_prefix_embeddings(
                jax.random.fold_in(self._prefix_key, step),
                self.tcfg.n_workers,
                per_worker,
                self.prefix_len,
                self.mcfg.d_model,
            )
        return batch

    def _step(self, state, key, full_b, mb_b):
        m = self.tcfg.method
        if m in ("marina", "gd", "pp_marina", "diana", "dcgd", "ec_sgd"):
            return self.method.step(state, key, full_b)
        return self.method.step(state, key, full_b, mb_b)

    def eval_loss(self, params, step: int = 10**6) -> float:
        b = self._batches(step, self.tcfg.batch_per_worker)
        losses = jax.vmap(self.loss_fn, in_axes=(None, 0))(params, b)
        return float(jnp.mean(losses))

    # ------------------------------------------------------------------
    def run(self) -> tuple[PyTree, TrainMetrics]:
        tc = self.tcfg
        b0 = self._batches(0, tc.batch_per_worker)
        if tc.method in ("diana", "dcgd", "ec_sgd"):
            state = self.method.init(self.params0)
        else:
            state = self.method.init(self.params0, b0)

        start = 0
        if tc.ckpt_dir:
            s = latest_step(tc.ckpt_dir)
            if s is not None:
                state = load_checkpoint(tc.ckpt_dir, s, state)
                start = s + 1

        hist = TrainMetrics()
        bits = 0.0
        oracle = 0.0
        t0 = time.time()
        for step in range(start, tc.steps):
            key = jax.random.fold_in(jax.random.PRNGKey(tc.seed), step)
            full_b = self._batches(step, tc.batch_per_worker)
            mb_b = self._batches(10**7 + step, tc.mb_per_worker)
            state, met = self._jitted_step(state, key, full_b, mb_b)
            bits += float(met.bits_per_worker)
            oracle += float(met.oracle_calls)

            if step % tc.log_every == 0 or step == tc.steps - 1:
                loss = self.eval_loss(state.params, step)
                hist.step.append(step)
                hist.loss.append(loss)
                hist.grad_est_norm.append(float(met.grad_est_norm))
                hist.bits_cum.append(bits)
                hist.oracle_cum.append(oracle)
                hist.wall.append(time.time() - t0)
            if tc.ckpt_dir and tc.ckpt_every and (step + 1) % tc.ckpt_every == 0:
                save_checkpoint(tc.ckpt_dir, step, state)
        return state, hist
