from .trainer import TrainConfig, Trainer, TrainMetrics

__all__ = ["TrainConfig", "Trainer", "TrainMetrics"]
