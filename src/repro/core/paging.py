"""Paged KV-cache substrate: page pool with refcounts, block tables, prefix index.

The serving engine's KV memory is one flat page pool per layer —
``(npage, page_size, kv_heads, head_dim)``, the KV twin of the flat
``(nblk, 1024)`` gradient layout in ``core/flat.py`` — plus ONE block
table shared by every layer: request r's token t lives in page
``table[r, t // page_size]`` at row ``t % page_size`` of every layer's
pool. This module owns the *host-side* bookkeeping (allocation is a
scheduling decision, not a device computation):

* :class:`PagedLayout` — the static geometry (pool size, page size, block
  table width, decode-slot count). Page 0 is the reserved **null page**:
  the free list never hands it out, every empty block-table entry points
  at it, idle decode slots write their garbage k/v there — and it is never
  refcounted, so the sharing machinery can never free or alias it.
* :class:`PagePool` — LIFO free list over pages ``1..npage-1`` with
  per-page **refcounts** for copy-on-write prefix sharing: :meth:`alloc`
  hands out pages at refcount 1, :meth:`fork` adds a reference when a new
  block-table row maps an existing page, :meth:`release` drops one and
  reclaims the page at zero. Every allocation bumps the page's **epoch**,
  so a stale pointer into a freed-and-reissued page is detectable
  (:class:`PrefixIndex` validates its entries this way). The
  :meth:`check_conservation` audit also cross-checks the block tables:
  a free-list page referenced by any table row, or a refcount that does
  not equal the number of rows referencing the page, is corruption.
* :class:`BlockTables` — the ``(n_slots, max_pages)`` int32 host mirror
  that is shipped to the device each step (it changes with request churn;
  the pool itself stays donated on-device).
* :class:`PrefixIndex` — a chain-hash index over prompt pages: full pages
  key on (parent digest, page tokens); the final partial page registers
  its exact token content so an identical or extending prompt can map it
  too (the first write into a shared page COW-splits it). Entries are
  *weak*: they hold no reference, and a lookup whose (page, epoch) no
  longer matches the pool is dropped — the cache lives exactly as long as
  some block-table row keeps the pages alive.

DESIGN.md §8 is the contract; ``launch/scheduler.py`` drives admission,
COW, and preemption; ``models/model.py::paged_decode_step`` consumes the
arrays.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: the reserved trash page: never allocated, refcounted, or freed
NULL_PAGE = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of the paged KV cache.

    npage:      total pages in the pool, including the reserved null page 0
    page_size:  tokens per page (the KV-pool analogue of the flat block width)
    max_pages:  block-table width — the per-request page budget, so a request
                may hold at most ``max_pages * page_size`` tokens
    n_slots:    decode batch width (concurrent requests in flight)
    """

    npage: int
    page_size: int
    max_pages: int
    n_slots: int

    def __post_init__(self):
        if self.npage < 2:
            raise ValueError("pool needs the null page plus at least one usable page")
        if self.page_size < 1 or self.max_pages < 1 or self.n_slots < 1:
            raise ValueError(f"degenerate layout {self}")

    @property
    def usable_pages(self) -> int:
        """Allocatable pages (the null page is never handed out)."""
        return self.npage - 1

    @property
    def max_len(self) -> int:
        """Longest sequence one block-table row can address."""
        return self.max_pages * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens."""
        return -(-int(n_tokens) // self.page_size)


class PagePool:
    """LIFO free-list allocator over pages ``1..npage-1`` with refcounts.

    LIFO keeps recently-freed (still cache-warm) pages hot. Every page is
    either on the free list or referenced by ≥1 holder; prefix sharing
    aliases one physical page into several block-table rows via
    :meth:`fork` (refcount++), and :meth:`release` drops a reference,
    reclaiming the page when the count hits zero. :meth:`free` is the
    strict exclusive path (rejects shared pages, double-frees, and
    never-allocated ids). :meth:`check_conservation` asserts the
    invariants the scheduler and fuzz tests rely on:
    ``n_free + n_allocated == usable_pages`` with no overlap, refcounts
    positive exactly on allocated pages — and, when the block tables are
    passed, no free-list page referenced by any row and every refcount
    equal to the number of rows referencing that page.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._free: List[int] = list(range(layout.npage - 1, 0, -1))
        self._allocated: set = set()
        self._ref: Dict[int, int] = {}
        # bumped on every alloc of the page: stale pointers (a PrefixIndex
        # entry outliving its page) are detected by epoch mismatch
        self._epoch: Dict[int, int] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def refcount(self, page: int) -> int:
        """References held on ``page`` (0 when free or never allocated)."""
        return self._ref.get(page, 0)

    def epoch(self, page: int) -> int:
        """Allocation generation of ``page`` (bumped each time it is handed
        out), for validating weak pointers like PrefixIndex entries."""
        return self._epoch.get(page, 0)

    def alloc(self, k: int) -> List[int]:
        """Pop ``k`` pages off the free list (all-or-nothing, refcount 1)."""
        if k < 0:
            raise ValueError(f"cannot allocate {k} pages")
        if k > len(self._free):
            raise PoolExhausted(
                f"asked for {k} pages with {len(self._free)} free "
                f"(pool of {self.layout.usable_pages})"
            )
        pages = [self._free.pop() for _ in range(k)]
        self._allocated.update(pages)
        for p in pages:
            self._ref[p] = 1
            self._epoch[p] = self._epoch.get(p, 0) + 1
        return pages

    def fork(self, page: int) -> int:
        """Add a reference to an allocated page (a new block-table row maps
        it); returns the new refcount. The null page is never refcounted."""
        if page == NULL_PAGE:
            raise ValueError("the null page is never forked")
        if page not in self._allocated:
            raise ValueError(f"page {page} is not allocated (fork of a free page?)")
        self._ref[page] += 1
        return self._ref[page]

    def release(self, page: int) -> int:
        """Drop one reference; at zero the page returns to the free list.
        Returns the remaining refcount."""
        if page == NULL_PAGE:
            raise ValueError("the null page is never allocated or freed")
        if page not in self._allocated:
            raise ValueError(f"page {page} is not allocated (double free?)")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            self._allocated.remove(page)
            self._free.append(page)
            return 0
        return self._ref[page]

    def free(self, pages: Sequence[int]) -> None:
        """Exclusive free: every page must be held exactly once (shared pages
        must go through :meth:`release`); double/foreign frees raise."""
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("the null page is never allocated or freed")
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated (double free?)")
            if self._ref.get(p, 0) != 1:
                raise ValueError(
                    f"page {p} has refcount {self._ref.get(p, 0)}; free() is "
                    "the exclusive-owner path, shared pages use release()"
                )
        for p in pages:
            del self._ref[p]
            self._allocated.remove(p)
            self._free.append(p)

    def check_conservation(self, tables: Optional["BlockTables"] = None) -> None:
        """Every usable page is free xor allocated, exactly once; refcounts
        are positive exactly on allocated pages. With ``tables``: no
        free-list page is referenced by any block-table row, and each
        allocated page's refcount equals the number of rows referencing it
        (the COW/sharing invariant the fuzz harness drives)."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("free list holds a duplicate page")
        if free & self._allocated:
            raise AssertionError(
                f"pages both free and allocated: {sorted(free & self._allocated)}"
            )
        union = free | self._allocated
        expect = set(range(1, self.layout.npage))
        if union != expect:
            raise AssertionError(
                f"page leak: missing {sorted(expect - union)}, "
                f"foreign {sorted(union - expect)}"
            )
        if set(self._ref) != self._allocated:
            raise AssertionError(
                f"refcount keys drifted from the allocated set: "
                f"extra {sorted(set(self._ref) - self._allocated)}, "
                f"missing {sorted(self._allocated - set(self._ref))}"
            )
        bad = {p: c for p, c in self._ref.items() if c < 1}
        if bad:
            raise AssertionError(f"non-positive refcounts on allocated pages: {bad}")
        if tables is not None:
            refs = tables.reference_counts()
            if NULL_PAGE in refs:
                del refs[NULL_PAGE]
            still_referenced = free & set(refs)
            if still_referenced:
                raise AssertionError(
                    f"free-list pages still referenced by block-table rows: "
                    f"{sorted(still_referenced)}"
                )
            if refs != dict(self._ref):
                drift = {
                    p: (self._ref.get(p, 0), refs.get(p, 0))
                    for p in set(refs) | set(self._ref)
                    if self._ref.get(p, 0) != refs.get(p, 0)
                }
                raise AssertionError(
                    "refcounts != block-table references (page: pool, table): "
                    f"{drift}"
                )


class BlockTables:
    """Host mirror of the device block tables: ``(n_slots, max_pages)`` int32.

    Empty entries hold :data:`NULL_PAGE`; :meth:`assign` fills a slot's row
    with its allocated pages in order, :meth:`set_entry` rewrites one entry
    (the COW-split and lazy-allocation paths), :meth:`clear` nulls it on
    eviction. ``array`` is the value shipped to the jitted step each
    iteration.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._table = np.full(
            (layout.n_slots, layout.max_pages), NULL_PAGE, dtype=np.int32
        )

    def assign(self, slot: int, pages: Sequence[int]) -> None:
        if len(pages) > self.layout.max_pages:
            raise ValueError(
                f"{len(pages)} pages exceed the block-table width "
                f"{self.layout.max_pages}"
            )
        self._table[slot] = NULL_PAGE
        self._table[slot, : len(pages)] = np.asarray(pages, np.int32)

    def set_entry(self, slot: int, idx: int, page: int) -> None:
        """Point one (slot, page-index) entry at a physical page — the COW
        split (shared → private copy) and lazy decode-page allocation both
        land here."""
        self._table[slot, idx] = np.int32(page)

    def clear(self, slot: int) -> None:
        self._table[slot] = NULL_PAGE

    def row(self, slot: int) -> np.ndarray:
        return self._table[slot].copy()

    def reference_counts(self) -> Dict[int, int]:
        """{page id: number of table entries referencing it} over non-null
        entries — what PagePool.check_conservation audits refcounts against."""
        ids, counts = np.unique(self._table, return_counts=True)
        return {
            int(p): int(c) for p, c in zip(ids, counts) if int(p) != NULL_PAGE
        }

    @property
    def array(self) -> np.ndarray:
        """The current (n_slots, max_pages) int32 table (a defensive copy)."""
        return self._table.copy()


def _chunk_digest(parent: int, tokens: np.ndarray) -> int:
    """crc32 chain over page-sized token chunks: stable across processes (no
    PYTHONHASHSEED dependence), cheap, and collisions are harmless because
    every hit is verified against the exact stored token content."""
    return zlib.crc32(
        np.asarray(tokens, np.int32).tobytes(), parent & 0xFFFFFFFF
    )


@dataclasses.dataclass
class _PrefixEntry:
    page: int
    epoch: int
    tokens: Tuple[int, ...]  # exact content — digest hits are verified


class PrefixIndex:
    """Weak chain-hash index from prompt-page content to physical pages.

    Full prompt pages register under the digest chain
    ``d_i = crc32(tokens[iP:(i+1)P], d_{i-1})``; the final *partial* page
    (when the prompt is not page-aligned) registers its exact content under
    its parent digest, so a new prompt that extends a cached one can map
    the partial page too and COW-split it on first write. Entries hold NO
    pool reference: :meth:`match` validates each hit against the pool's
    (allocated, epoch) state and silently drops stale entries — the prefix
    cache lives exactly as long as some block-table row keeps its pages
    alive (the fuzz invariant "refcount == table references" stays exact).
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        # digest -> candidate entries: several live requests may each hold a
        # private copy of the same content (they were admitted before anyone
        # registered), and any one of them can serve as the donor — keeping
        # them all means the cache survives the earliest donor completing
        self._full: Dict[int, List[_PrefixEntry]] = {}
        # parent digest -> partial-page entries (longest-prefix match wins)
        self._partial: Dict[int, List[_PrefixEntry]] = {}

    def _valid(self, pool: PagePool, e: _PrefixEntry) -> bool:
        return (
            pool.refcount(e.page) > 0 and pool.epoch(e.page) == e.epoch
        )

    def match(
        self, pool: PagePool, prompt: np.ndarray, max_tokens: int
    ) -> Tuple[List[int], int]:
        """Longest cached prefix of ``prompt`` still live in the pool.

        Returns ``(pages, n_tokens)`` — the physical pages covering the
        first ``n_tokens`` prompt tokens (full pages, possibly plus one
        partial page), capped at ``max_tokens`` so the caller can force the
        final prompt position through prefill (its logits seed the first
        generated token). The caller forks each returned page. Stale
        entries encountered on the walk are pruned."""
        P = self.layout.page_size
        prompt = np.asarray(prompt, np.int32)
        pages: List[int] = []
        matched = 0
        parent = 0
        while matched + P <= min(len(prompt), max_tokens):
            chunk = prompt[matched:matched + P]
            d = _chunk_digest(parent, chunk)
            cands = self._full.get(d, [])
            live = [e for e in cands if self._valid(pool, e)]
            if len(live) != len(cands):
                if live:
                    self._full[d] = live
                else:
                    self._full.pop(d, None)
            want = tuple(int(t) for t in chunk)
            hit = next((e for e in live if e.tokens == want), None)
            if hit is None:
                break
            pages.append(hit.page)
            matched += P
            parent = d
        # the final partial page: longest registered content that is a
        # prefix of the remaining prompt tokens
        remaining = prompt[matched:min(len(prompt), max_tokens)]
        cands = self._partial.get(parent, [])
        live = [e for e in cands if self._valid(pool, e)]
        if len(live) != len(cands):
            self._partial[parent] = live
        best = None
        for e in live:
            n = len(e.tokens)
            if 0 < n <= len(remaining) and tuple(
                int(t) for t in remaining[:n]
            ) == e.tokens:
                if best is None or n > len(best.tokens):
                    best = e
        if best is not None:
            pages.append(best.page)
            matched += len(best.tokens)
        return pages, matched

    def register(
        self, pool: PagePool, prompt: np.ndarray, pages: Sequence[int]
    ) -> None:
        """Publish a fully-prefilled prompt's pages: one full-page entry per
        complete chunk, plus a partial entry for the tail. ``pages`` is the
        block-table row prefix covering the prompt (physical ids in logical
        order). Stale entries are pruned; live duplicates of the same page
        are not re-added (a follower that forked the donor's pages registers
        the very same ids)."""
        P = self.layout.page_size
        prompt = np.asarray(prompt, np.int32)
        parent = 0
        for i, page in enumerate(pages):
            lo = i * P
            hi = min(lo + P, len(prompt))
            tokens = tuple(int(t) for t in prompt[lo:hi])
            if page == NULL_PAGE or pool.refcount(page) == 0:
                break
            entry = _PrefixEntry(page=page, epoch=pool.epoch(page), tokens=tokens)
            if hi - lo == P:
                d = _chunk_digest(parent, prompt[lo:hi])
                bucket = self._full.setdefault(d, [])
                bucket[:] = [e for e in bucket if self._valid(pool, e)]
                if not any(
                    e.page == page and e.epoch == entry.epoch for e in bucket
                ):
                    bucket.append(entry)
                parent = d
            else:
                bucket = self._partial.setdefault(parent, [])
                bucket[:] = [
                    e for e in bucket
                    if self._valid(pool, e)
                    and not (e.page == page and e.epoch == entry.epoch)
                ] + [entry]
                break
