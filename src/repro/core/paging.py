"""Paged KV-cache substrate: fixed-size page pool, free list, block tables.

The serving engine's KV memory is one flat page pool per layer —
``(npage, page_size, kv_heads, head_dim)``, the KV twin of the flat
``(nblk, 1024)`` gradient layout in ``core/flat.py`` — plus ONE block
table shared by every layer: request r's token t lives in page
``table[r, t // page_size]`` at row ``t % page_size`` of every layer's
pool. This module owns the *host-side* bookkeeping (allocation is a
scheduling decision, not a device computation):

* :class:`PagedLayout` — the static geometry (pool size, page size, block
  table width, decode-slot count). Page 0 is the reserved **null page**:
  the free list never hands it out, every empty block-table entry points
  at it, and idle decode slots write their garbage k/v there — so the
  jitted decode step needs no masking on the write path.
* :class:`PagePool` — LIFO free list over pages ``1..npage-1`` with
  conservation checking (a page is either free or owned by exactly one
  request; double-free and foreign-free raise).
* :class:`BlockTables` — the ``(n_slots, max_pages)`` int32 host mirror
  that is shipped to the device each step (it changes with request churn;
  the pool itself stays donated on-device).

DESIGN.md §8 is the contract; ``launch/scheduler.py`` drives admission and
eviction; ``models/model.py::paged_decode_step`` consumes the arrays.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

#: the reserved trash page: never allocated, absorbs idle-slot writes
NULL_PAGE = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation asks for more pages than are free."""


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of the paged KV cache.

    npage:      total pages in the pool, including the reserved null page 0
    page_size:  tokens per page (the KV-pool analogue of the flat block width)
    max_pages:  block-table width — the per-request page budget, so a request
                may hold at most ``max_pages * page_size`` tokens
    n_slots:    decode batch width (concurrent requests in flight)
    """

    npage: int
    page_size: int
    max_pages: int
    n_slots: int

    def __post_init__(self):
        if self.npage < 2:
            raise ValueError("pool needs the null page plus at least one usable page")
        if self.page_size < 1 or self.max_pages < 1 or self.n_slots < 1:
            raise ValueError(f"degenerate layout {self}")

    @property
    def usable_pages(self) -> int:
        """Allocatable pages (the null page is never handed out)."""
        return self.npage - 1

    @property
    def max_len(self) -> int:
        """Longest sequence one block-table row can address."""
        return self.max_pages * self.page_size

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` tokens."""
        return -(-int(n_tokens) // self.page_size)


class PagePool:
    """LIFO free-list allocator over pages ``1..npage-1``.

    LIFO keeps recently-freed (still cache-warm) pages hot. Every page is
    either on the free list or owned by exactly one holder; :meth:`free`
    rejects double-frees and never-allocated ids, and
    :meth:`check_conservation` asserts the invariant the scheduler tests
    rely on: ``n_free + n_allocated == usable_pages`` with no overlap.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._free: List[int] = list(range(layout.npage - 1, 0, -1))
        self._allocated: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def alloc(self, k: int) -> List[int]:
        """Pop ``k`` pages off the free list (all-or-nothing)."""
        if k < 0:
            raise ValueError(f"cannot allocate {k} pages")
        if k > len(self._free):
            raise PoolExhausted(
                f"asked for {k} pages with {len(self._free)} free "
                f"(pool of {self.layout.usable_pages})"
            )
        pages = [self._free.pop() for _ in range(k)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the free list; double/foreign frees raise."""
        for p in pages:
            if p == NULL_PAGE:
                raise ValueError("the null page is never allocated or freed")
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated (double free?)")
        for p in pages:
            self._allocated.remove(p)
            self._free.append(p)

    def check_conservation(self) -> None:
        """Every usable page is free xor allocated, exactly once."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("free list holds a duplicate page")
        if free & self._allocated:
            raise AssertionError(
                f"pages both free and allocated: {sorted(free & self._allocated)}"
            )
        union = free | self._allocated
        expect = set(range(1, self.layout.npage))
        if union != expect:
            raise AssertionError(
                f"page leak: missing {sorted(expect - union)}, "
                f"foreign {sorted(union - expect)}"
            )


class BlockTables:
    """Host mirror of the device block tables: ``(n_slots, max_pages)`` int32.

    Empty entries hold :data:`NULL_PAGE`; :meth:`assign` fills a slot's row
    with its allocated pages in order, :meth:`clear` nulls it on eviction.
    ``array`` is the value shipped to the jitted step each iteration.
    """

    def __init__(self, layout: PagedLayout):
        self.layout = layout
        self._table = np.full(
            (layout.n_slots, layout.max_pages), NULL_PAGE, dtype=np.int32
        )

    def assign(self, slot: int, pages: Sequence[int]) -> None:
        if len(pages) > self.layout.max_pages:
            raise ValueError(
                f"{len(pages)} pages exceed the block-table width "
                f"{self.layout.max_pages}"
            )
        self._table[slot] = NULL_PAGE
        self._table[slot, : len(pages)] = np.asarray(pages, np.int32)

    def clear(self, slot: int) -> None:
        self._table[slot] = NULL_PAGE

    def row(self, slot: int) -> np.ndarray:
        return self._table[slot].copy()

    @property
    def array(self) -> np.ndarray:
        """The current (n_slots, max_pages) int32 table (a defensive copy)."""
        return self._table.copy()
