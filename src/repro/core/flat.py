"""Flat-buffer compression engine (DESIGN.md §4).

The per-leaf path in :mod:`repro.core.compressors` compresses a gradient
pytree leaf by leaf in a Python loop and the server densifies every worker
payload to an ``(n, d)`` tree before averaging — O(n·d) memory and FLOPs for
a round whose whole point is touching only ζ_Q ≪ d coordinates. This module
replaces that with a single packed representation:

* :class:`FlatLayout` — a *static* description of how a pytree maps onto one
  zero-padded ``(nblk, B)`` block buffer (B lane-aligned, default 1024).
  Computed once per parameter structure; pack/unpack are pure reshapes +
  one concatenate/slice, jit/vmap/donate friendly.
* :class:`FlatEngine` — the fused compress → uplink → decompress-mean
  pipeline over that buffer. Per-worker payloads are ``(nblk, kb)`` seeded
  RandK values whose indices are *regenerated from the seed* on the server
  (wire format: one uint32 seed + K values, DESIGN.md §4.2); aggregation is a
  scatter-accumulate into a single ``(nblk, B)`` accumulator — the ``(n, d)``
  dense worker trees are never materialized, so the round's cost scales with
  ζ_Q, not n·d.

Backends (DESIGN.md §5): ``pallas`` dispatches to the TPU kernels in
:mod:`repro.kernels.randk` (``randk_seeded`` / ``scatter_accum``);
``ref`` is the bit-exact pure-jnp oracle from :mod:`repro.kernels.ref`
(the two share the murmur3 counter RNG, so payloads are identical bit for
bit); ``pallas_interpret`` runs the kernels in interpret mode for CPU
validation. ``auto`` picks ``pallas`` on TPU and ``ref`` elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

DEFAULT_BLOCK = 1024  # 8 × 128 VMEM tile width; must be a power of two

BACKENDS = ("auto", "pallas", "pallas_interpret", "ref")


def resolve_backend(backend: str = "auto") -> str:
    """'auto' → 'pallas' on TPU, bit-exact 'ref' (pure jnp) elsewhere."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, expected one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


# ---------------------------------------------------------------------------
# Static layout: pytree ↔ (nblk, B) padded block buffer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one leaf lives inside the flat buffer (static metadata)."""

    offset: int
    size: int
    shape: tuple
    dtype: Any


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Precomputed static layout of a pytree over a padded block buffer.

    Leaves are concatenated in ``jax.tree.flatten`` order at offsets
    ``slots[i].offset``; the tail ``padded - d`` entries are structural zeros
    (DESIGN.md §4.1). Hashable/static: safe to close over in jitted functions.
    """

    treedef: Any
    slots: tuple
    d: int          # true dimension Σ leaf sizes
    block: int      # B, lane-aligned power of two
    nblk: int       # number of blocks = ceil(d / B)
    dtype: Any      # buffer compute dtype (leaves are cast in/out)

    @property
    def padded(self) -> int:
        return self.nblk * self.block


def make_layout(
    tree: PyTree, block: int = DEFAULT_BLOCK, dtype=jnp.float32
) -> FlatLayout:
    """Build the static layout for ``tree`` (shapes/dtypes only are read)."""
    assert block > 0 and block & (block - 1) == 0, "block must be a power of two"
    leaves, treedef = jax.tree.flatten(tree)
    slots = []
    off = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        slots.append(LeafSlot(off, size, tuple(leaf.shape), leaf.dtype))
        off += size
    d = off
    nblk = max(1, -(-d // block))
    return FlatLayout(
        treedef=treedef, slots=tuple(slots), d=d, block=block, nblk=nblk,
        dtype=dtype,
    )


def pack(layout: FlatLayout, tree: PyTree) -> jax.Array:
    """Pytree → ``(nblk, B)`` padded buffer (one concatenate, zero pad)."""
    leaves = layout.treedef.flatten_up_to(tree)
    flat = jnp.concatenate(
        [jnp.ravel(l).astype(layout.dtype) for l in leaves]
    )
    pad = layout.padded - layout.d
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(layout.nblk, layout.block)


def unpack(layout: FlatLayout, buf: jax.Array) -> PyTree:
    """Inverse of :func:`pack`; restores leaf shapes and dtypes."""
    flat = buf.reshape(-1)
    outs = [
        flat[s.offset : s.offset + s.size].reshape(s.shape).astype(s.dtype)
        for s in layout.slots
    ]
    return jax.tree.unflatten(layout.treedef, outs)


def pack_stacked(layout: FlatLayout, tree: PyTree) -> jax.Array:
    """Worker-stacked pytree (leading axis n) → ``(n, nblk, B)``."""
    return jax.vmap(lambda t: pack(layout, t))(tree)


# ---------------------------------------------------------------------------
# Backend-switched block primitives (shared with launch/distributed.py)
# ---------------------------------------------------------------------------


def seeded_offsets(seed: jax.Array, nblk: int, block: int, kb: int) -> jax.Array:
    """(nblk, kb) int32 offsets in [0, block) from the murmur3 counter RNG.

    Bit-identical to what the ``randk_seeded`` kernel samples on-chip for the
    same ``seed`` (the server regenerates indices from the 4-byte seed instead
    of receiving them — DESIGN.md §4.2).
    """
    from repro.kernels import ref

    ctr = (
        jnp.arange(kb, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * kb)[:, None]
    )
    bits = ref.murmur_bits_ref(seed.astype(jnp.uint32), ctr)
    return (bits & jnp.uint32(block - 1)).astype(jnp.int32)


def block_compress(
    x2d: jax.Array, seed: jax.Array, kb: int, scale: float, backend: str = "auto"
):
    """Seeded RandK over a block buffer: (nblk, B) → values/offsets (nblk, kb)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.randk_seeded_ref(x2d, seed.astype(jnp.uint32), kb, scale)
    from repro.kernels.randk import randk_seeded

    return randk_seeded(
        x2d, seed, kb, scale, interpret=(backend == "pallas_interpret")
    )


def block_compress_workers(
    x3d: jax.Array, seeds: jax.Array, kb: int, scale: float, backend: str = "auto"
):
    """Per-worker seeded RandK: (n, nblk, B) + (n,) seeds → (n, nblk, kb) ×2."""
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.randk_seeded_workers_ref(
            x3d, seeds.astype(jnp.uint32), kb, scale
        )
    from repro.kernels.randk import randk_seeded_workers

    return randk_seeded_workers(
        x3d, seeds, kb, scale, interpret=(backend == "pallas_interpret")
    )


def block_gather(
    x2d: jax.Array, offsets: jax.Array, scale: float, backend: str = "auto"
) -> jax.Array:
    """Gather+scale with host-supplied offsets: (nblk, B), (nblk, kb) → (nblk, kb)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.randk_block_compress_ref(x2d, offsets, scale)
    from repro.kernels.randk import randk_gather

    return randk_gather(
        x2d, offsets, scale, interpret=(backend == "pallas_interpret")
    )


def block_scatter_mean(
    values: jax.Array, offsets: jax.Array, block: int, backend: str = "auto"
) -> jax.Array:
    """Scatter-accumulate mean over workers: (n, nblk, kb) ×2 → (nblk, block).

    The only dense buffer is the single (nblk, block) accumulator — the n
    worker payloads stay ζ-sized (never densified per worker).
    """
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.scatter_accum_ref(values, offsets, block)
    from repro.kernels.randk import scatter_accum

    return scatter_accum(
        values, offsets, block, interpret=(backend == "pallas_interpret")
    )


def block_permk_workers(x3d: jax.Array, seed: jax.Array, backend: str = "auto"):
    """PermK uplink: (n, nblk, B) + ONE shared seed → values/offsets
    (n, nblk, B/n). The n workers' offsets partition every block (correlated
    compressor — DESIGN.md §4.5)."""
    backend = resolve_backend(backend)
    n = x3d.shape[0]
    if backend == "ref":
        from repro.kernels import ref

        return ref.permk_seeded_workers_ref(x3d, seed.astype(jnp.uint32), n)
    from repro.kernels.permk import permk_seeded_workers

    return permk_seeded_workers(
        x3d, seed, interpret=(backend == "pallas_interpret")
    )


def permk_concat_mean(
    values: jax.Array, seed: jax.Array, block: int, backend: str = "auto"
) -> jax.Array:
    """Scatter-free PermK aggregation: (n, nblk, B/n) payloads → (nblk, B)
    mean via concatenation + inverse-perm gather. Equal to
    :func:`block_scatter_mean` on the same payloads (disjoint supports ⇒ the
    scatter has no collisions), but never builds scatter index machinery —
    this is the server-side shape of the exact d/n-shard exchange."""
    del backend  # pure gather; the jnp form is already the fused shape
    from repro.kernels import ref

    return ref.permk_concat_mean_ref(values, seed, block)


def key_to_seed(key: jax.Array) -> jax.Array:
    """PRNG key → uint32 seed for the counter-based kernel RNG."""
    return jax.random.bits(key, dtype=jnp.uint32)


def seeded_payload_bits(nblk: int, kb: int) -> float:
    """Wire bits of one seeded-RandK payload: uint32 seed + K f32 values
    (indices are regenerated from the seed server-side — DESIGN.md §4.2).
    Single source of truth for FlatEngine and BlockRandK."""
    return 32.0 + 32.0 * nblk * kb


# ---------------------------------------------------------------------------
# The fused engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatEngine:
    """Fused compressed-round pipeline over a packed flat buffer.

    One engine instance is built per parameter structure (the layout is
    static) and handed to the MARINA-family optimizers; their compressed
    branch then runs

        pack (n workers) → seeded RandK (kb coords / B-block / worker)
        → scatter-accumulate mean → unpack

    with every stage dispatched through the kernel backend switch. Worker w's
    seed is derived from the round key exactly like the per-leaf tree path
    derives its worker keys (``jax.random.split``), and its counter stream
    restarts at 0 — masks are independent across workers (the 1/n variance
    averaging of Thm 2.1) and, on block-aligned single-leaf layouts, the flat
    path reproduces the tree path's randomness bit for bit (the trajectory
    equivalence test in tests/test_flat.py).

    ω/ζ_Q bookkeeping (DESIGN.md §4.3): sampling is with replacement, so
    E[Q(x)] = x with E‖Q(x)−x‖² = (B/kb)(1−1/B)‖x‖² ≤ ω‖x‖², ω = B/kb.

    ``sampler="permk"`` switches the uplink to the *correlated* PermK sampler
    (DESIGN.md §4.5): one shared seed per round, each worker's payload a
    disjoint (nblk·B)/n slice of the permuted buffer (wire: 32 + 32·(nblk·B)/n
    bits per worker), aggregation collision-free. ``kb`` is ignored there —
    the chunk width is forced to B/n by the partition.
    """

    layout: FlatLayout
    kb: int = 8
    backend: str = "auto"
    sampler: str = "randk"  # "randk" | "permk"

    def __post_init__(self):
        assert self.sampler in ("randk", "permk"), self.sampler

    def worker_seeds(self, key: jax.Array, n: int) -> jax.Array:
        """(n,) uint32 seeds, mirroring the tree path's per-worker key split."""
        return jax.vmap(key_to_seed)(jax.random.split(key, n))

    @property
    def scale(self) -> float:
        return self.layout.block / self.kb

    @property
    def omega(self) -> float:
        assert self.sampler == "randk", "PermK ω is n−1; ask the compressor"
        return self.layout.block / self.kb

    def payload_bits(self, n: "int | None" = None) -> float:
        """Wire bits per worker per compressed round. A permk engine REQUIRES
        the worker count — its chunk width is the partition share B/n, and a
        defaulted n would silently book the full dense buffer as one worker's
        compressed payload, corrupting the loss-vs-bits ledger."""
        if self.sampler == "permk":
            assert n is not None, "permk payload_bits needs the worker count"
            assert self.layout.block % n == 0, "n must divide the block width"
            return 32.0 + 32.0 * self.layout.padded / n
        return seeded_payload_bits(self.layout.nblk, self.kb)

    # -- stages -------------------------------------------------------------
    def compress_stacked(self, seeds: jax.Array, bufs: jax.Array):
        """(n, nblk, B) + (n,) seeds → per-worker payloads (values, offsets).

        Workers are folded into the kernel grid (one pallas_call over n·nblk
        blocks) rather than vmapped; per-worker seeds live in SMEM.
        """
        return block_compress_workers(
            bufs, seeds, self.kb, self.scale, self.backend
        )

    def decompress_mean(self, vals: jax.Array, offs: jax.Array) -> jax.Array:
        """(n, nblk, kb) payloads → (nblk, B) dense mean over workers."""
        return block_scatter_mean(vals, offs, self.layout.block, self.backend)

    # -- the hot path -------------------------------------------------------
    def fused_delta(self, key: jax.Array, diffs: PyTree, n: int) -> PyTree:
        """Compressed-round aggregate: worker-stacked diff tree → mean Q tree.

        Equivalent to decompressing every worker payload and averaging, but
        the per-worker dense (d,) trees are never built. The PermK sampler
        shares ONE seed across workers (the correlation IS the algorithm) and
        aggregates scatter-free: the disjoint chunks concatenate through the
        inverse permutation.
        """
        bufs = pack_stacked(self.layout, diffs)
        if self.sampler == "permk":
            seed = key_to_seed(key)  # shared: all workers, same permutation
            vals, _ = block_permk_workers(bufs, seed, self.backend)
            dense = permk_concat_mean(
                vals, seed, self.layout.block, self.backend
            )
        else:
            vals, offs = self.compress_stacked(self.worker_seeds(key, n), bufs)
            dense = self.decompress_mean(vals, offs)
        return unpack(self.layout, dense)

    # -- test/validation helpers -------------------------------------------
    def roundtrip_worker(self, key: jax.Array, tree: PyTree) -> PyTree:
        """Single-worker Q(x) through the full fused pipeline (for tests)."""
        stacked = jax.tree.map(lambda x: x[None], tree)
        return self.fused_delta(key, stacked, 1)


def make_engine(
    params: PyTree,
    kb: int = 8,
    block: int = DEFAULT_BLOCK,
    backend: str = "auto",
    dtype=jnp.float32,
    sampler: str = "randk",
) -> FlatEngine:
    """Engine for a parameter tree: layout once, fused pipeline forever."""
    return FlatEngine(
        layout=make_layout(params, block=block, dtype=dtype), kb=kb,
        backend=backend, sampler=sampler,
    )
