"""Flat-buffer compression engine (DESIGN.md §4).

The per-leaf path in :mod:`repro.core.compressors` compresses a gradient
pytree leaf by leaf in a Python loop and the server densifies every worker
payload to an ``(n, d)`` tree before averaging — O(n·d) memory and FLOPs for
a round whose whole point is touching only ζ_Q ≪ d coordinates. This module
replaces that with a single packed representation:

* :class:`FlatLayout` — a *static* description of how a pytree maps onto one
  zero-padded ``(nblk, B)`` block buffer (B lane-aligned, default 1024).
  Computed once per parameter structure; pack/unpack are pure reshapes +
  one concatenate/slice, jit/vmap/donate friendly.
* :class:`FlatEngine` — the fused compress → uplink → decompress-mean
  pipeline over that buffer. Per-worker payloads are ``(nblk, kb)`` seeded
  RandK values whose indices are *regenerated from the seed* on the server
  (wire format: one uint32 seed + K values, DESIGN.md §4.2); aggregation is a
  scatter-accumulate into a single ``(nblk, B)`` accumulator — the ``(n, d)``
  dense worker trees are never materialized, so the round's cost scales with
  ζ_Q, not n·d.

Backends (DESIGN.md §5): ``pallas`` dispatches to the TPU kernels in
:mod:`repro.kernels` (``randk_seeded`` / ``scatter_accum`` /
``qsgd_block_workers`` / ``qsgd_dequant_mean`` / …); ``ref`` is the
bit-exact pure-jnp oracle from :mod:`repro.kernels.ref` (the two share the
murmur3 counter RNG, so payloads are identical bit for bit);
``pallas_interpret`` runs the kernels in interpret mode for CPU validation.
``auto`` picks ``pallas`` on TPU and ``ref`` elsewhere.

Samplers: seeded RandK (f32 values wire), PermK (correlated partition,
DESIGN.md §4.5), and the packed quantization wire (DESIGN.md §4.6) —
blockwise QSGD (4-bit/int8 levels + per-block norms), blockwise natural
compression, and the bandwidth-optimal RandK∘QSGD composition.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

DEFAULT_BLOCK = 1024  # 8 × 128 VMEM tile width; must be a power of two

BACKENDS = ("auto", "pallas", "pallas_interpret", "ref")


def resolve_backend(backend: str = "auto") -> str:
    """'auto' → 'pallas' on TPU, bit-exact 'ref' (pure jnp) elsewhere."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}, expected one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


# ---------------------------------------------------------------------------
# Static layout: pytree ↔ (nblk, B) padded block buffer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one leaf lives inside the flat buffer (static metadata)."""

    offset: int
    size: int
    shape: tuple
    dtype: Any


@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Precomputed static layout of a pytree over a padded block buffer.

    Leaves are concatenated in ``jax.tree.flatten`` order at offsets
    ``slots[i].offset``; the tail ``padded - d`` entries are structural zeros
    (DESIGN.md §4.1). Hashable/static: safe to close over in jitted functions.
    """

    treedef: Any
    slots: tuple
    d: int          # true dimension Σ leaf sizes
    block: int      # B, lane-aligned power of two
    nblk: int       # number of blocks = ceil(d / B)
    dtype: Any      # buffer compute dtype (leaves are cast in/out)

    @property
    def padded(self) -> int:
        return self.nblk * self.block


def make_layout(
    tree: PyTree, block: int = DEFAULT_BLOCK, dtype=jnp.float32
) -> FlatLayout:
    """Build the static layout for ``tree`` (shapes/dtypes only are read)."""
    assert block > 0 and block & (block - 1) == 0, "block must be a power of two"
    leaves, treedef = jax.tree.flatten(tree)
    slots = []
    off = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        slots.append(LeafSlot(off, size, tuple(leaf.shape), leaf.dtype))
        off += size
    d = off
    nblk = max(1, -(-d // block))
    return FlatLayout(
        treedef=treedef, slots=tuple(slots), d=d, block=block, nblk=nblk,
        dtype=dtype,
    )


def pack(layout: FlatLayout, tree: PyTree) -> jax.Array:
    """Pytree → ``(nblk, B)`` padded buffer (one concatenate, zero pad)."""
    leaves = layout.treedef.flatten_up_to(tree)
    flat = jnp.concatenate(
        [jnp.ravel(l).astype(layout.dtype) for l in leaves]
    )
    pad = layout.padded - layout.d
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(layout.nblk, layout.block)


def unpack(layout: FlatLayout, buf: jax.Array) -> PyTree:
    """Inverse of :func:`pack`; restores leaf shapes and dtypes."""
    flat = buf.reshape(-1)
    outs = [
        flat[s.offset : s.offset + s.size].reshape(s.shape).astype(s.dtype)
        for s in layout.slots
    ]
    return jax.tree.unflatten(layout.treedef, outs)


def pack_stacked(layout: FlatLayout, tree: PyTree) -> jax.Array:
    """Worker-stacked pytree (leading axis n) → ``(n, nblk, B)``."""
    return jax.vmap(lambda t: pack(layout, t))(tree)


# ---------------------------------------------------------------------------
# Backend-switched block primitives (shared with launch/distributed.py)
# ---------------------------------------------------------------------------


def seeded_offsets(seed: jax.Array, nblk: int, block: int, kb: int) -> jax.Array:
    """(nblk, kb) int32 offsets in [0, block) from the murmur3 counter RNG.

    Bit-identical to what the ``randk_seeded`` kernel samples on-chip for the
    same ``seed`` (the server regenerates indices from the 4-byte seed instead
    of receiving them — DESIGN.md §4.2).
    """
    from repro.kernels import ref

    ctr = (
        jnp.arange(kb, dtype=jnp.uint32)[None, :]
        + (jnp.arange(nblk, dtype=jnp.uint32) * kb)[:, None]
    )
    bits = ref.murmur_bits_ref(seed.astype(jnp.uint32), ctr)
    return (bits & jnp.uint32(block - 1)).astype(jnp.int32)


def block_compress(
    x2d: jax.Array, seed: jax.Array, kb: int, scale: float, backend: str = "auto"
):
    """Seeded RandK over a block buffer: (nblk, B) → values/offsets (nblk, kb)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.randk_seeded_ref(x2d, seed.astype(jnp.uint32), kb, scale)
    from repro.kernels.randk import randk_seeded

    return randk_seeded(
        x2d, seed, kb, scale, interpret=(backend == "pallas_interpret")
    )


def block_compress_workers(
    x3d: jax.Array, seeds: jax.Array, kb: int, scale: float, backend: str = "auto"
):
    """Per-worker seeded RandK: (n, nblk, B) + (n,) seeds → (n, nblk, kb) ×2."""
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.randk_seeded_workers_ref(
            x3d, seeds.astype(jnp.uint32), kb, scale
        )
    from repro.kernels.randk import randk_seeded_workers

    return randk_seeded_workers(
        x3d, seeds, kb, scale, interpret=(backend == "pallas_interpret")
    )


def block_gather(
    x2d: jax.Array, offsets: jax.Array, scale: float, backend: str = "auto"
) -> jax.Array:
    """Gather+scale with host-supplied offsets: (nblk, B), (nblk, kb) → (nblk, kb)."""
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.randk_block_compress_ref(x2d, offsets, scale)
    from repro.kernels.randk import randk_gather

    return randk_gather(
        x2d, offsets, scale, interpret=(backend == "pallas_interpret")
    )


def block_scatter_mean(
    values: jax.Array, offsets: jax.Array, block: int, backend: str = "auto"
) -> jax.Array:
    """Scatter-accumulate mean over workers: (n, nblk, kb) ×2 → (nblk, block).

    The only dense buffer is the single (nblk, block) accumulator — the n
    worker payloads stay ζ-sized (never densified per worker).
    """
    backend = resolve_backend(backend)
    if backend == "ref":
        from repro.kernels import ref

        return ref.scatter_accum_ref(values, offsets, block)
    from repro.kernels.randk import scatter_accum

    return scatter_accum(
        values, offsets, block, interpret=(backend == "pallas_interpret")
    )


def block_permk_workers(x3d: jax.Array, seed: jax.Array, backend: str = "auto"):
    """PermK uplink: (n, nblk, B) + ONE shared seed → values/offsets
    (n, nblk, B/n). The n workers' offsets partition every block (correlated
    compressor — DESIGN.md §4.5)."""
    backend = resolve_backend(backend)
    n = x3d.shape[0]
    if backend == "ref":
        from repro.kernels import ref

        return ref.permk_seeded_workers_ref(x3d, seed.astype(jnp.uint32), n)
    from repro.kernels.permk import permk_seeded_workers

    return permk_seeded_workers(
        x3d, seed, interpret=(backend == "pallas_interpret")
    )


def permk_concat_mean(
    values: jax.Array, seed: jax.Array, block: int, backend: str = "auto"
) -> jax.Array:
    """Scatter-free PermK aggregation: (n, nblk, B/n) payloads → (nblk, B)
    mean via concatenation + inverse-perm gather. Equal to
    :func:`block_scatter_mean` on the same payloads (disjoint supports ⇒ the
    scatter has no collisions), but never builds scatter index machinery —
    this is the server-side shape of the exact d/n-shard exchange."""
    del backend  # pure gather; the jnp form is already the fused shape
    from repro.kernels import ref

    return ref.permk_concat_mean_ref(values, seed, block)


def block_qsgd_workers(x3d: jax.Array, seeds: jax.Array, s: int,
                       backend: str = "auto"):
    """Fused blockwise QSGD uplink: (n, nblk, B) + (n,) seeds →
    (levels (n, nblk, B) int8, norms (n, nblk) f32). Per-block ℓ2 norms ride
    the wire; the dither is regenerated from the seed and never transmitted."""
    from repro.kernels import quantize

    return quantize.qsgd_block_workers(
        x3d, seeds, s, backend=resolve_backend(backend)
    )


def block_qsgd_dequant_mean(levels: jax.Array, norms: jax.Array, s: int,
                            backend: str = "auto") -> jax.Array:
    """Fused dequantize-and-mean: (n, nblk, B) int8 + (n, nblk) f32 →
    (nblk, B) f32. Aggregation reads the payloads at int8 bandwidth; the only
    dense f32 buffer is the single (nblk, B) accumulator."""
    from repro.kernels import quantize

    return quantize.qsgd_dequant_mean(
        levels, norms, s, backend=resolve_backend(backend)
    )


def block_natural_workers(x3d: jax.Array, seeds: jax.Array,
                          backend: str = "auto"):
    """Fused blockwise natural-compression uplink: (n, nblk, B) + (n,) seeds
    → (codes (n, nblk, B) int8, scales (n, nblk) f32)."""
    from repro.kernels import quantize

    return quantize.natural_block_workers(
        x3d, seeds, backend=resolve_backend(backend)
    )


def block_natural_dequant_mean(codes: jax.Array, scales: jax.Array,
                               backend: str = "auto") -> jax.Array:
    """Fused decode-and-mean of natural payloads → (nblk, B) f32."""
    from repro.kernels import quantize

    return quantize.natural_dequant_mean(
        codes, scales, backend=resolve_backend(backend)
    )


def nibble_roundtrip(levels: jax.Array, block: int,
                     backend: str = "auto") -> jax.Array:
    """Push int8 levels through the genuine 4-bit wire: pack two-per-byte
    into uint32 lane words, then unpack (sign-extended). The identity on
    levels in [-8, 7] — running it in the pipeline keeps the simulation
    honest about what the wire can represent. levels: (n, nblk, B)."""
    from repro.kernels import quantize

    backend = resolve_backend(backend)
    n, nblk, B = levels.shape
    assert B == block, f"levels last dim {B} != wire block width {block}"
    words = quantize.nibble_pack(levels.reshape(n * nblk, B), backend=backend)
    out = quantize.nibble_unpack(words, B, backend=backend)
    return out.reshape(n, nblk, B)


def key_to_seed(key: jax.Array) -> jax.Array:
    """PRNG key → uint32 seed for the counter-based kernel RNG."""
    return jax.random.bits(key, dtype=jnp.uint32)


def seeded_payload_bits(nblk: int, kb: int) -> float:
    """Wire bits of one seeded-RandK payload (delegates to
    :mod:`repro.core.wire`, the single source of truth — DESIGN.md §4.6)."""
    from . import wire

    return wire.seeded_randk_bits(nblk, kb)


# ---------------------------------------------------------------------------
# The fused engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlatEngine:
    """Fused compressed-round pipeline over a packed flat buffer.

    One engine instance is built per parameter structure (the layout is
    static) and handed to the MARINA-family optimizers; their compressed
    branch then runs

        pack (n workers) → seeded RandK (kb coords / B-block / worker)
        → scatter-accumulate mean → unpack

    with every stage dispatched through the kernel backend switch. Worker w's
    seed is derived from the round key exactly like the per-leaf tree path
    derives its worker keys (``jax.random.split``), and its counter stream
    restarts at 0 — masks are independent across workers (the 1/n variance
    averaging of Thm 2.1) and, on block-aligned single-leaf layouts, the flat
    path reproduces the tree path's randomness bit for bit (the trajectory
    equivalence test in tests/test_flat.py).

    ω/ζ_Q bookkeeping (DESIGN.md §4.3): sampling is with replacement, so
    E[Q(x)] = x with E‖Q(x)−x‖² = (B/kb)(1−1/B)‖x‖² ≤ ω‖x‖², ω = B/kb.

    ``sampler="permk"`` switches the uplink to the *correlated* PermK sampler
    (DESIGN.md §4.5): one shared seed per round, each worker's payload a
    disjoint (nblk·B)/n slice of the permuted buffer (wire: 32 + 32·(nblk·B)/n
    bits per worker), aggregation collision-free. ``kb`` is ignored there —
    the chunk width is forced to B/n by the partition.

    The *packed quantization wire* (DESIGN.md §4.6) adds three samplers whose
    on-wire representation is bit-packed rather than f32:

    * ``"qsgd"`` — blockwise s-level ℓ2 QSGD: per-block f32 norm + one level
      per coordinate (signed nibble for s ≤ 7 — the pipeline genuinely packs
      through uint32 lane words — int8 for s ≤ 127). Aggregation is the fused
      dequantize-and-mean kernel: int8 input bandwidth, one f32 accumulator.
    * ``"natural"`` — blockwise power-of-two stochastic rounding (ω = 1/8):
      per-block f32 scale + int8 exponent-delta codes.
    * ``"randk_qsgd"`` — the bandwidth-optimal composition: seeded RandK
      keeps kb coords per block, QSGD quantizes ONLY those K values (per-block
      norms of the sampled vector). Wire: seed + nblk norms + K packed levels;
      aggregation dequantizes the K-sized payload and scatter-accumulates.
    """

    layout: FlatLayout
    kb: int = 8
    backend: str = "auto"
    sampler: str = "randk"  # "randk" | "permk" | "qsgd" | "natural" | "randk_qsgd"
    s: int = 7              # quantization levels for the qsgd-family samplers
    #: optional NamedSharding pinned onto the derived per-worker seeds. On a
    #: GSPMD mesh the partitioner may otherwise re-partition the
    #: split→bits threefry chain of :meth:`worker_seeds` and produce
    #: DIFFERENT seed values than the same key yields on one device
    #: (observed on the CPU SPMD partitioner; an optimization barrier does
    #: not prevent it), silently breaking core↔mesh trajectory equality.
    #: Single-device engines leave it None — a no-op.
    seed_constraint: Any = None

    SAMPLERS = ("randk", "permk", "qsgd", "natural", "randk_qsgd")

    def __post_init__(self):
        assert self.sampler in self.SAMPLERS, self.sampler
        if self.sampler in ("qsgd", "randk_qsgd"):
            from . import wire

            assert 1 <= self.s <= wire.INT8_MAX_S, (
                f"s={self.s} does not fit the int8 wire"
            )

    def worker_seeds(self, key: jax.Array, n: int) -> jax.Array:
        """(n,) uint32 seeds, mirroring the tree path's per-worker key split."""
        seeds = jax.vmap(key_to_seed)(jax.random.split(key, n))
        if self.seed_constraint is not None:
            seeds = jax.lax.with_sharding_constraint(seeds, self.seed_constraint)
        return seeds

    def _shared_seed(self, key: jax.Array) -> jax.Array:
        """ONE uint32 seed for the correlated (PermK) sampler, with the same
        partitioner pin as :meth:`worker_seeds`."""
        seed = key_to_seed(key)
        if self.seed_constraint is not None:
            seed = jax.lax.with_sharding_constraint(seed, self.seed_constraint)
        return seed

    @property
    def scale(self) -> float:
        return self.layout.block / self.kb

    @property
    def omega(self) -> float:
        """Def-1.1 ω of one worker's sampler (PermK's is collection-level —
        ask the compressor). Composition: 1+ω multiplies over independent
        stages, the QSGD stage acting on the kb-dim sampled block vector."""
        B = self.layout.block
        if self.sampler == "randk":
            return B / self.kb
        if self.sampler == "qsgd":
            return min(B / self.s**2, float(np.sqrt(B)) / self.s)
        if self.sampler == "natural":
            return 1.0 / 8.0
        if self.sampler == "randk_qsgd":
            w_q = min(self.kb / self.s**2, float(np.sqrt(self.kb)) / self.s)
            return (1.0 + B / self.kb) * (1.0 + w_q) - 1.0
        raise AssertionError("PermK ω is n−1; ask the compressor")

    def payload_bits(self, n: "int | None" = None) -> float:
        """Wire bits per worker per compressed round, from the shared wire
        accounting (repro.core.wire — DESIGN.md §4.6). A permk engine
        REQUIRES the worker count — its chunk width is the partition share
        B/n, and a defaulted n would silently book the full dense buffer as
        one worker's compressed payload, corrupting the loss-vs-bits ledger."""
        from . import wire

        lay = self.layout
        if self.sampler == "permk":
            assert n is not None, "permk payload_bits needs the worker count"
            assert lay.block % n == 0, "n must divide the block width"
            return wire.permk_bits(lay.padded, n)
        if self.sampler == "qsgd":
            return wire.block_qsgd_bits(lay.nblk, lay.block, self.s)
        if self.sampler == "natural":
            return wire.block_natural_bits(lay.nblk, lay.block)
        if self.sampler == "randk_qsgd":
            return wire.randk_qsgd_bits(lay.nblk, self.kb, self.s)
        return wire.seeded_randk_bits(lay.nblk, self.kb)

    # -- stages -------------------------------------------------------------
    def compress_stacked(self, seeds: jax.Array, bufs: jax.Array):
        """(n, nblk, B) + (n,) seeds → per-worker payloads (values, offsets).

        Workers are folded into the kernel grid (one pallas_call over n·nblk
        blocks) rather than vmapped; per-worker seeds live in SMEM.
        """
        return block_compress_workers(
            bufs, seeds, self.kb, self.scale, self.backend
        )

    def decompress_mean(self, vals: jax.Array, offs: jax.Array) -> jax.Array:
        """(n, nblk, kb) payloads → (nblk, B) dense mean over workers."""
        return block_scatter_mean(vals, offs, self.layout.block, self.backend)

    # -- per-worker dense decode (robust GARs — DESIGN.md §4.9) -------------
    def worker_dense(self, key: jax.Array, bufs: jax.Array, n: int) -> jax.Array:
        """Decode each worker's payload densely: (n, nblk, B) diffs →
        (n, nblk, B) f32 rows Q_i(Δ_i). The robust aggregation rules need the
        individual worker values — a scatter-*mean* is exactly what they must
        not compute. Same seeds/payloads as :meth:`aggregate` (the server
        combination is the only thing that changes). PermK refuses: its
        workers partition the coordinates (exactly one worker per coordinate
        — there is no per-coordinate sample to trim or median)."""
        from repro.kernels import ref as kref
        from . import wire

        if self.sampler == "permk":
            raise ValueError(
                "PermK partitions coordinates across workers; robust "
                "aggregation is undefined on its payloads (DESIGN.md §4.9)"
            )
        if self.sampler == "qsgd":
            seeds = self.worker_seeds(key, n)
            levels, norms = block_qsgd_workers(bufs, seeds, self.s, self.backend)
            if self.s <= wire.NIBBLE_MAX_S:
                levels = nibble_roundtrip(levels, self.layout.block, self.backend)
            return levels.astype(jnp.float32) * (norms / self.s)[..., None]
        if self.sampler == "natural":
            seeds = self.worker_seeds(key, n)
            codes, scales = block_natural_workers(bufs, seeds, self.backend)
            return jax.vmap(kref.natural_decode_ref)(codes, scales)
        if self.sampler == "randk_qsgd":
            seeds = self.worker_seeds(key, n)
            vals, offs = self.compress_stacked(seeds, bufs)
            levels, norms = kref.qsgd_sampled_quantize_ref(vals, seeds, self.s)
            vals = kref.randk_qsgd_dequant_ref(levels, norms, self.s)
        else:  # randk
            vals, offs = self.compress_stacked(self.worker_seeds(key, n), bufs)
        # per-worker scatter (n = 1 per row: the scatter-mean divides by 1)
        return jax.vmap(
            lambda v, o: block_scatter_mean(
                v[None], o[None], self.layout.block, self.backend
            )
        )(vals, offs)

    # -- the hot path -------------------------------------------------------
    def fused_delta(
        self, key: jax.Array, diffs: PyTree, n: int, aggregator=None
    ) -> PyTree:
        """Compressed-round aggregate: worker-stacked diff tree → mean Q tree.

        Equivalent to decompressing every worker payload and averaging, but
        the per-worker dense (d,) trees are never built. The PermK sampler
        shares ONE seed across workers (the correlation IS the algorithm) and
        aggregates scatter-free: the disjoint chunks concatenate through the
        inverse permutation. A robust ``aggregator`` (DESIGN.md §4.9) swaps
        the mean for its GAR over the per-worker decoded rows.
        """
        bufs = pack_stacked(self.layout, diffs)
        return unpack(self.layout, self.aggregate(key, bufs, n, aggregator))

    def aggregate(
        self, key: jax.Array, bufs: jax.Array, n: int, aggregator=None
    ) -> jax.Array:
        """Server-side aggregate over packed diffs: (n, nblk, B) → the dense
        (nblk, B) round delta (the buffer-level body of :meth:`fused_delta`,
        exposed so the downlink can re-compress the aggregate before it ever
        leaves flat form — DESIGN.md §4.7). With a robust ``aggregator``
        (a :class:`repro.core.aggregators.ServerAggregator` whose rule is not
        the mean) the combination runs the GAR over :meth:`worker_dense`."""
        if aggregator is not None and aggregator.robust:
            return aggregator.combine_rows(self.worker_dense(key, bufs, n))
        if self.sampler == "permk":
            seed = self._shared_seed(key)  # shared: all workers, same perm
            vals, _ = block_permk_workers(bufs, seed, self.backend)
            dense = permk_concat_mean(
                vals, seed, self.layout.block, self.backend
            )
        elif self.sampler == "qsgd":
            from . import wire

            seeds = self.worker_seeds(key, n)
            levels, norms = block_qsgd_workers(bufs, seeds, self.s, self.backend)
            if self.s <= wire.NIBBLE_MAX_S:
                # the levels genuinely cross the wire as packed nibbles
                levels = nibble_roundtrip(levels, self.layout.block, self.backend)
            dense = block_qsgd_dequant_mean(levels, norms, self.s, self.backend)
        elif self.sampler == "natural":
            seeds = self.worker_seeds(key, n)
            codes, scales = block_natural_workers(bufs, seeds, self.backend)
            dense = block_natural_dequant_mean(codes, scales, self.backend)
        elif self.sampler == "randk_qsgd":
            from repro.kernels import ref
            from . import wire

            # the gather/scatter stay on the backend-switched fused kernels;
            # only the K-sized quantize/dequant runs in plain jnp (ζ ≪ d —
            # bandwidth irrelevant, and bit-exact on every backend).
            seeds = self.worker_seeds(key, n)
            vals, offs = self.compress_stacked(seeds, bufs)
            levels, norms = ref.qsgd_sampled_quantize_ref(vals, seeds, self.s)
            # the K-sized levels are wire-accounted at 4/8 bits (wire.py) but
            # skip the in-pipeline pack/unpack: nibble_pack∘nibble_unpack is
            # a proven bit-exact identity on |level| ≤ s ≤ 7 (tests), and on
            # CPU the roundtrip defeats XLA's gather/scatter fusion for no
            # semantic difference. The dense qsgd sampler above DOES cross
            # the packed representation (its payload is where packing pays).
            vals = ref.randk_qsgd_dequant_ref(levels, norms, self.s)
            dense = self.decompress_mean(vals, offs)
        else:
            vals, offs = self.compress_stacked(self.worker_seeds(key, n), bufs)
            dense = self.decompress_mean(vals, offs)
        return dense

    # -- the fused server epilogue (DESIGN.md §4.7) -------------------------
    def fused_round(
        self,
        key: jax.Array,
        diff_bufs: jax.Array,
        n: int,
        g2d: jax.Array,
        x2d: jax.Array,
        gamma: float,
        down: "FlatEngine | None" = None,
        down_key: "jax.Array | None" = None,
        aggregator=None,
    ):
        """Finish a compressed round in ONE (nblk, B)-tile sweep: sample the
        uplink payloads from the packed diffs, then run the fused epilogue
        (kernels/epilogue.py) — dequant/scatter-mean → ``g += δ`` →
        ``x −= γ·g`` — directly on the wire representation. Returns
        ``(g_new (nblk, B) f32, x_new (nblk, B) layout-dtype)``.

        With ``down`` set (a second engine sharing this layout), the round is
        bidirectional: the uplink aggregates to the dense δ_up, the server
        broadcasts ``Q_down(δ_up)`` (= Q_down(g^{k+1} − g^k) — the estimator
        recursion runs on the broadcast sequence), and the epilogue consumes
        the single downlink payload (n = 1): the worker-side
        decompress-accumulate."""
        from repro.kernels import epilogue as epi
        from repro.kernels import ref as kref

        if down is not None:
            delta = self.aggregate(key, diff_bufs, n, aggregator)
            assert down.layout.block == self.layout.block and (
                down.layout.nblk == self.layout.nblk
            ), "downlink engine must share the uplink layout"
            assert down.sampler != "permk", (
                "PermK is a partition across n receivers; a broadcast "
                "downlink has one payload — use randk/qsgd/natural"
            )
            # the downlink's single server payload is past the GAR already
            return down.fused_round(down_key, delta[None], 1, g2d, x2d, gamma)

        backend = self.backend
        if aggregator is not None and aggregator.robust:
            rows = self.worker_dense(key, diff_bufs, n)
            if aggregator.coordinatewise:
                lo, hi = aggregator.trim_bounds(n)
                return epi.trimmed_delta_epilogue(
                    rows, g2d, x2d, gamma, lo, hi, backend=backend
                )
            delta = aggregator.combine_rows(rows)
            return epi.delta_epilogue(delta, g2d, x2d, gamma, backend=backend)
        if self.sampler == "permk":
            seed = self._shared_seed(key)
            vals, _ = block_permk_workers(diff_bufs, seed, backend)
            delta = permk_concat_mean(vals, seed, self.layout.block, backend)
            return epi.delta_epilogue(delta, g2d, x2d, gamma, backend=backend)
        if self.sampler == "qsgd":
            from . import wire

            seeds = self.worker_seeds(key, n)
            levels, norms = block_qsgd_workers(
                diff_bufs, seeds, self.s, backend
            )
            if self.s <= wire.NIBBLE_MAX_S:
                levels = nibble_roundtrip(levels, self.layout.block, backend)
            return epi.qsgd_epilogue(
                levels, norms, g2d, x2d, gamma, self.s, backend=backend
            )
        if self.sampler == "natural":
            seeds = self.worker_seeds(key, n)
            codes, scales = block_natural_workers(diff_bufs, seeds, backend)
            return epi.natural_epilogue(
                codes, scales, g2d, x2d, gamma, backend=backend
            )
        if self.sampler == "randk_qsgd":
            seeds = self.worker_seeds(key, n)
            vals, offs = self.compress_stacked(seeds, diff_bufs)
            levels, norms = kref.qsgd_sampled_quantize_ref(vals, seeds, self.s)
            vals = kref.randk_qsgd_dequant_ref(levels, norms, self.s)
            return epi.scatter_epilogue(
                vals, offs, g2d, x2d, gamma, backend=backend
            )
        vals, offs = self.compress_stacked(self.worker_seeds(key, n), diff_bufs)
        return epi.scatter_epilogue(vals, offs, g2d, x2d, gamma, backend=backend)

    def fused_sync(self, grad_bufs: jax.Array, x2d: jax.Array, gamma: float,
                   aggregator=None):
        """Sync-round epilogue: worker-mean over the ONE packed gradient
        buffer (the fused psum replacing the per-leaf tree exchange) fused
        with the iterate update. Returns (g_new, x_new) like fused_round.
        A robust ``aggregator`` replaces the mean with its GAR: the
        coordinate-wise rules run the trimmed sync kernel; Krum/norm-clip
        reduce the rows first and reuse the dense-δ epilogue (g = GAR)."""
        from repro.kernels import epilogue as epi

        if aggregator is not None and aggregator.robust:
            n = grad_bufs.shape[0]
            if aggregator.coordinatewise:
                lo, hi = aggregator.trim_bounds(n)
                return epi.trimmed_sync_epilogue(
                    grad_bufs, x2d, gamma, lo, hi, backend=self.backend
                )
            g_agg = aggregator.combine_rows(grad_bufs)
            return epi.delta_epilogue(
                g_agg, jnp.zeros_like(g_agg), x2d, gamma, backend=self.backend
            )
        return epi.mean_epilogue(grad_bufs, x2d, gamma, backend=self.backend)

    # -- test/validation helpers -------------------------------------------
    def roundtrip_worker(self, key: jax.Array, tree: PyTree) -> PyTree:
        """Single-worker Q(x) through the full fused pipeline (for tests)."""
        stacked = jax.tree.map(lambda x: x[None], tree)
        return self.fused_delta(key, stacked, 1)


def make_engine(
    params: PyTree,
    kb: int = 8,
    block: int = DEFAULT_BLOCK,
    backend: str = "auto",
    dtype=jnp.float32,
    sampler: str = "randk",
    s: int = 7,
) -> FlatEngine:
    """Engine for a parameter tree: layout once, fused pipeline forever."""
    return FlatEngine(
        layout=make_layout(params, block=block, dtype=dtype), kb=kb,
        backend=backend, sampler=sampler, s=s,
    )


def make_downlink(
    engine: FlatEngine,
    sampler: str = "qsgd",
    kb: "int | None" = None,
    s: "int | None" = None,
) -> FlatEngine:
    """Downlink engine sharing ``engine``'s layout/backend: the server-side
    compressor of Q_down(g^{k+1} − g^k) (DESIGN.md §4.7). PermK is rejected
    at use time (a broadcast has one payload, not an n-partition)."""
    return dataclasses.replace(
        engine, sampler=sampler,
        kb=engine.kb if kb is None else kb,
        s=engine.s if s is None else s,
    )
