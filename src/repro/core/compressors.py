"""Unbiased quantization operators (Def. 1.1) and biased contractive compressors.

Every compressor exposes the four quantities the MARINA theory consumes:

* ``omega(d)``            — the variance parameter ω of Def. 1.1:
                            ``E[Q(x)] = x`` and ``E‖Q(x) − x‖² ≤ ω‖x‖²``.
* ``expected_density(d)`` — ζ_Q = sup_x E‖Q(x)‖₀ (Def. 1.1), used for p = ζ_Q/d.
* ``payload_bits(d)``     — actual bits on the wire per compressed vector, used by the
                            trainer's communication ledger and the benchmarks that
                            reproduce the "total transmitted bits" axes of Fig. 1/2.
* ``ab_constants(d, n)``  — the (A, B) constants of the AB-inequality of Szlendak
                            et al. (2021) for the n-worker *collection* {Q_i}:

                              E‖(1/n) Σ_i Q_i(x_i) − x̄‖² ≤ A·(1/n)Σ_i‖x_i‖² − B·‖x̄‖²

                            with x̄ = (1/n)Σ_i x_i. This refines ω: MARINA's rate
                            depends on the collection only through (A, B)
                            (``stepsize.marina_gamma_ab``), and *correlated*
                            collections (PermK, CorrelatedQ below) achieve strictly
                            better constants than any independent ω-compressor.

Compression is defined on *flat* vectors; :func:`tree_compress` lifts a compressor to
pytrees by splitting the budget proportionally to leaf sizes (Block-RandK — see
DESIGN.md §3: unbiased with the same ω when the budget is proportional).

All operators are pure functions of an explicit PRNG key so they are jit/vmap/shard_map
safe. Payloads are fixed-shape pytrees (TPU-friendly: no data-dependent shapes).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Payload = Any  # pytree of fixed-shape arrays
PyTree = Any


# ---------------------------------------------------------------------------
# Base class
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base for stochastic mappings Q: R^d -> R^d (Def. 1.1 when unbiased)."""

    #: True for quantizations in the paper's sense (Def 1.1). Biased compressors
    #: (TopK) are only valid inside error-feedback methods (EC-SGD).
    unbiased: bool = dataclasses.field(default=True, init=False)

    name: str = dataclasses.field(default="base", init=False)

    # -- theory quantities -------------------------------------------------
    def omega(self, d: int) -> float:
        raise NotImplementedError

    def expected_density(self, d: int) -> float:
        raise NotImplementedError

    def payload_bits(self, d: int) -> float:
        """Bits per compressed vector of dimension d (32-bit value convention)."""
        raise NotImplementedError

    def default_p(self, d: int) -> float:
        """The paper's synchronization probability choice p = ζ_Q/d (Cor. 2.1)."""
        return min(1.0, max(self.expected_density(d) / max(d, 1), 1e-6))

    def ab_constants(self, d: int, n: int) -> tuple:
        """(A, B) of the AB-inequality for n independent copies of this Q.

        Tight constants for an uncorrelated collection: the aggregation error is
        (1/n²)Σ_i Var[Q_i(x_i)] ≤ (ω/n)·(1/n)Σ‖x_i‖², and since ‖x̄‖² ≤
        (1/n)Σ‖x_i‖² (Jensen) this equals ((1+ω)/n)·(1/n)Σ‖x_i‖² − (1/n)‖x̄‖²
        at worst, with equality when all x_i coincide. Hence

            (A, B) = ((1 + ω)/n, 1/n),

        whose homogeneous-smoothness rate term A − B = ω/n recovers Thm 2.1
        exactly. Note the constants are NOT (1+ω, ω): with x_i ≡ x that pair's
        right side is (1+ω)‖x‖² − ω‖x‖² = ‖x‖², so the inequality would force
        (ω/n)‖x‖² ≤ ‖x‖², i.e. ω ≤ n — false for e.g. RandK(k) on d > (n+1)k.
        Correlated subclasses override this."""
        w = self.omega(d)
        return ((1.0 + w) / n, 1.0 / n)

    # -- mechanics ----------------------------------------------------------
    def compress(self, key: jax.Array, x: jax.Array) -> Payload:
        raise NotImplementedError

    def decompress(self, payload: Payload, d: int) -> jax.Array:
        raise NotImplementedError

    def __call__(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Q(x) as a dense vector (compress → decompress round trip)."""
        return self.decompress(self.compress(key, x), x.shape[0])


# ---------------------------------------------------------------------------
# Identity — MARINA reduces to GD (paper §2: "if Q is identity ... GD")
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    name: str = dataclasses.field(default="identity", init=False)

    def omega(self, d: int) -> float:
        return 0.0

    def expected_density(self, d: int) -> float:
        return float(d)

    def payload_bits(self, d: int) -> float:
        return 32.0 * d

    def compress(self, key, x):
        return {"dense": x}

    def decompress(self, payload, d):
        return payload["dense"]


# ---------------------------------------------------------------------------
# RandK sparsification — the paper's main experimental compressor
# ---------------------------------------------------------------------------


def _randk_indices(key: jax.Array, d: int, k: int) -> jax.Array:
    """K uniform indices without replacement: top-K of iid uniform keys."""
    u = jax.random.uniform(key, (d,))
    _, idx = jax.lax.top_k(u, k)
    return idx.astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Uniform-K sparsification with scaling d/K.

    ω = d/K − 1, ζ_Q = K (Beznosikov et al. 2020). ``k`` may be an absolute count
    (``k >= 1``) or a fraction of d (``0 < k < 1``).
    """

    k: float = 1
    name: str = dataclasses.field(default="randk", init=False)

    def k_for(self, d: int) -> int:
        if self.k < 1:
            return max(1, int(round(self.k * d)))
        return min(int(self.k), d)

    def omega(self, d: int) -> float:
        return d / self.k_for(d) - 1.0

    def expected_density(self, d: int) -> float:
        return float(self.k_for(d))

    def payload_bits(self, d: int) -> float:
        # value (32b) + index (32b) per retained coordinate
        return 64.0 * self.k_for(d)

    def compress(self, key, x):
        d = x.shape[0]
        k = self.k_for(d)
        idx = _randk_indices(key, d, k)
        vals = x[idx] * (d / k)
        return {"values": vals, "indices": idx}

    def decompress(self, payload, d):
        out = jnp.zeros((d,), payload["values"].dtype)
        return out.at[payload["indices"]].add(payload["values"])


@dataclasses.dataclass(frozen=True)
class BlockRandK(Compressor):
    """Seeded blockwise RandK — the wire format of the flat engine (DESIGN.md §4).

    The vector is viewed as ``(nblk, block)`` zero-padded blocks; ``kb``
    coordinates per block are drawn *with replacement* by the murmur3 counter
    RNG shared with the Pallas kernels, and scaled by ``block/kb``. The payload
    is ``{values, seed}`` — indices are regenerated from the 4-byte seed at the
    server, so the wire cost is 32 + 32·K bits instead of RandK's 64·K.

    ω/ζ_Q (DESIGN.md §4.3): E[Q(x)] = x and
    E‖Q(x)−x‖² = (B/kb)(1−1/B)‖x‖² ⇒ ω = block/kb;
    ζ_Q = nblk·B·(1−(1−1/B)^kb) expected distinct coordinates.

    Used standalone it is a drop-in Def-1.1 quantization; used through
    :class:`repro.core.flat.FlatEngine` the same sampler runs fused over the
    packed gradient buffer without per-leaf Python loops.
    """

    kb: int = 8
    block: int = 1024
    name: str = dataclasses.field(default="block_randk", init=False)

    def __post_init__(self):
        assert self.block & (self.block - 1) == 0, "block must be a power of two"
        assert 1 <= self.kb <= self.block

    def _nblk(self, d: int) -> int:
        return max(1, -(-d // self.block))

    def omega(self, d: int) -> float:
        return self.block / self.kb

    def expected_density(self, d: int) -> float:
        per_block = self.block * (1.0 - (1.0 - 1.0 / self.block) ** self.kb)
        return float(min(d, self._nblk(d) * per_block))

    def payload_bits(self, d: int) -> float:
        from . import flat

        return flat.seeded_payload_bits(self._nblk(d), self.kb)

    def compress(self, key, x):
        from . import flat
        from repro.kernels import ops, ref

        x2d = ops.pad_to_blocks(x, self.block)
        seed = flat.key_to_seed(key)
        vals, _ = ref.randk_seeded_ref(x2d, seed, self.kb, self.block / self.kb)
        return {"values": vals, "seed": seed}

    def decompress(self, payload, d):
        from . import flat
        from repro.kernels import ref

        vals = payload["values"]
        nblk = vals.shape[0]
        offs = flat.seeded_offsets(payload["seed"], nblk, self.block, self.kb)
        dense = ref.scatter_accum_ref(vals[None], offs[None], self.block)
        return dense.reshape(-1)[:d].astype(vals.dtype)


@dataclasses.dataclass(frozen=True)
class BlockQSGD(Compressor):
    """Blockwise s-level ℓ2 QSGD — the packed quantization wire (DESIGN.md §4.6).

    The vector is viewed as ``(nblk, block)`` zero-padded blocks; each block
    is quantized against its OWN ℓ2 norm with the murmur3-seeded dither the
    Pallas kernels draw on-chip, so the flat engine (``sampler="qsgd"``)
    reproduces this compressor bit for bit. Wire per vector: nblk f32 norms +
    one level per coordinate — a signed 4-bit nibble (two per byte, eight per
    uint32 lane word) for s ≤ 7, int8 for s ≤ 127. The dither never rides the
    wire: the server only needs levels + norms.

    ω: per-block QSGD (Alistarh et al. 2017, Lemma 3.1 at dimension B) gives
    E‖Q(x_b)−x_b‖² ≤ min(B/s², √B/s)·‖x_b‖²; per-block norms make the bound
    additive over the orthogonal blocks, so ω = min(B/s², √B/s) — *better*
    than global-norm QSGD's min(d/s², √d/s) for d > B.
    ζ_Q: expected nnz ≤ s(s + √B) per block (Thm 3.2), capped at B.
    """

    s: int = 7
    block: int = 1024
    name: str = dataclasses.field(default="block_qsgd", init=False)

    def __post_init__(self):
        from . import wire

        assert self.block & (self.block - 1) == 0, "block must be a power of two"
        assert 1 <= self.s <= wire.INT8_MAX_S, "levels must fit the int8 wire"

    def _nblk(self, d: int) -> int:
        return max(1, -(-d // self.block))

    def omega(self, d: int) -> float:
        return min(self.block / self.s**2, math.sqrt(self.block) / self.s)

    def expected_density(self, d: int) -> float:
        per_block = min(self.block, self.s * (self.s + math.sqrt(self.block)))
        return float(min(d, self._nblk(d) * per_block))

    def payload_bits(self, d: int) -> float:
        from . import wire

        return wire.block_qsgd_bits(self._nblk(d), self.block, self.s)

    def default_p(self, d: int) -> float:
        """Dense quantizers make Cor. 2.1's p = ζ_Q/d degenerate (ζ ≈ d ⇒
        p ≈ 1 ⇒ MARINA = GD). The bits-balanced generalization — equalize
        the *expected uplink* of sync (32d) and compressed (payload_bits)
        rounds, the same motivation as the paper's choice — gives
        p = bits_Q/(32d): ≈ 1/8 on the 4-bit wire, ≈ 1/4 on int8."""
        return min(1.0, max(self.payload_bits(d) / (32.0 * d), 1e-6))

    def compress(self, key, x):
        from . import flat, wire
        from repro.kernels import ops, ref

        x2d = ops.pad_to_blocks(x, self.block)
        seed = flat.key_to_seed(key)
        levels, norms = ref.qsgd_block_ref(x2d, seed, self.s)
        if self.s <= wire.NIBBLE_MAX_S:
            # honesty: push the levels through the genuine 4-bit wire
            levels = ref.nibble_unpack_ref(
                ref.nibble_pack_ref(levels), self.block
            )
        return {"q": levels, "norms": norms}

    def decompress(self, payload, d):
        from repro.kernels import ref

        dense = ref.qsgd_dequant_mean_ref(
            payload["q"][None], payload["norms"][None], self.s
        )
        return dense.reshape(-1)[:d]


@dataclasses.dataclass(frozen=True)
class BlockNatural(Compressor):
    """Blockwise natural compression (Horváth et al. 2019) on the packed wire.

    |x| is stochastically rounded to a power of two (unbiased, ω = 1/8); the
    wire ships, per block, one f32 reference scale (the power of two just
    above the block max) and an int8 ``sign·(exponent-delta+1)`` code per
    coordinate — 8 bits/coord on a byte-aligned wire, vs the 9-bit
    sign+exponent entropy estimate of the per-leaf ``NaturalCompression``.
    Magnitudes ≥ 2^126 below the block max encode as 0 (a ≤ 2^-126·‖x_b‖_∞
    perturbation — below f32 relative resolution).
    """

    block: int = 1024
    name: str = dataclasses.field(default="block_natural", init=False)

    def __post_init__(self):
        assert self.block & (self.block - 1) == 0, "block must be a power of two"

    def _nblk(self, d: int) -> int:
        return max(1, -(-d // self.block))

    def omega(self, d: int) -> float:
        return 1.0 / 8.0

    def expected_density(self, d: int) -> float:
        return float(d)

    def payload_bits(self, d: int) -> float:
        from . import wire

        return wire.block_natural_bits(self._nblk(d), self.block)

    def default_p(self, d: int) -> float:
        """Bits-balanced p (see BlockQSGD.default_p): ζ_Q = d would give
        the degenerate p = 1; the int8 wire gives p ≈ 1/4."""
        return min(1.0, max(self.payload_bits(d) / (32.0 * d), 1e-6))

    def compress(self, key, x):
        from . import flat
        from repro.kernels import ops, ref

        x2d = ops.pad_to_blocks(x, self.block)
        seed = flat.key_to_seed(key)
        codes, scales = ref.natural_block_ref(x2d, seed)
        return {"q": codes, "scales": scales}

    def decompress(self, payload, d):
        from repro.kernels import ref

        dense = ref.natural_decode_ref(payload["q"], payload["scales"])
        return dense.reshape(-1)[:d]


@dataclasses.dataclass(frozen=True)
class SharedRandK(RandK):
    """RandK where all workers share the index key for a given round.

    Beyond-paper optimization (EXPERIMENTS.md §Perf): with identical masks across
    workers, the *sum* of worker payloads is supported on the same K indices, so
    aggregation is a K-sized psum instead of an n×K all-gather. Still an unbiased
    ω = d/K−1 quantization per worker; the cross-worker error correlation forfeits
    the 1/n variance averaging (theory cost: ω instead of ω/√n in the rate), which
    is exactly the trade the §Perf log quantifies.
    """

    name: str = dataclasses.field(default="shared_randk", init=False)

    def ab_constants(self, d: int, n: int) -> tuple:
        """Shared mask ⇒ (1/n)Σ Q_M(x_i) = Q_M(x̄) (the masked-scale map is
        linear for a fixed mask), so the aggregation error is E‖Q_M(x̄) − x̄‖²
        ≤ ω‖x̄‖² ≤ ω·(1/n)Σ‖x_i‖²: (A, B) = (ω, 0) with no 1/n — the formal
        statement of the "forfeits the 1/n variance averaging" trade."""
        return (self.omega(d), 0.0)


# ---------------------------------------------------------------------------
# Correlated collections (Szlendak et al. 2021; Panferov et al. 2024)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CorrelatedCompressor(Compressor):
    """Base for collections {Q_1..Q_n} with *shared* round randomness.

    Workers draw from ONE round key (no per-worker key split) and are told
    their index: ``compress_worker(key, x, wid)``. The correlation across
    workers is the point — it buys AB-inequality constants that no
    independent collection can reach (A − B = 0 for PermK vs ω/n). The
    single-operator ``compress(key, x)`` view samples a uniform worker index,
    which preserves Def.-1.1 unbiasedness for standalone use and tests.

    ``n`` is the worker-collection size; ``n = 0`` means "infer at wiring
    time" (the trainer replaces it with its worker count)."""

    n: int = 0

    def _n(self) -> int:
        assert self.n >= 1, f"{self.name}: worker count not set (n={self.n})"
        return self.n

    def compress_worker(self, key: jax.Array, x: jax.Array, wid) -> Payload:
        raise NotImplementedError

    def compress(self, key, x):
        k_w, k_q = jax.random.split(key)
        wid = jax.random.randint(k_w, (), 0, self._n())
        return self.compress_worker(k_q, x, wid)


@dataclasses.dataclass(frozen=True)
class PermK(CorrelatedCompressor):
    """Perm-K (Szlendak et al. 2021): a shared random permutation partitions
    the coordinates across the n workers; worker i keeps its d/n-slice scaled
    by n. Jointly unbiased with per-worker ω = n − 1, and — the headline —
    AB constants (A, B) = (1, 1): for homogeneous smoothness the MARINA rate
    term A − B vanishes and γ = 1/L (the GD stepsize) is admissible while
    each worker uplinks only d/n coordinates.

    Mechanics mirror :class:`BlockRandK` so the wire format stays
    ``seed + values``: the vector is zero-padded to ``(nblk, B)`` blocks and
    each block is permuted by a seeded *affine* bijection
    ``π(t) = (a·t + c) mod B`` with a odd (a unit of Z_B since B is a power
    of two), a and c drawn from the murmur3 counter RNG shared with the
    kernels. Worker w owns slots ``[w·B/n, (w+1)·B/n)`` of every block — the
    n supports partition the coordinate space exactly, so server aggregation
    is collision-free (concatenation + inverse-perm gather; no scatter).
    Marginal uniformity of π (c is uniform) gives per-worker unbiasedness,
    and partition + joint unbiasedness give (A, B) = (1, 1) *exactly*:
    E‖(1/n)ΣQ_i(x_i) − x̄‖² = (1/n)Σ‖x_i‖² − ‖x̄‖².

    Payload per worker: uint32 seed + (nblk·B)/n float32 values =
    ``32 + 32·(nblk·B)/n`` bits. Requires n | B (both powers of two)."""

    block: int = 1024
    name: str = dataclasses.field(default="permk", init=False)

    def __post_init__(self):
        assert self.block & (self.block - 1) == 0, "block must be a power of two"
        if self.n:
            assert self.block % self.n == 0, "worker count must divide block"

    def _nblk(self, d: int) -> int:
        return max(1, -(-d // self.block))

    def chunk(self) -> int:
        return self.block // self._n()

    def omega(self, d: int) -> float:
        # E‖n·x|_S − x‖² = Σ_j [(1/n)(n−1)² + (1−1/n)] x_j² = (n−1)‖x‖².
        return float(self._n() - 1)

    def expected_density(self, d: int) -> float:
        return d / self._n()

    def payload_bits(self, d: int) -> float:
        return 32.0 + 32.0 * self._nblk(d) * self.block / self._n()

    def ab_constants(self, d: int, n: int) -> tuple:
        assert n == self._n(), f"PermK built for n={self.n}, asked for n={n}"
        return (1.0, 1.0)

    def compress_worker(self, key, x, wid):
        from . import flat
        from repro.kernels import ops, ref

        x2d = ops.pad_to_blocks(x, self.block)
        seed = flat.key_to_seed(key)  # SHARED across workers: same key, same π
        wid = jnp.asarray(wid, jnp.int32)
        offs = ref.permk_offsets_ref(
            seed, x2d.shape[0], self.block, self._n(), wid
        )
        vals = jnp.take_along_axis(x2d, offs, axis=1) * jnp.asarray(
            float(self._n()), x2d.dtype
        )
        return {"values": vals, "seed": seed, "wid": wid}

    def decompress(self, payload, d):
        from repro.kernels import ref

        vals = payload["values"]
        nblk = vals.shape[0]
        offs = ref.permk_offsets_ref(
            payload["seed"], nblk, self.block, self._n(), payload["wid"]
        )
        dense = ref.scatter_accum_ref(vals[None], offs[None], self.block)
        return dense.reshape(-1)[:d].astype(vals.dtype)


@dataclasses.dataclass(frozen=True)
class CorrelatedQ(CorrelatedCompressor):
    """Correlated s-level quantization (Panferov et al. 2024 flavour).

    Each worker stochastically rounds ``s·x/‖x‖`` with a dither that is
    *stratified across the collection*: u_ij = frac(v_j + (wid + r_j)/n) with
    v, r shared (one round key for all workers). Marginally u_ij ~ U[0,1), so
    each worker is an unbiased ω = d/(4s²) quantization; jointly the n dithers
    per coordinate form an exact stratified grid, so for identical inputs the
    aggregate rounding error collapses to (1/n)·one stochastic rounding of
    n·s·x/‖x‖ (Hermite's identity Σ_w ⌊y + w/n⌋ = ⌊ny⌋) — variance ω/n² per
    round instead of the independent collection's ω/n.

    ``ab_constants`` stays conservative: for *heterogeneous* inputs the
    cross-worker error covariance can be positive (all n dithers are a
    deterministic function of one shared uniform), so the independent
    collection's ((1+ω)/n, 1/n) is not provable here and we expose the
    correlation-free Jensen bound (A, B) = (ω, 0). The homogeneous-regime
    n² win shows up empirically (tests/test_permk.py) rather than in an
    over-promised stepsize."""

    s: int = 4
    name: str = dataclasses.field(default="correlated_qsgd", init=False)

    def __post_init__(self):
        assert 1 <= self.s <= 63, "levels must fit int8 with the sign folded in"

    def omega(self, d: int) -> float:
        # E[(⌊t+u⌋ − t)²] = frac(t)(1 − frac(t)) ≤ 1/4 per coordinate.
        return d / (4.0 * self.s**2)

    def expected_density(self, d: int) -> float:
        return float(d)

    def payload_bits(self, d: int) -> float:
        # f32 norm + one packed signed level per coordinate (nibble for
        # s ≤ 7, int8 otherwise); the stratified dither is shared randomness,
        # never transmitted (wire.py — DESIGN.md §4.6)
        from . import wire

        return wire.correlated_q_bits(d, self.s)

    def ab_constants(self, d: int, n: int) -> tuple:
        return (self.omega(d), 0.0)

    def compress_worker(self, key, x, wid):
        n = self._n()
        norm = jnp.linalg.norm(x.astype(jnp.float32))
        safe = jnp.where(norm > 0, norm, 1.0)
        k_v, k_r = jax.random.split(key)
        v = jax.random.uniform(k_v, x.shape)          # shared base dither
        r = jax.random.randint(k_r, x.shape, 0, n)    # shared stratum rotation
        u = jnp.mod(v + (jnp.asarray(wid, jnp.float32) + r) / n, 1.0)
        level = jnp.floor(self.s * x.astype(jnp.float32) / safe + u)
        return {"q": level.astype(jnp.int8), "norm": norm}

    def decompress(self, payload, d):
        return payload["norm"] * payload["q"].astype(jnp.float32) / self.s


# ---------------------------------------------------------------------------
# TopK — biased, for the EC-SGD baseline
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Greedy magnitude selection. Biased: E[Q(x)] ≠ x; contractive with δ = K/d.

    Only valid inside error-feedback wrappers (paper §1.2 "Biased Compression";
    plain distributed SGD + Top1 can diverge — Beznosikov et al. 2020).
    """

    k: float = 1
    unbiased: bool = dataclasses.field(default=False, init=False)
    name: str = dataclasses.field(default="topk", init=False)

    def k_for(self, d: int) -> int:
        if self.k < 1:
            return max(1, int(round(self.k * d)))
        return min(int(self.k), d)

    def omega(self, d: int) -> float:  # not a Def-1.1 quantization
        raise ValueError("TopK is biased; it has no ω. Use delta().")

    def delta(self, d: int) -> float:
        """Contraction factor: E‖Q(x) − x‖² ≤ (1 − δ)‖x‖²."""
        return self.k_for(d) / d

    def expected_density(self, d: int) -> float:
        return float(self.k_for(d))

    def payload_bits(self, d: int) -> float:
        return 64.0 * self.k_for(d)

    def compress(self, key, x):
        del key  # deterministic
        d = x.shape[0]
        k = self.k_for(d)
        _, idx = jax.lax.top_k(jnp.abs(x), k)
        return {"values": x[idx.astype(jnp.int32)], "indices": idx.astype(jnp.int32)}

    def decompress(self, payload, d):
        out = jnp.zeros((d,), payload["values"].dtype)
        return out.at[payload["indices"]].add(payload["values"])


# ---------------------------------------------------------------------------
# QSGD / ℓ2-quantization with s levels (Alistarh et al. 2017)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """Stochastic s-level ℓ2 quantization.

    Q(x)_j = ‖x‖₂ · sign(x_j) · ξ_j / s with ξ_j = ⌊s|x_j|/‖x‖ + u_j⌋, u_j ~ U[0,1).
    ω = min(d/s², √d/s) (Alistarh et al. 2017, Lemma 3.1).
    Payload: one f32 norm + (sign, level) in int8 per coordinate (s ≤ 127).
    """

    s: int = 1
    name: str = dataclasses.field(default="qsgd", init=False)

    def __post_init__(self):
        assert 1 <= self.s <= 127, "levels must fit int8 payload"

    def omega(self, d: int) -> float:
        return min(d / self.s**2, math.sqrt(d) / self.s)

    def expected_density(self, d: int) -> float:
        # Expected nnz ≤ s(s + √d) (Alistarh et al. Thm 3.2); cap at d.
        return float(min(d, self.s * (self.s + math.sqrt(d))))

    def payload_bits(self, d: int) -> float:
        # f32 norm + one packed signed level per coordinate. The old
        # ceil(log2(2s+1))-bit estimate priced an entropy code nothing
        # shipped; the packed wire is 4-bit nibbles (s ≤ 7) or int8
        # (wire.py — DESIGN.md §4.6).
        from . import wire

        return wire.qsgd_global_bits(d, self.s)

    def compress(self, key, x):
        norm = jnp.linalg.norm(x)
        safe = jnp.where(norm > 0, norm, 1.0)
        u = jax.random.uniform(key, x.shape)
        level = jnp.floor(self.s * jnp.abs(x) / safe + u)
        q = (jnp.sign(x) * level).astype(jnp.int8)
        return {"q": q, "norm": norm}

    def decompress(self, payload, d):
        return payload["norm"] * payload["q"].astype(jnp.float32) / self.s


# ---------------------------------------------------------------------------
# Natural compression (Horváth et al. 2019) — exponent-only stochastic rounding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NaturalCompression(Compressor):
    """C_nat: round |x| to a power of two, stochastically, preserving expectation.

    ω = 1/8, density d, 9 bits/coordinate (sign + 8-bit exponent).
    """

    name: str = dataclasses.field(default="natural", init=False)

    def omega(self, d: int) -> float:
        return 1.0 / 8.0

    def expected_density(self, d: int) -> float:
        return float(d)

    def payload_bits(self, d: int) -> float:
        # f32 reference exponent + int8 sign·exponent-delta code per
        # coordinate: a byte-aligned wire cannot ship 9-bit symbols, so the
        # honest count is 32 + 8d (wire.py — DESIGN.md §4.6)
        from . import wire

        return wire.natural_tree_bits(d)

    def compress(self, key, x):
        ax = jnp.abs(x)
        lo_exp = jnp.floor(jnp.log2(jnp.where(ax > 0, ax, 1.0)))
        lo = jnp.exp2(lo_exp)
        prob_up = jnp.where(ax > 0, (ax - lo) / lo, 0.0)  # in [0,1)
        up = jax.random.bernoulli(key, jnp.clip(prob_up, 0.0, 1.0))
        mag = jnp.where(up, 2.0 * lo, lo)
        q = jnp.where(ax > 0, jnp.sign(x) * mag, 0.0)
        return {"dense": q.astype(x.dtype)}

    def decompress(self, payload, d):
        return payload["dense"]


# ---------------------------------------------------------------------------
# Tree lifting (Block-RandK semantics)
# ---------------------------------------------------------------------------


def tree_compress(comp: Compressor, key: jax.Array, tree: PyTree) -> PyTree:
    """Compress each leaf independently with a per-leaf key (budget ∝ leaf size).

    Single-leaf trees consume the key directly (no split) so the flat engine
    can mirror this path's random stream exactly (DESIGN.md §4.2)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = [key] if len(leaves) == 1 else list(jax.random.split(key, len(leaves)))
    payloads = [comp.compress(k, leaf.reshape(-1)) for k, leaf in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, payloads)


def tree_compress_worker(
    comp: CorrelatedCompressor, key: jax.Array, tree: PyTree, wid
) -> PyTree:
    """:func:`tree_compress` for correlated collections: the round key is
    SHARED across workers (the correlation lives in the shared randomness) and
    the worker index is passed through. Same per-leaf key schedule as
    tree_compress so flat/tree path equivalence carries over."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = [key] if len(leaves) == 1 else list(jax.random.split(key, len(leaves)))
    payloads = [
        comp.compress_worker(k, leaf.reshape(-1), wid)
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, payloads)


def tree_decompress(comp: Compressor, payload_tree: PyTree, like: PyTree) -> PyTree:
    """Inverse of tree_compress; `like` supplies leaf shapes *and dtypes*.

    Decompressed leaves are cast back to the ``like`` leaf dtype (exactly as
    ``flat.unpack`` does): compressors may decompress to f32 (e.g. QSGD), and
    under bf16 params an uncast result makes ``Marina.step``'s ``lax.cond``
    branches disagree on dtype (sync branch bf16, compressed branch f32)."""
    like_leaves, treedef = jax.tree.flatten(like)
    # payload_tree has payload-dicts at the positions of `like` leaves
    pay_leaves = treedef.flatten_up_to(payload_tree)
    outs = [
        comp.decompress(p, l.size).reshape(l.shape).astype(l.dtype)
        for p, l in zip(pay_leaves, like_leaves)
    ]
    return jax.tree.unflatten(treedef, outs)


def tree_roundtrip(comp: Compressor, key: jax.Array, tree: PyTree) -> PyTree:
    """Q applied leafwise, returning a dense tree (compress→decompress)."""
    return tree_decompress(comp, tree_compress(comp, key, tree), tree)


def tree_omega(comp: Compressor, tree: PyTree) -> float:
    """Effective ω of the leafwise compressor = max over leaves (worst case)."""
    return max(comp.omega(int(np.prod(l.shape))) for l in jax.tree.leaves(tree))


def tree_payload_bits(comp: Compressor, tree: PyTree) -> float:
    """Per-worker wire bits of one compressed round under per-leaf lifting:
    Σ_leaf payload_bits(d_leaf) — the ζ_Q the ledgers book (wire.py)."""
    return sum(comp.payload_bits(int(np.prod(l.shape))) for l in jax.tree.leaves(tree))


def tree_ab_constants(comp: Compressor, tree: PyTree, n: int) -> tuple:
    """Collection (A, B) of the leafwise-lifted compressor: the AB-inequality
    is additive over orthogonal coordinate blocks, so the worst leaf's A and
    the best-case-safe min over leaves' B bound the whole tree."""
    pairs = [
        comp.ab_constants(int(np.prod(l.shape)), n) for l in jax.tree.leaves(tree)
    ]
    return (max(a for a, _ in pairs), min(b for _, b in pairs))


def tree_dim(tree: PyTree) -> int:
    """Total dimension d = Σ leaf sizes (the paper's problem dimension)."""
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def make_compressor(name: str, **kw) -> Compressor:
    """Registry: compressor by name ("randk", "permk", "block_qsgd", …) —
    the Def-1.1 quantizers the trainer/config layer selects from."""
    name = name.lower()
    if name in ("identity", "none"):
        return Identity()
    if name == "randk":
        return RandK(**kw)
    if name in ("block_randk", "flat_randk"):
        return BlockRandK(**kw)
    if name in ("block_qsgd", "flat_qsgd"):
        return BlockQSGD(**kw)
    if name in ("block_natural", "flat_natural"):
        return BlockNatural(**kw)
    if name == "shared_randk":
        return SharedRandK(**kw)
    if name in ("permk", "perm_k"):
        return PermK(**kw)
    if name in ("correlated_qsgd", "correlated_q", "cqsgd"):
        return CorrelatedQ(**kw)
    if name == "topk":
        return TopK(**kw)
    if name == "qsgd":
        return QSGD(**kw)
    if name == "natural":
        return NaturalCompression()
    raise ValueError(f"unknown compressor {name!r}")
