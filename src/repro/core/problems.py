"""The paper's experimental objectives, for tests/benchmarks/examples.

* :func:`nonconvex_binclass_loss` — eq. (11): ℓ(b,c) = (1 − 1/(1+exp(−bc)))²,
  the non-convex loss used in §5.1 / Appendix A.1 on LibSVM data.
* :func:`make_synthetic_binclass` — a heterogeneous synthetic stand-in for the
  LibSVM splits (container is offline): each worker draws features from its own
  rotated/shifted Gaussian so local losses are genuinely dissimilar, matching the
  paper's "arbitrarily heterogeneous" regime.
* :func:`quadratic_loss` / PL problems for the Thm 2.2 (PŁ) validation tests.

Smoothness constants: for eq. (11), ℓ(a'x, y) has Hessian bounded by
c·‖a‖² with c = sup|ℓ''| < 0.16; we expose an upper bound usable as L_i.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# sup over z of |d²/dz² (1 − sigmoid(z))²| — numerically ≈ 0.1556
_ELL_SMOOTH = 0.16


class BinClassData(NamedTuple):
    """Worker-stacked dataset: features (n, m, d), labels (n, m) in {−1, +1}."""

    a: jax.Array
    y: jax.Array


def nonconvex_binclass_loss(x: jax.Array, batch: BinClassData) -> jax.Array:
    """Eq. (11) mean loss for one worker's batch: x (d,), a (m, d), y (m,)."""
    z = batch.a @ x * batch.y
    s = jax.nn.sigmoid(z)
    return jnp.mean((1.0 - s) ** 2)


def binclass_full_grad(x: jax.Array, data: BinClassData) -> jax.Array:
    return jax.grad(nonconvex_binclass_loss)(x, data)


def binclass_smoothness(data: BinClassData) -> float:
    """L with L² = (1/n) Σ L_i², L_i ≤ c · mean_t ‖a_t‖² (Assumption 1.2)."""
    sq = np.asarray(jnp.mean(jnp.sum(data.a**2, axis=-1), axis=-1))  # (n,)
    Li = _ELL_SMOOTH * sq
    return float(np.sqrt(np.mean(Li**2)))


def make_synthetic_binclass(
    key: jax.Array, n_workers: int, m: int, d: int, heterogeneity: float = 1.0
) -> BinClassData:
    """Heterogeneous synthetic binary classification (stand-in for LibSVM splits).

    Worker i's features ~ N(µ_i, Σ_i) with worker-specific mean/scale; labels from
    a worker-specific noisy linear teacher. heterogeneity=0 → iid workers.
    """
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    base = jax.random.normal(k1, (n_workers, m, d)) / jnp.sqrt(d)
    shift = heterogeneity * jax.random.normal(k2, (n_workers, 1, d)) / jnp.sqrt(d)
    scale = 1.0 + 0.5 * heterogeneity * jax.random.uniform(k3, (n_workers, 1, 1))
    a = (base + shift) * scale
    teacher = jax.random.normal(k4, (n_workers, d))
    teacher = (
        (1.0 - heterogeneity * 0.5) * teacher[0:1] + heterogeneity * 0.5 * teacher
    )
    logits = jnp.einsum("nmd,nd->nm", a, teacher) * jnp.sqrt(d)
    flips = jax.random.bernoulli(k5, 0.05, logits.shape)
    y = jnp.where(flips, -jnp.sign(logits), jnp.sign(logits))
    y = jnp.where(y == 0, 1.0, y)
    return BinClassData(a=a, y=y)


def sample_minibatch(key: jax.Array, data: BinClassData, b: int) -> BinClassData:
    """Per-worker i.i.d. uniform minibatch indices (Assumption 3.1 regime)."""
    n, m, _ = data.a.shape
    idx = jax.random.randint(key, (n, b), 0, m)
    take = jax.vmap(lambda arr, ix: arr[ix])
    return BinClassData(a=take(data.a, idx), y=take(data.y, idx))


# ---------------------------------------------------------------------------
# Quadratics (PŁ with µ = λ_min ≥ 0; strongly convex if λ_min > 0)
# ---------------------------------------------------------------------------


class QuadData(NamedTuple):
    A: jax.Array  # (n, d, d) PSD per worker
    b: jax.Array  # (n, d)


def quadratic_loss(x: jax.Array, batch: QuadData) -> jax.Array:
    """f_i(x) = ½ xᵀA_i x − b_iᵀx, averaged if batch carries extra dims."""
    return 0.5 * x @ batch.A @ x - batch.b @ x


def make_quadratic(key: jax.Array, n_workers: int, d: int, kappa: float = 10.0):
    """Heterogeneous PSD quadratics with controlled condition number."""
    kA, kb = jax.random.split(key)
    qs = jax.random.normal(kA, (n_workers, d, d))
    eigs = jnp.logspace(0, jnp.log10(kappa), d) / kappa  # in [1/κ, 1]
    def mk(q):
        qq, _ = jnp.linalg.qr(q)
        return (qq * eigs) @ qq.T
    A = jax.vmap(mk)(qs)
    b = jax.random.normal(kb, (n_workers, d)) / jnp.sqrt(d)
    data = QuadData(A=A, b=b)
    L = float(jnp.max(jnp.linalg.eigvalsh(jnp.mean(A, 0))))
    mu = float(jnp.min(jnp.linalg.eigvalsh(jnp.mean(A, 0))))
    return data, L, mu


def quad_optimum(data: QuadData) -> jax.Array:
    """Minimizer of the client-average quadratic: x* = Ā⁻¹ b̄."""
    Abar = jnp.mean(data.A, 0)
    bbar = jnp.mean(data.b, 0)
    return jnp.linalg.solve(Abar, bbar)


# ---------------------------------------------------------------------------
# Federated heterogeneity (the PP-MARINA scenario layer — DESIGN.md §6)
#
# Two controllable knobs, matching the two ways the paper's "arbitrarily
# heterogeneous" regime is instantiated in federated experiments:
#
# * ζ-heterogeneity — shifted quadratics where the gradient dissimilarity
#   (1/n)Σ‖∇f_i(x) − ∇f(x)‖² equals ζ² EXACTLY at every x (the constant the
#   DIANA/heterogeneity literature calls ζ²; Mishchenko et al. 2019).
# * Dirichlet(α) label skew — each client's class mixture ~ Dir(α): α → ∞
#   recovers iid clients, α → 0 gives one-class clients (the standard
#   federated non-IID protocol of Hsu et al. 2019).
# ---------------------------------------------------------------------------


def make_shifted_quadratics(
    key: jax.Array, n_workers: int, d: int, zeta: float = 1.0,
    kappa: float = 10.0,
):
    """Per-client shifted quadratics with EXACT ζ-heterogeneity.

    f_i(x) = ½ xᵀA x − b_iᵀ x with one shared PSD A (spectrum in [1/κ, 1])
    and b_i = b̄ + ζ·u_i where the u_i are orthonormal-ish directions with
    Σ_i u_i = 0 and (1/n)Σ‖u_i‖² = 1. Then ∇f_i − ∇f = −ζ·u_i independent
    of x, so the gradient dissimilarity is ζ² everywhere — the cleanest
    dial for "how much does gradient-difference compression matter".
    Returns (QuadData, L, mu).
    """
    kA, kb, ku = jax.random.split(key, 3)
    q, _ = jnp.linalg.qr(jax.random.normal(kA, (d, d)))
    eigs = jnp.logspace(0, jnp.log10(kappa), d) / kappa
    A = (q * eigs) @ q.T
    bbar = jax.random.normal(kb, (d,)) / jnp.sqrt(d)
    u = jax.random.normal(ku, (n_workers, d))
    u = u - jnp.mean(u, axis=0, keepdims=True)            # Σ u_i = 0
    u = u / jnp.sqrt(jnp.mean(jnp.sum(u * u, axis=-1)))   # (1/n)Σ‖u_i‖² = 1
    b = bbar[None, :] + zeta * u
    data = QuadData(A=jnp.broadcast_to(A, (n_workers, d, d)), b=b)
    return data, float(eigs[-1]), float(eigs[0])


def gradient_heterogeneity(grads: jax.Array) -> jax.Array:
    """Empirical ζ²(x) = (1/n)Σ‖∇f_i(x) − ∇f(x)‖² from stacked (n, d) grads."""
    mean = jnp.mean(grads, axis=0, keepdims=True)
    return jnp.mean(jnp.sum((grads - mean) ** 2, axis=-1))


def make_dirichlet_binclass(
    key: jax.Array,
    n_workers: int,
    m: int,
    d: int,
    alpha: float = 1.0,
    n_clusters: int = 8,
) -> BinClassData:
    """Dirichlet(α) non-IID federated split of the eq.-(11) problem.

    Samples live in ``n_clusters`` feature clusters (distinct Gaussian
    means); labels come from ONE global noisy linear teacher, so all clients
    minimize proxies of the same objective but see it through skewed data.
    Client i draws each of its m samples' cluster from its own
    proportions π_i ~ Dir(α): α → ∞ (or ``np.inf``) gives the uniform
    mixture (iid clients), α = 0.1 gives near-single-cluster clients — the
    regime where local gradients genuinely disagree and PP-MARINA's
    gradient-difference compression beats direct compression (DIANA/DCGD).
    """
    k_pi, k_mu, k_asn, k_x, k_t, k_flip = jax.random.split(key, 6)
    if alpha is not None and np.isfinite(alpha):
        pi = jax.random.dirichlet(
            k_pi, jnp.full((n_clusters,), float(alpha)), (n_workers,)
        )
    else:
        pi = jnp.full((n_workers, n_clusters), 1.0 / n_clusters)
    centers = jax.random.normal(k_mu, (n_clusters, d)) * (2.0 / jnp.sqrt(d))
    asn = jax.vmap(
        lambda k, p: jax.random.choice(k, n_clusters, (m,), p=p)
    )(jax.random.split(k_asn, n_workers), pi)              # (n, m)
    noise = jax.random.normal(k_x, (n_workers, m, d)) / jnp.sqrt(d)
    a = centers[asn] + noise
    teacher = jax.random.normal(k_t, (d,))
    logits = jnp.einsum("nmd,d->nm", a, teacher) * jnp.sqrt(d)
    flips = jax.random.bernoulli(k_flip, 0.05, logits.shape)
    y = jnp.where(flips, -jnp.sign(logits), jnp.sign(logits))
    y = jnp.where(y == 0, 1.0, y)
    return BinClassData(a=a, y=y)
