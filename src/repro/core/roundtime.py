"""Per-client compute-time models for straggler simulation (DESIGN.md §4.10).

MARINA's convergence story is stated in rounds and bits; a real federated
fleet pays WALL CLOCK, and a synchronous round costs the fleet the time of
its slowest client. :class:`RoundTimeModel` is the dial that turns the
simulated optimizers into wall-clock benchmarks: each round it draws one
compute time per client from a heterogeneity distribution —

* ``lognormal``   — multiplicative heterogeneity (the classic straggler
                    model: most clients near the mean, a heavy right tail),
                    parameterized so E[T_i] = ``mean_s`` for any ``sigma``;
* ``exponential`` — memoryless service times, E[T_i] = ``mean_s``;
* ``fixed``       — every client takes exactly ``mean_s`` (the degenerate
                    no-straggler baseline, and the deterministic harness the
                    deadline-equivalence tests are built on);

optionally with a **fixed slow set**: the clients in ``slow_ids`` take
``slow_factor``× their drawn time every round (a persistently slow shard —
the regime where a deadline permanently excludes the same cohort and the
carry table pins their anchors, exactly the static ``drop`` fault).

Sampling is jittable and keyed: the async round derives the time key from
the step key via :data:`TIME_FOLD` (like ``_DOWN_FOLD``/``_FAULT_FOLD`` in
``core/marina.py``), so adding wall-clock simulation NEVER perturbs the
``(k_bern, k_q)`` split — timed and untimed trajectories stay bit-identical.

The quantile helpers are host-side (pure ``math``): benchmarks pick the
per-round deadline as a quantile of the honest (non-slow) distribution,
e.g. ``deadline_for_quantile(0.8)`` admits ~80% of honest uploads per round.
"""

from __future__ import annotations

import dataclasses
import math
from statistics import NormalDist

import jax
import jax.numpy as jnp

#: fold_in constant deriving the round-time key from the step key WITHOUT
#: perturbing the (k_bern, k_q) split — wall-clock simulation must not
#: change the optimizer's Bernoulli/compressor randomness (reads "CLOC").
TIME_FOLD = 0xC10C

DISTS = ("lognormal", "exponential", "fixed")


@dataclasses.dataclass(frozen=True)
class RoundTimeModel:
    """Static description of per-client compute-time heterogeneity.

    ``dist`` is one of :data:`DISTS`; ``mean_s`` the mean honest compute
    time (seconds; the unit is nominal — every downstream number is a
    ratio); ``sigma`` the lognormal shape (ignored otherwise); ``slow_ids``
    an optional fixed set of persistently slow clients whose drawn time is
    multiplied by ``slow_factor``. Frozen/hashable: safe as jit-static
    config, like :class:`repro.core.faults.FaultSpec`.
    """

    dist: str = "lognormal"
    mean_s: float = 1.0
    sigma: float = 0.5
    slow_ids: tuple = ()
    slow_factor: float = 4.0

    def __post_init__(self):
        if self.dist not in DISTS:
            raise ValueError(f"unknown dist {self.dist!r}, expected {DISTS}")
        if self.mean_s <= 0.0:
            raise ValueError("mean_s must be positive")
        if self.sigma < 0.0:
            raise ValueError("sigma must be non-negative")
        if self.slow_factor < 1.0:
            raise ValueError(
                "slow_factor < 1 would make the slow set FASTER; use the "
                "honest distribution instead"
            )
        ids = tuple(self.slow_ids)
        if any((not isinstance(i, int)) or i < 0 for i in ids):
            raise ValueError(f"slow_ids must be non-negative ints: {ids!r}")
        if len(set(ids)) != len(ids):
            raise ValueError(f"slow_ids has duplicates: {ids!r}")
        object.__setattr__(self, "slow_ids", ids)

    # -- sampling (jittable) ------------------------------------------------

    def sample(self, key: jax.Array, n: int) -> jax.Array:
        """One compute time per client: (n,) f32, E[T_i] = mean_s for
        honest clients under every ``dist`` (the lognormal is mean-
        corrected by exp(−σ²/2))."""
        if self.dist == "lognormal":
            z = jax.random.normal(key, (n,))
            t = self.mean_s * jnp.exp(
                self.sigma * z - 0.5 * self.sigma**2
            )
        elif self.dist == "exponential":
            t = self.mean_s * jax.random.exponential(key, (n,))
        else:  # fixed
            t = jnp.full((n,), self.mean_s)
        if self.slow_ids:
            slow = jnp.zeros((n,), bool).at[jnp.asarray(self.slow_ids)].set(
                True
            )
            t = jnp.where(slow, self.slow_factor * t, t)
        return t.astype(jnp.float32)

    # -- host-side quantile helpers (deadline dials) ------------------------

    def deadline_for_quantile(self, q: float) -> float:
        """The deadline admitting a ``q`` fraction of HONEST uploads per
        round: the q-quantile of the non-slow compute-time distribution
        (host-side closed forms; ``fixed`` returns mean_s for any q)."""
        if not 0.0 < q < 1.0:
            raise ValueError("quantile must be in (0, 1)")
        if self.dist == "lognormal":
            z = NormalDist().inv_cdf(q)
            return self.mean_s * math.exp(
                self.sigma * z - 0.5 * self.sigma**2
            )
        if self.dist == "exponential":
            return -self.mean_s * math.log(1.0 - q)
        return self.mean_s

    def miss_prob(self, deadline: float) -> float:
        """P(T_i > deadline) for an honest client — the expected per-round
        non-participation fraction the deadline buys its wall-clock bound
        with (0 for ``fixed`` whenever deadline ≥ mean_s)."""
        if deadline <= 0.0:
            return 1.0
        if self.dist == "lognormal":
            z = (
                math.log(deadline / self.mean_s) + 0.5 * self.sigma**2
            ) / max(self.sigma, 1e-12)
            return 1.0 - NormalDist().cdf(z)
        if self.dist == "exponential":
            return math.exp(-deadline / self.mean_s)
        return 0.0 if deadline >= self.mean_s else 1.0
