"""repro.core — the paper's contribution: MARINA-family optimizers + compression."""

from .compressors import (
    BlockNatural,
    BlockQSGD,
    BlockRandK,
    Compressor,
    CorrelatedCompressor,
    CorrelatedQ,
    Identity,
    NaturalCompression,
    PermK,
    QSGD,
    RandK,
    SharedRandK,
    TopK,
    make_compressor,
    tree_ab_constants,
    tree_compress,
    tree_compress_worker,
    tree_decompress,
    tree_dim,
    tree_omega,
    tree_payload_bits,
    tree_roundtrip,
)
from .flat import (
    FlatEngine,
    FlatLayout,
    make_downlink,
    make_engine,
    make_layout,
    pack,
    pack_stacked,
    unpack,
)
from .marina import Marina, MarinaState, PPMarina, StepMetrics, VRMarina, make_gd
from .baselines import DCGD, Diana, ECSGD, VRDiana
from .aggregators import ServerAggregator
from .faults import FaultSpec, flip_binclass_labels
from .roundtime import RoundTimeModel
from .async_rounds import AsyncMarinaState, AsyncStepMetrics, DeadlineMarina
from .stepsize import (
    ab_from_omega,
    async_marina_gamma,
    diana_alpha,
    diana_gamma,
    marina_comm_per_worker,
    marina_gamma,
    marina_gamma_ab,
    marina_gamma_permk,
    marina_gamma_pl,
    marina_iteration_bound,
    permk_default_p,
    pp_marina_gamma,
    robust_marina_gamma,
    robust_n_eff,
    robust_pp_marina_gamma,
    vr_marina_gamma,
)

__all__ = [
    "BlockNatural", "BlockQSGD",
    "BlockRandK", "Compressor", "CorrelatedCompressor", "CorrelatedQ",
    "FlatEngine", "FlatLayout", "Identity", "PermK",
    "make_downlink", "make_engine", "make_layout", "pack", "pack_stacked",
    "unpack",
    "NaturalCompression", "QSGD", "RandK",
    "SharedRandK", "TopK", "make_compressor", "tree_ab_constants",
    "tree_compress", "tree_compress_worker",
    "tree_decompress", "tree_dim", "tree_omega", "tree_payload_bits",
    "tree_roundtrip", "Marina", "MarinaState", "PPMarina", "StepMetrics",
    "VRMarina", "make_gd", "DCGD", "Diana", "ECSGD", "VRDiana",
    "ServerAggregator", "FaultSpec", "flip_binclass_labels",
    "RoundTimeModel", "AsyncMarinaState", "AsyncStepMetrics",
    "DeadlineMarina",
    "ab_from_omega", "async_marina_gamma", "diana_alpha", "diana_gamma",
    "marina_comm_per_worker",
    "marina_gamma", "marina_gamma_ab", "marina_gamma_permk",
    "marina_gamma_pl", "marina_iteration_bound", "permk_default_p",
    "pp_marina_gamma", "robust_marina_gamma", "robust_n_eff",
    "robust_pp_marina_gamma", "vr_marina_gamma",
]
