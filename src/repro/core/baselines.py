"""Baseline distributed methods the paper compares against.

* DIANA (Mishchenko et al. 2019): unbiased compression of gradient *shifts*.
* VR-DIANA (Horváth et al. 2019): DIANA + SVRG-style local variance reduction.
* QSGD-style DCGD (Alistarh et al. 2017): direct quantization of gradients.
* EC-SGD (Seide et al. 2014; Stich & Karimireddy 2020): biased TopK + error
  feedback.

Same worker-stacked-tree conventions as core/marina.py.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from .compressors import Compressor, tree_compress, tree_decompress, tree_dim, tree_payload_bits
from .marina import GradFn, StepMetrics, _per_worker_grads
from .tree_util import (
    tree_axpy,
    tree_mean_axis0,
    tree_norm,
    tree_sub,
    tree_zeros_like,
)

PyTree = Any


def _vmap_compress(comp: Compressor, key, trees, n):
    keys = jax.random.split(key, n)
    return jax.vmap(partial(tree_compress, comp))(keys, trees)


def _vmap_decompress(comp: Compressor, payloads, like):
    return jax.vmap(lambda p: tree_decompress(comp, p, like))(payloads)


# ---------------------------------------------------------------------------
# DIANA
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DianaState:
    params: PyTree
    h: PyTree        # per-worker shifts h_i, leading axis n
    h_mean: PyTree   # server-side (1/n)Σ h_i
    step: jax.Array


@dataclasses.dataclass
class Diana:
    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    alpha: float  # shift stepsize, ≤ 1/(1+ω)
    n: int

    def init(self, params: PyTree) -> DianaState:
        h = jax.tree.map(
            lambda x: jnp.zeros((self.n, *x.shape), x.dtype), params
        )
        return DianaState(
            params=params,
            h=h,
            h_mean=tree_zeros_like(params),
            step=jnp.zeros((), jnp.int32),
        )

    def step(self, state: DianaState, key: jax.Array, batches: PyTree):
        grads = _per_worker_grads(self.grad_fn, state.params, batches)   # (n, …)
        deltas = tree_sub(grads, state.h)                                # ∇f_i − h_i
        payloads = _vmap_compress(self.compressor, key, deltas, self.n)
        q = _vmap_decompress(self.compressor, payloads, state.params)    # Q(Δ_i)
        g = jax.tree.map(jnp.add, state.h_mean, tree_mean_axis0(q))      # unbiased
        h_new = jax.tree.map(lambda hi, qi: hi + self.alpha * qi, state.h, q)
        h_mean_new = jax.tree.map(
            lambda hm, qm: hm + self.alpha * qm, state.h_mean, tree_mean_axis0(q)
        )
        x_new = tree_axpy(-self.gamma, g, state.params)
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g),
            bits_per_worker=jnp.asarray(
                tree_payload_bits(self.compressor, state.params)
            ),
            sync_round=jnp.zeros((), jnp.int32),
            oracle_calls=jnp.asarray(1.0),
            # dense estimator broadcast every round (now counted — DESIGN.md §4.7)
            down_bits=jnp.asarray(32.0 * tree_dim(state.params)),
        )
        return (
            DianaState(params=x_new, h=h_new, h_mean=h_mean_new, step=state.step + 1),
            metrics,
        )


# ---------------------------------------------------------------------------
# VR-DIANA (SVRG-flavoured local variance reduction, option II snapshots)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class VRDianaState:
    params: PyTree
    h: PyTree
    h_mean: PyTree
    snapshot: PyTree      # w_i — shared x at snapshot time (replicated)
    mu: PyTree            # per-worker full gradients at the snapshot, axis n
    step: jax.Array


@dataclasses.dataclass
class VRDiana:
    full_grad_fn: GradFn
    mb_grad_fn: GradFn
    compressor: Compressor
    gamma: float
    alpha: float
    n: int
    snapshot_prob: float  # SVRG option II: refresh w_i with prob 1/m

    def init(self, params: PyTree, full_batches: PyTree) -> VRDianaState:
        mu = _per_worker_grads(self.full_grad_fn, params, full_batches)
        h = jax.tree.map(lambda x: jnp.zeros((self.n, *x.shape), x.dtype), params)
        return VRDianaState(
            params=params,
            h=h,
            h_mean=tree_zeros_like(params),
            snapshot=params,
            mu=mu,
            step=jnp.zeros((), jnp.int32),
        )

    def step(
        self,
        state: VRDianaState,
        key: jax.Array,
        full_batches: PyTree,
        mb_batches: PyTree,
    ):
        k_q, k_snap = jax.random.split(key)
        # SVRG estimator: v_i = ∇f_iB(x) − ∇f_iB(w) + µ_i
        g_x = _per_worker_grads(self.mb_grad_fn, state.params, mb_batches)
        g_w = _per_worker_grads(self.mb_grad_fn, state.snapshot, mb_batches)
        v = jax.tree.map(lambda a, b, m: a - b + m, g_x, g_w, state.mu)

        deltas = tree_sub(v, state.h)
        payloads = _vmap_compress(self.compressor, k_q, deltas, self.n)
        q = _vmap_decompress(self.compressor, payloads, state.params)
        g = jax.tree.map(jnp.add, state.h_mean, tree_mean_axis0(q))
        h_new = jax.tree.map(lambda hi, qi: hi + self.alpha * qi, state.h, q)
        h_mean_new = jax.tree.map(
            lambda hm, qm: hm + self.alpha * qm, state.h_mean, tree_mean_axis0(q)
        )
        x_new = tree_axpy(-self.gamma, g, state.params)

        # Option-II snapshot refresh (shared coin; refresh costs m oracle calls).
        refresh = jax.random.bernoulli(k_snap, self.snapshot_prob)

        def do_refresh(_):
            mu = _per_worker_grads(self.full_grad_fn, x_new, full_batches)
            return x_new, mu

        def no_refresh(_):
            return state.snapshot, state.mu

        snapshot, mu = jax.lax.cond(refresh, do_refresh, no_refresh, None)

        m_full = jax.tree.leaves(full_batches)[0].shape[1]
        b = jax.tree.leaves(mb_batches)[0].shape[1]
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g),
            bits_per_worker=jnp.asarray(
                tree_payload_bits(self.compressor, state.params)
            ),
            sync_round=refresh.astype(jnp.int32),
            oracle_calls=jnp.where(refresh, 2.0 * b + m_full, 2.0 * b),
            # dense estimator broadcast every round (now counted — DESIGN.md §4.7)
            down_bits=jnp.asarray(32.0 * tree_dim(state.params)),
        )
        return (
            VRDianaState(
                params=x_new,
                h=h_new,
                h_mean=h_mean_new,
                snapshot=snapshot,
                mu=mu,
                step=state.step + 1,
            ),
            metrics,
        )


# ---------------------------------------------------------------------------
# DCGD / QSGD: x^{k+1} = x^k − γ (1/n) Σ Q(∇f_i(x^k))
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DCGDState:
    params: PyTree
    step: jax.Array


@dataclasses.dataclass
class DCGD:
    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    n: int

    def init(self, params: PyTree) -> DCGDState:
        return DCGDState(params=params, step=jnp.zeros((), jnp.int32))

    def step(self, state: DCGDState, key: jax.Array, batches: PyTree):
        grads = _per_worker_grads(self.grad_fn, state.params, batches)
        payloads = _vmap_compress(self.compressor, key, grads, self.n)
        q = _vmap_decompress(self.compressor, payloads, state.params)
        g = tree_mean_axis0(q)
        x_new = tree_axpy(-self.gamma, g, state.params)
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g),
            bits_per_worker=jnp.asarray(
                tree_payload_bits(self.compressor, state.params)
            ),
            sync_round=jnp.zeros((), jnp.int32),
            oracle_calls=jnp.asarray(1.0),
            # dense estimator broadcast every round (now counted — DESIGN.md §4.7)
            down_bits=jnp.asarray(32.0 * tree_dim(state.params)),
        )
        return DCGDState(params=x_new, step=state.step + 1), metrics


# ---------------------------------------------------------------------------
# EC-SGD: biased compressor + error feedback
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ECSGDState:
    params: PyTree
    e: PyTree  # per-worker error buffers, axis n
    step: jax.Array


@dataclasses.dataclass
class ECSGD:
    grad_fn: GradFn
    compressor: Compressor  # typically TopK (biased)
    gamma: float
    n: int

    def init(self, params: PyTree) -> ECSGDState:
        e = jax.tree.map(lambda x: jnp.zeros((self.n, *x.shape), x.dtype), params)
        return ECSGDState(params=params, e=e, step=jnp.zeros((), jnp.int32))

    def step(self, state: ECSGDState, key: jax.Array, batches: PyTree):
        grads = _per_worker_grads(self.grad_fn, state.params, batches)
        # p_i = e_i + γ ∇f_i ; transmit C(p_i); e_i ← p_i − C(p_i)
        p_i = jax.tree.map(lambda e, g: e + self.gamma * g, state.e, grads)
        payloads = _vmap_compress(self.compressor, key, p_i, self.n)
        c = _vmap_decompress(self.compressor, payloads, state.params)
        e_new = tree_sub(p_i, c)
        update = tree_mean_axis0(c)
        x_new = tree_sub(state.params, update)
        metrics = StepMetrics(
            grad_est_norm=tree_norm(update) / self.gamma,
            bits_per_worker=jnp.asarray(
                tree_payload_bits(self.compressor, state.params)
            ),
            sync_round=jnp.zeros((), jnp.int32),
            oracle_calls=jnp.asarray(1.0),
            # dense estimator broadcast every round (now counted — DESIGN.md §4.7)
            down_bits=jnp.asarray(32.0 * tree_dim(state.params)),
        )
        return ECSGDState(params=x_new, e=e_new, step=state.step + 1), metrics
