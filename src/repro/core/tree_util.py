"""Small pytree algebra used by the optimizer layer."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x: PyTree, y: PyTree) -> PyTree:
    """alpha*x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_mean_axis0(a: PyTree) -> PyTree:
    """Mean over the leading (worker) axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), a)


def tree_sum_sq(a: PyTree):
    return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(a))


def tree_norm(a: PyTree):
    return jnp.sqrt(tree_sum_sq(a))


def tree_dot(a: PyTree, b: PyTree):
    return sum(
        jnp.sum(x * y) for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def tree_size(a: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(a))


def tree_stack_workers(trees: list[PyTree]) -> PyTree:
    """Stack a list of per-worker trees into one tree with leading worker dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_worker_slice(tree: PyTree, i) -> PyTree:
    return jax.tree.map(lambda x: x[i], tree)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)
