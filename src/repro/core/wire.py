"""Wire-format accounting — the single source of truth for payload bits.

Every "bits on the wire" number in the repo (compressor ``payload_bits``,
``FlatEngine.payload_bits``, the trainer's communication ledger, the
benchmark payload columns) must come from this module, so compressor
bookkeeping and the engine can never drift apart (DESIGN.md §4.6).

The packed quantization wire (ISSUE 3) fixes the representation per family:

* seeded RandK    — uint32 seed + K float32 values (indices regenerate from
                    the seed server-side).
* PermK           — uint32 seed + (padded/n) float32 values (the partition IS
                    the index).
* block QSGD      — per-block f32 ℓ2 norm + one level per coordinate:
                    a signed 4-bit nibble when s ≤ 7 (two per byte, eight per
                    uint32 lane word), int8 when s ≤ 127. The dither never
                    rides the wire (the server only needs levels + norms).
* block natural   — per-block f32 scale (reference power of two) + int8
                    sign·(exponent-delta+1) code per coordinate.
* RandK ∘ QSGD    — uint32 seed + per-block f32 norm of the K sampled values
                    + K quantized levels (4-bit/int8 as above): the
                    bandwidth-optimal composition quantizes only what RandK
                    kept.

All values are bits per worker per compressed round; float so the ledgers
can accumulate without overflow at production scale.

Since ISSUE 7 the module also owns the **bytes-by-link-tier ledger**
(:class:`TierLedger`): the transport layer (`launch/transport.py`) books
every payload collective it stages — direction (up/down), link tier
(loopback / ici / dcn — `launch/topology.py` classifies), collective kind,
and the bits from the per-format helpers above — so "how many bits crossed
the slow link" is answered by the same module that defines what a bit is.
"""

from __future__ import annotations

import dataclasses

F32_BITS = 32.0
SEED_BITS = 32.0      # one uint32 murmur3 seed
NIBBLE_BITS = 4.0     # signed 4-bit level (two per byte / eight per uint32)
INT8_BITS = 8.0

#: largest s whose signed levels fit a 4-bit two's-complement nibble
NIBBLE_MAX_S = 7
#: largest s whose signed levels fit int8
INT8_MAX_S = 127


def qsgd_level_bits(s: int) -> float:
    """Bits per quantized level on the packed wire: sign folded into the
    level, 4-bit nibble for s ≤ 7, int8 for s ≤ 127."""
    assert 1 <= s <= INT8_MAX_S, f"s={s} does not fit the int8 wire"
    return NIBBLE_BITS if s <= NIBBLE_MAX_S else INT8_BITS


def dense_f32_bits(d: int) -> float:
    """The uncompressed wire: one f32 per coordinate (sync rounds, Identity)."""
    return F32_BITS * d


def seeded_randk_bits(nblk: int, kb: int) -> float:
    """Seeded-RandK flat wire: uint32 seed + K f32 values (DESIGN.md §4.2)."""
    return SEED_BITS + F32_BITS * nblk * kb


def permk_bits(padded: int, n: int) -> float:
    """PermK flat wire: uint32 seed + the worker's padded/n f32 shard
    (DESIGN.md §4.5)."""
    assert padded % n == 0, "worker count must divide the padded dimension"
    return SEED_BITS + F32_BITS * padded / n


def block_qsgd_bits(nblk: int, block: int, s: int) -> float:
    """Packed block-QSGD wire: per-block f32 norm + one level per coordinate."""
    return F32_BITS * nblk + qsgd_level_bits(s) * nblk * block


def block_natural_bits(nblk: int, block: int) -> float:
    """Packed natural-compression wire: per-block f32 scale + int8
    sign·(exponent-delta+1) code per coordinate."""
    return F32_BITS * nblk + INT8_BITS * nblk * block


def randk_qsgd_bits(nblk: int, kb: int, s: int) -> float:
    """RandK∘QSGD composition wire: uint32 seed (indices regenerate) +
    per-block f32 norm of the K sampled values + K packed levels."""
    return SEED_BITS + F32_BITS * nblk + qsgd_level_bits(s) * nblk * kb


def qsgd_global_bits(d: int, s: int) -> float:
    """Per-leaf QSGD (one global ℓ2 norm over the whole vector): f32 norm +
    one packed level per coordinate. Replaces the old ceil(log2(2s+1))
    entropy-coding estimate with what the packed wire actually ships."""
    return F32_BITS + qsgd_level_bits(s) * d


def natural_tree_bits(d: int) -> float:
    """Per-leaf natural compression: f32 reference exponent + int8 code per
    coordinate (the historical 9-bit sign+exponent estimate ignored that a
    byte-aligned wire cannot ship 9-bit symbols)."""
    return F32_BITS + INT8_BITS * d


def correlated_q_bits(d: int, s: int) -> float:
    """CorrelatedQ wire: f32 norm + one packed level per coordinate (the
    stratified dither is shared randomness, never transmitted)."""
    return F32_BITS + qsgd_level_bits(s) * d


# ---------------------------------------------------------------------------
# Partial-participation accounting (PP-MARINA, Alg. 4 — DESIGN.md §4.8)
#
# In the federated regime only the sampled cohort uploads: a compressed round
# costs exactly r·ζ_Q bits fleet-wide (r payloads, each the compressor's
# per-worker wire), a sync round costs n·32d (every client ships its dense
# local gradient). The ledgers book the PER-ROUND totals from these helpers
# and divide by n for the per-client average — so the loss-vs-bits x-axis
# (Figs. 1–2 shape) reflects the r/n uplink saving exactly, never an
# approximation smuggled in at the call site.
# ---------------------------------------------------------------------------


def pp_uplink_total_bits(r: int, zeta_bits):
    """Fleet-total uplink of one PP compressed round: r sampled clients ×
    one compressed payload each (Alg. 4 line 9 — the r·ζ_Q term of the
    Thm 4.1 communication complexity). ``zeta_bits`` is the per-worker
    payload from the per-format helpers above."""
    return r * zeta_bits


def pp_sync_total_bits(n: int, d: int) -> float:
    """Fleet-total uplink of one PP sync round: all n clients ship the dense
    f32 local gradient (Alg. 4 line 7)."""
    return n * dense_f32_bits(d)


def pp_expected_round_bits(p: float, n: int, r: int, d: int, zeta_bits):
    """Expected fleet-total uplink per PP round: p·n·32d + (1−p)·r·ζ_Q —
    the quantity Thm 4.1 trades against the iteration count."""
    return p * pp_sync_total_bits(n, d) + (1.0 - p) * pp_uplink_total_bits(
        r, zeta_bits
    )


# ---------------------------------------------------------------------------
# Downlink accounting (DESIGN.md §4.7)
#
# The server→worker direction was historically invisible to the ledger: every
# round broadcast the dense f32 estimator g^{k+1} (or equivalently the
# params) and booked zero bits. The bidirectional wire makes the direction
# explicit: sync rounds and unconfigured downlinks book the dense broadcast,
# compressed downlinks book the Q_down(g^{k+1} − g^k) payload — which reuses
# the per-sampler formats above (the payload is ONE worker-shaped message,
# n = 1), so there are no new per-format formulas to drift.
# ---------------------------------------------------------------------------


def downlink_dense_bits(d: int) -> float:
    """The uncompressed downlink: the dense f32 estimator broadcast each
    worker receives (sync rounds, and every round when no Q_down is set)."""
    return F32_BITS * d


def round_total_bits(up_bits_per_worker: float,
                     down_bits_per_worker: float) -> float:
    """Total up+down wire bits one worker moves in one round (the benchmark
    and ledger convention: per worker, both directions — multiply by n for
    the fleet)."""
    return up_bits_per_worker + down_bits_per_worker


# ---------------------------------------------------------------------------
# Bytes-by-link-tier ledger (ISSUE 7 — DESIGN.md §7)
#
# A payload bit is not priced by its count alone but by WHICH link it
# crosses: host-loopback (fake-device single process), ici (intra-pod), or
# dcn (the cross-pod bandwidth cliff the compressed wires were built for).
# The transport layer books every collective it stages here, tagged by
# (scope, direction, tier, kind), so EXPERIMENTS.md and the multiproc bench
# can report "uplink bits on the dcn" rather than one flat number.
# ---------------------------------------------------------------------------

#: canonical link-tier names, fast → slow (launch/topology.py assigns them)
LINK_TIERS = ("loopback", "ici", "dcn")


@dataclasses.dataclass
class TierLedger:
    """Mutable bits-by-link-tier ledger the transport layer books into.

    Entries are keyed ``(scope, direction, tier, kind)``:

    * ``scope``     — which step traced the collective ("sync_step",
                      "compressed_step", …; the round-assembly layer scopes
                      each jitted step so one shared transport never
                      double-books across step entries),
    * ``direction`` — "up" (worker → server) or "down" (server → worker),
    * ``tier``      — one of :data:`LINK_TIERS`,
    * ``kind``      — the collective family ("all-gather", "all-to-all",
                      "psum", "broadcast", …).

    Booked values are BITS PER WORKER PER ROUND from the per-format helpers
    in this module — the ledger adds the *where*, never a second opinion on
    the *how much*.
    """

    bits: dict = dataclasses.field(default_factory=dict)
    counts: dict = dataclasses.field(default_factory=dict)

    def book(self, scope: str, direction: str, tier: str, kind: str,
             bits: float) -> None:
        """Accumulate ``bits`` under ``(scope, direction, tier, kind)``.
        Direction must be "up"/"down"; tier must be a LINK_TIERS name."""
        assert direction in ("up", "down"), direction
        assert tier in LINK_TIERS, tier
        key = (scope, direction, tier, kind)
        self.bits[key] = self.bits.get(key, 0.0) + float(bits)
        self.counts[key] = self.counts.get(key, 0) + 1

    def total_bits(self, scope=None, direction=None, tier=None) -> float:
        """Sum booked bits, filtered by any of scope/direction/tier (None
        matches everything)."""
        return sum(
            v for (s, d, t, _k), v in self.bits.items()
            if (scope is None or s == scope)
            and (direction is None or d == direction)
            and (tier is None or t == tier)
        )

    def by_tier(self, scope=None) -> dict:
        """{tier: {direction: bits}} summary for one scope (or all)."""
        out: dict = {}
        for (s, d, t, _k), v in self.bits.items():
            if scope is not None and s != scope:
                continue
            out.setdefault(t, {}).setdefault(d, 0.0)
            out[t][d] += v
        return out

    def to_dict(self) -> dict:
        """JSON-serializable dump: ``{"scope/direction/tier/kind": bits}``
        plus per-key trace counts — what the bench artifacts persist."""
        return {
            "bits": {"/".join(k): v for k, v in self.bits.items()},
            "counts": {"/".join(k): v for k, v in self.counts.items()},
        }

    def clear(self) -> None:
        """Drop all bookings (used between benchmark configurations)."""
        self.bits.clear()
        self.counts.clear()
