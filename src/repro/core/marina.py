"""MARINA, VR-MARINA and PP-MARINA (Algorithms 1–4 of the paper).

The algorithms are written against *worker-stacked* pytrees: every per-worker
quantity (minibatch, payload, shift) carries a leading axis of size ``n``. On a
single device this leading axis is a plain vmap dimension; on a mesh the launcher
shards it over the worker mesh axes, so the same code runs in both the CPU
simulation used by tests/examples and the multi-pod production path
(see launch/distributed.py for the sharded LM instantiation that additionally
annotates model-parallel dimensions).

Faithfulness notes
------------------
* ``c_k ~ Be(p)`` is shared across workers (Alg. 1 line 4): a scalar drawn from the
  step key, applied through ``lax.cond``.
* ``g^0 = ∇f(x^0)`` exactly (Alg. 1 line 2) — init computes the full gradient.
* Compressed rounds evaluate gradients at *both* points on the *same* minibatch
  (Alg. 2 line 8); we recompute at the old point instead of storing a second full
  gradient (PAGE-style; DESIGN.md §3).
* Compressor randomness is independent across workers (the n-fold key split),
  which is what gives the 1/n variance averaging in Thm 2.1's proof (eq. 21).
  ``SharedRandK`` deliberately breaks this for the §Perf communication experiment.

Beyond-paper round engineering (DESIGN.md §4.7)
-----------------------------------------------
* ``carry=True`` — *gradient-carry rounds*: the state additionally carries the
  per-worker gradients ``h_i^k = ∇f_i(x^k)`` that the previous round already
  computed, so a compressed round runs ONE backprop (at x^{k+1}) instead of
  two; the difference Δ_i = ∇f_i(x^{k+1}) − h_i^k is bit-identical to the
  recompute-at-the-old-point path whenever the local gradient oracle is
  deterministic in the iterate (fixed local datasets — the Alg. 1/2 regime).
  In the online Alg. 3 regime (fresh minibatch per round) the carry replaces
  the same-minibatch correlation with last round's realization; this is a
  different (higher-variance) estimator, so the flag is opt-in. Carry states
  are *lookahead*: the stored params are already stepped (x^{k+1} after init,
  x^{k+2} after step k), which is what lets the fused epilogue finish
  ``g += δ`` and ``x −= γ·g`` in one sweep; ``g`` sequences coincide with the
  seed estimator step for step, and params lead by exactly one step.
* With an engine, a carry round ends in the fused epilogue kernel
  (kernels/epilogue.py): dequant/scatter-mean of the payloads, the estimator
  update and the iterate update in a single (nblk, B)-tile HBM sweep, and the
  carried ``h`` / estimator ``g`` live as packed flat buffers
  ((n, nblk, B) / (nblk, B)) rather than trees.
* ``down_compressor`` / ``down_engine`` — *compressed downlink* (Gruntkowska
  et al. 2024's bidirectional program on DIANA-style shifts): on compressed
  rounds the server broadcasts Q_down(g^{k+1} − g^k) = Q_down(δ_up) instead
  of the dense estimator, and every worker decompress-accumulates; since the
  recursion runs on the single broadcast estimator, unbiased Q_down composes
  with the uplink as (1+ω_down)(1+ω_up/n) − 1. Sync rounds broadcast dense
  (32d down-bits), mirroring the Bernoulli structure in both directions.
  ``StepMetrics.down_bits`` books the per-worker received bits every round —
  the dense 32d broadcast that the seed ledger silently ignored is now
  counted even when no downlink compressor is configured.
* ``PPMarina`` (Alg. 4) additionally carries the federated scenario dials
  (DESIGN.md §4.8): without-replacement cohorts, arbitrary client weights,
  and an opt-in *server-side carry table* (h per client, refreshed only for
  sampled clients) that lets PP rounds run one backprop per sampled client
  and end in the fused epilogue; its ledger books the fleet totals n·32d /
  r·ζ_Q from :mod:`repro.core.wire`.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .compressors import (
    Compressor,
    CorrelatedCompressor,
    Identity,
    SharedRandK,
    tree_compress,
    tree_compress_worker,
    tree_decompress,
    tree_dim,
    tree_payload_bits,
)
from . import faults as fault_lib
from .flat import FlatEngine, pack, pack_stacked, unpack
from .tree_util import (
    tree_axpy,
    tree_mean_axis0,
    tree_norm,
    tree_scale,
    tree_sub,
)

PyTree = Any
GradFn = Callable[[PyTree, PyTree], PyTree]  # (params, batch) -> grad tree

#: fold_in constant deriving the downlink key from the step key WITHOUT
#: perturbing the (k_bern, k_q) split — carry/downlink rounds must draw the
#: same uplink randomness as the seed estimator for bit-exact trajectories.
_DOWN_FOLD = 0x0D0C

#: fold_in constant deriving the fault-injection key (garbage payload noise)
#: from the step key — like _DOWN_FOLD, it must not perturb the
#: (k_bern, k_sel, k_q) split so faulted and honest runs share their
#: Bernoulli/cohort/compressor randomness (only the payloads differ).
_FAULT_FOLD = 0xFA17

class StepMetrics(NamedTuple):
    grad_est_norm: jax.Array      # ‖g^k‖ (the estimator driving the step)
    bits_per_worker: jax.Array    # bits uplinked by one worker this round
    sync_round: jax.Array         # c_k (1 = dense round)
    oracle_calls: jax.Array       # stochastic first-order oracle calls per worker
    down_bits: jax.Array = 0.0    # bits each worker RECEIVES this round


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MarinaState:
    params: PyTree
    g: PyTree          # server estimator g^k, replicated ((nblk, B) flat
                       # buffer in the fused carry path, tree otherwise)
    step: jax.Array
    h: Optional[PyTree] = None  # carry mode: per-worker ∇f_i(x^k), a
                                # worker-stacked tree (kept in tree form even
                                # on the fused path: the subtract-and-pack
                                # then fuses into the ζ-sized sampler gather
                                # instead of materializing (n, nblk, B))


def _per_worker_grads(grad_fn: GradFn, params: PyTree, batches: PyTree) -> PyTree:
    """∇f_i at params for every worker: vmap over the leading worker axis."""
    return jax.vmap(grad_fn, in_axes=(None, 0))(params, batches)


def _compress_workers(
    comp: Compressor, key: jax.Array, diffs: PyTree, n: int
) -> PyTree:
    """Compress each worker's difference tree. Independent keys per worker,
    except SharedRandK which reuses one key (correlated masks by design) and
    CorrelatedCompressor collections (PermK, CorrelatedQ), where ALL workers
    share the round key and receive their index — the shared randomness is
    what buys the (A, B) constants (Szlendak et al. 2021)."""
    if isinstance(comp, CorrelatedCompressor):
        # a mismatched fleet is not an error the math survives: extra wids
        # alias back onto the first shards (mask wraparound) and the mean
        # silently double-counts them — refuse loudly instead.
        assert n == comp.n, (
            f"{comp.name} collection sized for n={comp.n} but the round has "
            f"{n} workers"
        )
        wids = jnp.arange(n, dtype=jnp.int32)
        return jax.vmap(
            lambda w, t: tree_compress_worker(comp, key, t, w)
        )(wids, diffs)
    if isinstance(comp, SharedRandK):
        keys = jnp.broadcast_to(key, (n, *key.shape))
    else:
        keys = jax.random.split(key, n)
    return jax.vmap(partial(tree_compress, comp))(keys, diffs)


def _decompress_mean(comp: Compressor, payloads: PyTree, like: PyTree, n: int) -> PyTree:
    """Server aggregation: decompress all n payloads, average (Alg. 1 line 10).

    Per-leaf reference path: densifies all n payloads to an (n, d) tree before
    averaging. The production compressed round goes through the flat engine
    (:func:`_compressed_delta`), which aggregates by scatter-accumulate and
    never materializes the (n, d) trees (DESIGN.md §4)."""
    dense = jax.vmap(lambda p: tree_decompress(comp, p, like))(payloads)
    return tree_mean_axis0(dense)


def _compressed_delta(
    comp: Compressor,
    engine: "FlatEngine | None",
    key: jax.Array,
    diffs: PyTree,
    like: PyTree,
    n: int,
    aggregator=None,
) -> PyTree:
    """One compressed uplink round: (1/n) Σ_i Q(Δ_i).

    With an engine: the fused flat-buffer pipeline (pack → sampler →
    aggregate → unpack), cost ∝ ζ_Q. The sampler is the engine's: seeded
    RandK / PermK with scatter- or concat-mean, or the packed quantization
    wire (blockwise QSGD / natural / RandK∘QSGD, DESIGN.md §4.6) whose
    aggregation is the fused dequantize-and-mean at int8 input bandwidth.
    Without: the per-leaf tree path (reference semantics, cost ∝ n·d).
    A robust ``aggregator`` (DESIGN.md §4.9) replaces the mean with its GAR
    over the per-worker decompressed payloads on either path."""
    if engine is not None:
        return engine.fused_delta(key, diffs, n, aggregator=aggregator)
    payloads = _compress_workers(comp, key, diffs, n)
    if _robust(aggregator):
        dense = jax.vmap(lambda p: tree_decompress(comp, p, like))(payloads)
        return aggregator.combine_stacked(dense)
    return _decompress_mean(comp, payloads, like, n)


def _down_roundtrip(
    down_comp: "Compressor | None",
    down_engine: "FlatEngine | None",
    key: jax.Array,
    delta: PyTree,
    like: PyTree,
) -> PyTree:
    """Compressed downlink on the aggregated round delta: the server
    broadcasts Q_down(δ_up) and every worker decompress-accumulates — since
    g^{k+1} − g^k = δ_up, this IS broadcasting the compressed estimator
    difference. Identity when no downlink is configured (dense broadcast)."""
    if down_engine is not None:
        return down_engine.roundtrip_worker(key, delta)
    if down_comp is not None:
        payload = tree_compress(down_comp, key, delta)
        return tree_decompress(down_comp, payload, like)
    return delta


def _round_bits(
    comp: Compressor, engine: "FlatEngine | None", like: PyTree, n: int = 1
):
    """Per-worker uplink bits of one compressed round (the paper's ζ_Q axis).

    ``n`` matters only for partition compressors (PermK): the per-worker
    payload is the d/n share, so the ledger needs the collection size."""
    if engine is not None:
        return jnp.asarray(engine.payload_bits(n))
    return jnp.asarray(tree_payload_bits(comp, like))


def _down_round_bits(
    down_comp: "Compressor | None",
    down_engine: "FlatEngine | None",
    like: PyTree,
    d: int,
):
    """Per-worker downlink bits of one compressed round: the compressed
    broadcast payload, or the dense 32d estimator when no downlink
    compression is configured (counted either way — DESIGN.md §4.7)."""
    from . import wire

    if down_engine is not None:
        return jnp.asarray(down_engine.payload_bits(1))
    if down_comp is not None:
        return jnp.asarray(tree_payload_bits(down_comp, like))
    return jnp.asarray(wire.downlink_dense_bits(d))


def _check_downlink_config(m) -> None:
    """The fused carry round consumes the downlink payload inside the
    epilogue kernel, which only speaks the flat wire formats — a per-leaf
    tree ``down_compressor`` cannot slot in there, and silently skipping it
    would book compressed down-bits for a dense broadcast. Refuse loudly."""
    if m.carry and m.engine is not None and (
        m.down_compressor is not None and m.down_engine is None
    ):
        raise ValueError(
            "carry=True with a flat engine needs a down_engine for the "
            "compressed downlink (make_downlink(engine, ...)); a per-leaf "
            "down_compressor only fits the tree paths"
        )


def _flat_sync_mean(engine: FlatEngine, grads: PyTree) -> PyTree:
    """Sync rounds ride the flat buffer: ONE fused mean over the packed
    (n, nblk, B) gradient buffer instead of a per-leaf tree exchange."""
    bufs = pack_stacked(engine.layout, grads)
    return unpack(engine.layout, jnp.mean(bufs, axis=0))


# ---------------------------------------------------------------------------
# Robust aggregation + fault injection plumbing (DESIGN.md §4.9)
# ---------------------------------------------------------------------------


def _robust(aggregator) -> bool:
    """True when a ServerAggregator with a non-mean rule is configured."""
    return aggregator is not None and aggregator.robust


def _check_robust_config(m) -> None:
    """Refuse GAR/wire/fault combinations whose semantics are undefined.

    Coordinate-wise (and row-score) GARs need per-worker payloads that are
    comparable coordinate by coordinate: correlated partition compressors
    (PermK et al.) give each coordinate to exactly ONE worker, so there is
    nothing to trim, median, score or clip — refuse rather than silently
    aggregate structure. Dropped clients are only recoverable when the
    server holds an anchor to substitute (``carry=True``'s h table: Δ̂_i = 0
    ⇔ reuse h_i); without a carry the recompute round would silently treat
    the drop as a zero *gradient*, which is a different (wrong) estimator.
    Client weights are a mean-specific concept (robust rules select/trim,
    they don't form convex combinations) — reject the pairing."""
    agg = getattr(m, "aggregator", None)
    if _robust(agg):
        if isinstance(m.compressor, CorrelatedCompressor):
            raise ValueError(
                f"robust rule {agg.rule!r} is undefined on the correlated "
                f"partition compressor {m.compressor.name}: each coordinate "
                "reaches the server from exactly one worker (DESIGN.md §4.9)"
            )
        if m.engine is not None and m.engine.sampler == "permk":
            raise ValueError(
                f"robust rule {agg.rule!r} is undefined on the permk engine "
                "wire: the workers partition the coordinates (DESIGN.md §4.9)"
            )
        if getattr(m, "weights", None) is not None:
            raise ValueError(
                "client weights only make sense for mean aggregation; "
                "robust GARs select/trim rows instead of weighting them"
            )
    flt = getattr(m, "faults", None)
    if flt is not None and flt.attack == "drop" and not m.carry:
        raise ValueError(
            "faults='drop' substitutes the server-side carry row h_i for "
            "the missing upload — carry=True is required (DESIGN.md §4.9); "
            f"construct {type(m).__name__}(..., carry=True) or drop the "
            "FaultSpec"
        )
    if flt is not None and flt.attack == "drop" and _robust(agg):
        # a zero payload row stands in for h_i ONLY under mean aggregation
        # (it contributes exactly h_i/n to the recursion); a GAR treats the
        # zero rows as candidate payloads and trims/medians/scores them —
        # a different, silently wrong estimator. Refuse at construction.
        raise ValueError(
            "faults='drop' relies on mean aggregation: the zero-row carry "
            f"substitution is not defined under the {agg.rule!r} GAR "
            "(DESIGN.md §4.9/§4.10) — use aggregator=None/mean with drop"
        )


def _sync_aggregate(engine, aggregator, grads, weights=None):
    """Sync-round server aggregation over the worker-stacked gradient tree:
    the GAR when a robust aggregator is configured, else the (flat-buffer)
    mean — weighted when client weights are set (mean only)."""
    if _robust(aggregator):
        return aggregator.combine_stacked(grads)
    if engine is not None and weights is None:
        return _flat_sync_mean(engine, grads)
    return _weighted_mean_axis0(grads, weights)


def _uplink_faults(faults, key, trees, ids, n):
    """Compressed-round payload faults on the worker-stacked diff tree:
    Byzantine attacks rewrite their rows; dropped rows zero (Δ̂_i = 0 is the
    carry-row substitution — the server's anchor h_i stands in)."""
    if faults is None:
        return trees
    if faults.attack == "drop":
        return fault_lib.zero_rows(trees, faults.byz_mask(ids, n))
    return fault_lib.inject(faults, key, trees, ids, n)


def _sync_faults(faults, key, trees, ids, n):
    """Sync-round payload faults: Byzantine attacks apply (liars lie on
    dense rounds too); ``drop`` does not — the sync round is the rendezvous
    every client attends (DESIGN.md §4.9 ledger rules)."""
    if faults is None:
        return trees
    return fault_lib.inject(faults, key, trees, ids, n)


def _uplink_bits_scale(faults, n) -> float:
    """Fraction of the fleet whose compressed upload actually arrived: the
    ledger books only real uploads, so drop rounds cost (n−f)/n of ζ_Q."""
    if faults is not None and faults.attack == "drop":
        return (n - faults.n_faulty(n)) / n
    return 1.0


def _carry_refresh(h_old, grads, faults, c_k, n):
    """Next-round carry h: this round's local gradients — except dropped
    rows on compressed rounds, whose upload the server never consumed: their
    anchor must stay the last value both sides agree on (sync rounds are the
    rendezvous where everyone refreshes)."""
    if faults is None or faults.attack != "drop" or faults.n_faulty(n) == 0:
        return grads
    dm = faults.byz_mask(jnp.arange(n), n)
    keep_old = jnp.logical_and(jnp.logical_not(c_k), dm)
    return jax.tree.map(
        lambda ho, gn: jnp.where(
            keep_old.reshape((n,) + (1,) * (gn.ndim - 1)),
            ho.astype(gn.dtype), gn,
        ),
        h_old, grads,
    )


# ---------------------------------------------------------------------------
# MARINA — Algorithm 1
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Marina:
    """Algorithm 1. ``grad_fn(params, batch)`` must return the *local full*
    gradient ∇f_i (the trainer passes each worker's full data shard — or, in the
    online LM setting, the round's large batch, matching Alg. 3 line 8 c_k=1).

    ``carry=True`` enables single-backprop lookahead rounds; ``down_*`` add
    the compressed downlink — see the module docstring for both contracts.
    ``aggregator`` swaps the server mean for a Byzantine-robust GAR
    (:class:`repro.core.aggregators.ServerAggregator`); ``faults`` injects
    per-round client faults (:class:`repro.core.faults.FaultSpec`) — both
    default off and leave every honest path untouched (DESIGN.md §4.9)."""

    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    engine: FlatEngine | None = None  # fused flat path when set (DESIGN.md §4)
    carry: bool = False
    down_compressor: Compressor | None = None
    down_engine: FlatEngine | None = None
    aggregator: Any = None  # ServerAggregator | None (DESIGN.md §4.9)
    faults: Any = None      # FaultSpec | None

    def __post_init__(self):
        _check_downlink_config(self)
        _check_robust_config(self)

    def init(self, params: PyTree, batches: PyTree) -> MarinaState:
        grads = _per_worker_grads(self.grad_fn, params, batches)
        if not self.carry:
            g0 = tree_mean_axis0(grads)
            return MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))
        g0 = tree_mean_axis0(grads)
        x1 = tree_axpy(-self.gamma, g0, params)
        if self.engine is not None:
            # lookahead fused state: estimator lives as the packed buffer
            return MarinaState(
                params=x1, g=pack(self.engine.layout, g0),
                step=jnp.zeros((), jnp.int32), h=grads,
            )
        return MarinaState(params=x1, g=g0, step=jnp.zeros((), jnp.int32), h=grads)

    # -- seed-shaped rounds (two backprops on compressed rounds) ------------
    def _step_recompute(self, state: MarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_f = jax.random.fold_in(key, _FAULT_FOLD)
        ids = jnp.arange(n)

        x_old = state.params
        x_new = tree_axpy(-self.gamma, state.g, x_old)  # Alg.1 line 7

        def sync_branch(_):
            grads = _per_worker_grads(self.grad_fn, x_new, batches)
            grads = _sync_faults(self.faults, k_f, grads, ids, n)
            return _sync_aggregate(self.engine, self.aggregator, grads)

        def compressed_branch(_):
            g_new = _per_worker_grads(self.grad_fn, x_new, batches)
            g_prev = _per_worker_grads(self.grad_fn, x_old, batches)
            diffs = tree_sub(g_new, g_prev)
            diffs = _uplink_faults(self.faults, k_f, diffs, ids, n)
            delta = _compressed_delta(
                self.compressor, self.engine, k_q, diffs, state.params, n,
                self.aggregator,
            )
            delta = _down_roundtrip(
                self.down_compressor, self.down_engine,
                jax.random.fold_in(key, _DOWN_FOLD), delta, state.params,
            )
            return jax.tree.map(jnp.add, state.g, delta)

        g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)

        d = tree_dim(state.params)
        bits_dense = jnp.asarray(32.0 * d)
        bits_q = _round_bits(self.compressor, self.engine, state.params, n)
        up_scale = _uplink_bits_scale(self.faults, n)
        if up_scale != 1.0:
            bits_q = bits_q * up_scale
        down_q = _down_round_bits(
            self.down_compressor, self.down_engine, state.params, d
        )
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g_next),
            bits_per_worker=jnp.where(c_k, bits_dense, bits_q),
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, 1.0, 2.0),
            down_bits=jnp.where(c_k, bits_dense, down_q),
        )
        return MarinaState(params=x_new, g=g_next, step=state.step + 1), metrics

    # -- gradient-carry lookahead rounds (one backprop, fused epilogue) -----
    def _step_carry(self, state: MarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_down = jax.random.fold_in(key, _DOWN_FOLD)
        k_f = jax.random.fold_in(key, _FAULT_FOLD)
        ids = jnp.arange(n)
        d = tree_dim(state.params)

        # the ONE backprop of the round, shared by both branches: state.params
        # is already the evaluation point x^{k+1} (lookahead state).
        grads = _per_worker_grads(self.grad_fn, state.params, batches)
        # h keeps the HONEST local gradients (a Byzantine client lies on the
        # wire, not to itself; a dropped client's row is pinned by
        # _carry_refresh) — only the uplinked payloads are faulted.
        h_new = _carry_refresh(state.h, grads, self.faults, c_k, n)

        if self.engine is not None:
            lay = self.engine.layout
            x2d = pack(lay, state.params)

            def sync_branch(_):
                g_up = _sync_faults(self.faults, k_f, grads, ids, n)
                return self.engine.fused_sync(
                    pack_stacked(lay, g_up), x2d, self.gamma,
                    aggregator=self.aggregator,
                )

            def compressed_branch(_):
                # subtract-and-pack stays in tree form until here so XLA can
                # fuse it into the sampler's ζ-sized gather (a packed h would
                # force an (n, nblk, B) materialization every round)
                diffs = _uplink_faults(
                    self.faults, k_f, tree_sub(grads, state.h), ids, n
                )
                return self.engine.fused_round(
                    k_q, pack_stacked(lay, diffs), n, state.g, x2d, self.gamma,
                    down=self.down_engine, down_key=k_down,
                    aggregator=self.aggregator,
                )

            g2d, x_new2d = jax.lax.cond(c_k, sync_branch, compressed_branch, None)
            new_state = MarinaState(
                params=unpack(lay, x_new2d), g=g2d, step=state.step + 1,
                h=h_new,
            )
            gnorm = tree_norm(g2d)
        else:
            def sync_branch(_):
                g_up = _sync_faults(self.faults, k_f, grads, ids, n)
                return _sync_aggregate(None, self.aggregator, g_up)

            def compressed_branch(_):
                diffs = _uplink_faults(
                    self.faults, k_f, tree_sub(grads, state.h), ids, n
                )
                delta = _compressed_delta(
                    self.compressor, None, k_q, diffs, state.params, n,
                    self.aggregator,
                )
                delta = _down_roundtrip(
                    self.down_compressor, self.down_engine, k_down, delta,
                    state.params,
                )
                return jax.tree.map(jnp.add, state.g, delta)

            g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)
            x_next = tree_axpy(-self.gamma, g_next, state.params)
            new_state = MarinaState(
                params=x_next, g=g_next, step=state.step + 1, h=h_new
            )
            gnorm = tree_norm(g_next)

        bits_dense = jnp.asarray(32.0 * d)
        bits_q = _round_bits(self.compressor, self.engine, state.params, n)
        up_scale = _uplink_bits_scale(self.faults, n)
        if up_scale != 1.0:
            bits_q = bits_q * up_scale
        down_q = _down_round_bits(
            self.down_compressor, self.down_engine, state.params, d
        )
        metrics = StepMetrics(
            grad_est_norm=gnorm,
            bits_per_worker=jnp.where(c_k, bits_dense, bits_q),
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.asarray(1.0),
            down_bits=jnp.where(c_k, bits_dense, down_q),
        )
        return new_state, metrics

    def step(self, state: MarinaState, key: jax.Array, batches: PyTree):
        if self.carry:
            return self._step_carry(state, key, batches)
        return self._step_recompute(state, key, batches)


# ---------------------------------------------------------------------------
# VR-MARINA — Algorithms 2 (finite-sum) and 3 (online)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class VRMarina:
    """Algorithms 2/3. Two oracles:

    * ``full_grad_fn(params, full_batch)`` — ∇f_i (finite-sum, Alg. 2) or the
      b-minibatch gradient (online, Alg. 3) used on c_k = 1 rounds.
    * ``mb_grad_fn(params, mb_batch)`` — the b′-minibatch gradient used at *both*
      points on compressed rounds.

    The trainer samples the batches; this keeps the algorithm agnostic to the
    dataset layout (and identical between the finite-sum and online cases, which
    differ only in what the oracles receive — exactly the Alg. 2 vs Alg. 3 delta).

    ``carry=True`` carries the minibatch recursion: h_i holds whatever local
    gradient the previous round evaluated (full on sync rounds, b′-minibatch
    on compressed rounds) and the compressed difference is
    ∇̂(x^{k+1}; ξ_k) − h_i — one oracle sweep per round instead of two.
    Bit-exact vs. the recompute path when the oracles and batches are
    deterministic per round (e.g. b′ = m); in the fresh-minibatch regime it
    trades the same-ξ correlation for the halved oracle cost (opt-in)."""

    full_grad_fn: GradFn
    mb_grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    engine: FlatEngine | None = None
    carry: bool = False
    down_compressor: Compressor | None = None
    down_engine: FlatEngine | None = None
    aggregator: Any = None  # ServerAggregator | None (DESIGN.md §4.9)
    faults: Any = None      # FaultSpec | None

    def __post_init__(self):
        _check_downlink_config(self)
        _check_robust_config(self)

    def init(self, params: PyTree, full_batches: PyTree) -> MarinaState:
        grads = _per_worker_grads(self.full_grad_fn, params, full_batches)
        if not self.carry:
            g0 = tree_mean_axis0(grads)
            return MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))
        g0 = tree_mean_axis0(grads)
        x1 = tree_axpy(-self.gamma, g0, params)
        if self.engine is not None:
            return MarinaState(
                params=x1, g=pack(self.engine.layout, g0),
                step=jnp.zeros((), jnp.int32), h=grads,
            )
        return MarinaState(params=x1, g=g0, step=jnp.zeros((), jnp.int32), h=grads)

    def _step_recompute(self, state, key, full_batches, mb_batches):
        n = jax.tree.leaves(full_batches)[0].shape[0]
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_f = jax.random.fold_in(key, _FAULT_FOLD)
        ids = jnp.arange(n)

        x_old = state.params
        x_new = tree_axpy(-self.gamma, state.g, x_old)

        def sync_branch(_):
            grads = _per_worker_grads(self.full_grad_fn, x_new, full_batches)
            grads = _sync_faults(self.faults, k_f, grads, ids, n)
            return _sync_aggregate(self.engine, self.aggregator, grads)

        def compressed_branch(_):
            # Alg. 2 line 8: same minibatch at x^{k+1} and x^k.
            g_new = _per_worker_grads(self.mb_grad_fn, x_new, mb_batches)
            g_prev = _per_worker_grads(self.mb_grad_fn, x_old, mb_batches)
            diffs = tree_sub(g_new, g_prev)
            diffs = _uplink_faults(self.faults, k_f, diffs, ids, n)
            delta = _compressed_delta(
                self.compressor, self.engine, k_q, diffs, state.params, n,
                self.aggregator,
            )
            delta = _down_roundtrip(
                self.down_compressor, self.down_engine,
                jax.random.fold_in(key, _DOWN_FOLD), delta, state.params,
            )
            return jax.tree.map(jnp.add, state.g, delta)

        g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)

        d = tree_dim(state.params)
        m_full = jax.tree.leaves(full_batches)[0].shape[1]
        b_prime = jax.tree.leaves(mb_batches)[0].shape[1]
        bits_q = _round_bits(self.compressor, self.engine, state.params, n)
        up_scale = _uplink_bits_scale(self.faults, n)
        if up_scale != 1.0:
            bits_q = bits_q * up_scale
        down_q = _down_round_bits(
            self.down_compressor, self.down_engine, state.params, d
        )
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g_next),
            bits_per_worker=jnp.where(
                c_k,
                jnp.asarray(32.0 * d),
                bits_q,
            ),
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, float(m_full), 2.0 * b_prime),
            down_bits=jnp.where(c_k, jnp.asarray(32.0 * d), down_q),
        )
        return MarinaState(params=x_new, g=g_next, step=state.step + 1), metrics

    def _step_carry(self, state, key, full_batches, mb_batches):
        n = jax.tree.leaves(full_batches)[0].shape[0]
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_down = jax.random.fold_in(key, _DOWN_FOLD)
        k_f = jax.random.fold_in(key, _FAULT_FOLD)
        ids = jnp.arange(n)
        d = tree_dim(state.params)

        if self.engine is not None:
            lay = self.engine.layout
            x2d = pack(lay, state.params)

            # each branch runs its ONE oracle sweep (the two branches use
            # different oracles, so the backprop cannot hoist out of the cond
            # as in plain MARINA — but each round still runs exactly one).
            def sync_branch(_):
                grads = _per_worker_grads(
                    self.full_grad_fn, state.params, full_batches
                )
                g_up = _sync_faults(self.faults, k_f, grads, ids, n)
                g2d, x_new2d = self.engine.fused_sync(
                    pack_stacked(lay, g_up), x2d, self.gamma,
                    aggregator=self.aggregator,
                )
                return g2d, x_new2d, grads

            def compressed_branch(_):
                grads = _per_worker_grads(
                    self.mb_grad_fn, state.params, mb_batches
                )
                diffs = _uplink_faults(
                    self.faults, k_f, tree_sub(grads, state.h), ids, n
                )
                g2d, x_new2d = self.engine.fused_round(
                    k_q, pack_stacked(lay, diffs), n, state.g, x2d, self.gamma,
                    down=self.down_engine, down_key=k_down,
                    aggregator=self.aggregator,
                )
                return g2d, x_new2d, grads

            g2d, x_new2d, h_new = jax.lax.cond(
                c_k, sync_branch, compressed_branch, None
            )
            new_state = MarinaState(
                params=unpack(lay, x_new2d), g=g2d, step=state.step + 1,
                h=_carry_refresh(state.h, h_new, self.faults, c_k, n),
            )
            gnorm = tree_norm(g2d)
        else:
            def sync_branch(_):
                grads = _per_worker_grads(
                    self.full_grad_fn, state.params, full_batches
                )
                g_up = _sync_faults(self.faults, k_f, grads, ids, n)
                return _sync_aggregate(None, self.aggregator, g_up), grads

            def compressed_branch(_):
                grads = _per_worker_grads(
                    self.mb_grad_fn, state.params, mb_batches
                )
                diffs = _uplink_faults(
                    self.faults, k_f, tree_sub(grads, state.h), ids, n
                )
                delta = _compressed_delta(
                    self.compressor, None, k_q, diffs, state.params, n,
                    self.aggregator,
                )
                delta = _down_roundtrip(
                    self.down_compressor, self.down_engine, k_down, delta,
                    state.params,
                )
                return jax.tree.map(jnp.add, state.g, delta), grads

            g_next, h_new = jax.lax.cond(
                c_k, sync_branch, compressed_branch, None
            )
            x_next = tree_axpy(-self.gamma, g_next, state.params)
            new_state = MarinaState(
                params=x_next, g=g_next,
                step=state.step + 1,
                h=_carry_refresh(state.h, h_new, self.faults, c_k, n),
            )
            gnorm = tree_norm(g_next)

        m_full = jax.tree.leaves(full_batches)[0].shape[1]
        b_prime = jax.tree.leaves(mb_batches)[0].shape[1]
        bits_q = _round_bits(self.compressor, self.engine, state.params, n)
        up_scale = _uplink_bits_scale(self.faults, n)
        if up_scale != 1.0:
            bits_q = bits_q * up_scale
        down_q = _down_round_bits(
            self.down_compressor, self.down_engine, state.params, d
        )
        metrics = StepMetrics(
            grad_est_norm=gnorm,
            bits_per_worker=jnp.where(
                c_k,
                jnp.asarray(32.0 * d),
                bits_q,
            ),
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, float(m_full), 1.0 * b_prime),
            down_bits=jnp.where(c_k, jnp.asarray(32.0 * d), down_q),
        )
        return new_state, metrics

    def step(
        self,
        state: MarinaState,
        key: jax.Array,
        full_batches: PyTree,
        mb_batches: PyTree,
    ):
        if self.carry:
            return self._step_carry(state, key, full_batches, mb_batches)
        return self._step_recompute(state, key, full_batches, mb_batches)


# ---------------------------------------------------------------------------
# PP-MARINA — Algorithm 4
# ---------------------------------------------------------------------------


def pp_sample_cohort(
    k_sel: jax.Array, n: int, r: int, replace: bool
) -> jax.Array:
    """Draw PP-MARINA's cohort I'_k (Alg. 4 line 5): r i.i.d. uniform client
    ids (``replace=True``, the analyzed variant) or r distinct ids
    (``replace=False``, the experiments' variant). THE single sampling
    definition — ``PPMarina`` and the mesh prefetch
    (``launch.distributed.pp_cohort_schedule``) both call it, so a schedule
    can never drift from the algorithm."""
    if replace:
        return jax.random.randint(k_sel, (r,), 0, n)
    return jax.random.permutation(k_sel, n)[:r]


def _weighted_mean_axis0(trees: PyTree, weights: "jax.Array | None") -> PyTree:
    """Σ_i w_i t_i over the leading client axis (plain mean when w is None)."""
    if weights is None:
        return tree_mean_axis0(trees)
    return jax.tree.map(
        lambda t: jnp.tensordot(weights.astype(t.dtype), t, axes=1), trees
    )


def _scale_rows(trees: PyTree, row_scale: jax.Array) -> PyTree:
    """Scale each leading-axis row of every leaf by ``row_scale`` (r,)."""
    return jax.tree.map(
        lambda t: t * row_scale.reshape((-1,) + (1,) * (t.ndim - 1)).astype(
            t.dtype
        ),
        trees,
    )


def _pp_carry_refresh(h_old, sel, grads_sel, faults, n):
    """PP server carry-table refresh: h.at[sel] ← ∇f_i for the sampled rows —
    except dropped clients, whose row the server never received, so their
    anchor h_i stays what the server last saw (matching the Δ̂_i = 0 uplink
    substitution of :func:`repro.core.faults.zero_rows`)."""
    if faults is None or faults.attack != "drop" or faults.n_faulty(n) == 0:
        return jax.tree.map(
            lambda ht, gt: ht.at[sel].set(gt.astype(ht.dtype)),
            h_old, grads_sel,
        )
    keep_old = faults.byz_mask(sel, n)

    def refresh(ht, gt):
        mask = keep_old.reshape((-1,) + (1,) * (gt.ndim - 1))
        vals = jnp.where(mask, ht[sel].astype(ht.dtype), gt.astype(ht.dtype))
        return ht.at[sel].set(vals)

    return jax.tree.map(refresh, h_old, grads_sel)


@dataclasses.dataclass
class PPMarina:
    """Algorithm 4 plus the federated-scenario extensions (DESIGN.md §4.8):

    * ``replace`` — Alg. 4 line 5 samples the cohort I'_k as r i.i.d. uniform
      clients (``replace=True``, the analyzed variant); ``replace=False``
      samples r *distinct* clients (the variant the paper's experiments run).
      Both keep the 1/r server scaling: each client lands in the cohort with
      the same marginal, so (1/r)·Σ_{i∈I'} Q(Δ_i) stays an unbiased estimate
      of the mean difference — without replacement only lowers its variance.
    * ``weights`` — arbitrary client weights w_i for unbalanced local
      datasets (raw sample counts are fine — normalized to Σw_i = 1 at
      construction): f(x) = Σ_i w_i f_i(x). Sync rounds average gradients
      with w; compressed rounds pre-scale the sampled differences by n·w_i
      before compression, so (1/r)·Σ Q(n·w_i·Δ_i) is unbiased for Σ w_i Δ_i
      under uniform sampling and the wire/engine path is unchanged.
    * ``carry`` — the *server-side carry table*: the server stores
      h_i = ∇f_i(x) from the last round client i participated in (all n rows
      refresh on sync rounds, only the sampled rows on compressed rounds), so
      a compressed round runs ONE backprop per sampled client — against the
      table instead of recomputing at x^k — and with an engine ends in the
      fused epilogue kernel (the PR-4 path). Beyond-paper and opt-in: for
      clients that sat rounds out the anchor is stale (a lazy-anchor
      estimator à la DIANA shifts); with r = n, replace=False it coincides
      with the recompute estimator step for step (tested). Carry states are
      lookahead, exactly like :class:`Marina` ``carry=True``.

    Bits: the ledger books the fleet totals from :mod:`repro.core.wire` —
    n·32d on sync rounds, exactly r·ζ_Q on compressed rounds — divided by n
    for the per-client ``bits_per_worker`` average. The compressed downlink
    applies unchanged (the broadcast reaches all n clients)."""

    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    r: int
    engine: FlatEngine | None = None
    down_compressor: Compressor | None = None
    down_engine: FlatEngine | None = None
    replace: bool = True
    weights: "jax.Array | None" = None
    carry: bool = False
    aggregator: Any = None  # ServerAggregator | None (DESIGN.md §4.9)
    faults: Any = None      # FaultSpec | None

    def __post_init__(self):
        _check_downlink_config(self)
        _check_robust_config(self)
        if self.weights is not None:
            # accept raw sample counts: normalize to Σw_i = 1 so the
            # weighted objective is a convex combination of the f_i
            w = jnp.asarray(self.weights, jnp.float32)
            self.weights = w / jnp.sum(w)

    def _cohort(self, k_sel: jax.Array, n: int) -> jax.Array:
        """I'_k via the shared sampler (:func:`pp_sample_cohort`)."""
        return pp_sample_cohort(k_sel, n, self.r, self.replace)

    def _cohort_diff_scale(self, sel: jax.Array, n: int) -> "jax.Array | None":
        """Pre-compression row scaling making the 1/r cohort mean unbiased
        for the w-weighted full mean: n·w_i (None when weights are uniform —
        n·(1/n) = 1 and the scaling is the identity)."""
        if self.weights is None:
            return None
        return n * self.weights[sel]

    def init(self, params: PyTree, batches: PyTree) -> MarinaState:
        grads = _per_worker_grads(self.grad_fn, params, batches)
        g0 = _weighted_mean_axis0(grads, self.weights)
        if not self.carry:
            return MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))
        # lookahead carry state: the server seeds the full carry table with
        # every client's ∇f_i(x^0) (the one round where all n backprop).
        x1 = tree_axpy(-self.gamma, g0, params)
        if self.engine is not None:
            return MarinaState(
                params=x1, g=pack(self.engine.layout, g0),
                step=jnp.zeros((), jnp.int32), h=grads,
            )
        return MarinaState(params=x1, g=g0, step=jnp.zeros((), jnp.int32), h=grads)

    # -- seed-shaped rounds (two backprops per sampled client) --------------
    def _step_recompute(self, state: MarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k_bern, k_sel, k_q = jax.random.split(key, 3)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_f = jax.random.fold_in(key, _FAULT_FOLD)

        x_old = state.params
        x_new = tree_axpy(-self.gamma, state.g, x_old)

        def sync_branch(_):
            grads = _per_worker_grads(self.grad_fn, x_new, batches)
            grads = _sync_faults(self.faults, k_f, grads, jnp.arange(n), n)
            return _sync_aggregate(
                self.engine, self.aggregator, grads, self.weights
            )

        def compressed_branch(_):
            sel = self._cohort(k_sel, n)
            take = lambda t: t[sel]
            sel_batches = jax.tree.map(take, batches)
            g_new = _per_worker_grads(self.grad_fn, x_new, sel_batches)
            g_prev = _per_worker_grads(self.grad_fn, x_old, sel_batches)
            diffs = tree_sub(g_new, g_prev)
            ws = self._cohort_diff_scale(sel, n)
            if ws is not None:
                diffs = _scale_rows(diffs, ws)
            diffs = _uplink_faults(self.faults, k_f, diffs, sel, n)
            delta = _compressed_delta(
                self.compressor, self.engine, k_q, diffs, state.params, self.r,
                self.aggregator,
            )
            delta = _down_roundtrip(
                self.down_compressor, self.down_engine,
                jax.random.fold_in(key, _DOWN_FOLD), delta, state.params,
            )
            return jax.tree.map(jnp.add, state.g, delta)

        g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)
        new_state = MarinaState(params=x_new, g=g_next, step=state.step + 1)
        metrics = self._metrics(
            c_k, tree_norm(g_next), state.params, n, oracle_factor=2.0
        )
        return new_state, metrics

    # -- carry rounds: ONE backprop per sampled client vs the server table --
    def _step_carry(self, state: MarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k_bern, k_sel, k_q = jax.random.split(key, 3)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_down = jax.random.fold_in(key, _DOWN_FOLD)
        k_f = jax.random.fold_in(key, _FAULT_FOLD)

        # the cohort is hoisted out of the cond so the ledger can count the
        # uploads that actually happened (dropped sampled clients don't bill)
        sel = self._cohort(k_sel, n)
        uploaded = None
        if self.faults is not None and self.faults.attack == "drop":
            uploaded = self.r - jnp.sum(
                self.faults.byz_mask(sel, n).astype(jnp.int32)
            )

        if self.engine is not None:
            lay = self.engine.layout
            x2d = pack(lay, state.params)

            def sync_branch(_):
                grads = _per_worker_grads(self.grad_fn, state.params, batches)
                g_up = _sync_faults(self.faults, k_f, grads, jnp.arange(n), n)
                if self.weights is None:
                    g2d, x_new2d = self.engine.fused_sync(
                        pack_stacked(lay, g_up), x2d, self.gamma,
                        aggregator=self.aggregator,
                    )
                else:
                    g_new = _weighted_mean_axis0(g_up, self.weights)
                    g2d = pack(lay, g_new)
                    x_new2d = x2d - self.gamma * g2d
                # the table keeps the HONEST gradients — liars lie on the
                # wire, the simulated clients still know their own state
                return g2d, x_new2d, grads

            def compressed_branch(_):
                sel_batches = jax.tree.map(lambda t: t[sel], batches)
                grads_sel = _per_worker_grads(
                    self.grad_fn, state.params, sel_batches
                )
                h_sel = jax.tree.map(lambda t: t[sel], state.h)
                diffs = tree_sub(grads_sel, h_sel)
                ws = self._cohort_diff_scale(sel, n)
                if ws is not None:
                    diffs = _scale_rows(diffs, ws)
                diffs = _uplink_faults(self.faults, k_f, diffs, sel, n)
                # the table keeps the RAW client gradients (weights apply at
                # aggregation): refresh only the sampled rows — minus drops.
                h_new = _pp_carry_refresh(
                    state.h, sel, grads_sel, self.faults, n
                )
                g2d, x_new2d = self.engine.fused_round(
                    k_q, pack_stacked(lay, diffs), self.r, state.g, x2d,
                    self.gamma, down=self.down_engine, down_key=k_down,
                    aggregator=self.aggregator,
                )
                return g2d, x_new2d, h_new

            g2d, x_new2d, h_new = jax.lax.cond(
                c_k, sync_branch, compressed_branch, None
            )
            new_state = MarinaState(
                params=unpack(lay, x_new2d), g=g2d, step=state.step + 1,
                h=h_new,
            )
            gnorm = tree_norm(g2d)
        else:
            def sync_branch(_):
                grads = _per_worker_grads(self.grad_fn, state.params, batches)
                g_up = _sync_faults(self.faults, k_f, grads, jnp.arange(n), n)
                return (
                    _sync_aggregate(None, self.aggregator, g_up, self.weights),
                    grads,
                )

            def compressed_branch(_):
                sel_batches = jax.tree.map(lambda t: t[sel], batches)
                grads_sel = _per_worker_grads(
                    self.grad_fn, state.params, sel_batches
                )
                h_sel = jax.tree.map(lambda t: t[sel], state.h)
                diffs = tree_sub(grads_sel, h_sel)
                ws = self._cohort_diff_scale(sel, n)
                if ws is not None:
                    diffs = _scale_rows(diffs, ws)
                diffs = _uplink_faults(self.faults, k_f, diffs, sel, n)
                h_new = _pp_carry_refresh(
                    state.h, sel, grads_sel, self.faults, n
                )
                delta = _compressed_delta(
                    self.compressor, None, k_q, diffs, state.params, self.r,
                    self.aggregator,
                )
                delta = _down_roundtrip(
                    self.down_compressor, self.down_engine, k_down, delta,
                    state.params,
                )
                return jax.tree.map(jnp.add, state.g, delta), h_new

            (g_next, h_new) = jax.lax.cond(
                c_k, sync_branch, compressed_branch, None
            )
            x_next = tree_axpy(-self.gamma, g_next, state.params)
            new_state = MarinaState(
                params=x_next, g=g_next, step=state.step + 1, h=h_new
            )
            gnorm = tree_norm(g_next)

        metrics = self._metrics(
            c_k, gnorm, state.params, n, oracle_factor=1.0, uploaded=uploaded
        )
        return new_state, metrics

    def _metrics(self, c_k, gnorm, like, n, oracle_factor, uploaded=None):
        """Fleet-total uplink from the wire helpers, divided by n: sync
        rounds cost n·32d, compressed rounds exactly r·ζ_Q (wire.py) — or
        uploaded·ζ_Q when dropped cohort members never delivered theirs."""
        from . import wire

        d = tree_dim(like)
        up = self.r if uploaded is None else uploaded
        bits_total = jnp.where(
            c_k,
            jnp.asarray(wire.pp_sync_total_bits(n, d)),
            wire.pp_uplink_total_bits(
                up, _round_bits(self.compressor, self.engine, like, self.r)
            ),
        )
        down_q = _down_round_bits(
            self.down_compressor, self.down_engine, like, d
        )
        return StepMetrics(
            grad_est_norm=gnorm,
            bits_per_worker=bits_total / n,
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, 1.0, oracle_factor * self.r / n),
            down_bits=jnp.where(c_k, jnp.asarray(32.0 * d), down_q),
        )

    def step(self, state: MarinaState, key: jax.Array, batches: PyTree):
        if self.carry:
            return self._step_carry(state, key, batches)
        return self._step_recompute(state, key, batches)


def make_gd(grad_fn: GradFn, gamma: float) -> Marina:
    """GD = MARINA with identity quantization (paper §2)."""
    return Marina(grad_fn=grad_fn, compressor=Identity(), gamma=gamma, p=1.0)
