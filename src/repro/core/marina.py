"""MARINA, VR-MARINA and PP-MARINA (Algorithms 1–4 of the paper).

The algorithms are written against *worker-stacked* pytrees: every per-worker
quantity (minibatch, payload, shift) carries a leading axis of size ``n``. On a
single device this leading axis is a plain vmap dimension; on a mesh the launcher
shards it over the worker mesh axes, so the same code runs in both the CPU
simulation used by tests/examples and the multi-pod production path
(see launch/distributed.py for the sharded LM instantiation that additionally
annotates model-parallel dimensions).

Faithfulness notes
------------------
* ``c_k ~ Be(p)`` is shared across workers (Alg. 1 line 4): a scalar drawn from the
  step key, applied through ``lax.cond``.
* ``g^0 = ∇f(x^0)`` exactly (Alg. 1 line 2) — init computes the full gradient.
* Compressed rounds evaluate gradients at *both* points on the *same* minibatch
  (Alg. 2 line 8); we recompute at the old point instead of storing a second full
  gradient (PAGE-style; DESIGN.md §3).
* Compressor randomness is independent across workers (the n-fold key split),
  which is what gives the 1/n variance averaging in Thm 2.1's proof (eq. 21).
  ``SharedRandK`` deliberately breaks this for the §Perf communication experiment.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from .compressors import (
    Compressor,
    CorrelatedCompressor,
    Identity,
    SharedRandK,
    tree_compress,
    tree_compress_worker,
    tree_decompress,
    tree_dim,
    tree_payload_bits,
)
from .flat import FlatEngine
from .tree_util import (
    tree_axpy,
    tree_mean_axis0,
    tree_norm,
    tree_scale,
    tree_sub,
)

PyTree = Any
GradFn = Callable[[PyTree, PyTree], PyTree]  # (params, batch) -> grad tree


class StepMetrics(NamedTuple):
    grad_est_norm: jax.Array      # ‖g^k‖ (the estimator driving the step)
    bits_per_worker: jax.Array    # bits uplinked by one worker this round
    sync_round: jax.Array         # c_k (1 = dense round)
    oracle_calls: jax.Array       # stochastic first-order oracle calls per worker


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MarinaState:
    params: PyTree
    g: PyTree          # server estimator g^k, replicated
    step: jax.Array


def _per_worker_grads(grad_fn: GradFn, params: PyTree, batches: PyTree) -> PyTree:
    """∇f_i at params for every worker: vmap over the leading worker axis."""
    return jax.vmap(grad_fn, in_axes=(None, 0))(params, batches)


def _compress_workers(
    comp: Compressor, key: jax.Array, diffs: PyTree, n: int
) -> PyTree:
    """Compress each worker's difference tree. Independent keys per worker,
    except SharedRandK which reuses one key (correlated masks by design) and
    CorrelatedCompressor collections (PermK, CorrelatedQ), where ALL workers
    share the round key and receive their index — the shared randomness is
    what buys the (A, B) constants (Szlendak et al. 2021)."""
    if isinstance(comp, CorrelatedCompressor):
        # a mismatched fleet is not an error the math survives: extra wids
        # alias back onto the first shards (mask wraparound) and the mean
        # silently double-counts them — refuse loudly instead.
        assert n == comp.n, (
            f"{comp.name} collection sized for n={comp.n} but the round has "
            f"{n} workers"
        )
        wids = jnp.arange(n, dtype=jnp.int32)
        return jax.vmap(
            lambda w, t: tree_compress_worker(comp, key, t, w)
        )(wids, diffs)
    if isinstance(comp, SharedRandK):
        keys = jnp.broadcast_to(key, (n, *key.shape))
    else:
        keys = jax.random.split(key, n)
    return jax.vmap(partial(tree_compress, comp))(keys, diffs)


def _decompress_mean(comp: Compressor, payloads: PyTree, like: PyTree, n: int) -> PyTree:
    """Server aggregation: decompress all n payloads, average (Alg. 1 line 10).

    Per-leaf reference path: densifies all n payloads to an (n, d) tree before
    averaging. The production compressed round goes through the flat engine
    (:func:`_compressed_delta`), which aggregates by scatter-accumulate and
    never materializes the (n, d) trees (DESIGN.md §4)."""
    dense = jax.vmap(lambda p: tree_decompress(comp, p, like))(payloads)
    return tree_mean_axis0(dense)


def _compressed_delta(
    comp: Compressor,
    engine: "FlatEngine | None",
    key: jax.Array,
    diffs: PyTree,
    like: PyTree,
    n: int,
) -> PyTree:
    """One compressed uplink round: (1/n) Σ_i Q(Δ_i).

    With an engine: the fused flat-buffer pipeline (pack → sampler →
    aggregate → unpack), cost ∝ ζ_Q. The sampler is the engine's: seeded
    RandK / PermK with scatter- or concat-mean, or the packed quantization
    wire (blockwise QSGD / natural / RandK∘QSGD, DESIGN.md §4.6) whose
    aggregation is the fused dequantize-and-mean at int8 input bandwidth.
    Without: the per-leaf tree path (reference semantics, cost ∝ n·d)."""
    if engine is not None:
        return engine.fused_delta(key, diffs, n)
    payloads = _compress_workers(comp, key, diffs, n)
    return _decompress_mean(comp, payloads, like, n)


def _round_bits(
    comp: Compressor, engine: "FlatEngine | None", like: PyTree, n: int = 1
):
    """Per-worker uplink bits of one compressed round (the paper's ζ_Q axis).

    ``n`` matters only for partition compressors (PermK): the per-worker
    payload is the d/n share, so the ledger needs the collection size."""
    if engine is not None:
        return jnp.asarray(engine.payload_bits(n))
    return jnp.asarray(tree_payload_bits(comp, like))


# ---------------------------------------------------------------------------
# MARINA — Algorithm 1
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Marina:
    """Algorithm 1. ``grad_fn(params, batch)`` must return the *local full*
    gradient ∇f_i (the trainer passes each worker's full data shard — or, in the
    online LM setting, the round's large batch, matching Alg. 3 line 8 c_k=1)."""

    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    engine: FlatEngine | None = None  # fused flat path when set (DESIGN.md §4)

    def init(self, params: PyTree, batches: PyTree) -> MarinaState:
        g0 = tree_mean_axis0(_per_worker_grads(self.grad_fn, params, batches))
        return MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))

    def step(self, state: MarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)

        x_old = state.params
        x_new = tree_axpy(-self.gamma, state.g, x_old)  # Alg.1 line 7

        def sync_branch(_):
            grads = _per_worker_grads(self.grad_fn, x_new, batches)
            return tree_mean_axis0(grads)

        def compressed_branch(_):
            g_new = _per_worker_grads(self.grad_fn, x_new, batches)
            g_prev = _per_worker_grads(self.grad_fn, x_old, batches)
            diffs = tree_sub(g_new, g_prev)
            delta = _compressed_delta(
                self.compressor, self.engine, k_q, diffs, state.params, n
            )
            return jax.tree.map(jnp.add, state.g, delta)

        g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)

        d = tree_dim(state.params)
        bits_dense = jnp.asarray(32.0 * d)
        bits_q = _round_bits(self.compressor, self.engine, state.params, n)
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g_next),
            bits_per_worker=jnp.where(c_k, bits_dense, bits_q),
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, 1.0, 2.0),
        )
        return MarinaState(params=x_new, g=g_next, step=state.step + 1), metrics


# ---------------------------------------------------------------------------
# VR-MARINA — Algorithms 2 (finite-sum) and 3 (online)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class VRMarina:
    """Algorithms 2/3. Two oracles:

    * ``full_grad_fn(params, full_batch)`` — ∇f_i (finite-sum, Alg. 2) or the
      b-minibatch gradient (online, Alg. 3) used on c_k = 1 rounds.
    * ``mb_grad_fn(params, mb_batch)`` — the b′-minibatch gradient used at *both*
      points on compressed rounds.

    The trainer samples the batches; this keeps the algorithm agnostic to the
    dataset layout (and identical between the finite-sum and online cases, which
    differ only in what the oracles receive — exactly the Alg. 2 vs Alg. 3 delta).
    """

    full_grad_fn: GradFn
    mb_grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    engine: FlatEngine | None = None

    def init(self, params: PyTree, full_batches: PyTree) -> MarinaState:
        g0 = tree_mean_axis0(_per_worker_grads(self.full_grad_fn, params, full_batches))
        return MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))

    def step(
        self,
        state: MarinaState,
        key: jax.Array,
        full_batches: PyTree,
        mb_batches: PyTree,
    ):
        n = jax.tree.leaves(full_batches)[0].shape[0]
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)

        x_old = state.params
        x_new = tree_axpy(-self.gamma, state.g, x_old)

        def sync_branch(_):
            grads = _per_worker_grads(self.full_grad_fn, x_new, full_batches)
            return tree_mean_axis0(grads)

        def compressed_branch(_):
            # Alg. 2 line 8: same minibatch at x^{k+1} and x^k.
            g_new = _per_worker_grads(self.mb_grad_fn, x_new, mb_batches)
            g_prev = _per_worker_grads(self.mb_grad_fn, x_old, mb_batches)
            diffs = tree_sub(g_new, g_prev)
            delta = _compressed_delta(
                self.compressor, self.engine, k_q, diffs, state.params, n
            )
            return jax.tree.map(jnp.add, state.g, delta)

        g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)

        d = tree_dim(state.params)
        m_full = jax.tree.leaves(full_batches)[0].shape[1]
        b_prime = jax.tree.leaves(mb_batches)[0].shape[1]
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g_next),
            bits_per_worker=jnp.where(
                c_k,
                jnp.asarray(32.0 * d),
                _round_bits(self.compressor, self.engine, state.params, n),
            ),
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, float(m_full), 2.0 * b_prime),
        )
        return MarinaState(params=x_new, g=g_next, step=state.step + 1), metrics


# ---------------------------------------------------------------------------
# PP-MARINA — Algorithm 4
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PPMarina:
    """Algorithm 4: on compressed rounds only r i.i.d.-sampled clients upload;
    the server averages the r quantized differences (line 11, 1/r scaling)."""

    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    r: int
    engine: FlatEngine | None = None

    def init(self, params: PyTree, batches: PyTree) -> MarinaState:
        g0 = tree_mean_axis0(_per_worker_grads(self.grad_fn, params, batches))
        return MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))

    def step(self, state: MarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k_bern, k_sel, k_q = jax.random.split(key, 3)
        c_k = jax.random.bernoulli(k_bern, self.p)

        x_old = state.params
        x_new = tree_axpy(-self.gamma, state.g, x_old)

        def sync_branch(_):
            grads = _per_worker_grads(self.grad_fn, x_new, batches)
            return tree_mean_axis0(grads)

        def compressed_branch(_):
            # I'_k: r i.i.d. uniform samples over {1..n} (with replacement, as in
            # Alg. 4 line 5).
            sel = jax.random.randint(k_sel, (self.r,), 0, n)
            take = lambda t: t[sel]
            sel_batches = jax.tree.map(take, batches)
            g_new = _per_worker_grads(self.grad_fn, x_new, sel_batches)
            g_prev = _per_worker_grads(self.grad_fn, x_old, sel_batches)
            diffs = tree_sub(g_new, g_prev)
            delta = _compressed_delta(
                self.compressor, self.engine, k_q, diffs, state.params, self.r
            )
            return jax.tree.map(jnp.add, state.g, delta)

        g_next = jax.lax.cond(c_k, sync_branch, compressed_branch, None)

        d = tree_dim(state.params)
        # Total (all-worker) uplink this round: n·32d dense vs r·bits(Q).
        bits_total = jnp.where(
            c_k,
            jnp.asarray(32.0 * d * n),
            _round_bits(self.compressor, self.engine, state.params, self.r)
            * self.r,
        )
        metrics = StepMetrics(
            grad_est_norm=tree_norm(g_next),
            bits_per_worker=bits_total / n,
            sync_round=c_k.astype(jnp.int32),
            oracle_calls=jnp.where(c_k, 1.0, 2.0 * self.r / n),
        )
        return MarinaState(params=x_new, g=g_next, step=state.step + 1), metrics


def make_gd(grad_fn: GradFn, gamma: float) -> Marina:
    """GD = MARINA with identity quantization (paper §2)."""
    return Marina(grad_fn=grad_fn, compressor=Identity(), gamma=gamma, p=1.0)
