"""Byzantine-robust server aggregation rules (GARs) — DESIGN.md §4.9.

MARINA's server update ``g^{k+1} = g^k + mean_i Q(Δ_i)`` trusts every
uploaded compressed difference; a single corrupted Δ̂_i poisons the estimator
*persistently* (the recursion never forgets a round), which is qualitatively
worse than one bad gradient in SGD. :class:`ServerAggregator` swaps the mean
for a gradient aggregation rule (GAR) at the one place all three optimizers
and the fused engine aggregate:

* ``mean``               — the paper's aggregation (the default; no change).
* ``trimmed_mean``       — coordinate-wise f-trimmed mean: per coordinate,
                           drop the f smallest and f largest worker values
                           and average the rest (needs n > 2f).
* ``coordinate_median``  — coordinate-wise median (the trim-bound special
                           case of the same kernel; breakdown point ~n/2).
* ``krum``               — select the single row minimizing the sum of its
                           n−f−2 smallest squared distances to the other
                           rows (Blanchard et al. 2017; needs n ≥ f+3).
* ``norm_clip``          — clip every row's global ℓ2 norm to τ (the median
                           row norm when ``clip_tau`` is None), then mean.

The coordinate-wise rules run on the fused wire as Pallas kernels
(``kernels/epilogue.py: trimmed_*_epilogue`` — sort-free rank selection over
the (n, nblk, B) payload rows); Krum/norm-clip are row-*score* reductions
(one scalar per worker) feeding the ordinary dense-δ epilogue.

Wire compatibility (DESIGN.md §4.9): coordinate-wise rules need the worker
payloads to be comparable per coordinate — dense quantizers (QSGD, natural)
or shared-support sparsifiers qualify; *independent* RandK supports make the
per-coordinate sample mostly structural zeros (the trim window then measures
the sparsity pattern, not the values), and PermK partitions coordinates
across workers (exactly one worker per coordinate — nothing to aggregate
robustly), so the optimizers refuse robust rules on correlated/partition
compressors outright.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any

RULES = ("mean", "trimmed_mean", "coordinate_median", "krum", "norm_clip")

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class ServerAggregator:
    """A gradient aggregation rule for the server side of a round.

    ``rule`` is one of :data:`RULES`; ``f`` is the assumed Byzantine count
    (the trim width of ``trimmed_mean`` and Krum's f — ignored by the median
    and norm-clip, whose breakdown is structural); ``clip_tau`` overrides the
    norm-clip threshold (default: the median row norm, self-tuning).

    Static config (hashable, frozen): safe to close over in jitted steps.
    The same instance drives the tree paths (:meth:`combine_stacked`), the
    flat engine (:meth:`combine_rows` + the trimmed Pallas epilogues via
    :meth:`trim_bounds`) and the γ bookkeeping (:meth:`n_eff`).
    """

    rule: str = "mean"
    f: int = 0
    clip_tau: Optional[float] = None

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule {self.rule!r}, expected {RULES}")
        if self.f < 0:
            raise ValueError("Byzantine count f must be >= 0")

    # -- static metadata ----------------------------------------------------
    @property
    def robust(self) -> bool:
        """True when the rule differs from the paper's plain mean."""
        return self.rule != "mean"

    @property
    def coordinatewise(self) -> bool:
        """True for the rules with a fused Pallas epilogue (trim/median)."""
        return self.rule in ("trimmed_mean", "coordinate_median")

    def trim_bounds(self, n: int) -> tuple:
        """Rank keep-window [lo, hi) of the coordinate-wise rules for n
        workers. Trimmed mean: (f, n−f). Median: ((n−1)//2, (n−1)//2+1) odd /
        (n//2−1, n//2+1) even (mean of the two middle values) — the median IS
        a trim-bound setting, so one kernel covers both."""
        if self.rule == "coordinate_median":
            if n % 2:
                m = (n - 1) // 2
                return m, m + 1
            return n // 2 - 1, n // 2 + 1
        lo, hi = self.f, n - self.f
        if not lo < hi:
            raise ValueError(
                f"trimmed_mean needs n > 2f (n={n}, f={self.f})"
            )
        return lo, hi

    def n_eff(self, n: int) -> int:
        """Effective averaging count of the rule (how many worker values the
        aggregate still averages over) — the robust-γ heuristic of
        :func:`repro.core.stepsize.robust_n_eff` substitutes it for n."""
        from . import stepsize

        return stepsize.robust_n_eff(self.rule, n, self.f)

    # -- single-array combine (flat engine / mesh rows) ---------------------
    def combine_rows(self, rows: jax.Array) -> jax.Array:
        """Aggregate a worker-stacked array: (n, ...) → (...).

        The jnp reference form of every rule; the fused engine routes the
        coordinate-wise rules to the Pallas epilogues instead (same rank
        semantics — ``kernels/ref.py: trimmed_mean_rows_ref`` is the shared
        oracle) and uses this only for Krum/norm-clip row scoring."""
        from repro.kernels import ref as kref

        n = rows.shape[0]
        if self.rule == "mean":
            return jnp.mean(rows.astype(jnp.float32), axis=0)
        if self.coordinatewise:
            lo, hi = self.trim_bounds(n)
            return kref.trimmed_mean_rows_ref(rows, lo, hi)
        flat = rows.reshape(n, -1).astype(jnp.float32)
        if self.rule == "krum":
            win = _krum_select(_pairwise_sq_dists(flat), n, self.f)
            return rows[win].astype(jnp.float32)
        # norm_clip — select-out non-finite rows before scaling (0·NaN = NaN)
        norms = jnp.sqrt(jnp.sum(flat * flat, axis=1))
        scale = _clip_scales(norms, self.clip_tau)
        clean = jnp.where(jnp.isfinite(flat), flat, 0.0)
        return jnp.mean(
            clean * scale[:, None], axis=0
        ).reshape(rows.shape[1:])

    # -- pytree combine (tree optimizer paths / mesh) -----------------------
    def combine_stacked(self, trees: PyTree) -> PyTree:
        """Aggregate a worker-stacked pytree (leading axis n on every leaf).

        Coordinate-wise rules apply leaf by leaf (a coordinate is a
        coordinate). Krum and norm-clip score rows *globally*: the pairwise
        distances / row norms sum across all leaves before the selection or
        clip scale, so a Byzantine client cannot hide a large leaf behind an
        honest-looking one."""
        leaves = jax.tree.leaves(trees)
        n = leaves[0].shape[0]
        if self.rule == "mean":
            return jax.tree.map(
                lambda t: jnp.mean(t.astype(jnp.float32), 0).astype(t.dtype),
                trees,
            )
        if self.coordinatewise:
            return jax.tree.map(
                lambda t: self.combine_rows(t).astype(t.dtype), trees
            )
        flats = [l.reshape(n, -1).astype(jnp.float32) for l in leaves]
        if self.rule == "krum":
            dists = sum(_pairwise_sq_dists(fl) for fl in flats)
            win = _krum_select(dists, n, self.f)
            return jax.tree.map(lambda t: t[win], trees)
        norms = jnp.sqrt(sum(jnp.sum(fl * fl, axis=1) for fl in flats))
        scale = _clip_scales(norms, self.clip_tau)

        def clip_mean(t):
            tf = t.astype(jnp.float32)
            clean = jnp.where(jnp.isfinite(tf), tf, 0.0)
            return jnp.mean(
                clean * scale.reshape((n,) + (1,) * (t.ndim - 1)), axis=0
            ).astype(t.dtype)

        return jax.tree.map(clip_mean, trees)


def _pairwise_sq_dists(flat: jax.Array) -> jax.Array:
    """(n, d) rows → (n, n) squared euclidean distances (Gram expansion)."""
    sq = jnp.sum(flat * flat, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (flat @ flat.T)
    return jnp.maximum(d2, 0.0)


def _krum_select(dists: jax.Array, n: int, f: int) -> jax.Array:
    """Krum winner index from the (n, n) pairwise distance matrix: score_i =
    sum of the n−f−2 smallest distances to OTHER rows, pick the argmin.
    Non-finite scores (NaN/garbage payloads poison their own row's distances)
    are demoted to +inf — a NaN must never win the argmin."""
    m = n - f - 2
    if m < 1:
        raise ValueError(f"krum needs n >= f + 3 (n={n}, f={f})")
    masked = dists + jnp.diag(jnp.full((n,), jnp.inf, dists.dtype))
    scores = jnp.sum(jnp.sort(masked, axis=1)[:, :m], axis=1)
    scores = jnp.where(jnp.isfinite(scores), scores, jnp.inf)
    return jnp.argmin(scores)


def _clip_scales(norms: jax.Array, clip_tau: Optional[float]) -> jax.Array:
    """Per-row clip factors min(1, τ/‖row‖); τ defaults to the median norm
    (self-tuning: with f < n/2 attackers the median norm is honest-sized).
    Rows with a non-finite norm (NaN/inf payloads no clip can repair) get
    scale 0 — the standard server-side sanity filter."""
    finite = jnp.isfinite(norms)
    safe = jnp.where(finite, norms, 0.0)
    tau = (
        jnp.median(jnp.where(finite, norms, jnp.inf))
        if clip_tau is None
        else jnp.asarray(clip_tau, jnp.float32)
    )
    scale = jnp.minimum(1.0, tau / jnp.maximum(safe, _EPS))
    return jnp.where(finite, scale, 0.0)
