"""Theory stepsizes and iteration bounds from the paper's theorems.

These are the *exact* admissible stepsizes of Theorems 2.1, 2.2, 3.1/3.2, 4.1 —
the experiments in §5 / Appendix A run MARINA and DIANA with these theoretical
choices, and our reproduction benchmarks do the same.
"""

from __future__ import annotations

import math


def marina_gamma(L: float, omega: float, p: float, n: int) -> float:
    """Thm 2.1:  γ ≤ 1 / ( L (1 + sqrt((1-p) ω / (p n))) )."""
    return 1.0 / (L * (1.0 + math.sqrt((1.0 - p) * omega / (p * n))))


def marina_gamma_pl(L: float, omega: float, p: float, n: int, mu: float) -> float:
    """Thm 2.2:  γ ≤ min{ 1/(L(1+sqrt(2(1-p)ω/(pn)))), p/(2µ) }."""
    g1 = 1.0 / (L * (1.0 + math.sqrt(2.0 * (1.0 - p) * omega / (p * n))))
    return min(g1, p / (2.0 * mu))


def vr_marina_gamma(
    L: float, calL: float, omega: float, p: float, n: int, b_prime: int
) -> float:
    """Thm 3.1/3.2:  γ ≤ 1 / ( L + sqrt((1-p)/(pn) (ω L² + (1+ω) 𝓛²/b')) )."""
    inner = (1.0 - p) / (p * n) * (omega * L**2 + (1.0 + omega) * calL**2 / b_prime)
    return 1.0 / (L + math.sqrt(inner))


def pp_marina_gamma(L: float, omega: float, p: float, r: int) -> float:
    """Thm 4.1:  γ ≤ 1 / ( L (1 + sqrt((1-p)(1+ω)/(p r))) )."""
    return 1.0 / (L * (1.0 + math.sqrt((1.0 - p) * (1.0 + omega) / (p * r))))


# ---------------------------------------------------------------------------
# (A, B)-refined stepsizes (Szlendak et al. 2021, "Permutation Compressors")
#
# The collection {Q_i} enters MARINA's rate only through the AB-inequality
#
#     E‖(1/n)Σ Q_i(x_i) − x̄‖² ≤ A·(1/n)Σ‖x_i‖² − B·‖x̄‖²
#
# (see Compressor.ab_constants). The estimator-drift term of the Thm 2.1
# proof then carries A·L₊² − B·L₋² instead of (ω/n)·L², where L₊² = (1/n)ΣL_i²
# and L₋ is the "Hessian variance" smoothness of f_i − f (L₋ ≤ L₊; equal in
# the worst case). Independent ω-compressors have (A, B) = ((1+ω)/n, 1/n)
# (tight — see ab_constants), which recovers marina_gamma exactly; PermK's
# (1, 1) makes the drift term vanish for homogeneous smoothness and admits
# the plain GD stepsize γ = 1/L at d/n uplink per worker.
# ---------------------------------------------------------------------------


def ab_from_omega(omega: float, n: int) -> tuple:
    """Tight (A, B) for n *independent* ω-compressors: ((1+ω)/n, 1/n).

    NOT (1+ω, ω): with identical inputs that pair demands ω ≤ n (its right
    side degenerates to ‖x‖² against a true aggregate variance of (ω/n)‖x‖²),
    so it is violated by any high-compression operator — see the counter-
    example in Compressor.ab_constants."""
    return ((1.0 + omega) / n, 1.0 / n)


def marina_gamma_ab(
    L: float,
    A: float,
    B: float,
    p: float,
    l_plus: float | None = None,
    l_minus: float | None = None,
) -> float:
    """AB-refined Thm 2.1:  γ ≤ 1 / ( L + sqrt((1-p)/p · (A·L₊² − B·L₋²)) ).

    With (A, B) = ab_from_omega(ω, n) and L₊ = L₋ = L this is exactly
    :func:`marina_gamma`; with PermK's (1, 1) and homogeneous smoothness the
    sqrt term vanishes and γ = 1/L."""
    lp = L if l_plus is None else l_plus
    lm = lp if l_minus is None else l_minus
    inner = max((1.0 - p) / p * (A * lp**2 - B * lm**2), 0.0)
    return 1.0 / (L + math.sqrt(inner))


def marina_gamma_permk(
    L: float,
    p: float,
    l_plus: float | None = None,
    l_minus: float | None = None,
) -> float:
    """Perm-K corollary of the AB theorem: (A, B) = (1, 1), so
    γ = 1 / (L + sqrt((1-p)/p · (L₊² − L₋²))) — and exactly 1/L whenever the
    workers share the smoothness constant (L₋ = L₊), i.e. MARINA+PermK runs
    at the uncompressed GD stepsize while uplinking d/n coords per worker."""
    return marina_gamma_ab(L, 1.0, 1.0, p, l_plus, l_minus)


def permk_default_p(n: int) -> float:
    """ζ_Q/d for PermK is (d/n)/d = 1/n (Cor. 2.1 choice)."""
    return 1.0 / n


def diana_alpha(omega: float) -> float:
    """DIANA shift learning rate α ≤ 1/(1+ω) (Mishchenko et al. 2019)."""
    return 1.0 / (1.0 + omega)


def diana_gamma(L: float, omega: float, n: int) -> float:
    """Non-convex DIANA stepsize (Li & Richtárik 2020, simplified constants):

    γ = 1 / ( L (1 + (1+ω) sqrt(ω/n) · c) ), c = O(1). We use c = 2 which satisfies
    the admissibility condition of their Theorem 4.1 specialization.
    """
    return 1.0 / (L * (1.0 + 2.0 * (1.0 + omega) * math.sqrt(omega / n) + 2.0 * omega / n))


# ---------------------------------------------------------------------------
# Robust-aggregation γ degradation (DESIGN.md §4.9)
#
# Swapping the server mean for a GAR costs variance averaging: the 1/n factor
# in Thm 2.1's drift term came from averaging n independent compressor
# noises, and a robust rule only averages over the values it keeps. The
# standard heuristic (e.g. El-Mhamdi et al.'s (f, λ)-resilient-averaging
# view) is to substitute the rule's *effective averaging count* n_eff for n:
# trimmed mean keeps n − 2f values per coordinate, the median one (odd n) or
# two (even n), Krum forwards a single row, norm-clip still averages all n
# (clipping only shrinks rows). This is a conservative bookkeeping device,
# not a theorem from the paper — MARINA's analysis leaves Byzantine rates to
# future work — so the helpers are explicitly labeled heuristic.
# ---------------------------------------------------------------------------


def robust_n_eff(rule: str, n: int, f: int = 0) -> int:
    """Effective averaging count n_eff of a GAR over n workers.

    mean/norm_clip: n (all rows enter the average); trimmed_mean: n − 2f
    (needs n > 2f); coordinate_median: 1 for odd n, 2 for even (the kept
    middle values); krum: 1 (a single selected row)."""
    if rule in ("mean", "norm_clip"):
        return n
    if rule == "trimmed_mean":
        if n <= 2 * f:
            raise ValueError(f"trimmed_mean needs n > 2f (n={n}, f={f})")
        return n - 2 * f
    if rule == "coordinate_median":
        return 2 if n % 2 == 0 else 1
    if rule == "krum":
        return 1
    raise ValueError(f"unknown GAR rule {rule!r}")


def robust_marina_gamma(
    L: float, omega: float, p: float, n: int, rule: str, f: int = 0
) -> float:
    """Thm 2.1 γ with the GAR's n_eff substituted for n — the robust-rate
    degradation: γ_robust = 1/(L(1 + sqrt((1−p)ω/(p·n_eff)))). Heuristic
    (see the section comment); equals :func:`marina_gamma` for the mean."""
    return marina_gamma(L, omega, p, robust_n_eff(rule, n, f))


def robust_pp_marina_gamma(
    L: float, omega: float, p: float, r: int, rule: str, f: int = 0
) -> float:
    """Thm 4.1 γ with n_eff(r) substituted for the cohort size r — the
    PP-MARINA robust degradation (the GAR acts on the r uploaded rows).
    Heuristic; equals :func:`pp_marina_gamma` for the mean."""
    return pp_marina_gamma(L, omega, p, robust_n_eff(rule, r, f))


# ---------------------------------------------------------------------------
# Deadline/staleness γ degradation (DESIGN.md §4.10)
#
# A deadline round looks like a PP round whose cohort the clock sampled:
# only the clients that beat the deadline (plus accepted late uploads)
# contribute fresh differences, so the variance-averaging count in the
# Thm 4.1 view is the expected arrivals r_eff = arrive_frac·n, not n. On
# top of that, an accepted upload that is τ rounds stale diffs against an
# anchor τ rounds old: under L-smoothness its second moment grows with the
# iterate drift ‖x^{k+1} − x^{k−τ+1}‖² ≲ (1+τ)·Σ‖x^{j+1} − x^j‖², which we
# book as a (1 + τ̄) inflation of the compressor-noise term — the same
# conservative substitution device as robust_n_eff, NOT a theorem from the
# paper (MARINA's analysis leaves asynchrony to future work), so the helper
# is explicitly labeled heuristic. At arrive_frac = 1, staleness = 0 it
# reduces exactly to marina_gamma.
# ---------------------------------------------------------------------------


def async_marina_gamma(
    L: float,
    omega: float,
    p: float,
    n: int,
    arrive_frac: float = 1.0,
    staleness: float = 0.0,
) -> float:
    """Heuristic deadline-MARINA stepsize, degrading with the observed
    participation and anchor staleness:

        γ = 1 / ( L (1 + sqrt((1−p) ω (1+τ̄) / (p · max(1, ā·n)))) )

    with ā = ``arrive_frac`` (the fraction of clients whose upload made the
    round — :attr:`AsyncStepMetrics.uploaded`/n averaged over rounds) and
    τ̄ = ``staleness`` (mean anchor age, ``staleness_mean``). Equals
    :func:`marina_gamma` at ā = 1, τ̄ = 0; heuristic otherwise (see the
    section comment)."""
    if not 0.0 <= arrive_frac <= 1.0:
        raise ValueError("arrive_frac must be in [0, 1]")
    if staleness < 0.0:
        raise ValueError("staleness must be non-negative")
    n_eff = max(1.0, arrive_frac * n)
    inflated = omega * (1.0 + staleness)
    return 1.0 / (L * (1.0 + math.sqrt((1.0 - p) * inflated / (p * n_eff))))


def marina_iteration_bound(
    delta0: float, L: float, omega: float, p: float, n: int, eps: float
) -> float:
    """Thm 2.1 iteration count K = 2Δ₀/(γ ε²) to reach E‖∇f‖² ≤ ε²."""
    return 2.0 * delta0 / (marina_gamma(L, omega, p, n) * eps**2)


def marina_comm_per_worker(d: int, zeta: float, p: float, K: float) -> float:
    """Expected communicated coordinates per worker (eq. 19): d + K(pd + (1-p)ζ)."""
    return d + K * (p * d + (1.0 - p) * zeta)
