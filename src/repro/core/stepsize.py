"""Theory stepsizes and iteration bounds from the paper's theorems.

These are the *exact* admissible stepsizes of Theorems 2.1, 2.2, 3.1/3.2, 4.1 —
the experiments in §5 / Appendix A run MARINA and DIANA with these theoretical
choices, and our reproduction benchmarks do the same.
"""

from __future__ import annotations

import math


def marina_gamma(L: float, omega: float, p: float, n: int) -> float:
    """Thm 2.1:  γ ≤ 1 / ( L (1 + sqrt((1-p) ω / (p n))) )."""
    return 1.0 / (L * (1.0 + math.sqrt((1.0 - p) * omega / (p * n))))


def marina_gamma_pl(L: float, omega: float, p: float, n: int, mu: float) -> float:
    """Thm 2.2:  γ ≤ min{ 1/(L(1+sqrt(2(1-p)ω/(pn)))), p/(2µ) }."""
    g1 = 1.0 / (L * (1.0 + math.sqrt(2.0 * (1.0 - p) * omega / (p * n))))
    return min(g1, p / (2.0 * mu))


def vr_marina_gamma(
    L: float, calL: float, omega: float, p: float, n: int, b_prime: int
) -> float:
    """Thm 3.1/3.2:  γ ≤ 1 / ( L + sqrt((1-p)/(pn) (ω L² + (1+ω) 𝓛²/b')) )."""
    inner = (1.0 - p) / (p * n) * (omega * L**2 + (1.0 + omega) * calL**2 / b_prime)
    return 1.0 / (L + math.sqrt(inner))


def pp_marina_gamma(L: float, omega: float, p: float, r: int) -> float:
    """Thm 4.1:  γ ≤ 1 / ( L (1 + sqrt((1-p)(1+ω)/(p r))) )."""
    return 1.0 / (L * (1.0 + math.sqrt((1.0 - p) * (1.0 + omega) / (p * r))))


def diana_alpha(omega: float) -> float:
    """DIANA shift learning rate α ≤ 1/(1+ω) (Mishchenko et al. 2019)."""
    return 1.0 / (1.0 + omega)


def diana_gamma(L: float, omega: float, n: int) -> float:
    """Non-convex DIANA stepsize (Li & Richtárik 2020, simplified constants):

    γ = 1 / ( L (1 + (1+ω) sqrt(ω/n) · c) ), c = O(1). We use c = 2 which satisfies
    the admissibility condition of their Theorem 4.1 specialization.
    """
    return 1.0 / (L * (1.0 + 2.0 * (1.0 + omega) * math.sqrt(omega / n) + 2.0 * omega / n))


def marina_iteration_bound(
    delta0: float, L: float, omega: float, p: float, n: int, eps: float
) -> float:
    """Thm 2.1 iteration count K = 2Δ₀/(γ ε²) to reach E‖∇f‖² ≤ ε²."""
    return 2.0 * delta0 / (marina_gamma(L, omega, p, n) * eps**2)


def marina_comm_per_worker(d: int, zeta: float, p: float, K: float) -> float:
    """Expected communicated coordinates per worker (eq. 19): d + K(pd + (1-p)ζ)."""
    return d + K * (p * d + (1.0 - p) * zeta)
