"""Deadline-cohort MARINA: straggler-tolerant rounds on the carry table.

The bridge from PP-MARINA to asynchrony (ROADMAP "Asynchronous /
straggler-tolerant rounds", DESIGN.md §4.10): the server closes every
compressed round after a fixed ``deadline``; clients whose compute time
(drawn from a :class:`repro.core.roundtime.RoundTimeModel`) beats it upload
the compressed difference against their carry anchor, clients that miss are
treated EXACTLY like PP non-participants / dropped clients — Δ̂_i = 0 on the
wire (the mean then contributes the server's anchor h_i back), no h refresh,
no bits booked. This generalizes the static-prefix ``drop`` fault of
DESIGN.md §4.9 to a time-driven, varying-size cohort.

Stale-difference acceptance: a client that misses round k by τ =
⌈T_i/deadline⌉ − 1 rounds keeps computing and its upload LANDS at round
k + τ. If τ ≤ ``tau_max`` the server accepts it there: the payload is
∇f_i(x^{k+1}) − h_i against the anchor the client actually diffed (its row
was pinned while in flight, so server and client agree), and the per-client
round ``tag`` records how old each anchor is. If τ > tau_max the client
abandons at the deadline (the staleness bound is public) and rejoins idle
next round — which is what makes a permanently-slow client with
``tau_max=0`` IDENTICAL to the static ``drop`` fault. Sync rounds (c_k ~
Be(p)) stay the rendezvous: every client finishes, in-flight work is
discarded, all anchors refresh, wall clock pays the slowest client.

Equivalence contracts (enforced by tests + scripts/check_async.py):

* deadline never missed  ⇒ bit-identical to ``Marina(carry=True)`` — the
  (k_bern, k_q) key split is untouched (time randomness rides
  :data:`repro.core.roundtime.TIME_FOLD`) and the diff rows coincide;
* fixed slow set always missing, ``tau_max=0``  ⇒  bit-identical to
  ``Marina(carry=True, faults=FaultSpec("drop", ids=slow))``.

Tree path only (`engine=None` semantics): the reference estimator the mesh
and bench layers are checked against, like ``_decompress_mean``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .faults import FaultSpec
from .marina import (
    GradFn,
    _compressed_delta,
    _per_worker_grads,
    _round_bits,
    tree_dim,
)
from .compressors import Compressor
from .roundtime import TIME_FOLD, RoundTimeModel
from .tree_util import tree_axpy, tree_mean_axis0, tree_norm

PyTree = Any


class AsyncStepMetrics(NamedTuple):
    grad_est_norm: jax.Array   # ‖g^{k+1}‖ (the estimator driving the step)
    bits_per_worker: jax.Array # fleet uplink / n: uploaded·ζ_Q on deadline
                               # rounds (only arrived payloads bill), 32d sync
    sync_round: jax.Array      # c_k (1 = dense rendezvous round)
    wall_clock_s: jax.Array    # simulated round duration (server view)
    uploaded: jax.Array        # compressed payloads accepted this round
    staleness_mean: jax.Array  # mean anchor age over clients, in rounds
    staleness_max: jax.Array   # oldest anchor age (the γ-rule dial)
    down_bits: jax.Array       # dense 32d estimator broadcast every round


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AsyncMarinaState:
    params: PyTree      # lookahead iterate x^{k+1} (carry convention)
    g: PyTree           # server estimator g^k
    step: jax.Array
    h: PyTree           # (n,)-stacked carry anchors, pinned while in flight
    tag: jax.Array      # (n,) i32: round whose lookahead produced h_i
                        # (−1 = init; fresh at entry to round k means k−1)
    pend_g: PyTree      # (n,)-stacked in-flight gradients (late uploads)
    arrive: jax.Array   # (n,) i32: round the in-flight upload lands; −1 idle
    born: jax.Array     # (n,) i32: round the in-flight compute started; −1


def _where_rows(mask: jax.Array, a: PyTree, b: PyTree) -> PyTree:
    """Row-select between two worker-stacked trees on a (n,) bool mask."""
    return jax.tree.map(
        lambda ta, tb: jnp.where(
            mask.reshape((-1,) + (1,) * (ta.ndim - 1)), ta, tb
        ),
        a, b,
    )


@dataclasses.dataclass
class DeadlineMarina:
    """MARINA with deadline cohorts and stale-difference acceptance.

    ``times`` draws each round's per-client compute times; ``deadline`` is
    the server's round budget; ``tau_max`` the staleness bound on accepted
    late uploads (0 = deadline misses are pure PP non-participation).
    Carry-only by construction — the deadline substitution IS the carry
    table (see the module docstring for the drop/PP equivalences)."""

    grad_fn: GradFn
    compressor: Compressor
    gamma: float
    p: float
    deadline: float
    times: RoundTimeModel = RoundTimeModel()
    tau_max: int = 0

    def __post_init__(self):
        if self.deadline <= 0.0:
            raise ValueError("deadline must be positive")
        if self.tau_max < 0:
            raise ValueError("tau_max must be non-negative")

    def static_miss_faults(self) -> "FaultSpec | None":
        """The equivalent static ``drop`` FaultSpec when the slow set ALWAYS
        misses and late uploads are never accepted — the reference the
        equivalence tests run ``Marina(carry=True)`` with. None when the
        configuration is not statically reducible (no fixed slow set, or a
        staleness window that admits their uploads)."""
        if not self.times.slow_ids or self.tau_max > 0:
            return None
        return FaultSpec("drop", ids=self.times.slow_ids)

    def init(self, params: PyTree, batches: PyTree) -> AsyncMarinaState:
        n = jax.tree.leaves(batches)[0].shape[0]
        grads = _per_worker_grads(self.grad_fn, params, batches)
        g0 = tree_mean_axis0(grads)
        x1 = tree_axpy(-self.gamma, g0, params)
        return AsyncMarinaState(
            params=x1, g=g0, step=jnp.zeros((), jnp.int32), h=grads,
            tag=jnp.full((n,), -1, jnp.int32),
            pend_g=jax.tree.map(jnp.zeros_like, grads),
            arrive=jnp.full((n,), -1, jnp.int32),
            born=jnp.full((n,), -1, jnp.int32),
        )

    def step(self, state: AsyncMarinaState, key: jax.Array, batches: PyTree):
        n = jax.tree.leaves(batches)[0].shape[0]
        k = state.step
        # the Marina carry key discipline, untouched: (k_bern, k_q) split,
        # side-channel randomness via fold_in constants only.
        k_bern, k_q = jax.random.split(key)
        c_k = jax.random.bernoulli(k_bern, self.p)
        k_t = jax.random.fold_in(key, TIME_FOLD)
        times = self.times.sample(k_t, n)
        d = tree_dim(state.params)
        D = jnp.float32(self.deadline)

        # the one backprop of the round at the lookahead point x^{k+1}
        # (busy clients' rows are computed too — simulation convenience,
        # their values are never consumed)
        grads = _per_worker_grads(self.grad_fn, state.params, batches)

        idle = state.arrive < 0            # free to start this round
        arriving = state.arrive == k       # late upload lands now
        busy = state.arrive > k            # still crunching an older round

        def sync_branch(_):
            # rendezvous: in-flight work is discarded, every client ships
            # the dense gradient, all anchors refresh, tags reset.
            g_next = tree_mean_axis0(grads)
            # busy clients finish (or abandon) their in-flight rounds
            # before computing the sync gradient: ≈ (arrive − k) extra
            # deadline windows on top of this round's draw.
            residual = jnp.maximum(state.arrive - k, 0).astype(jnp.float32)
            wall = jnp.max(times + residual * D)
            return (
                g_next, grads,
                jnp.broadcast_to(k, (n,)).astype(jnp.int32),
                jax.tree.map(jnp.zeros_like, grads),
                jnp.full((n,), -1, jnp.int32),
                jnp.full((n,), -1, jnp.int32),
                wall, jnp.asarray(n, jnp.int32),
            )

        def deadline_branch(_):
            on_time = idle & (times <= D)
            # staleness of a missed upload: it lands τ windows late
            tau = jnp.ceil(times / D).astype(jnp.int32) - 1
            pending = idle & (times > D) & (tau <= self.tau_max)

            contrib = on_time | arriving
            # accepted rows diff against the anchor BOTH sides hold (in-
            # flight rows were pinned); everyone else's row is h_i − h_i = 0
            # — exactly the zero-row carry substitution of the drop fault.
            up_src = _where_rows(
                on_time, grads, _where_rows(arriving, state.pend_g, state.h)
            )
            diffs = jax.tree.map(jnp.subtract, up_src, state.h)
            delta = _compressed_delta(
                self.compressor, None, k_q, diffs, state.params, n
            )
            g_next = jax.tree.map(jnp.add, state.g, delta)

            h_next = _where_rows(contrib, up_src, state.h)
            tag_next = jnp.where(
                on_time, k, jnp.where(arriving, state.born, state.tag)
            )
            pend_next = _where_rows(pending, grads, state.pend_g)
            arrive_next = jnp.where(
                pending, k + tau,
                jnp.where(arriving, -1, state.arrive),
            )
            born_next = jnp.where(
                pending, k, jnp.where(arriving, -1, state.born)
            )
            # server view of the round: the deadline is only paid when
            # someone is late/in flight; an all-on-time round closes at the
            # slowest on-time upload (the synchronous wall clock).
            all_on_time = jnp.all(on_time)
            wall = jnp.where(
                all_on_time, jnp.max(jnp.where(idle, times, 0.0)), D
            )
            uploaded = jnp.sum(contrib.astype(jnp.int32))
            return (
                g_next, h_next, tag_next, pend_next, arrive_next,
                born_next, wall, uploaded,
            )

        g_next, h_next, tag_next, pend_next, arrive_next, born_next, wall, \
            uploaded = jax.lax.cond(c_k, sync_branch, deadline_branch, None)
        # the iterate update happens ONCE, on the cond output — the same op
        # sequence as Marina._step_carry, which is what keeps the p_miss=0
        # trajectory bit-identical (XLA fuses an in-branch axpy differently).
        x_next = tree_axpy(-self.gamma, g_next, state.params)
        new_state = AsyncMarinaState(
            params=x_next, g=g_next, step=k + 1, h=h_next, tag=tag_next,
            pend_g=pend_next, arrive=arrive_next, born=born_next,
        )

        bits_dense = jnp.asarray(32.0 * d)
        zeta = _round_bits(self.compressor, None, state.params, n)
        # fleet-total / n (the PP ledger convention, DESIGN.md §4.8): only
        # payloads that arrived bill — uploaded·ζ_Q of wire.py, exactly.
        bits_q = uploaded.astype(jnp.float32) * zeta / n
        age = (new_state.step - 1) - new_state.tag
        metrics = AsyncStepMetrics(
            grad_est_norm=tree_norm(new_state.g),
            bits_per_worker=jnp.where(c_k, bits_dense, bits_q),
            sync_round=c_k.astype(jnp.int32),
            wall_clock_s=wall,
            uploaded=uploaded,
            staleness_mean=jnp.mean(age.astype(jnp.float32)),
            staleness_max=jnp.max(age),
            down_bits=bits_dense,
        )
        return new_state, metrics
