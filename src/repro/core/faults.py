"""Client fault injection for the federated round pipeline (DESIGN.md §4.9).

The harness that turns "honest lab conditions" into "real federated clients":
:class:`FaultSpec` is a static dial on the MARINA-family optimizers (and on
``launch.distributed.build_train_steps``) that rewrites the worker-stacked
uplink *payloads* each round. Faulty clients are the fixed id prefix
``{0, …, f−1}`` with ``f = ⌊frac·n⌋`` — a deterministic adversary, so every
trajectory is reproducible and tests can assert exact semantics.

Attacks (what the server receives from a faulty client):

* ``sign_flip``  — the negated, ``scale``-amplified honest payload
                   (−scale·Δ_i): the classic estimator-reversal attack.
* ``mean_shift`` — the *omniscient* attack: every Byzantine row is
                   −scale·mean(honest rows), steering the plain mean to
                   ``(h − f·scale·h)/n`` — sign-reversed for scale large
                   enough — while staying perfectly coordinated.
* ``nan``        — NaN payloads: one round poisons a mean-aggregated
                   estimator forever (the robustness motivation, and the
                   trainer's non-finite-guard regression input).
* ``garbage``    — i.i.d. Gaussian noise of standard deviation ``scale``.
* ``drop``       — stragglers: the client computed but never uploaded.
                   Requires ``carry=True``: the server substitutes the
                   carry-table row h_i, which on the difference wire is just
                   Δ̂_i = 0 (zero rows — :func:`zero_rows`), skips the row's
                   h refresh (the anchor must stay what the server last saw)
                   and books uplink bits only for the clients that uploaded.
* ``none``       — identity (the f=0 grid baseline).

Label-flipping — a *data* poisoning attack, not a payload one — is provided
as :func:`flip_binclass_labels` for the benchmark problems: the faulty
clients honestly follow the protocol on maliciously mislabeled local data.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

ATTACKS = ("none", "sign_flip", "mean_shift", "nan", "garbage", "drop")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static description of the per-round client faults.

    ``attack`` is one of :data:`ATTACKS`; ``frac`` the faulty fraction of
    the fleet (ids ``< ⌊frac·n⌋`` are faulty — fixed, so partial
    participation naturally samples cohorts with a varying Byzantine count);
    ``scale`` the attack amplitude (sign_flip/mean_shift multiplier,
    garbage standard deviation). ``ids`` optionally names the faulty set
    EXPLICITLY (overriding the ``frac`` prefix) — the crash/deadline
    machinery needs arbitrary dead-client sets, not just prefixes: a worker
    process that dies on the mesh takes its device rows with it, wherever
    they sit (DESIGN.md §4.10). Frozen/hashable: safe as jit-static config.
    """

    attack: str = "sign_flip"
    frac: float = 0.25
    scale: float = 1.0
    ids: "tuple | None" = None

    def __post_init__(self):
        if self.attack not in ATTACKS:
            raise ValueError(
                f"unknown attack {self.attack!r}, expected {ATTACKS}"
            )
        if not 0.0 <= self.frac <= 1.0:
            raise ValueError("faulty fraction must be in [0, 1]")
        if self.ids is not None:
            ids = tuple(self.ids)
            if any((not isinstance(i, int)) or i < 0 for i in ids):
                raise ValueError(
                    f"faulty ids must be non-negative ints: {ids!r}"
                )
            if len(set(ids)) != len(ids):
                raise ValueError(f"faulty ids has duplicates: {ids!r}")
            object.__setattr__(self, "ids", tuple(sorted(ids)))

    def n_faulty(self, n: int) -> int:
        """Faulty client count of an n-client fleet: |ids| when the set is
        explicit (ids ≥ n don't exist in the fleet), else f = ⌊frac·n⌋."""
        if self.ids is not None:
            return sum(1 for i in self.ids if i < n)
        return int(self.frac * n)

    def byz_mask(self, ids: jax.Array, n: int) -> jax.Array:
        """Boolean fault mask for the given client-id rows: membership in
        the explicit set when one is named, else the prefix ids < f. ``ids``
        may be traced (a PP cohort) — the faulty set itself is static."""
        if self.ids is not None:
            if not self.ids:
                return jnp.zeros(ids.shape, bool)
            hits = ids[..., None] == jnp.asarray(self.ids)
            return jnp.any(hits, axis=-1)
        return ids < self.n_faulty(n)


def _row_mask(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """(rows,) bool → broadcastable (rows, 1, …, 1) for the leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


def zero_rows(trees: PyTree, mask: jax.Array) -> PyTree:
    """Zero the masked leading-axis rows of every leaf — the dropped-client
    substitution: Δ̂_i = 0 is exactly "the server reuses carry row h_i"."""
    return jax.tree.map(
        lambda t: jnp.where(_row_mask(mask, t), jnp.zeros((), t.dtype), t),
        trees,
    )


def inject(
    spec: "FaultSpec | None",
    key: jax.Array,
    trees: PyTree,
    ids: jax.Array,
    n: int,
) -> PyTree:
    """Rewrite the faulty rows of a worker-stacked payload tree.

    ``trees`` carries the per-client uplink quantity on its leading axis
    (gradients on sync rounds, differences on compressed rounds); ``ids``
    are the client ids of those rows (``arange(n)`` for a full fleet, the
    cohort ``sel`` under partial participation). ``drop``/``none`` are
    identities here — dropping is a *transport* fault, handled by the
    optimizer via :func:`zero_rows` + carry bookkeeping, and it must NOT
    corrupt sync rounds (the dense rendezvous all clients attend)."""
    if spec is None or spec.attack in ("none", "drop"):
        return trees
    if spec.n_faulty(n) == 0:
        return trees
    mask = spec.byz_mask(ids, n)

    if spec.attack == "sign_flip":
        return jax.tree.map(
            lambda t: jnp.where(
                _row_mask(mask, t), (-spec.scale * t).astype(t.dtype), t
            ),
            trees,
        )
    if spec.attack == "mean_shift":
        honest = jnp.maximum(
            jnp.sum((~mask).astype(jnp.float32)), 1.0
        )

        def shift(t):
            hmean = (
                jnp.sum(
                    t.astype(jnp.float32) * _row_mask(~mask, t), axis=0
                )
                / honest
            )
            byz = (-spec.scale * hmean).astype(t.dtype)
            return jnp.where(_row_mask(mask, t), byz[None], t)

        return jax.tree.map(shift, trees)
    if spec.attack == "nan":
        return jax.tree.map(
            lambda t: jnp.where(
                _row_mask(mask, t), jnp.asarray(jnp.nan, t.dtype), t
            ),
            trees,
        )
    # garbage
    leaves, treedef = jax.tree.flatten(trees)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        jnp.where(
            _row_mask(mask, t),
            (spec.scale * jax.random.normal(k, t.shape)).astype(t.dtype),
            t,
        )
        for k, t in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, noisy)


def flip_binclass_labels(data, n_byz: int):
    """Label-flip data poisoning for the binary-classification problems:
    negate the ±1 labels of the first ``n_byz`` clients (the faulty prefix)
    and leave the features alone. The poisoned clients then run the honest
    protocol on bad data — a fault no payload-level defense can see, only a
    GAR can bound. Works on any NamedTuple dataset with a (n, m) ``y``."""
    return data._replace(y=data.y.at[:n_byz].multiply(-1))
