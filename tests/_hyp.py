"""Thin hypothesis compatibility shim.

The property tests use ``hypothesis`` when it is installed; on bare containers
(the optional dependency is not baked in) they fall back to a deterministic
sampled grid so the suite still *collects and runs* instead of erroring at
import time. The fallback draws a fixed number of pseudo-random samples per
strategy from a seeded RNG — weaker than real shrinking/fuzzing, but it keeps
every property exercised.

Usage (drop-in for the common subset)::

    from _hyp import given, settings, st
"""

from __future__ import annotations

import random
import zlib

try:  # pragma: no cover - exercised implicitly by which branch imports
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import pytest

    _FALLBACK_EXAMPLES = 8

    class _IntStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def sample(self, rng: random.Random) -> int:
            # always exercise the endpoints, then uniform draws
            return rng.choice(
                [self.lo, self.hi, rng.randint(self.lo, self.hi)]
            )

    class st:  # noqa: N801 - mimics hypothesis.strategies namespace
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

    def settings(**_kw):
        """No-op decorator (max_examples/deadline have no fallback meaning)."""

        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        """Parametrize over a deterministic sample grid of the strategies."""

        def deco(fn):
            # crc32, not hash(): stable across processes/PYTHONHASHSEED so
            # collected case IDs are reproducible (xdist, --last-failed)
            rng = random.Random(0xC0FFEE ^ zlib.crc32(fn.__name__.encode()))
            cases = [
                {k: s.sample(rng) for k, s in sorted(strategies.items())}
                for _ in range(_FALLBACK_EXAMPLES)
            ]
            ids = ["-".join(f"{k}{v}" for k, v in c.items()) for c in cases]

            @pytest.mark.parametrize("_hyp_case", cases, ids=ids)
            def wrapper(_hyp_case, *args, **kw):
                return fn(*args, **kw, **_hyp_case)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
