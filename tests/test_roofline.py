"""Roofline analyzer unit tests: HLO collective parsing with ring-algorithm
byte accounting, shape parsing, and term arithmetic."""

import numpy as np
import pytest

from repro.roofline import HW, RooflineReport, CollectiveStats, collective_bytes_from_hlo


HLO = """
HloModule jit_step
%fused (x: bf16[128,256]) -> bf16[128,256] {
  %ag = bf16[16,128,256] all-gather(%x), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
  %ar = f32[1024] all-reduce(%y), replica_groups=[32,16]<=[512], to_apply=%add
  %rs = f32[64] reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = bf16[8,32] all-to-all(%w), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[100] collective-permute(%v), source_target_pairs={{0,1}}
  %ag2 = (bf16[2,4], bf16[2,4]) all-gather-start(%q), replica_groups={{0,1}}
  %agd = bf16[2,4] all-gather-done(%ag2)
}
"""


def test_collective_parsing_counts_and_bytes():
    st = collective_bytes_from_hlo(HLO, 512)
    assert st.counts["all-gather"] == 2   # ag + ag2 (start form), done skipped
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["all-to-all"] == 1
    assert st.counts["collective-permute"] == 1

    ag = 16 * 128 * 256 * 2          # bf16 output
    want_ag = ag * 15 / 16
    ar = 1024 * 4
    want_ar = 2 * ar * 15 / 16       # group size 16 from [32,16] iota form
    rs = 64 * 4
    want_rs = rs * 3
    a2a = 8 * 32 * 2 * 7 / 8
    cp = 100 * 4
    ag2 = 2 * (2 * 4 * 2) * 1 / 2    # tuple of two bf16[2,4], group 2
    total = want_ag + want_ar + want_rs + a2a + cp + ag2
    np.testing.assert_allclose(st.per_device_bytes, total, rtol=1e-6)


def test_group_size_defaults_to_world():
    st = collective_bytes_from_hlo(
        "%ar = f32[10] all-reduce(%x), to_apply=%add\n", 8
    )
    np.testing.assert_allclose(st.per_device_bytes, 2 * 40 * 7 / 8)


def test_report_terms_and_dominant():
    rep = RooflineReport(
        flops_per_device=197e12,       # exactly 1s of compute
        bytes_per_device=819e9 * 2,    # 2s of memory
        collective=CollectiveStats(per_device_bytes=50e9 * 3),  # 3s
        n_devices=256,
        model_flops_total=197e12 * 256 * 0.5,
    )
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(2.0)
    assert rep.collective_s == pytest.approx(3.0)
    assert rep.dominant == "collective"
    assert rep.useful_ratio == pytest.approx(0.5)
    assert rep.analytic_compute_s == pytest.approx(0.5)


def test_analytic_compute_can_dominate():
    """Scan-heavy programs under-report HLO flops; the analytic term guards
    the dominant-term call (DESIGN/EXPERIMENTS note)."""
    rep = RooflineReport(
        flops_per_device=1e9,         # undercounted
        bytes_per_device=819e9 * 0.1,
        collective=CollectiveStats(per_device_bytes=50e9 * 0.05),
        n_devices=2,
        model_flops_total=197e12 * 2 * 5.0,
    )
    assert rep.dominant == "compute"
