"""Multi-process launch tests (ISSUE 7, `multiproc` marker).

The worker program below is ONE program run two ways through the same
bring-up path (`topology.spawn_local_cluster` → `init_from_env` →
`jax.distributed.initialize` with gloo CPU collectives):

* 2 processes × 2 fake devices — the worker ("data") axis crosses the OS
  process boundary, so every payload collective genuinely leaves the
  process (the local cluster's simulated dcn);
* 1 process × 4 fake devices — the historical fake-device simulation.

Both runs execute a sync round plus three compressed grad-carry MARINA
rounds on identical data (all randomness flows from threefry keys, which
are layout-independent) and print the parameter/estimator trajectory and
the link tiers the transport booked. The assertions:

1. the trajectories agree across process layouts (the refactor's
   trajectory-equality contract extends across the process boundary — only
   collective reduction order may differ, so tolerance is float32-tight,
   not bitwise);
2. every rank of the 2-process run agrees exactly (same global program);
3. the ledger books the SAME bits under "dcn" cross-process that the
   single-process run books under "loopback" — the wire cost is a property
   of the algorithm, the tier is a property of the fabric.

Excluded from tier-1 (`-m "not multiproc"` in pytest.ini): each run
compiles the reduced model per process. CI runs these in the dedicated
`multiproc` job. Run locally:  pytest -m multiproc tests/test_multiproc.py
"""

import re

import numpy as np
import pytest

from repro.launch import topology as topo
from repro.launch.topology import spawn_local_cluster, run_with_recovery
from repro.launch.transport import RetryPolicy

pytestmark = pytest.mark.multiproc


_WORKER_PROG = r"""
from repro.launch import topology as topo
pid, nproc = topo.init_from_env()

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.launch import sharding as shd
from repro.launch.distributed import build_train_steps
from repro.models import init_params, reduced

n_dev = jax.device_count()
assert n_dev == 4, n_dev
mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

t = topo.detect_topology(mesh)
expect = "dcn" if nproc > 1 else "loopback"
assert t.tier_for_axes(("data",)) == expect, (t.axis_tiers, nproc)
assert t.n_processes == nproc

arch = get_arch("qwen1.5-0.5b")
arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=64))
bundle = build_train_steps(
    arch, mesh, multi_pod=False, global_batch=2 * n_dev, seq_len=32,
    gamma=0.1, dtype=jnp.float32, grad_carry=True,
)
cfg = arch.model
rep = NamedSharding(mesh, P())

# all state is materialized INSIDE jit from threefry keys with replicated
# output sharding: bit-identical values regardless of the process layout,
# and globally addressable on every rank
params = jax.jit(
    lambda: init_params(jax.random.PRNGKey(0), cfg, jnp.float32),
    out_shardings=rep,
)()
g0 = jax.tree.map(jnp.zeros_like, params)
h0 = jax.tree.map(lambda p: jnp.zeros((n_dev, *p.shape), p.dtype), params)
toks = jax.jit(
    lambda: jax.random.randint(
        jax.random.PRNGKey(1), (n_dev, 2, 32), 0, cfg.vocab_size
    ),
    out_shardings=rep,
)()

# the step fns are jitted with explicit in_shardings, and multi-process jit
# refuses to silently reshard committed arrays — place the state exactly
# where the round assembly expects it (same shardings build_train_steps
# computed: fsdp off and replicate_params off => inner batch axis None)
tr = bundle.transport
p_shard = tr.param_shardings
wlead = tr.waxes if len(tr.waxes) > 1 else tr.waxes[0]
h_shard = jax.tree.map(
    lambda ns: NamedSharding(mesh, P(wlead, *ns.spec)), p_shard
)
b_shard = NamedSharding(mesh, shd.batch_spec(tr.waxes, None, 3))
params = jax.device_put(params, p_shard)
g0 = jax.device_put(g0, p_shard)
h0 = jax.device_put(h0, h_shard)
batch = {"tokens": jax.device_put(toks, b_shard)}


def checksum(tree):
    fp = jax.jit(
        lambda s: sum(jnp.sum(leaf) for leaf in jax.tree.leaves(s)),
        out_shardings=rep,
    )(tree)
    return float(fp)


traj = []
with bundle.mesh:
    fs, _ = bundle.fns["sync_step"]
    fc, _ = bundle.fns["compressed_step"]
    x, g, h = fs(params, g0, h0, batch)
    traj += [checksum(x), checksum(g)]
    for i in range(3):
        # numpy keys: host-consistent across ranks, no committed-device traps
        x, g, h = fc(x, g, h, batch, np.asarray(jax.random.PRNGKey(10 + i)))
        traj += [checksum(x), checksum(g)]

led = bundle.transport.ledger
up_tiers = sorted({tier for (_s, d, tier, _k) in led.bits if d == "up"})
assert up_tiers == [expect], (up_tiers, expect)
print("TIERS", ",".join(up_tiers))
print("UPBITS", repr(led.total_bits(direction="up")))
print("TRAJ", " ".join(f"{v:.9e}" for v in traj), flush=True)
"""


_CRASH_PROG = r"""
from repro.launch import topology as topo
pid, nproc = topo.init_from_env()

import dataclasses
import os
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.core.faults import FaultSpec
from repro.launch import sharding as shd
from repro.launch.distributed import build_train_steps
from repro.models import init_params, reduced

n_dev = jax.device_count()
assert n_dev == 4, n_dev
mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

# recovery contract: rounds < resume replay fault-free (the fleet completed
# them before the crash), rounds >= resume treat the dead clients as a
# static drop set — permanent deadline-missers on the carry table.
dead, resume = topo.recovery_from_env()
rounds = int(os.environ.get("MARINA_MP_ROUNDS", "6"))

arch = get_arch("qwen1.5-0.5b")
arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=64))


def make_bundle(faults):
    return build_train_steps(
        arch, mesh, multi_pod=False, global_batch=2 * n_dev, seq_len=32,
        gamma=0.1, dtype=jnp.float32, grad_carry=True, faults=faults,
    )


bundle = make_bundle(None)
faulted = make_bundle(FaultSpec("drop", ids=dead)) if dead else None
cfg = arch.model
rep = NamedSharding(mesh, P())

params = jax.jit(
    lambda: init_params(jax.random.PRNGKey(0), cfg, jnp.float32),
    out_shardings=rep,
)()
g0 = jax.tree.map(jnp.zeros_like, params)
h0 = jax.tree.map(lambda p: jnp.zeros((n_dev, *p.shape), p.dtype), params)
toks = jax.jit(
    lambda: jax.random.randint(
        jax.random.PRNGKey(1), (n_dev, 2, 32), 0, cfg.vocab_size
    ),
    out_shardings=rep,
)()

tr = bundle.transport
p_shard = tr.param_shardings
wlead = tr.waxes if len(tr.waxes) > 1 else tr.waxes[0]
h_shard = jax.tree.map(
    lambda ns: NamedSharding(mesh, P(wlead, *ns.spec)), p_shard
)
b_shard = NamedSharding(mesh, shd.batch_spec(tr.waxes, None, 3))
params = jax.device_put(params, p_shard)
g0 = jax.device_put(g0, p_shard)
h0 = jax.device_put(h0, h_shard)
batch = {"tokens": jax.device_put(toks, b_shard)}


def checksum(tree):
    fp = jax.jit(
        lambda s: sum(jnp.sum(leaf) for leaf in jax.tree.leaves(s)),
        out_shardings=rep,
    )(tree)
    return float(fp)


with bundle.mesh:
    fs, _ = bundle.fns["sync_step"]
    fc, _ = bundle.fns["compressed_step"]
    fcd = faulted.fns["compressed_step"][0] if faulted else None
    # round 0 is the dense sync rendezvous (all clients attend either way)
    x, g, h = fs(params, g0, h0, batch)
    print(f"TRAJ0 {checksum(x):.9e} {checksum(g):.9e}")
    print(f"{topo.HEARTBEAT} 0", flush=True)
    for k in range(1, rounds):
        topo.maybe_crash(pid, k)
        step = fcd if (fcd is not None and k >= resume) else fc
        x, g, h = step(x, g, h, batch, np.asarray(jax.random.PRNGKey(10 + k)))
        print(f"TRAJ{k} {checksum(x):.9e} {checksum(g):.9e}")
        print(f"{topo.HEARTBEAT} {k}", flush=True)

# per-trace uplink bits of each bundle's compressed scope: the faulted
# bundle must book only the surviving uploads ((n-f)/n of the fault-free)
print("UPFREE", repr(
    bundle.transport.ledger.total_bits(scope="compressed_step", direction="up")
))
if faulted is not None:
    print("UPDROP", repr(
        faulted.transport.ledger.total_bits(
            scope="compressed_step", direction="up"
        )
    ))
print("DONE", flush=True)
"""


def _parse(stdout: str, tag: str) -> str:
    m = re.search(rf"^{tag} (.+)$", stdout, re.M)
    assert m, f"no {tag} line in:\n{stdout[-2000:]}"
    return m.group(1)


def _run(num_processes: int, devices_per_process: int):
    results = spawn_local_cluster(
        _WORKER_PROG,
        num_processes=num_processes,
        devices_per_process=devices_per_process,
    )
    for r in results:
        assert r.returncode == 0, (
            f"rank failed ({num_processes}p):\n{r.stderr[-4000:]}"
        )
    return results


def test_two_process_compressed_carry_matches_single_process():
    mp = _run(num_processes=2, devices_per_process=2)
    sp = _run(num_processes=1, devices_per_process=4)

    # every rank of the 2-process run computed the same global trajectory
    assert _parse(mp[0].stdout, "TRAJ") == _parse(mp[1].stdout, "TRAJ")

    traj_mp = np.array([float(v) for v in _parse(mp[0].stdout, "TRAJ").split()])
    traj_sp = np.array([float(v) for v in _parse(sp[0].stdout, "TRAJ").split()])
    assert traj_mp.shape == traj_sp.shape == (8,)
    assert np.all(np.isfinite(traj_mp))
    # cross-process gloo collectives may reduce in a different order than the
    # single-process fused all-reduce — float32-tight, not bitwise
    np.testing.assert_allclose(traj_mp, traj_sp, rtol=1e-5, atol=1e-6)

    # same wire, different fabric: identical booked bits, re-tiered
    assert _parse(mp[0].stdout, "TIERS") == "dcn"
    assert _parse(sp[0].stdout, "TIERS") == "loopback"
    assert float(_parse(mp[0].stdout, "UPBITS")) == pytest.approx(
        float(_parse(sp[0].stdout, "UPBITS"))
    )


def _traj(stdout: str, k: int) -> np.ndarray:
    return np.array([float(v) for v in _parse(stdout, f"TRAJ{k}").split()])


def test_crash_recovery_matches_single_process_drop():
    """A worker killed mid-training on the 2-process gloo cluster must not
    stall the run: the resilient runner detects the death, kills the hung
    survivor, and relaunches with the crashed rank's clients as a static
    drop set from the first incomplete round. The recovered trajectory must
    match the single-process reference where those clients simply missed
    every deadline from that round on, and the drop rounds must book only
    the surviving uploads."""
    crash_round, rounds = 3, 6
    outcome, rec = run_with_recovery(
        _CRASH_PROG,
        num_processes=2,
        devices_per_process=2,
        extra_env={
            topo.CRASH_ENV: f"1@{crash_round}",
            "MARINA_MP_ROUNDS": str(rounds),
        },
        retry=RetryPolicy(timeout_s=540.0, retries=1, backoff_s=2.0),
    )
    assert outcome.crashed
    assert outcome.dead_ranks == (1,), [
        (r.returncode, r.stderr[-500:]) for r in outcome.results
    ]
    # rank 1 died at the top of round `crash_round`: the fleet completed
    # exactly the rounds before it
    assert outcome.last_round == crash_round - 1
    assert rec is not None and rec.returncode == 0, rec.stderr[-4000:]

    # reference: a straight single-process run with the same dead set from
    # the same round (no crash, no recovery machinery)
    ref = spawn_local_cluster(
        _CRASH_PROG,
        num_processes=1,
        devices_per_process=4,
        extra_env={
            topo.DEAD_ENV: "2,3",
            topo.RESUME_ENV: str(crash_round),
            "MARINA_MP_ROUNDS": str(rounds),
        },
    )[0]
    assert ref.returncode == 0, ref.stderr[-4000:]

    for k in range(rounds):
        np.testing.assert_allclose(
            _traj(rec.stdout, k), _traj(ref.stdout, k),
            rtol=1e-5, atol=1e-6, err_msg=f"round {k}",
        )
    # the recovery's replayed prefix reproduces what the 2-process fleet
    # actually computed before the crash. Looser than the recovery-vs-
    # reference check above: gloo collectives reduce in a different order
    # than the single-process fused all-reduce, and the g checksum sums
    # every parameter, compounding the reorder noise across rounds.
    for k in range(crash_round):
        np.testing.assert_allclose(
            _traj(outcome.results[0].stdout, k), _traj(rec.stdout, k),
            rtol=5e-5, atol=1e-6, err_msg=f"pre-crash round {k}",
        )

    # ledger: drop rounds book (n − f)/n of the fault-free uplink — only
    # the 2 surviving clients of 4 bill
    up_free = float(_parse(rec.stdout, "UPFREE"))
    up_drop = float(_parse(rec.stdout, "UPDROP"))
    assert up_free > 0
    assert up_drop == pytest.approx(up_free * 0.5)
