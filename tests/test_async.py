"""Tier-1 tests for the straggler-tolerant async path (DESIGN.md §4.10).

Covers the deadline-cohort equivalence contracts at test scale:

* p_miss = 0 ⇒ ``DeadlineMarina`` is BIT-identical to ``Marina(carry=True)``
  (the TIME_FOLD side channel never perturbs the (k_bern, k_q) split);
* a statically-slow set with tau_max = 0 is bit-identical to the same ids
  under ``FaultSpec("drop", ids=...)``;
* stale-difference acceptance: a late upload lands τ rounds later against
  the pinned anchor, refreshes it, and bills on the landing round;
* the wall-clock model, the uploaded·ζ_Q ledger drift guard (core metrics
  AND ``Transport.uplink_mean(uploaded_rows=...)``), the ``RoundTimeModel``
  statistics, the FaultSpec construction-time refusals, the atomic
  BENCH_pp.json read-merge-update, and the launch-layer retry/crash
  helpers (``RetryPolicy``/``retry_call``, heartbeat/env parsing).
"""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeadlineMarina,
    FaultSpec,
    Marina,
    RandK,
    RoundTimeModel,
    ServerAggregator,
    async_marina_gamma,
    marina_gamma,
)
from repro.core.problems import (
    make_synthetic_binclass,
    nonconvex_binclass_loss,
)

N, M, D = 5, 48, 20
GRAD = jax.grad(nonconvex_binclass_loss)


@pytest.fixture(scope="module")
def data():
    return make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)


def run_states(method, data, steps, seed=3):
    st = method.init(jnp.zeros((D,)), data)
    step = jax.jit(method.step)
    states, metrics = [], []
    for k in range(steps):
        st, met = step(st, jax.random.PRNGKey(seed * 100_000 + k), data)
        states.append(st)
        metrics.append(met)
    return states, metrics


def assert_bit_identical(sa, sb):
    for name in ("params", "g"):
        np.testing.assert_array_equal(
            np.asarray(getattr(sa, name)), np.asarray(getattr(sb, name)),
            err_msg=name,
        )


# ---------------------------------------------------------------------------
# equivalence contracts (the scripts/check_async.py gate at test scale)
# ---------------------------------------------------------------------------

def test_never_miss_deadline_bit_identical_to_full_participation(data):
    dm = DeadlineMarina(GRAD, RandK(k=3), 0.05, 0.3, deadline=1e9,
                        times=RoundTimeModel(dist="fixed", mean_s=1.0))
    ref = Marina(GRAD, RandK(k=3), 0.05, 0.3, carry=True)
    sa, ma = run_states(dm, data, 15)
    sb, mb = run_states(ref, data, 15)
    for a, b in zip(sa, sb):
        assert_bit_identical(a, b)
    # identical ledger: every round bills the full fleet on both sides
    assert [float(m.bits_per_worker) for m in ma] == \
        [float(m.bits_per_worker) for m in mb]


def test_static_slow_set_bit_identical_to_drop_fault(data):
    slow = (1, 3)
    dm = DeadlineMarina(
        GRAD, RandK(k=3), 0.05, 0.3, deadline=2.0,
        times=RoundTimeModel(dist="fixed", mean_s=1.0,
                             slow_ids=slow, slow_factor=8.0),
    )
    assert dm.static_miss_faults() == FaultSpec("drop", ids=slow)
    ref = Marina(GRAD, RandK(k=3), 0.05, 0.3, carry=True,
                 faults=FaultSpec("drop", ids=slow))
    sa, ma = run_states(dm, data, 15)
    sb, mb = run_states(ref, data, 15)
    for a, b in zip(sa, sb):
        assert_bit_identical(a, b)
    assert [float(m.bits_per_worker) for m in ma] == \
        [float(m.bits_per_worker) for m in mb]


def test_static_reduction_is_none_when_late_uploads_allowed():
    tm = RoundTimeModel(dist="fixed", slow_ids=(0,), slow_factor=8.0)
    m = DeadlineMarina(GRAD, RandK(k=3), 0.05, 0.3, deadline=2.0,
                       times=tm, tau_max=2)
    assert m.static_miss_faults() is None  # stale uploads DO land
    assert DeadlineMarina(GRAD, RandK(k=3), 0.05, 0.3, deadline=2.0
                          ).static_miss_faults() is None  # no fixed slow set


def test_deadline_validation():
    with pytest.raises(ValueError, match="deadline"):
        DeadlineMarina(GRAD, RandK(k=3), 0.05, 0.3, deadline=0.0)
    with pytest.raises(ValueError, match="tau_max"):
        DeadlineMarina(GRAD, RandK(k=3), 0.05, 0.3, deadline=1.0,
                       tau_max=-1)


# ---------------------------------------------------------------------------
# stale-difference acceptance + the wall-clock model
# ---------------------------------------------------------------------------

def test_late_upload_lands_and_refreshes_anchor(data):
    """Client 0 always takes 3 deadline windows: with tau_max=2 its upload
    lands 2 rounds after it started, refreshes its (pinned) anchor, and
    bills on the landing round; the server pays the deadline whenever
    anybody is late/in flight."""
    tm = RoundTimeModel(dist="fixed", mean_s=1.0, slow_ids=(0,),
                        slow_factor=3.0)
    m = DeadlineMarina(GRAD, RandK(k=3), 0.05, p=1e-9, deadline=1.0,
                       times=tm, tau_max=2)
    states, metrics = run_states(m, data, 6)
    uploaded = [int(mt.uploaded) for mt in metrics]
    # τ = ceil(3/1) − 1 = 2: client 0 starts at k, lands at k+2 — so rounds
    # alternate: miss (n−1), in-flight (n−1), landing (n−1 on-time + 1 late)
    assert uploaded[:6] == [N - 1, N - 1, N, N - 1, N - 1, N]
    # wall clock: the deadline is paid on every round with a miss/in-flight
    assert all(float(mt.wall_clock_s) == 1.0 for mt in metrics)
    # landing round: the late anchor refreshes to the round it was BORN
    # (k=0), so entering round 3 its age is (3−1) − 0 = 2 = tau_max
    assert int(metrics[2].staleness_max) == 2
    # while in flight the anchor tag is pinned at init (−1)
    assert int(states[0].tag[0]) == -1 and int(states[1].tag[0]) == -1
    assert int(states[2].tag[0]) == 0
    assert int(states[2].arrive[0]) == -1  # idle again after landing


def test_all_on_time_round_closes_at_slowest_upload(data):
    tm = RoundTimeModel(dist="fixed", mean_s=0.7)
    m = DeadlineMarina(GRAD, RandK(k=3), 0.05, p=1e-9, deadline=1.0,
                       times=tm)
    _, metrics = run_states(m, data, 3)
    # nobody misses: the round closes at max(T_i) = 0.7, not the deadline
    assert all(float(mt.wall_clock_s) == pytest.approx(0.7)
               for mt in metrics)
    assert all(int(mt.uploaded) == N for mt in metrics)
    assert all(int(mt.staleness_max) == 0 for mt in metrics)


def test_sync_round_is_a_rendezvous(data):
    tm = RoundTimeModel(dist="fixed", mean_s=1.0, slow_ids=(0,),
                        slow_factor=3.0)
    m = DeadlineMarina(GRAD, RandK(k=3), 0.05, p=1.0 - 1e-9, deadline=1.0,
                       times=tm, tau_max=2)
    states, metrics = run_states(m, data, 2)
    for st, mt in zip(states, metrics):
        assert int(mt.sync_round) == 1
        assert int(mt.uploaded) == N
        # every anchor refreshes, nothing stays in flight
        assert np.all(np.asarray(st.arrive) == -1)
        assert float(mt.wall_clock_s) == pytest.approx(3.0)  # slowest client
        assert float(mt.bits_per_worker) == pytest.approx(32.0 * D)


# ---------------------------------------------------------------------------
# ledger drift guards (uploaded·ζ_Q — core metrics and the mesh transport)
# ---------------------------------------------------------------------------

def test_deadline_bits_scale_with_arrivals(data):
    """Compressed-round bits: miss rounds bill (n−f)/n of the full-fleet
    booking, bit-for-bit against the never-miss run (same ζ_Q source)."""
    kw = dict(gamma=0.05, p=1e-9, deadline=2.0)
    full = DeadlineMarina(GRAD, RandK(k=3), times=RoundTimeModel(
        dist="fixed", mean_s=1.0), **kw)
    slow = DeadlineMarina(GRAD, RandK(k=3), times=RoundTimeModel(
        dist="fixed", mean_s=1.0, slow_ids=(0, 2), slow_factor=8.0), **kw)
    _, mf = run_states(full, data, 4)
    _, ms = run_states(slow, data, 4)
    for f, s in zip(mf, ms):
        assert int(f.uploaded) == N and int(s.uploaded) == N - 2
        assert float(s.bits_per_worker) == pytest.approx(
            float(f.bits_per_worker) * (N - 2) / N)


def test_transport_uplink_books_only_uploaded_rows():
    """`Transport.uplink_mean(uploaded_rows=u)` scales every up booking by
    u/n while the collective still carries n (zero-padded) rows."""
    from repro.launch.topology import detect_topology
    from repro.launch.transport import make_transport

    mesh = jax.make_mesh((1,), ("data",))
    topo = detect_topology(mesh)
    diffs = jax.random.normal(jax.random.PRNGKey(0), (4, 256))

    def booked(uploaded_rows):
        t = make_transport(mesh, topo, waxes=("data",), n=4)
        with t.scope("compressed_step"):
            out = t.uplink_mean(jax.random.PRNGKey(1), diffs,
                                uploaded_rows=uploaded_rows)
        assert jax.tree.leaves(out)[0].shape == (256,)
        return t.ledger.total_bits(direction="up")

    full = booked(None)
    assert full > 0.0
    assert booked(4) == pytest.approx(full)
    assert booked(2) == pytest.approx(full * 0.5)
    assert booked(0) == 0.0
    with pytest.raises(ValueError, match="uploaded_rows"):
        booked(5)


# ---------------------------------------------------------------------------
# RoundTimeModel statistics + validation
# ---------------------------------------------------------------------------

def test_roundtime_validation():
    with pytest.raises(ValueError, match="dist"):
        RoundTimeModel(dist="uniform")
    with pytest.raises(ValueError, match="mean_s"):
        RoundTimeModel(mean_s=0.0)
    with pytest.raises(ValueError, match="sigma"):
        RoundTimeModel(sigma=-0.1)
    with pytest.raises(ValueError, match="slow_factor"):
        RoundTimeModel(slow_ids=(0,), slow_factor=0.5)
    with pytest.raises(ValueError, match="duplicates"):
        RoundTimeModel(slow_ids=(1, 1))
    with pytest.raises(ValueError, match="non-negative"):
        RoundTimeModel(slow_ids=(-1,))


def test_roundtime_fixed_dist_and_slow_set():
    tm = RoundTimeModel(dist="fixed", mean_s=2.0, slow_ids=(1,),
                        slow_factor=4.0)
    t = np.asarray(tm.sample(jax.random.PRNGKey(0), 4))
    np.testing.assert_allclose(t, [2.0, 8.0, 2.0, 2.0])
    assert tm.deadline_for_quantile(0.9) == 2.0
    assert tm.miss_prob(2.0) == 0.0 and tm.miss_prob(1.9) == 1.0


@pytest.mark.parametrize("dist", ["lognormal", "exponential"])
def test_roundtime_mean_and_quantile_roundtrip(dist):
    tm = RoundTimeModel(dist=dist, mean_s=1.5, sigma=0.8)
    t = np.asarray(tm.sample(jax.random.PRNGKey(1), 200_000))
    assert np.mean(t) == pytest.approx(1.5, rel=0.05)  # mean-corrected
    for q in (0.5, 0.8, 0.95):
        dl = tm.deadline_for_quantile(q)
        # closed form agrees with itself ...
        assert tm.miss_prob(dl) == pytest.approx(1.0 - q, abs=1e-9)
        # ... and with the sampler
        assert np.mean(t > dl) == pytest.approx(1.0 - q, abs=0.01)
    with pytest.raises(ValueError, match="quantile"):
        tm.deadline_for_quantile(1.0)
    assert tm.miss_prob(0.0) == 1.0


def test_async_gamma_degrades_with_staleness_and_misses():
    base = marina_gamma(1.0, 4.0, 0.25, 8)
    assert async_marina_gamma(1.0, 4.0, 0.25, 8) == pytest.approx(base)
    g_miss = async_marina_gamma(1.0, 4.0, 0.25, 8, arrive_frac=0.5)
    g_stale = async_marina_gamma(1.0, 4.0, 0.25, 8, staleness=2.0)
    assert g_miss < base and g_stale < base
    assert async_marina_gamma(
        1.0, 4.0, 0.25, 8, arrive_frac=0.5, staleness=2.0) < min(
        g_miss, g_stale)
    with pytest.raises(ValueError, match="arrive_frac"):
        async_marina_gamma(1.0, 4.0, 0.25, 8, arrive_frac=1.5)
    with pytest.raises(ValueError, match="staleness"):
        async_marina_gamma(1.0, 4.0, 0.25, 8, staleness=-1.0)


# ---------------------------------------------------------------------------
# FaultSpec construction-time refusals (regression: ISSUE 8 satellite)
# ---------------------------------------------------------------------------

def test_faultspec_ids_validation():
    assert FaultSpec("drop", ids=(3, 1)).ids == (1, 3)  # sorted
    assert FaultSpec("drop", ids=(1, 9)).n_faulty(5) == 1  # id 9 not in fleet
    mask = FaultSpec("drop", ids=(1, 3)).byz_mask(jnp.arange(5), 5)
    assert np.asarray(mask).tolist() == [False, True, False, True, False]
    assert not np.asarray(
        FaultSpec("drop", ids=()).byz_mask(jnp.arange(5), 5)).any()
    with pytest.raises(ValueError, match="non-negative"):
        FaultSpec("drop", ids=(-1,))
    with pytest.raises(ValueError, match="duplicates"):
        FaultSpec("drop", ids=(2, 2))


def test_drop_without_carry_refused():
    with pytest.raises(ValueError, match="carry=True is required"):
        Marina(GRAD, RandK(k=3), 0.05, 0.3,
               faults=FaultSpec("drop", ids=(0,)))


def test_drop_with_robust_gar_refused():
    with pytest.raises(ValueError, match="mean aggregation"):
        Marina(GRAD, RandK(k=3), 0.05, 0.3, carry=True,
               faults=FaultSpec("drop", ids=(0,)),
               aggregator=ServerAggregator("trimmed_mean", f=1))


# ---------------------------------------------------------------------------
# atomic BENCH_pp.json read-merge-update
# ---------------------------------------------------------------------------

def test_write_merged_is_atomic_and_merges(tmp_path, monkeypatch):
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import bench_pp

    monkeypatch.setattr(bench_pp, "ROOT", str(tmp_path))
    path = tmp_path / "BENCH_pp.json"
    path.write_text(json.dumps({"curves": [1, 2], "robust": {"keep": True}}))
    out = bench_pp._write_merged({"async": {"quick": True}})
    on_disk = json.loads(path.read_text())
    assert on_disk == out
    assert on_disk["curves"] == [1, 2]          # other sections survive
    assert on_disk["robust"] == {"keep": True}
    assert on_disk["async"] == {"quick": True}
    # the temp file never outlives the os.replace
    assert list(tmp_path.iterdir()) == [path]


# ---------------------------------------------------------------------------
# transport retry/timeout/backoff + crash/recovery env helpers
# ---------------------------------------------------------------------------

def test_retry_policy_validation_and_backoff():
    from repro.launch.transport import RetryPolicy

    p = RetryPolicy(timeout_s=10.0, retries=3, backoff_s=0.5,
                    backoff_mult=2.0)
    assert [p.backoff(a) for a in range(3)] == [0.5, 1.0, 2.0]
    for bad in (dict(timeout_s=0.0), dict(retries=-1),
                dict(backoff_s=-1.0), dict(backoff_mult=0.5)):
        with pytest.raises(ValueError):
            RetryPolicy(**bad)


def test_retry_call_retries_then_succeeds():
    from repro.launch.transport import RetryPolicy, retry_call

    calls, sleeps, retries = [], [], []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"
    policy = RetryPolicy(retries=2, backoff_s=1.0, backoff_mult=3.0)
    out = retry_call(flaky, policy, retryable=(OSError,),
                     on_retry=lambda a, e: retries.append((a, str(e))),
                     sleep=sleeps.append)
    assert out == "ok" and len(calls) == 3
    assert sleeps == [1.0, 3.0]          # exponential backoff schedule
    assert retries == [(0, "transient"), (1, "transient")]


def test_retry_call_exhaustion_and_nonretryable():
    from repro.launch.transport import RetryPolicy, retry_call

    policy = RetryPolicy(retries=1, backoff_s=0.0)
    with pytest.raises(OSError):  # exhausted after retries+1 attempts
        retry_call(lambda: (_ for _ in ()).throw(OSError("down")), policy,
                   retryable=(OSError,), sleep=lambda s: None)
    with pytest.raises(KeyError):  # non-retryable escapes on attempt 0
        retry_call(lambda: {}["x"], policy, retryable=(OSError,),
                   sleep=lambda s: None)


def test_crash_recovery_env_helpers(monkeypatch):
    from repro.launch import topology as topo

    assert topo.clients_of_rank(0, 2) == (0, 1)
    assert topo.clients_of_rank(1, 3) == (3, 4, 5)

    monkeypatch.delenv(topo.CRASH_ENV, raising=False)
    assert topo.crash_spec_from_env() is None
    monkeypatch.setenv(topo.CRASH_ENV, "1@3")
    assert topo.crash_spec_from_env() == (1, 3)
    # non-matching rank/round is a no-op (a matching one would os._exit)
    topo.maybe_crash(0, 3)
    topo.maybe_crash(1, 2)

    monkeypatch.delenv(topo.DEAD_ENV, raising=False)
    monkeypatch.delenv(topo.RESUME_ENV, raising=False)
    assert topo.recovery_from_env() == ((), 0)
    monkeypatch.setenv(topo.DEAD_ENV, "2,3")
    monkeypatch.setenv(topo.RESUME_ENV, "4")
    assert topo.recovery_from_env() == ((2, 3), 4)

    out = f"x\n{topo.HEARTBEAT} 0\nnoise\n{topo.HEARTBEAT} 7\ny"
    assert topo.last_heartbeat(out) == 7
    assert topo.last_heartbeat("no beats") == -1
