"""Byzantine-robust aggregation + client fault injection (DESIGN.md §4.9).

Covers the full robust stack at test scale:
* the sort-free trimmed-mean / median rank semantics vs numpy sort oracles
  (odd/even n, ties), and the NaN-exclusion property of the trim window;
* the fused trimmed epilogue kernels vs the jnp refs (f32 + bf16, odd/even
  n) under the repo's 1-ulp interpret-mode tolerance;
* Krum / norm-clip behaviour under omniscient and garbage payloads;
* fault-injection end-to-end: a NaN client poisons the plain mean's MARINA
  recursion but not a trimmed aggregate; sign-flip at f=2/n=8 diverges the
  mean while trimmed-mean still reaches stationarity;
* dropped clients: carry-row substitution (stale h rows) and the exact
  uploads-only bits ledger (drift guard vs an honest same-key run);
* the robust-γ bookkeeping and the config refusals (GAR/wire compatibility);
* the default dials ("mean" + "none") are bit-identical to the seed path;
* the trainer's non-finite round guard (skipped_rounds ledger).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FaultSpec,
    Marina,
    PPMarina,
    ServerAggregator,
    make_compressor,
    make_engine,
    marina_gamma,
    robust_marina_gamma,
    robust_n_eff,
    robust_pp_marina_gamma,
)
from repro.core.marina import pp_sample_cohort
from repro.core.problems import (
    binclass_full_grad,
    binclass_smoothness,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
    BinClassData,
)
from repro.kernels import epilogue as epi
from repro.kernels import ref as kref

N, M, D = 8, 32, 20


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    L = binclass_smoothness(data)
    return data, L


def _grad_sqnorm(x, data):
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    g = binclass_full_grad(x, flat)
    return float(jnp.sum(g**2))


def _run(method, state, data, steps, seed=0):
    step = jax.jit(method.step)
    mets = []
    for k in range(steps):
        state, met = step(state, jax.random.PRNGKey(seed * 100_000 + k), data)
        mets.append(met)
    return state, mets


# ---------------------------------------------------------------------------
# Rank semantics: sort-free trim/median vs numpy sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,f", [(4, 1), (5, 1), (7, 2), (8, 3)])
def test_trimmed_mean_matches_numpy_sort(n, f):
    rows = jax.random.normal(jax.random.PRNGKey(n), (n, 3, 17))
    # inject exact ties so the stable tie-break matters
    rows = rows.at[1].set(rows[0])
    got = np.asarray(kref.trimmed_mean_rows_ref(rows, f, n - f))
    want = np.sort(np.asarray(rows), axis=0)[f : n - f].mean(axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n", [3, 4, 5, 6])
def test_coordinate_median_matches_numpy(n):
    rows = jax.random.normal(jax.random.PRNGKey(10 + n), (n, 31))
    lo, hi = ServerAggregator("coordinate_median").trim_bounds(n)
    got = np.asarray(kref.trimmed_mean_rows_ref(rows, lo, hi))
    np.testing.assert_allclose(
        got, np.median(np.asarray(rows), axis=0), rtol=1e-6, atol=1e-6
    )


def test_nan_rows_are_trimmed():
    """NaN payloads rank 0 (all NaN comparisons are false), so any window
    with lo >= 1 drops them; the survivors are the honest values minus their
    f smallest — and the accumulation must select, not multiply (0·NaN)."""
    n, f = 8, 2
    rows = jax.random.normal(jax.random.PRNGKey(3), (n, 50))
    rows = rows.at[:f].set(jnp.nan)
    got = np.asarray(kref.trimmed_mean_rows_ref(rows, f, n - f))
    assert np.isfinite(got).all()
    honest = np.sort(np.asarray(rows)[f:], axis=0)
    np.testing.assert_allclose(got, honest[f:].mean(axis=0), rtol=1e-6,
                               atol=1e-6)


# ---------------------------------------------------------------------------
# Fused epilogue kernels vs refs (f32 + bf16, odd/even n)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [4, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_trimmed_epilogues_ref_vs_interpret(n, dtype):
    nblk, B = 3, 128
    k = jax.random.PRNGKey(17)
    bufs = jax.random.normal(k, (n, nblk, B), dtype)
    g = jax.random.normal(jax.random.fold_in(k, 1), (nblk, B))
    x = jax.random.normal(jax.random.fold_in(k, 2), (nblk, B)).astype(dtype)
    lo, hi = 1, n - 1
    for fn, args in (
        (epi.trimmed_delta_epilogue, (bufs, g, x, 0.07, lo, hi)),
        (epi.trimmed_sync_epilogue, (bufs, x, 0.07, lo, hi)),
    ):
        g_r, x_r = fn(*args, backend="ref")
        g_p, x_p = fn(*args, backend="pallas_interpret")
        # 1-ulp FMA-fusion tolerance, as for the non-robust epilogues
        np.testing.assert_allclose(np.asarray(g_r), np.asarray(g_p),
                                   rtol=1e-5, atol=1e-6)
        assert x_r.dtype == x.dtype == x_p.dtype
        np.testing.assert_allclose(
            np.asarray(x_r, np.float32), np.asarray(x_p, np.float32),
            rtol=(2e-2 if dtype == jnp.bfloat16 else 1e-5), atol=1e-6,
        )
        # the kernel must agree with the plain (n,)-rows reference too
        g_direct = kref.trimmed_mean_rows_ref(bufs, lo, hi)
        base = g + g_direct if fn is epi.trimmed_delta_epilogue else g_direct
        np.testing.assert_allclose(np.asarray(g_r), np.asarray(base),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Krum / norm-clip
# ---------------------------------------------------------------------------


def test_krum_picks_honest_row_under_mean_shift():
    n, f = 8, 2
    honest = jax.random.normal(jax.random.PRNGKey(5), (n - f, 40)) + 2.0
    byz = jnp.tile(-4.0 * honest.mean(0, keepdims=True), (f, 1))
    rows = jnp.concatenate([byz, honest], axis=0)
    out = np.asarray(ServerAggregator("krum", f=f).combine_rows(rows))
    assert any(
        np.array_equal(out, np.asarray(honest[i])) for i in range(n - f)
    ), "krum must select an honest row under the omniscient attack"


def test_krum_never_selects_nan_row():
    rows = jax.random.normal(jax.random.PRNGKey(6), (6, 10))
    rows = rows.at[0].set(jnp.nan)
    out = np.asarray(ServerAggregator("krum", f=1).combine_rows(rows))
    assert np.isfinite(out).all()


def test_norm_clip_bounds_and_sanitizes():
    rows = jax.random.normal(jax.random.PRNGKey(7), (6, 30))
    rows = rows.at[0].mul(1e4)          # garbage-scale row
    rows = rows.at[1].set(jnp.inf)      # unrepairable row -> scale 0
    agg = ServerAggregator("norm_clip", clip_tau=2.0)
    out = np.asarray(agg.combine_rows(rows))
    assert np.isfinite(out).all()
    assert np.linalg.norm(out) <= 2.0 + 1e-5  # mean of rows each clipped to τ


# ---------------------------------------------------------------------------
# Fault injection end-to-end on the optimizers
# ---------------------------------------------------------------------------


def _marina(problem, aggregator=None, faults=None, gamma=None, carry=False):
    data, L = problem
    comp = make_compressor("qsgd", s=7)
    p = comp.default_p(D)
    g = gamma if gamma is not None else marina_gamma(L, comp.omega(D), p, N)
    return Marina(
        grad_fn=jax.grad(nonconvex_binclass_loss), compressor=comp,
        gamma=g, p=p, aggregator=aggregator, faults=faults, carry=carry,
    ), data


def test_nan_attack_poisons_mean_but_not_trimmed(problem):
    m, data = _marina(problem, faults=FaultSpec("nan", frac=0.25))
    st, _ = _run(m, m.init(jnp.zeros((D,)), data), data, 8)
    assert not np.isfinite(np.asarray(st.params)).all(), (
        "a NaN client must poison the unprotected mean recursion"
    )
    m2, _ = _marina(problem, aggregator=ServerAggregator("trimmed_mean", f=2),
                    faults=FaultSpec("nan", frac=0.25))
    st2, _ = _run(m2, m2.init(jnp.zeros((D,)), data), data, 8)
    assert np.isfinite(np.asarray(st2.params)).all()


def test_sign_flip_trimmed_converges_mean_degrades(problem):
    """f = 2 of n = 8 sign-flipped clients at scale 10: the trimmed mean
    stays near the attack-free loss (bounded trim bias — the flipped rows
    are rank extremes and fall outside the keep window) while the plain
    mean is steered far uphill. Loss, not grad-norm: the attacked mean run
    performs gradient *ascent*, and a maximum is also a stationary point."""
    data, L = problem
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    loss = lambda st: float(nonconvex_binclass_loss(st.params, flat))
    faults = FaultSpec("sign_flip", frac=0.25, scale=10.0)
    gamma, p = 0.05, 0.5

    def fit(aggregator=None, flt=None):
        m, _ = _marina(problem, aggregator=aggregator, faults=flt,
                       gamma=gamma)
        m = dataclasses.replace(m, p=p)
        st, _ = _run(m, m.init(jnp.zeros((D,)), data), data, 300)
        return loss(st)

    l_free = fit()
    l_rob = fit(aggregator=ServerAggregator("trimmed_mean", f=2), flt=faults)
    l_avg = fit(flt=faults)
    assert l_rob < l_free + 0.1, (
        f"trimmed under attack should stay near attack-free "
        f"({l_rob} vs {l_free})"
    )
    assert l_avg > l_rob + 0.15, (
        f"plain mean should visibly degrade (mean {l_avg} vs robust {l_rob})"
    )


def test_default_dials_are_bit_identical(problem):
    m0, data = _marina(problem)
    m1, _ = _marina(problem, aggregator=ServerAggregator("mean"),
                    faults=FaultSpec("none", frac=0.0))
    st0, _ = _run(m0, m0.init(jnp.zeros((D,)), data), data, 25)
    st1, _ = _run(m1, m1.init(jnp.zeros((D,)), data), data, 25)
    np.testing.assert_array_equal(np.asarray(st0.params),
                                  np.asarray(st1.params))


# ---------------------------------------------------------------------------
# Dropped clients: carry substitution + uploads-only ledger
# ---------------------------------------------------------------------------


def test_pp_drop_ledger_books_actual_uploads(problem):
    """Drift guard: with dropped clients the per-round uplink bits must equal
    the honest run's bits scaled by uploaded/r on every compressed round
    (same keys → same cohorts), and match a from-scratch cohort recount."""
    data, _ = problem
    comp = make_compressor("qsgd", s=7)
    faults = FaultSpec("drop", frac=0.25)  # ids {0, 1} of 8 never upload
    kw = dict(grad_fn=jax.grad(nonconvex_binclass_loss), compressor=comp,
              gamma=0.05, p=0.3, r=4, carry=True)
    m_drop = PPMarina(**kw, faults=faults)
    m_ok = PPMarina(**kw)
    st_d, mets_d = _run(m_drop, m_drop.init(jnp.zeros((D,)), data), data, 12)
    st_o, mets_o = _run(m_ok, m_ok.init(jnp.zeros((D,)), data), data, 12)
    f = faults.n_faulty(N)
    for k, (md, mo) in enumerate(zip(mets_d, mets_o)):
        key = jax.random.PRNGKey(k)
        _, k_sel, _ = jax.random.split(key, 3)
        sel = pp_sample_cohort(k_sel, N, 4, True)
        uploaded = 4 - int(np.sum(np.asarray(sel) < f))
        if int(md.sync_round):
            assert float(md.bits_per_worker) == float(mo.bits_per_worker)
        else:
            np.testing.assert_allclose(
                float(md.bits_per_worker),
                float(mo.bits_per_worker) * uploaded / 4.0, rtol=1e-6,
            )


def test_pp_drop_keeps_stale_carry_rows(problem):
    data, _ = problem
    faults = FaultSpec("drop", frac=0.25)
    m = PPMarina(grad_fn=jax.grad(nonconvex_binclass_loss),
                 compressor=make_compressor("qsgd", s=7),
                 gamma=0.05, p=0.0,  # no sync rendezvous: drops never refresh
                 r=4, carry=True, faults=faults)
    st0 = m.init(jnp.zeros((D,)), data)
    h0 = np.asarray(st0.h)
    st, _ = _run(m, st0, data, 10)
    h = np.asarray(st.h)
    f = faults.n_faulty(N)
    np.testing.assert_array_equal(h[:f], h0[:f])  # dropped rows stay stale
    assert not np.array_equal(h[f:], h0[f:])      # honest rows refreshed


# ---------------------------------------------------------------------------
# Config refusals + γ bookkeeping
# ---------------------------------------------------------------------------


def test_drop_requires_carry(problem):
    with pytest.raises(ValueError, match="carry"):
        _marina(problem, faults=FaultSpec("drop", frac=0.25), carry=False)


def test_robust_refuses_partitioning_wire():
    params = jnp.zeros((256,))
    eng = make_engine(params, block=128, backend="ref", sampler="permk")
    with pytest.raises(ValueError):
        Marina(grad_fn=lambda x, b: x, compressor=make_compressor("qsgd", s=7),
               gamma=0.1, p=0.5, engine=eng,
               aggregator=ServerAggregator("trimmed_mean", f=1))


def test_robust_n_eff_and_gamma():
    assert robust_n_eff("mean", 8) == 8
    assert robust_n_eff("trimmed_mean", 8, 2) == 4
    assert robust_n_eff("coordinate_median", 7) == 1
    assert robust_n_eff("coordinate_median", 8) == 2
    assert robust_n_eff("krum", 8, 2) == 1
    with pytest.raises(ValueError):
        robust_n_eff("trimmed_mean", 4, 2)
    g_plain = marina_gamma(1.0, 3.0, 0.1, 8)
    g_rob = robust_marina_gamma(1.0, 3.0, 0.1, 8, "trimmed_mean", f=2)
    assert 0 < g_rob <= g_plain
    assert 0 < robust_pp_marina_gamma(1.0, 3.0, 0.1, 4, "coordinate_median")


# ---------------------------------------------------------------------------
# Trainer non-finite round guard
# ---------------------------------------------------------------------------


def test_trainer_nan_guard_skips_poisoned_rounds():
    from repro.models import init_params
    from repro.models.config import ModelConfig, dense_stack
    from repro.train import TrainConfig, Trainer

    cfg = ModelConfig(
        name="rg", arch_type="dense", d_model=32, num_heads=2, num_kv_heads=2,
        d_ff=64, vocab_size=64, segments=dense_stack(1),
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    tc = TrainConfig(method="marina", compressor="qsgd",
                     comp_kwargs={"s": 7}, gamma=0.02, n_workers=4,
                     steps=8, log_every=4, faults="nan", faults_frac=0.25)
    st, hist = Trainer(cfg, tc, params).run()
    assert hist.skipped_cum[-1] > 0, "NaN rounds must be counted as skipped"
    assert np.isfinite(hist.loss[-1]), "the guard must keep the state finite"
    for leaf in jax.tree.leaves(st.params):
        assert np.isfinite(np.asarray(leaf)).all()
    # guard refusal: robust dials are marina-family only
    with pytest.raises(ValueError, match="marina-family"):
        Trainer(cfg, dataclasses.replace(tc, method="dcgd"), params)
