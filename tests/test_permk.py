"""PermK / CorrelatedQ correlated-compressor validation (DESIGN.md §4.5).

* the n worker supports PARTITION the coordinate space (every block, exactly
  once) — the property everything else rides on;
* payload per worker is exactly 32 + 32·(nblk·B)/n bits;
* per-worker unbiasedness (MC) and the zero-variance aggregate on identical
  inputs (the Perm-K hallmark);
* the AB-inequality holds empirically with (A, B) = (1, 1) — and is in fact
  an equality — while the ISSUE's (1+ω, ω) pair is refuted by measurement;
* jnp ref and interpreted Pallas kernel agree bit-exactly;
* disjoint (scatter-free) aggregation == scatter mean == densify-and-average;
* stepsize layer: ab_from_omega recovers Thm 2.1, PermK admits γ = 1/L, and
  MARINA+PermK actually converges at that stepsize;
* tree path == flat path trajectories (same seeds ⇒ same iterates);
* CorrelatedQ: unbiased, ω bound holds, and the stratified dithers beat the
  independent collection's ω/n aggregate variance in the homogeneous regime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CorrelatedQ,
    Marina,
    PermK,
    ab_from_omega,
    make_compressor,
    make_engine,
    marina_gamma,
    marina_gamma_ab,
    marina_gamma_permk,
    permk_default_p,
)
from repro.core.flat import (
    FlatEngine,
    block_permk_workers,
    key_to_seed,
    make_layout,
    pack_stacked,
    permk_concat_mean,
    unpack,
)
from repro.core.marina import _compress_workers, _decompress_mean
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
)
from repro.kernels import ref
from repro.kernels.permk import permk_seeded_workers

B, N = 128, 4


# ---------------------------------------------------------------------------
# partition + wire format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 4, 16])
@pytest.mark.parametrize("seed", [0, 99, 2**31])
def test_permk_offsets_partition_every_block(n, seed):
    nblk = 5
    offs = np.concatenate(
        [
            np.asarray(ref.permk_offsets_ref(jnp.uint32(seed), nblk, B, n, w))
            for w in range(n)
        ],
        axis=1,
    )  # (nblk, B)
    for b in range(nblk):
        assert sorted(offs[b].tolist()) == list(range(B))


def test_permk_payload_bits_exact():
    comp = PermK(n=N, block=B)
    d = 300  # nblk = 3
    assert comp.payload_bits(d) == 32.0 + 32.0 * (3 * B) / N
    eng = make_engine({"w": jnp.ones((d,))}, block=B, sampler="permk")
    assert eng.payload_bits(N) == 32.0 + 32.0 * (3 * B) / N
    pay = comp.compress_worker(jax.random.PRNGKey(0), jnp.ones((d,)), 1)
    assert set(pay) == {"values", "seed", "wid"}
    assert pay["values"].shape == (3, B // N)  # the d/n slice, values only


def test_permk_compressor_supports_are_disjoint_and_scaled():
    comp = PermK(n=N, block=B)
    x = jax.random.normal(jax.random.PRNGKey(0), (200,))
    key = jax.random.PRNGKey(1)  # SHARED round key
    dense = [
        np.asarray(comp.decompress(comp.compress_worker(key, x, w), 200))
        for w in range(N)
    ]
    support = np.stack([d != 0 for d in dense])
    # disjoint: no coordinate held by two workers...
    assert (support.sum(0) <= 1).all()
    # ...and the union covers every nonzero coordinate of x
    covered = support.any(0)
    np.testing.assert_array_equal(covered, np.asarray(x) != 0)
    # retained values carry the ×n Perm-K scale
    total = np.sum(dense, axis=0)
    np.testing.assert_allclose(total, np.asarray(x) * N, rtol=1e-5)


# ---------------------------------------------------------------------------
# Def. 1.1 moments + the AB-inequality
# ---------------------------------------------------------------------------


def test_permk_unbiased_and_omega():
    comp = PermK(n=N, block=32)
    d = 24
    x = jax.random.normal(jax.random.PRNGKey(7), (d,))
    keys = jax.random.split(jax.random.PRNGKey(0), 4000)
    qs = jax.vmap(lambda k: comp(k, x))(keys)
    nx2 = float(jnp.sum(x**2))
    omega = comp.omega(d)
    assert omega == N - 1
    se = np.sqrt(omega * nx2 / 4000)
    assert float(jnp.linalg.norm(qs.mean(0) - x)) < 6 * se
    var = float(jnp.mean(jnp.sum((qs - x) ** 2, -1)))
    # ω = n−1 is EXACT for PermK, so allow MC slack both sides
    assert var <= omega * nx2 * 1.15
    assert var >= omega * nx2 * 0.85


def test_permk_ab_constants_empirical():
    """Measured E‖(1/n)ΣQ_i(x_i) − x̄‖² equals A·avg − B·‖x̄‖² with
    (A, B) = (1, 1) — and refutes the naive (1+ω, ω) pair, which demands the
    aggregate error EXCEED avg here."""
    comp = PermK(n=N, block=32)
    d = 32  # block-aligned so padding doesn't dilute the equality
    xs = jax.random.normal(jax.random.PRNGKey(3), (N, d)) + jnp.arange(N)[:, None]
    xbar = xs.mean(0)
    avg = float(jnp.mean(jnp.sum(xs**2, -1)))
    nb2 = float(jnp.sum(xbar**2))

    def agg_err(key):
        wids = jnp.arange(N)
        dense = jax.vmap(
            lambda w, x: comp.decompress(comp.compress_worker(key, x, w), d)
        )(wids, xs)
        return jnp.sum((dense.mean(0) - xbar) ** 2)

    keys = jax.random.split(jax.random.PRNGKey(4), 3000)
    measured = float(jax.vmap(agg_err)(keys).mean())
    A, Bc = comp.ab_constants(d, N)
    assert (A, Bc) == (1.0, 1.0)
    bound = A * avg - Bc * nb2
    assert measured <= bound * 1.1
    assert measured >= bound * 0.9  # (1,1) is tight (equality in expectation)
    # the (1+ω, ω) pair from the issue text is NOT a valid convention here:
    # with x_i ≡ x it would force the aggregate error below (1+ω)avg − ω·avg
    # = ‖x‖² yet CLAIM to cover independent RandK whose error is (ω/n)‖x‖² >
    # ‖x‖² for ω > n; for PermK it is simply not tight either way. Check the
    # honest statement instead: measured ≈ avg − ‖x̄‖² exactly.
    np.testing.assert_allclose(measured, avg - nb2, rtol=0.1)


def test_ab_from_omega_recovers_thm21_and_rejects_naive_pair():
    L, omega, p, n = 2.3, 63.0, 1 / 128, 10
    g_ab = marina_gamma_ab(L, *ab_from_omega(omega, n), p)
    assert g_ab == pytest.approx(marina_gamma(L, omega, p, n), rel=1e-12)
    A, Bc = ab_from_omega(omega, n)
    # the valid pair scales with 1/n; the naive (1+ω, ω) would claim an
    # A − B of 1.0 independent of n — a different (wrong) rate
    assert A - Bc == pytest.approx(omega / n)
    assert (1 + omega) - omega == 1.0 != pytest.approx(omega / n)


def test_marina_gamma_permk_is_gd_stepsize():
    assert marina_gamma_permk(4.0, p=0.25) == pytest.approx(1 / 4.0)
    assert permk_default_p(8) == 0.125
    # heterogeneous smoothness keeps a premium but strictly beats independent
    g_het = marina_gamma_permk(4.0, p=0.25, l_plus=5.0, l_minus=3.0)
    assert g_het < 1 / 4.0
    g_ind = marina_gamma_ab(4.0, *ab_from_omega(3.0, 4), 0.25, l_plus=5.0)
    assert g_het > g_ind


# ---------------------------------------------------------------------------
# kernels + fused engine
# ---------------------------------------------------------------------------


def test_permk_ref_and_pallas_interpret_bit_exact():
    x3d = jax.random.normal(jax.random.PRNGKey(0), (N, 3, B))
    seed = jnp.uint32(77)
    v_r, o_r = ref.permk_seeded_workers_ref(x3d, seed, N)
    v_p, o_p = permk_seeded_workers(x3d, seed, interpret=True)
    np.testing.assert_array_equal(np.asarray(v_r), np.asarray(v_p))
    np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_p))
    # and the backend switch routes identically
    v_b, o_b = block_permk_workers(x3d, seed, backend="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(v_r), np.asarray(v_b))
    np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_b))


def test_permk_disjoint_aggregation_equals_reference_mean():
    """Scatter-free concat aggregation == scatter_accum == densify each worker
    and average (collision-free supports make all three identical)."""
    x3d = jax.random.normal(jax.random.PRNGKey(1), (N, 2, B))
    seed = jnp.uint32(5)
    vals, offs = ref.permk_seeded_workers_ref(x3d, seed, N)
    concat = permk_concat_mean(vals, seed, B)
    scat = ref.scatter_accum_ref(vals, offs, B)
    np.testing.assert_allclose(np.asarray(concat), np.asarray(scat), rtol=1e-6)
    dense = np.zeros((N, 2, B), np.float32)
    for w in range(N):
        for b in range(2):
            dense[w, b, np.asarray(offs)[w, b]] = np.asarray(vals)[w, b]
    np.testing.assert_allclose(
        np.asarray(concat), dense.mean(0), rtol=1e-6
    )


def test_permk_engine_zero_variance_on_identical_workers():
    """(1/n)Σ Q_i(x) == x exactly — the correlated collection's hallmark,
    unreachable for any independent ω > 0 compressor in one round."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (40, 9)),
            "b": jnp.arange(17.0)}
    eng = make_engine(tree, block=B, sampler="permk", backend="ref")
    diffs = jax.tree.map(lambda x: jnp.broadcast_to(x, (N, *x.shape)) * 1.0, tree)
    out = jax.jit(lambda k, d: eng.fused_delta(k, d, N))(
        jax.random.PRNGKey(3), diffs
    )
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_permk_tree_path_equals_flat_path():
    """Same seeds ⇒ identical MARINA trajectories between the per-leaf tree
    path and the fused flat path (single-leaf, block-aligned problem)."""
    n, M, D = 4, 16, 256  # D = 2 blocks of 128
    data = make_synthetic_binclass(jax.random.PRNGKey(0), n, M, D)
    comp = PermK(n=n, block=128)
    grad = jax.grad(nonconvex_binclass_loss)

    m_tree = Marina(grad, comp, gamma=0.05, p=0.3)
    eng = FlatEngine(layout=make_layout(jnp.zeros((D,)), block=128),
                     backend="ref", sampler="permk")
    m_flat = Marina(grad, comp, gamma=0.05, p=0.3, engine=eng)

    st_t = m_tree.init(jnp.zeros((D,)), data)
    st_f = m_flat.init(jnp.zeros((D,)), data)
    step_t = jax.jit(m_tree.step)
    step_f = jax.jit(m_flat.step)
    saw_compressed = False
    for k in range(20):
        key = jax.random.PRNGKey(k)
        st_t, met_t = step_t(st_t, key, data)
        st_f, met_f = step_f(st_f, key, data)
        saw_compressed |= int(met_t.sync_round) == 0
        np.testing.assert_allclose(
            np.asarray(st_f.params), np.asarray(st_t.params), rtol=1e-5,
            atol=1e-6,
        )
        # ledger: both paths report the 32 + 32·(nblk·B)/n wire
        if int(met_t.sync_round) == 0:
            assert float(met_t.bits_per_worker) == 32.0 + 32.0 * D / n
            assert float(met_f.bits_per_worker) == 32.0 + 32.0 * D / n
    assert saw_compressed


def test_marina_permk_converges_at_gd_stepsize():
    """The AB headline end to end: MARINA + PermK with γ = 1/L reaches
    stationarity while uplinking d/n coordinates on compressed rounds."""
    n, M, D = 4, 32, 30
    data = make_synthetic_binclass(jax.random.PRNGKey(0), n, M, D)
    L = binclass_smoothness(data)
    comp = PermK(n=n, block=32)
    gamma = marina_gamma_permk(L, p=permk_default_p(n))
    assert gamma == pytest.approx(1.0 / L)
    m = Marina(jax.grad(nonconvex_binclass_loss), comp, gamma, permk_default_p(n))
    st = m.init(jnp.zeros((D,)), data)
    step = jax.jit(m.step)
    for k in range(300):
        st, _ = step(st, jax.random.PRNGKey(k), data)
    flat_d = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    assert float(jnp.sum(binclass_full_grad(st.params, flat_d) ** 2)) < 1e-3


def test_permk_registry_and_trainer_sizing():
    comp = make_compressor("permk", n=8, block=256)
    assert isinstance(comp, PermK) and comp.chunk() == 32
    assert make_compressor("correlated_qsgd", s=2, n=4).s == 2
    with pytest.raises(AssertionError):
        PermK(n=3, block=128)  # n must divide the block


# ---------------------------------------------------------------------------
# CorrelatedQ
# ---------------------------------------------------------------------------


def test_correlated_q_unbiased_and_omega_bound():
    comp = CorrelatedQ(s=2, n=N)
    d = 24
    x = jax.random.normal(jax.random.PRNGKey(11), (d,))
    keys = jax.random.split(jax.random.PRNGKey(12), 4000)
    qs = jax.vmap(lambda k: comp(k, x))(keys)
    nx2 = float(jnp.sum(x**2))
    se = np.sqrt(comp.omega(d) * nx2 / 4000)
    assert float(jnp.linalg.norm(qs.mean(0) - x)) < 6 * se + 1e-5
    var = float(jnp.mean(jnp.sum((qs - x) ** 2, -1)))
    assert var <= comp.omega(d) * nx2 * 1.15


def test_correlated_q_beats_independent_aggregate_variance():
    """Stratified dithers: homogeneous-input aggregate variance collapses to
    ω/n² (Hermite identity) — strictly below the independent collection's
    ω/n."""
    comp = CorrelatedQ(s=2, n=N)
    d = 24
    x = jax.random.normal(jax.random.PRNGKey(13), (d,))
    nx2 = float(jnp.sum(x**2))

    def agg_err(key):
        wids = jnp.arange(N)
        ps = jax.vmap(lambda w: comp.compress_worker(key, x, w))(wids)
        dec = jax.vmap(
            lambda q, nm: comp.decompress({"q": q, "norm": nm}, d)
        )(ps["q"], ps["norm"])
        return jnp.sum((dec.mean(0) - x) ** 2)

    keys = jax.random.split(jax.random.PRNGKey(14), 3000)
    measured = float(jax.vmap(agg_err)(keys).mean())
    omega = comp.omega(d)
    assert measured <= omega * nx2 / N**2 * 1.2   # the n² win
    assert measured < omega * nx2 / N * 0.5       # far below independent ω/n
