"""Paged-KV serving correctness (DESIGN.md §8): the block-table-gather
attention kernel against its jnp oracle, paged decode against the dense-cache
reference over a mixed-length batch, page free-list conservation, and the
continuous-batching scheduler's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.paging import (
    NULL_PAGE,
    BlockTables,
    PagePool,
    PagedLayout,
    PoolExhausted,
)
from repro.kernels import paged as kpaged
from repro.kernels import quantize as kq
from repro.kernels import ref as kref
from repro.launch.scheduler import ContinuousEngine, ContinuousScheduler, Request
from repro.models import (
    decode_step,
    init_paged_cache,
    init_params,
    paged_copy_pages,
    paged_decode_step,
    paged_gather_pages,
    paged_prefill_chunk,
    paged_scatter_pages,
    prefill,
    reduced,
)

# fp32 accumulation tolerance for paged-vs-dense MODEL logits: the two paths
# reduce over different shapes (gathered flat cache vs dense windows), so XLA
# emits different reduction orders. The KERNEL itself is bit-exact vs its
# oracle (tested below); greedy token streams must agree exactly.
LOGIT_TOL = 1e-4


def _cfg():
    return reduced(get_arch("qwen3-32b").model, layers=2, d_model=128)


# ---------------------------------------------------------------------------
# kernel: ref == pallas-interpret, bit-exact
# ---------------------------------------------------------------------------


def test_paged_attn_kernel_bit_exact():
    rng = np.random.default_rng(0)
    S, H, KV, hd, P, maxp, npage = 3, 4, 2, 8, 4, 3, 8
    q = jnp.asarray(rng.normal(size=(S, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(npage, P, KV, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(npage, P, KV, hd)), jnp.float32)
    tables = jnp.asarray([[1, 2, 0], [3, 0, 0], [4, 5, 6]], jnp.int32)
    n_valid = jnp.asarray([6, 3, 11], jnp.int32)
    ref = kpaged.paged_attn_decode(q, kp, vp, tables, n_valid, backend="ref")
    itp = kpaged.paged_attn_decode(
        q, kp, vp, tables, n_valid, backend="pallas_interpret"
    )
    assert ref.shape == (S, H, hd)
    assert bool(jnp.all(ref == itp)), "kernel is not bit-exact vs oracle"


def test_paged_gather_ref_layout():
    """The oracle's gather places token t of slot s at flat row t."""
    rng = np.random.default_rng(1)
    P, maxp, npage, KV, hd = 4, 2, 6, 2, 4
    pages = jnp.asarray(rng.normal(size=(npage, P, KV, hd)), jnp.float32)
    tables = jnp.asarray([[3, 1]], jnp.int32)
    flat = kref.paged_gather_ref(pages, tables)
    assert flat.shape == (1, maxp * P, KV, hd)
    np.testing.assert_array_equal(np.asarray(flat[0, :P]), np.asarray(pages[3]))
    np.testing.assert_array_equal(np.asarray(flat[0, P:]), np.asarray(pages[1]))


def test_absmax_quant_rows_bit_exact_and_bounded():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(6, 8)), jnp.float32)
    c_r, s_r = kref.absmax_quant_rows_ref(x)
    c_i, s_i = kq.absmax_quant_rows(x, backend="pallas_interpret")
    assert bool(jnp.all(c_r == c_i)) and bool(jnp.all(s_r == s_i))
    xd = kq.absmax_dequant_rows(c_i, s_i, backend="pallas_interpret")
    # deterministic absmax error model: |x - dq(q(x))| <= rowmax/254
    bound = np.asarray(jnp.max(jnp.abs(x), axis=1)) / 254 + 1e-7
    err = np.asarray(jnp.max(jnp.abs(xd - x), axis=1))
    assert (err <= bound).all()


# ---------------------------------------------------------------------------
# model: paged decode vs dense cache over a mixed-length batch
# ---------------------------------------------------------------------------


def _dense_greedy(params, cfg, prompt, n_extra, max_len):
    logits, cache = prefill(params, cfg, prompt[None], max_len=max_len)
    outs = [logits[0]]
    tok = jnp.argmax(outs[-1])[None]
    pos = prompt.shape[0]
    for _ in range(n_extra):
        lg, cache = decode_step(params, cfg, cache, tok, pos)
        outs.append(lg[0])
        tok = jnp.argmax(lg[0])[None]
        pos += 1
    return outs


@pytest.mark.parametrize("quantized", [False, True])
def test_paged_decode_matches_dense(quantized):
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    page_size, max_len = 4, 16
    maxp = max_len // page_size
    prompts = [
        jnp.asarray(rng.integers(0, cfg.vocab_size, size=(n,)), jnp.int32)
        for n in (5, 9)
    ]
    n_extra = 4
    dense = [_dense_greedy(params, cfg, p, n_extra, max_len) for p in prompts]

    B = len(prompts)
    layout = PagedLayout(
        npage=1 + B * maxp, page_size=page_size, max_pages=maxp, n_slots=B
    )
    pool, tbl = PagePool(layout), BlockTables(layout)
    cache = init_paged_cache(cfg, layout.npage, page_size, quantized=quantized)

    # chunked prefill, one request at a time
    C = 4
    lengths = np.zeros((B,), np.int32)
    first = []
    for s, prompt in enumerate(prompts):
        n = int(prompt.shape[0])
        tbl.assign(s, pool.alloc(layout.pages_for(n + n_extra + 1)))
        row = jnp.asarray(tbl.row(s), jnp.int32)
        lg = None
        for start in range(0, n, C):
            piece = prompt[start:start + C]
            nv = piece.shape[0]
            piece = jnp.pad(piece, (0, C - nv))
            lg, cache = paged_prefill_chunk(
                params, cfg, cache, piece[None], jnp.int32(start), row,
                jnp.int32(nv),
            )
        first.append(lg)
        lengths[s] = n

    # f32 pages: logits within fp32 accumulation noise, greedy argmax exact.
    # int8 pages: documented error model (DESIGN.md §8) — compare the
    # softmax distributions under teacher forcing (dense's greedy tokens fed
    # to both paths, so per-step error is measured on identical histories).
    def check(got, want, where):
        if quantized:
            np.testing.assert_allclose(
                np.asarray(jax.nn.softmax(got)),
                np.asarray(jax.nn.softmax(want)),
                atol=5e-3, rtol=0, err_msg=where,
            )
        else:
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), atol=LOGIT_TOL, rtol=0,
                err_msg=where,
            )
            assert int(jnp.argmax(got)) == int(jnp.argmax(want)), where

    for s in range(B):
        check(first[s], dense[s][0], f"prefill slot {s}")

    toks = jnp.stack([jnp.argmax(d[0]) for d in dense]).astype(jnp.int32)
    tables = jnp.asarray(tbl.array, jnp.int32)
    for step in range(n_extra):
        lg, cache = paged_decode_step(
            params, cfg, cache, toks, jnp.asarray(lengths), tables
        )
        for s in range(B):
            check(lg[s], dense[s][step + 1], f"step {step} slot {s}")
        # teacher-force the dense greedy stream into both paths
        toks = jnp.stack(
            [jnp.argmax(dense[s][step + 1]) for s in range(B)]
        ).astype(jnp.int32)
        lengths += 1


def test_paged_rejects_non_attn_mixer():
    cfg = reduced(get_arch("recurrentgemma-2b").model, layers=2, d_model=128)
    with pytest.raises(ValueError, match="global-attention"):
        jax.eval_shape(lambda: init_paged_cache(cfg, 8, 4))


# ---------------------------------------------------------------------------
# page pool: free-list conservation
# ---------------------------------------------------------------------------


def test_pool_alloc_free_roundtrip():
    layout = PagedLayout(npage=9, page_size=4, max_pages=4, n_slots=2)
    pool = PagePool(layout)
    assert pool.n_free == 8
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert NULL_PAGE not in a + b and len(set(a + b)) == 5
    pool.check_conservation()
    pool.free(a)
    pool.check_conservation()
    c = pool.alloc(4)
    assert not set(c) & set(b)
    pool.free(b)
    pool.free(c)
    pool.check_conservation()
    assert pool.n_free == 8


def test_pool_double_free_and_exhaustion():
    layout = PagedLayout(npage=5, page_size=4, max_pages=4, n_slots=1)
    pool = PagePool(layout)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(ValueError, match="double free"):
        pool.free(pages)
    with pytest.raises(ValueError, match="null page"):
        pool.free([NULL_PAGE])
    with pytest.raises(PoolExhausted):
        pool.alloc(5)
    # failed alloc is all-or-nothing: nothing leaked
    pool.check_conservation()
    assert pool.n_free == 4


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------


def _fake_engine(layout, reqs, chunk=4):
    """Engine over a fake model: prefill/decode return constant tokens and an
    unchanged cache, so only the scheduling logic is exercised."""
    sched = ContinuousScheduler(layout)

    def prefill_fn(cache, toks, start, row, nv):
        return np.int32(7), cache

    def decode_fn(cache, toks, lengths, tables):
        return np.full(toks.shape, 7, np.int32), cache

    eng = ContinuousEngine(sched, cache=0, prefill_fn=prefill_fn,
                           decode_fn=decode_fn, chunk=chunk)
    return eng, sched


def test_scheduler_completion_releases_everything():
    layout = PagedLayout(npage=17, page_size=4, max_pages=4, n_slots=2)
    reqs = [
        Request(rid=i, prompt=np.arange(p, dtype=np.int32), max_new=g)
        for i, (p, g) in enumerate([(6, 3), (9, 2), (3, 5), (5, 1), (8, 4)])
    ]
    eng, sched = _fake_engine(layout, reqs)
    rep = eng.run(reqs)
    assert rep.n_requests == len(reqs)
    assert rep.total_new_tokens == sum(r.max_new for r in reqs)
    # every page came back and every slot is free
    sched.pool.check_conservation()
    assert sched.pool.n_free == layout.usable_pages
    assert all(s is None for s in sched.slots)
    assert (sched.tables.array == NULL_PAGE).all()
    for r in reqs:
        assert len(r.generated) == r.max_new
        assert r.t_first >= r.t_submit and r.t_done >= r.t_first


def test_scheduler_no_starvation_fifo():
    """A big request at the head of the queue admits before later small ones,
    and still completes even while small requests churn through."""
    layout = PagedLayout(npage=9, page_size=4, max_pages=8, n_slots=2)
    big = Request(rid=0, prompt=np.arange(16, dtype=np.int32), max_new=8)
    smalls = [
        Request(rid=1 + i, prompt=np.arange(3, dtype=np.int32), max_new=2)
        for i in range(6)
    ]
    eng, sched = _fake_engine(layout, [big] + smalls)
    rep = eng.run([big] + smalls)
    assert rep.n_requests == 7
    assert big.t_admit <= min(s.t_admit for s in smalls), (
        "FIFO head must not be starved by later small requests"
    )
    assert len(big.generated) == big.max_new
    sched.pool.check_conservation()
    assert sched.pool.n_free == layout.usable_pages


def test_scheduler_rejects_oversized_request():
    layout = PagedLayout(npage=5, page_size=4, max_pages=8, n_slots=1)
    sched = ContinuousScheduler(layout)
    with pytest.raises(ValueError, match="pool has"):
        sched.submit(
            Request(rid=0, prompt=np.arange(30, dtype=np.int32), max_new=8)
        )


def test_scheduler_reservation_blocks_admission():
    """With pages for only one request in flight, the second waits — and is
    admitted the moment the first completes (reservation, not preemption)."""
    layout = PagedLayout(npage=5, page_size=4, max_pages=4, n_slots=2)
    r1 = Request(rid=0, prompt=np.arange(9, dtype=np.int32), max_new=2)  # 3 pages
    r2 = Request(rid=1, prompt=np.arange(9, dtype=np.int32), max_new=2)
    sched = ContinuousScheduler(layout, admission="reserve")
    sched.submit(r1)
    sched.submit(r2)
    admitted = sched.admit()
    assert [r.rid for r in admitted] == [0], "only one reservation fits"
    assert sched.admit() == []
    r1.generated = [7, 7]
    sched.complete(r1)
    assert [r.rid for r in sched.admit()] == [1]
    sched.pool.check_conservation(sched.tables)


def test_pool_audit_rejects_referenced_free_page():
    """The cross-checked audit catches the two COW corruption modes: a page
    that went back to the free list while a block-table row still points at
    it, and a pool refcount that drifted from the number of referencing
    rows."""
    layout = PagedLayout(npage=9, page_size=4, max_pages=4, n_slots=2)
    pool, tbl = PagePool(layout), BlockTables(layout)

    # released-but-still-mapped: the row keeps pointing at a freed page
    pages = pool.alloc(2)
    tbl.assign(0, pages)
    pool.check_conservation(tbl)
    pool.release(pages[1])  # bug: row entry not cleared
    with pytest.raises(AssertionError, match="still referenced"):
        pool.check_conservation(tbl)
    tbl.set_entry(0, 1, NULL_PAGE)
    pool.check_conservation(tbl)

    # refcount drift: fork without mapping the page into a second row
    pool.fork(pages[0])
    with pytest.raises(AssertionError, match="refcounts"):
        pool.check_conservation(tbl)
    tbl.set_entry(1, 0, pages[0])
    pool.check_conservation(tbl)


def test_share_prefix_requires_expected_admission():
    layout = PagedLayout(npage=9, page_size=4, max_pages=4, n_slots=2)
    with pytest.raises(ValueError, match="expected"):
        ContinuousScheduler(layout, admission="reserve", share_prefix=True)


def _logit_capture_engine(params, cfg, layout, *, chunk, share_prefix, quantized):
    """Real-model engine whose prefill/decode record per-request logits: the
    prefix-sharing regression compares these arrays bit-for-bit against an
    engine that prefills everything from scratch."""
    cache = init_paged_cache(
        cfg, layout.npage, layout.page_size, quantized=quantized
    )
    sched = ContinuousScheduler(layout, share_prefix=share_prefix)
    captured = {}  # rid -> [logits for each generated token, in order]

    def prefill_fn(cache, toks, start, row, nv):
        lg, cache = paged_prefill_chunk(
            params, cfg, cache, jnp.asarray(toks), jnp.int32(start),
            jnp.asarray(row), jnp.int32(nv),
        )
        cands = [r for r in sched.active if r.prefilling]
        req = min(cands, key=lambda r: r.t_admit)
        if req.prefill_done + int(nv) == req.prompt_len:
            captured.setdefault(req.rid, []).append(np.asarray(lg))
        return jnp.argmax(lg).astype(jnp.int32), cache

    def decode_fn(cache, toks, lengths, tables):
        lg, cache = paged_decode_step(
            params, cfg, cache, jnp.asarray(toks), jnp.asarray(lengths),
            jnp.asarray(tables),
        )
        for s, req in enumerate(sched.slots):
            if req is not None and req.decoding and int(lengths[s]) > 0:
                captured.setdefault(req.rid, []).append(np.asarray(lg[s]))
        return jnp.argmax(lg, axis=-1).astype(jnp.int32), cache

    eng = ContinuousEngine(
        sched, cache, prefill_fn, decode_fn, chunk=chunk,
        copy_fn=lambda c, s, d: paged_copy_pages(c, jnp.asarray(s), jnp.asarray(d)),
        gather_fn=lambda c, i: jax.tree.map(
            np.asarray, paged_gather_pages(c, jnp.asarray(i))
        ),
        scatter_fn=lambda c, i, sn: paged_scatter_pages(c, jnp.asarray(i), sn),
    )
    return eng, sched, captured


@pytest.mark.parametrize("quantized", [False, True])
def test_shared_prefix_logits_bit_identical(quantized):
    """Two requests sharing a prompt prefix (COW pages) produce logits
    BIT-identical to fully independent prefills: aliasing only changes
    block-table content, never the values the kernel gathers. Covers both
    full-page sharing and the COW split of a shared partial page."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    P, chunk = 4, 4
    prefix = rng.integers(0, cfg.vocab_size, size=(14,))  # 3.5 pages

    def reqs():
        # A holds the prefix resident while C admits (B is churn in between);
        # C extends A's prompt past its partial tail page -> COW split
        return [
            Request(rid=0, prompt=np.asarray(prefix, np.int32), max_new=12),
            Request(
                rid=1,
                prompt=np.asarray(
                    rng.integers(0, cfg.vocab_size, size=(10,)), np.int32
                ),
                max_new=2,
            ),
            Request(
                rid=2,
                prompt=np.asarray(
                    list(prefix) + [11, 13], np.int32
                ),
                max_new=3,
            ),
        ]

    layout = PagedLayout(npage=17, page_size=P, max_pages=7, n_slots=2)
    rng_state = rng.bit_generator.state
    eng, sched, shared = _logit_capture_engine(
        params, cfg, layout, chunk=chunk, share_prefix=True,
        quantized=quantized,
    )
    shared_reqs = reqs()
    eng.run(shared_reqs)
    sched.pool.check_conservation(sched.tables)
    assert sched.shared_tokens_total == 14, "C must map A's prompt pages"
    assert sched.cow_splits >= 1, "writing A's shared partial page must split"

    rng.bit_generator.state = rng_state  # identical workload for the baseline
    eng0, sched0, base = _logit_capture_engine(
        params, cfg, layout, chunk=chunk, share_prefix=False,
        quantized=quantized,
    )
    base_reqs = reqs()
    eng0.run(base_reqs)
    assert sched0.shared_tokens_total == 0

    assert set(shared) == set(base)
    for rid in base:
        assert len(shared[rid]) == len(base[rid])
        for step, (got, want) in enumerate(zip(shared[rid], base[rid])):
            np.testing.assert_array_equal(
                got, want, err_msg=f"rid {rid} token {step} ({quantized=})"
            )
    for rs, rb in zip(shared_reqs, base_reqs):
        assert rs.generated == rb.generated


def test_scheduler_preemption_oversubscribed_completes_all():
    """Expected admission over a pool far too small for the whole workload:
    preemption must kick in, every request must still complete, and every
    page must come back."""
    layout = PagedLayout(npage=9, page_size=4, max_pages=8, n_slots=3)
    reqs = [
        Request(rid=i, prompt=np.arange(6, dtype=np.int32) + i, max_new=18)
        for i in range(5)
    ]  # each grows to 6+18=24 tokens = 6 pages; the pool holds 8
    eng, sched = _fake_engine(layout, reqs)
    rep = eng.run(reqs)
    assert rep.n_requests == len(reqs)
    assert rep.preemptions > 0, "an oversubscribed pool must preempt"
    for r in reqs:
        assert len(r.generated) == r.max_new
    sched.pool.check_conservation(sched.tables)
    assert sched.pool.n_free == layout.usable_pages
    assert all(s is None for s in sched.slots)
