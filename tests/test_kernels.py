"""Pallas kernel validation: interpret-mode kernel == pure-jnp oracle (ref.py)
across shape/dtype sweeps, plus statistical checks for the seeded sampler and
end-to-end unbiasedness of the fused compression round trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.randk import randk_gather, randk_seeded, scatter_accum
from repro.kernels.quantize import block_sumsq, qsgd_dequantize, qsgd_quantize

SHAPES = [(1, 128), (2, 256), (4, 1024), (3, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("nblk,B", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=str)
def test_randk_gather_matches_ref(nblk, B, dtype):
    kb = max(8, B // 16)
    key = jax.random.PRNGKey(nblk * B)
    x2d = jax.random.normal(key, (nblk, B)).astype(dtype)
    offsets = jax.random.randint(jax.random.fold_in(key, 1), (nblk, kb), 0, B)
    scale = B / kb
    out = randk_gather(x2d, offsets.astype(jnp.int32), scale, interpret=True)
    want = ref.randk_block_compress_ref(x2d, offsets, scale)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


@pytest.mark.parametrize("n", [1, 4, 7])
@pytest.mark.parametrize("nblk,B", [(1, 128), (3, 256)])
def test_scatter_accum_matches_ref(n, nblk, B):
    kb = B // 8
    key = jax.random.PRNGKey(17 + n)
    values = jax.random.normal(key, (n, nblk, kb), jnp.float32)
    offsets = jax.random.randint(jax.random.fold_in(key, 1), (n, nblk, kb), 0, B)
    out = scatter_accum(values, offsets.astype(jnp.int32), B, interpret=True)
    want = ref.scatter_accum_ref(values, offsets, B)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_scatter_handles_duplicate_indices():
    values = jnp.array([[[1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]]] * 2)  # (2,1,8)
    offsets = jnp.zeros((2, 1, 8), jnp.int32)  # all collide on index 0
    out = scatter_accum(values, offsets, 128, interpret=True)
    want = ref.scatter_accum_ref(values, offsets, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)
    assert float(out[0, 0]) == pytest.approx(10.0)


@pytest.mark.parametrize("nblk,B", SHAPES)
@pytest.mark.parametrize("s", [1, 4, 15])
def test_qsgd_quantize_matches_ref(nblk, B, s):
    key = jax.random.PRNGKey(B + s)
    x2d = jax.random.normal(key, (nblk, B), jnp.float32) * 3
    u2d = jax.random.uniform(jax.random.fold_in(key, 1), (nblk, B))
    norm = jnp.linalg.norm(x2d)
    q = qsgd_quantize(x2d, u2d, norm, s, backend="pallas_interpret")
    want = ref.qsgd_quantize_ref(x2d, u2d, norm, s)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(want))
    deq = qsgd_dequantize(q, norm, s, backend="pallas_interpret")
    np.testing.assert_allclose(
        np.asarray(deq), np.asarray(ref.qsgd_dequantize_ref(want, norm, s)), rtol=1e-6
    )


@pytest.mark.parametrize("nblk,B", SHAPES)
def test_block_sumsq_matches_ref(nblk, B):
    x2d = jax.random.normal(jax.random.PRNGKey(0), (nblk, B), jnp.float32)
    out = block_sumsq(x2d, backend="pallas_interpret")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.block_sumsq_ref(x2d)), rtol=1e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(min_value=10, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_randk_roundtrip_unbiased_support(d, seed):
    """ops-level wrapper: padding + jittered offsets + gather + scatter."""
    block = 256
    kb = 32
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    vals, offs = ops.randk_compress(x, jax.random.PRNGKey(seed + 1), kb, block=block)
    dense = ops.randk_decompress_mean(vals[None], offs[None], d, block=block)
    assert dense.shape == (d,)
    # every nonzero equals x * block/kb at its coordinate
    nz = np.nonzero(np.asarray(dense))[0]
    np.testing.assert_allclose(
        np.asarray(dense)[nz], np.asarray(x)[nz] * block / kb, rtol=1e-4
    )


def test_randk_roundtrip_is_unbiased_mc():
    d, block, kb = 500, 128, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))

    def rt(key):
        vals, offs = ops.randk_compress(x, key, kb, block=block)
        return ops.randk_decompress_mean(vals[None], offs[None], d, block=block)

    keys = jax.random.split(jax.random.PRNGKey(1), 2000)
    mean = jnp.mean(jax.vmap(rt)(keys), axis=0)
    # E||mean - x||^2 = omega ||x||^2 / trials with omega = block/kb - 1 = 7
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 2.0 * np.sqrt(7 / 2000)  # 2x the expected MC error


@pytest.mark.parametrize("nblk,B,kb", [(1, 128, 16), (2, 256, 32), (3, 512, 8)])
def test_seeded_sampler_matches_ref_exactly(nblk, B, kb):
    """In-kernel counter-based RNG is bit-exact vs the pure-jnp oracle."""
    x2d = jax.random.normal(jax.random.PRNGKey(0), (nblk, B))
    scale = B / kb
    vals, offs = randk_seeded(x2d, jnp.int32(7), kb, scale, interpret=True)
    want_v, want_o = ref.randk_seeded_ref(x2d, jnp.uint32(7), kb, scale)
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(want_o))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(want_v), rtol=1e-6)


def test_seeded_sampler_statistics():
    """Production in-kernel PRNG path: unbiased in expectation over seeds."""
    nblk, B, kb = 2, 256, 32
    x2d = jax.random.normal(jax.random.PRNGKey(0), (nblk, B))
    scale = B / kb

    def rt(seed):
        vals, offs = ref.randk_seeded_ref(x2d, seed, kb, scale)
        return ref.scatter_accum_ref(vals[None], offs[None], B)

    seeds = jnp.arange(4000, dtype=jnp.uint32) * jnp.uint32(2654435761)
    mean = jnp.mean(jax.vmap(rt)(seeds), axis=0)
    rel = float(jnp.linalg.norm(mean - x2d) / jnp.linalg.norm(x2d))
    assert rel < 2.0 * np.sqrt((B / kb) / 4000)


def test_qsgd_ops_roundtrip_unbiased():
    d, s = 700, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))

    def rt(key):
        q, norm = ops.qsgd_compress(x, key, s, block=256)
        return ops.qsgd_decompress(q, norm, s, d, block=256)

    keys = jax.random.split(jax.random.PRNGKey(1), 1000)
    mean = jnp.mean(jax.vmap(rt)(keys), axis=0)
    omega = min(d / s**2, np.sqrt(d) / s)
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 2.0 * np.sqrt(omega / 1000)
