"""Scheduler soak (slow tier): over-subscribed randomized serving.

The model here is fake but CONTENT-SENSITIVE: the "KV cache" is a numpy
page pool of token values, prefill/decode write real tokens through the
block tables, and every emitted token is a deterministic hash of the
request's own cached history. Any paging bug — a COW split that loses a
page, a swap-out that restores the wrong snapshot, a lazy allocation that
lands in another request's page — changes some request's token stream.

Each seeded workload runs twice: once over a pool far too small (forcing
preemption and COW prefix sharing) and once over a roomy pool with sharing
off (every request fully independent). The streams must match token for
token, every admitted request must complete, and the latency percentiles
must be ordered. Opt in with ``-m slow``; the failing parametrize id names
the seed.
"""

import numpy as np
import pytest

from repro.core.paging import NULL_PAGE, PagedLayout
from repro.launch.scheduler import ContinuousEngine, ContinuousScheduler, Request

PAGE = 4
SLOTS = 4
VOCAB = 997


def _content_engine(layout, *, share_prefix, admission="expected"):
    """Engine over the numpy content model described in the module docstring."""
    sched = ContinuousScheduler(
        layout, admission=admission, share_prefix=share_prefix
    )
    cache = np.zeros((layout.npage, layout.page_size), np.int64)

    def _gather(cache, row, n):
        pages = row[: -(-n // layout.page_size)]
        flat = cache[pages].reshape(-1)[:n]
        return flat

    def _emit(cache, row, n):
        h = 1469
        for v in _gather(cache, row, n):
            h = (h * 31 + int(v) + 1) % VOCAB
        return h

    def prefill_fn(cache, toks, start, row, nv):
        start, nv = int(start), int(nv)
        for j in range(nv):
            pos = start + j
            cache[row[pos // layout.page_size], pos % layout.page_size] = toks[0, j]
        return np.int64(_emit(cache, row, start + nv)), cache

    def decode_fn(cache, toks, lengths, tables):
        out = np.zeros(toks.shape, np.int64)
        for s in range(len(toks)):
            n = int(lengths[s])
            row = tables[s]
            cache[row[n // layout.page_size], n % layout.page_size] = toks[s]
            if n > 0:
                out[s] = _emit(cache, row, n + 1)
        return out, cache

    def copy_fn(cache, src, dst):
        cache[dst] = cache[src]
        return cache

    def gather_fn(cache, ids):
        return cache[ids].copy()

    def scatter_fn(cache, ids, snap):
        cache[ids] = snap
        return cache

    eng = ContinuousEngine(
        sched, cache, prefill_fn, decode_fn, chunk=PAGE,
        copy_fn=copy_fn, gather_fn=gather_fn, scatter_fn=scatter_fn,
    )
    return eng, sched


def _workload(rng, n_requests):
    """Mixed lengths; about half the requests draw one of 4 common prompt
    prefixes (grouped arrivals, like a shared system prompt)."""
    prefixes = [
        rng.integers(1, VOCAB, size=int(rng.integers(5, 12))) for _ in range(4)
    ]
    reqs = []
    for rid in range(n_requests):
        tail = rng.integers(1, VOCAB, size=int(rng.integers(1, 8)))
        if rng.random() < 0.5:
            prompt = np.concatenate([prefixes[rid % 4], tail])
        else:
            prompt = tail
        reqs.append(
            Request(
                rid=rid,
                prompt=np.asarray(prompt, np.int32),
                max_new=int(rng.integers(2, 10)),
            )
        )
    # grouped by prefix, so same-prefix requests overlap in flight
    reqs.sort(key=lambda r: r.rid % 4)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


@pytest.mark.slow
@pytest.mark.parametrize("seed", [11, 23, 47])
def test_soak_preempted_streams_match_unpreempted(seed):
    rng = np.random.default_rng(seed)
    n_requests = 40
    longest = 0

    reqs_tight = _workload(np.random.default_rng(seed), n_requests)
    reqs_roomy = _workload(np.random.default_rng(seed), n_requests)
    for a, b in zip(reqs_tight, reqs_roomy):
        np.testing.assert_array_equal(a.prompt, b.prompt)
        assert a.max_new == b.max_new
        longest = max(longest, a.prompt_len + a.max_new)

    max_pages = -(-longest // PAGE)

    # tight: ~2 worst-case requests' worth of pages for 4 slots + sharing
    tight = PagedLayout(
        npage=1 + 2 * max_pages, page_size=PAGE,
        max_pages=max_pages, n_slots=SLOTS,
    )
    eng_t, sched_t = _content_engine(tight, share_prefix=True)
    rep_t = eng_t.run(reqs_tight)

    roomy = PagedLayout(
        npage=1 + SLOTS * max_pages, page_size=PAGE,
        max_pages=max_pages, n_slots=SLOTS,
    )
    eng_r, sched_r = _content_engine(roomy, share_prefix=False)
    rep_r = eng_r.run(reqs_roomy)

    assert rep_t.preemptions > 0, "the tight pool must force preemption"
    assert rep_t.shared_tokens > 0, "grouped prefixes must share pages"
    assert rep_r.preemptions == 0 and rep_r.shared_tokens == 0

    assert rep_t.n_requests == n_requests == rep_r.n_requests
    for rt, rr in zip(reqs_tight, reqs_roomy):
        assert len(rt.generated) == rt.max_new
        assert rt.generated == rr.generated, (
            f"rid {rt.rid}: preempted/shared stream diverged "
            f"(repro seed {seed})"
        )

    for rep in (rep_t, rep_r):
        assert rep.first_token_p50_ms <= rep.first_token_p99_ms
        assert rep.completion_p50_ms <= rep.completion_p99_ms

    for sched in (sched_t, sched_r):
        sched.pool.check_conservation(sched.tables)
        assert sched.pool.n_free == sched.layout.usable_pages
        assert (sched.tables.array == NULL_PAGE).all()
