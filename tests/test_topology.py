"""Topology-layer tests (ISSUE 7): mesh constructors, axis naming, link-tier
classification, the α–β link table, and the TierLedger bookkeeping contract.

Everything here is metadata-only — no test needs more than the single real
CPU device, so the whole module runs in-process (multi-device execution lives
in test_sharding.py subprocesses; multi-PROCESS execution in
test_multiproc.py behind the `multiproc` marker)."""

import jax
import pytest

from repro.core.wire import LINK_TIERS, TierLedger
from repro.launch.topology import (
    DEFAULT_LINKS,
    TIERS,
    LinkSpec,
    Topology,
    cohort_group_size,
    detect_topology,
    num_workers,
    production_topology,
    worker_axis_names,
)


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (same idiom as
    test_sharding.py) — worker-count math never needs devices."""

    def __init__(self, **axes):
        self.shape = axes


# ---------------------------------------------------------------------------
# link table + tier ordering
# ---------------------------------------------------------------------------


def test_link_table_matches_wire_tiers():
    # topology and wire must agree on the canonical tier names/order
    assert TIERS == LINK_TIERS == ("loopback", "ici", "dcn")
    assert set(DEFAULT_LINKS) == set(TIERS)


def test_link_table_is_monotone_fast_to_slow():
    # the documented table must actually order loopback > ici > dcn:
    # bandwidth strictly decreasing, launch latency strictly increasing
    lo, ici, dcn = (DEFAULT_LINKS[t] for t in TIERS)
    assert lo.bw > ici.bw > dcn.bw
    assert lo.alpha_s < ici.alpha_s < dcn.alpha_s
    # the headline constants DESIGN.md §7 documents
    assert ici.bw == 50e9
    assert dcn.bw == 6.25e9
    assert dcn.alpha_s == 25e-6


# ---------------------------------------------------------------------------
# production fabrics
# ---------------------------------------------------------------------------


def test_production_topology_single_pod():
    t = production_topology(multi_pod=False)
    assert t.n_devices == 256 and t.devices_per_pod == 256
    assert t.tier_of_axis("data") == "ici"
    assert t.tier_of_axis("model") == "ici"
    with pytest.raises(KeyError):
        t.tier_of_axis("pod")


def test_production_topology_multi_pod():
    t = production_topology(multi_pod=True)
    assert t.n_devices == 512 and t.devices_per_pod == 256
    assert t.tier_of_axis("pod") == "dcn"
    # a collective spanning pod+data is priced at its worst link
    assert t.tier_for_axes(("pod", "data")) == "dcn"
    assert t.tier_for_axes(("data", "model")) == "ici"
    assert t.tier_for_axes("data") == "ici"       # bare string accepted
    assert t.tier_for_axes(()) == "loopback"      # device-local exchange


def test_tier_for_group_size_production():
    t = production_topology(multi_pod=True)
    # wider than one pod -> must cross the dcn
    assert t.tier_for_group_size(512) == "dcn"
    assert t.tier_for_group_size(257) == "dcn"
    # inside one pod on a modeled-chip fabric -> ici
    assert t.tier_for_group_size(256) == "ici"
    assert t.tier_for_group_size(16) == "ici"


def test_tier_for_group_size_local_cluster():
    # the 2-process local CPU cluster: 4 devices, 2 per process; the worker
    # axis crosses the process boundary (its simulated dcn)
    t = Topology(
        axis_tiers=(("data", "dcn"), ("model", "loopback")),
        n_devices=4, n_processes=2,
    )
    assert t.devices_per_process == 2
    # groups wider than one process cross the (simulated) slow link tier —
    # without a pod bound they classify as ici at minimum
    assert t.tier_for_group_size(4) in ("ici", "dcn")
    # inside one process but fabric has non-loopback axes -> not loopback
    assert t.tier_for_group_size(2) != "dcn"
    # a pure single-process fake-device fabric is loopback end to end
    t1 = Topology(
        axis_tiers=(("data", "loopback"), ("model", "loopback")),
        n_devices=4, n_processes=1,
    )
    assert t1.tier_for_group_size(4) == "loopback"
    assert t1.tier_for_group_size(2) == "loopback"


def test_link_lookup():
    t = production_topology()
    assert t.link("ici") == LinkSpec(alpha_s=1e-6, bw=50e9)
    assert t.link("dcn").bw < t.link("ici").bw


# ---------------------------------------------------------------------------
# worker-axis math (folded in from the old launch/mesh.py)
# ---------------------------------------------------------------------------


def test_worker_axis_names():
    assert worker_axis_names(False, "data") == ("data",)
    assert worker_axis_names(True, "pod") == ("pod",)
    assert worker_axis_names(True, "pod_data") == ("pod", "data")


def test_num_workers():
    single = FakeMesh(data=16, model=16)
    multi = FakeMesh(pod=2, data=16, model=16)
    assert num_workers(single, False, "data") == 16
    assert num_workers(multi, True, "pod") == 2
    assert num_workers(multi, True, "pod_data") == 32


def test_cohort_group_size():
    assert cohort_group_size(8, 2) == 4
    assert cohort_group_size(8, 8) == 1
    assert cohort_group_size(8, 3) is None       # r does not divide n
    assert cohort_group_size(8, 0) is None       # degenerate cohort


# ---------------------------------------------------------------------------
# runtime classification (single real device — the degenerate but real case)
# ---------------------------------------------------------------------------


def test_detect_topology_single_process():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = detect_topology(mesh)
    assert t.n_devices == 1 and t.n_processes == 1
    assert t.devices_per_pod is None
    if jax.default_backend() == "cpu":
        # no axis spans a process: fake-device loopback end to end
        assert t.tier_for_axes(("data", "model")) == "loopback"
        assert t.tier_for_group_size(1) == "loopback"


# ---------------------------------------------------------------------------
# TierLedger (repro.core.wire)
# ---------------------------------------------------------------------------


def test_tier_ledger_book_and_filter():
    led = TierLedger()
    led.book("compressed_step", "up", "dcn", "all-gather", 100.0)
    led.book("compressed_step", "up", "dcn", "all-gather", 50.0)
    led.book("compressed_step", "down", "ici", "broadcast", 10.0)
    led.book("sync_step", "up", "loopback", "psum", 1.0)

    assert led.total_bits() == pytest.approx(161.0)
    assert led.total_bits(scope="compressed_step") == pytest.approx(160.0)
    assert led.total_bits(direction="up") == pytest.approx(151.0)
    assert led.total_bits(tier="dcn") == pytest.approx(150.0)
    assert led.total_bits(scope="sync_step", tier="dcn") == 0.0
    # repeated bookings under one key accumulate bits AND trace counts
    key = ("compressed_step", "up", "dcn", "all-gather")
    assert led.counts[key] == 2


def test_tier_ledger_by_tier_and_dict_roundtrip():
    led = TierLedger()
    led.book("s", "up", "dcn", "all-gather", 8.0)
    led.book("s", "down", "dcn", "broadcast", 4.0)
    led.book("s", "up", "loopback", "psum", 2.0)
    by = led.by_tier(scope="s")
    assert by["dcn"] == {"up": 8.0, "down": 4.0}
    assert by["loopback"] == {"up": 2.0}
    d = led.to_dict()
    assert d["bits"]["s/up/dcn/all-gather"] == 8.0
    assert d["counts"]["s/down/dcn/broadcast"] == 1
    led.clear()
    assert led.total_bits() == 0.0 and led.to_dict() == {"bits": {}, "counts": {}}


def test_tier_ledger_rejects_bad_keys():
    led = TierLedger()
    with pytest.raises(AssertionError):
        led.book("s", "sideways", "dcn", "psum", 1.0)
    with pytest.raises(AssertionError):
        led.book("s", "up", "wan", "psum", 1.0)


def test_tier_for_ids_pod_straddle():
    # a 32-device group strided across the pod boundary is dcn even though
    # it is far narrower than one pod (the group-size heuristic says ici)
    t = production_topology(multi_pod=True)
    straddle = list(range(0, 512, 16))        # one id per (pod, data) slice
    assert len(straddle) == 32
    assert t.tier_for_ids(straddle) == "dcn"
    assert t.tier_for_group_size(len(straddle)) == "ici"
    # a contiguous intra-pod group stays ici; singleton groups are loopback
    assert t.tier_for_ids(range(16)) == "ici"
    assert t.tier_for_ids([7]) == "loopback"
    # local 2-process cluster: ids spanning processes cross the simulated dcn
    t2 = Topology(
        axis_tiers=(("data", "dcn"), ("model", "loopback")),
        n_devices=4, n_processes=2,
    )
    assert t2.tier_for_ids([0, 2]) == "dcn"
    assert t2.tier_for_ids([0, 1]) != "dcn"


def test_hlo_replica_group_ids_classification():
    from repro.roofline.analysis import collective_bytes_from_hlo

    t = production_topology(multi_pod=True)
    # iota reshape-transpose form: mesh (pod=2, data=16, model=16) psum over
    # (pod, data) -> 16 groups of 32, strided across pods -> dcn
    hlo_iota = (
        "  ar = f32[1024]{0} all-reduce(x), "
        "replica_groups=[16,32]<=[2,16,16]T(2,0,1), to_apply=add\n"
    )
    st = collective_bytes_from_hlo(hlo_iota, 512, t)
    assert list(st.by_tier_bytes) == ["dcn"]
    # explicit-list form straddling pods
    hlo_expl = (
        "  ar2 = f32[64]{0} all-reduce(x), "
        "replica_groups={{0,256},{1,257}}, to_apply=add\n"
    )
    st = collective_bytes_from_hlo(hlo_expl, 512, t)
    assert list(st.by_tier_bytes) == ["dcn"]
    # intra-pod iota groups classify ici; size-only form falls back to the
    # group-size heuristic
    hlo_ici = (
        "  ag = f32[256]{0} all-gather(x), replica_groups=[32,16]<=[512], "
        "dimensions={0}\n"
    )
    st = collective_bytes_from_hlo(hlo_ici, 512, t)
    assert list(st.by_tier_bytes) == ["ici"]
