"""Checkpoint store: exact restore, content checksum, corruption errors.

The npz store writes a CRC-32 content digest under the reserved
``__checksum__`` entry; ``load_checkpoint`` verifies it (and the zip layer's
own per-entry CRC) and raises :class:`CheckpointCorruptionError` — NOT a
KeyError, so the trainer's old-format fallback tiers never swallow a corrupt
file. Pre-checksum checkpoints (no digest entry) must keep loading.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruptionError,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.checkpoint import store


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": jnp.ones((5,), jnp.bfloat16) * 1.5,
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_exact(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 3, tree)
    assert latest_step(d) == 3
    out = load_checkpoint(d, 3, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_corrupt_byte_raises(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _tree())
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointCorruptionError, match="corrupt"):
        load_checkpoint(d, 1, _tree())


def test_truncated_file_raises(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 1, _tree())
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) // 3])
    with pytest.raises(CheckpointCorruptionError, match="corrupt"):
        load_checkpoint(d, 1, _tree())


def test_digest_mismatch_raises(tmp_path):
    """A file whose zip layer is intact but whose stored digest disagrees
    with the content must still fail (guards against a stale/forged digest,
    not just bit rot the zip CRC would catch)."""
    d = str(tmp_path)
    tree = _tree()
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for p, leaf in flat:
        arr, tag = store._encode(np.asarray(leaf))
        arrays[store._path_str(p) + (f"::{tag}" if tag else "")] = arr
    arrays[store._CHECKSUM_KEY] = np.uint32(store._digest(arrays) ^ 0x1)
    np.savez(os.path.join(d, "ckpt_00000002.npz"), **arrays)
    with pytest.raises(CheckpointCorruptionError, match="checksum mismatch"):
        load_checkpoint(d, 2, tree)


def test_pre_checksum_checkpoint_still_loads(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for p, leaf in flat:
        arr, tag = store._encode(np.asarray(leaf))
        arrays[store._path_str(p) + (f"::{tag}" if tag else "")] = arr
    np.savez(os.path.join(d, "ckpt_00000005.npz"), **arrays)  # no digest
    out = load_checkpoint(d, 5, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_missing_leaf_stays_keyerror(tmp_path):
    """Format-mismatch (a leaf the caller expects but the file lacks) must
    stay a KeyError — the trainer's back-compat tiers dispatch on it."""
    d = str(tmp_path)
    save_checkpoint(d, 4, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        load_checkpoint(d, 4, {"w": jnp.zeros((2,)), "extra": jnp.zeros(())})
