"""Flat-buffer compression engine validation (DESIGN.md §4).

* pack → unpack is the identity on ragged/odd-shaped pytrees (incl. scalars,
  0-d leaves, mixed dtypes);
* the fused RandK path is unbiased: E[Q(x)] ≈ x over many seeds;
* the jnp ref backend and the interpreted Pallas backend agree bit-exactly;
* the fused scatter-accumulate aggregation equals the unfused
  decompress-every-worker-then-average reference;
* MARINA trajectories are identical (same seeds, float tolerance) between the
  old per-leaf tree path and the new flat path when the two samplers coincide
  (single-leaf, block-aligned problem).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlockRandK, Marina, make_engine
from repro.core.flat import (
    FlatEngine,
    block_scatter_mean,
    key_to_seed,
    make_layout,
    pack,
    pack_stacked,
    resolve_backend,
    seeded_offsets,
    unpack,
)
from repro.core.problems import make_synthetic_binclass, nonconvex_binclass_loss
from repro.kernels import ref

RAGGED_TREES = [
    {"w": jnp.arange(24.0).reshape(4, 6), "b": jnp.arange(5.0)},
    {
        "a": jnp.ones((3, 3, 3)),
        "nested": {"s": jnp.float32(2.5), "v": jnp.arange(7.0)},
        "bf16": jnp.ones((2, 129), jnp.bfloat16),
    },
    [jnp.zeros((1,)), jnp.arange(1000.0), jnp.ones((13, 17))],
]


@pytest.mark.parametrize("tree", RAGGED_TREES, ids=["small", "mixed", "list"])
@pytest.mark.parametrize("block", [128, 1024])
def test_pack_unpack_roundtrip_identity(tree, block):
    layout = make_layout(tree, block=block)
    buf = pack(layout, tree)
    assert buf.shape == (layout.nblk, block)
    out = unpack(layout, buf)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_pack_pads_with_zeros():
    tree = {"v": jnp.ones((5,))}
    layout = make_layout(tree, block=128)
    flat = pack(layout, tree).reshape(-1)
    assert layout.d == 5 and layout.padded == 128
    np.testing.assert_array_equal(np.asarray(flat[5:]), 0.0)


def test_pack_stacked_worker_axis():
    tree = {"w": jnp.ones((4, 6)), "b": jnp.zeros((5,))}
    stacked = jax.tree.map(lambda x: jnp.stack([x, 2 * x, 3 * x]), tree)
    layout = make_layout(tree, block=128)
    bufs = pack_stacked(layout, stacked)
    assert bufs.shape == (3, layout.nblk, 128)
    np.testing.assert_allclose(np.asarray(bufs[2]), 3 * np.asarray(bufs[0]))


def test_seeded_offsets_match_kernel_rng():
    """Server-side index regeneration is bit-exact vs the kernel sampler."""
    x2d = jax.random.normal(jax.random.PRNGKey(0), (3, 256))
    _, offs = ref.randk_seeded_ref(x2d, jnp.uint32(99), 16, 16.0)
    regen = seeded_offsets(jnp.uint32(99), 3, 256, 16)
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(regen))


def test_fused_unbiased_over_keys():
    """E[Q(x)] ≈ x for the full pack→compress→scatter→unpack pipeline."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (20, 10)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (30,))}
    eng = make_engine(tree, kb=16, block=128, backend="ref")
    trials = 3000

    def rt(key):
        return eng.roundtrip_worker(key, tree)

    keys = jax.random.split(jax.random.PRNGKey(2), trials)
    qs = jax.vmap(rt)(keys)  # tree with leading trials axis
    mean = jax.tree.map(lambda x: jnp.mean(x, 0), qs)
    # flatten both and compare with MC tolerance: omega = B/kb = 8
    mf = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(mean)])
    xf = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(tree)])
    rel = float(jnp.linalg.norm(mf - xf) / jnp.linalg.norm(xf))
    assert rel < 2.0 * np.sqrt((128 / 16) / trials)


@pytest.mark.parametrize("n", [1, 4])
def test_ref_and_pallas_interpret_bit_exact(n):
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (11, 13)),
            "b": jax.random.normal(jax.random.PRNGKey(4), (200,))}
    diffs = jax.tree.map(lambda x: jnp.stack([x * (i + 1) for i in range(n)]), tree)
    key = jax.random.PRNGKey(5)
    eng_ref = make_engine(tree, kb=8, block=128, backend="ref")
    eng_pal = make_engine(tree, kb=8, block=128, backend="pallas_interpret")
    out_ref = eng_ref.fused_delta(key, diffs, n)
    out_pal = eng_pal.fused_delta(key, diffs, n)
    for a, b in zip(jax.tree.leaves(out_ref), jax.tree.leaves(out_pal)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_equals_unfused_mean():
    """Scatter-accumulate aggregation == densify-every-worker-then-average."""
    n = 5
    tree = {"w": jax.random.normal(jax.random.PRNGKey(6), (9, 31))}
    diffs = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(7), (n, *x.shape)), tree
    )
    eng = make_engine(tree, kb=4, block=128, backend="ref")
    key = jax.random.PRNGKey(8)
    fused = eng.fused_delta(key, diffs, n)

    bufs = pack_stacked(eng.layout, diffs)
    vals, offs = eng.compress_stacked(eng.worker_seeds(key, n), bufs)
    dense = sum(
        ref.scatter_accum_ref(vals[w : w + 1], offs[w : w + 1], 128)
        for w in range(n)
    ) / n
    unfused = unpack(eng.layout, dense)
    for a, b in zip(jax.tree.leaves(fused), jax.tree.leaves(unfused)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_block_randk_compressor_wire_format():
    """BlockRandK payload = values + seed; decompress regenerates indices."""
    comp = BlockRandK(kb=8, block=128)
    x = jax.random.normal(jax.random.PRNGKey(9), (300,))
    pay = comp.compress(jax.random.PRNGKey(10), x)
    assert set(pay) == {"values", "seed"}
    assert pay["values"].shape == (3, 8)  # nblk=ceil(300/128)=3
    y = comp.decompress(pay, 300)
    assert y.shape == x.shape
    # support: every nonzero equals x * block/kb at its coordinate, up to
    # with-replacement duplicate accumulation (integer multiples)
    nz = np.nonzero(np.asarray(y))[0]
    assert len(nz) <= 3 * 8
    ratio = np.asarray(y)[nz] / (np.asarray(x)[nz] * 128 / 8)
    np.testing.assert_allclose(ratio, np.round(ratio), rtol=1e-4)
    # ledger: 32-bit seed + 32 bits per retained value, indices free
    assert comp.payload_bits(300) == 32.0 + 32.0 * 3 * 8


def test_marina_tree_path_equals_flat_path():
    """Same seeds ⇒ identical trajectories between the per-leaf tree path and
    the fused flat path, on a problem where the two samplers coincide
    (single-leaf params, d a multiple of the block)."""
    N, M, D = 4, 32, 256  # D == 2 blocks of 128
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    comp = BlockRandK(kb=8, block=128)
    grad = jax.grad(nonconvex_binclass_loss)

    m_tree = Marina(grad, comp, gamma=0.05, p=0.3)
    eng = FlatEngine(layout=make_layout(jnp.zeros((D,)), block=128), kb=8,
                     backend="ref")
    m_flat = Marina(grad, comp, gamma=0.05, p=0.3, engine=eng)

    st_t = m_tree.init(jnp.zeros((D,)), data)
    st_f = m_flat.init(jnp.zeros((D,)), data)
    step_t = jax.jit(m_tree.step)
    step_f = jax.jit(m_flat.step)
    saw_compressed = False
    for k in range(25):
        key = jax.random.PRNGKey(k)
        st_t, met_t = step_t(st_t, key, data)
        st_f, met_f = step_f(st_f, key, data)
        saw_compressed |= int(met_t.sync_round) == 0
        np.testing.assert_allclose(
            np.asarray(st_f.params), np.asarray(st_t.params), rtol=1e-5,
            atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(st_f.g), np.asarray(st_t.g), rtol=1e-5, atol=1e-6
        )
    assert saw_compressed  # the equality must cover compressed rounds


def test_engine_payload_bits_and_backend_resolution():
    tree = {"w": jnp.ones((2000,))}
    eng = make_engine(tree, kb=8, block=1024)
    assert eng.layout.nblk == 2
    assert eng.payload_bits() == 32.0 + 32.0 * 2 * 8
    assert resolve_backend("auto") in ("pallas", "ref")
    assert resolve_backend("ref") == "ref"
    with pytest.raises(ValueError):
        resolve_backend("cuda")


@pytest.mark.parametrize("path", ["tree", "flat"])
def test_bf16_params_compressed_round_smoke(path):
    """bf16 params survive full compressed rounds on both the per-leaf tree
    path (QSGD decompresses to f32 — tree_decompress must cast back, or
    Marina.step's lax.cond branches disagree on dtype) and the fused flat
    path (pack/unpack round-trips the leaf dtype)."""
    from repro.core import QSGD
    from repro.core.tree_util import tree_sub

    n = 3
    params = {
        "w": jnp.ones((4, 40), jnp.bfloat16) * 0.5,
        "b": jnp.zeros((10,), jnp.bfloat16),
    }

    def loss(p, batch):
        return sum(
            jnp.sum((a.astype(jnp.float32) - b) ** 2)
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(batch))
        )

    batches = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(0), (n, *x.shape)), params
    )
    if path == "tree":
        comp = QSGD(s=4)
        m = Marina(jax.grad(loss), comp, gamma=0.01, p=0.5)
    else:
        comp = BlockRandK(kb=8, block=128)
        eng = make_engine(params, kb=8, block=128, backend="ref")
        m = Marina(jax.grad(loss), comp, gamma=0.01, p=0.5, engine=eng)

    st = m.init(params, batches)
    step = jax.jit(m.step)
    seen = set()
    for k in range(12):
        st, met = step(st, jax.random.PRNGKey(k), batches)
        seen.add(int(met.sync_round))
    assert seen == {0, 1}  # both lax.cond branches actually traced + ran
    for leaf, like in zip(jax.tree.leaves(st.params), jax.tree.leaves(params)):
        assert leaf.dtype == like.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    for leaf in jax.tree.leaves(st.g):
        assert leaf.dtype == jnp.bfloat16


def test_scatter_mean_never_materializes_dense_workers():
    """The aggregation jaxpr must not contain an (n, padded) dense
    intermediate — peak memory of the fused path is payload + one
    accumulator (ISSUE acceptance: no n·d scaling)."""
    n, nblk, B, kb = 16, 64, 1024, 8

    def agg(vals, offs):
        return block_scatter_mean(vals, offs, B, backend="ref")

    jaxpr = jax.make_jaxpr(agg)(
        jnp.zeros((n, nblk, kb)), jnp.zeros((n, nblk, kb), jnp.int32)
    )
    d_padded = nblk * B
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            shape = getattr(v.aval, "shape", ())
            size = int(np.prod(shape)) if shape else 1
            assert size < n * d_padded, (
                f"dense (n·d)-sized intermediate {shape} in fused aggregation"
            )
