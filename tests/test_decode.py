"""Serving-path correctness: for every architecture the decode path (KV cache /
ring buffer / recurrent state / MLA latent cache) reproduces the training
forward logits token-for-token, and prefill+decode splices exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PUBLIC_TO_MODULE, get_arch
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
    reduced,
)

ARCHS = sorted(PUBLIC_TO_MODULE)
TOL = 5e-4


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    arch = get_arch(name)
    cfg = reduced(arch.model, layers=2, d_model=128)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits, *_ = jax.jit(lambda p, t: forward(p, cfg, t))(params, toks)

    cache = init_cache(cfg, B, S, jnp.float32)
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    outs = []
    for t in range(S):
        lg, cache = dec(params, cache, toks[:, t], t)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(logits), atol=TOL, rtol=1e-3
    )


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_forward(name):
    arch = get_arch(name)
    cfg = reduced(arch.model, layers=2, d_model=128)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S, P = 2, 16, 11  # prefill length deliberately != window multiples
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    logits, *_ = jax.jit(lambda p, t: forward(p, cfg, t))(params, toks)
    last, cache = jax.jit(lambda p, t: prefill(p, cfg, t, max_len=S))(
        params, toks[:, :P]
    )
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits[:, P - 1]), atol=TOL, rtol=1e-3
    )
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    for t in range(P, S):
        lg, cache = dec(params, cache, toks[:, t], t)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits[:, t]), atol=TOL, rtol=1e-3
        )


def test_ring_buffer_evicts_beyond_window():
    """Local-attention decode must *not* attend past the window: logits differ
    from full attention once the context exceeds the window."""
    arch = get_arch("gemma3-27b")
    cfg = reduced(arch.model, layers=2, d_model=128)  # window = 16
    assert cfg.window == 16
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    B, S = 1, 40  # > 2x window
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, *_ = jax.jit(lambda p, t: forward(p, cfg, t))(params, toks)

    cache = init_cache(cfg, B, S, jnp.float32)
    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    for t in range(S):
        lg, cache = dec(params, cache, toks[:, t], t)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits[:, -1]), atol=TOL, rtol=1e-3
    )


def test_recurrent_state_is_o1_memory():
    """SSM/hybrid decode state must not grow with sequence length."""
    for name in ("xlstm-350m", "recurrentgemma-2b"):
        arch = get_arch(name)
        cfg = reduced(arch.model, layers=2, d_model=128)
        c_small = init_cache(cfg, 1, 64, jnp.float32)
        c_big = init_cache(cfg, 1, 4096, jnp.float32)

        def total(c):
            return sum(
                int(np.prod(l.shape))
                for l in jax.tree.leaves(c)
            )

        if name == "xlstm-350m":
            assert total(c_small) == total(c_big)
        else:  # recurrentgemma has bounded local-attn rings only
            assert total(c_big) <= total(c_small) * 20  # ring capped at window
