"""Mesh/sharding tests. The device-count flag must be set before jax
initializes, so the sharded-execution tests run in a subprocess with 8 fake
CPU devices; rule-level tests run in-process (pure metadata, no devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.launch.sharding import _fit, M, F
from jax.sharding import PartitionSpec as P


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping for rule tests."""

    def __init__(self, **axes):
        self.shape = axes


def test_fit_divisibility_fallback():
    mesh = FakeMesh(data=16, model=16)
    # divisible: both axes land
    assert _fit((F, M), (1024, 4096), mesh, True) == P("data", "model")
    # fsdp off: data axis dropped
    assert _fit((F, M), (1024, 4096), mesh, False) == P(None, "model")
    # non-divisible model dim: dropped
    assert _fit((F, M), (1024, 10), mesh, True) == P("data", None)
    # leading (scan) dims replicate
    assert _fit((M, F, None), (58, 256, 7168, 2048), mesh, True) == P(
        None, "model", "data", None
    )


@pytest.mark.slow
def test_param_rules_cover_all_archs():
    """Every leaf of every full config gets a spec without error, and large
    2D+ leaves are sharded on at least one axis. Slow: eval_shape traces all
    ten full-depth configs (~60 layers each)."""
    from repro.configs import all_archs
    from repro.launch.sharding import param_spec
    from repro.models import init_params

    mesh = FakeMesh(data=16, model=16)
    for name, arch in all_archs().items():
        shapes = jax.eval_shape(
            lambda k: init_params(k, arch.model), jax.random.PRNGKey(0)
        )
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        unsharded_big = []
        for path, leaf in flat:
            spec = param_spec(path, leaf, mesh, arch.fsdp)
            assert isinstance(spec, P)
            if leaf.size > 4e6 and all(s is None for s in spec):
                unsharded_big.append((path, leaf.shape))
        assert not unsharded_big, f"{name}: large replicated leaves {unsharded_big[:3]}"


_SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_arch
    from repro.launch.distributed import build_train_steps
    from repro.models import reduced, init_params, lm_loss
    import dataclasses

    assert jax.device_count() == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    arch = get_arch("qwen1.5-0.5b")
    arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=64))
    bundle = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=8, seq_len=64,
        gamma=0.1, dtype=jnp.float32,
    )
    assert bundle.n_workers == 4

    # run for real on the 8 fake devices: numerical equivalence with the
    # unsharded reference step
    cfg = arch.model
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g0 = jax.tree.map(jnp.zeros_like, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks}

    # reference first: sync_step donates its params argument
    grads = jax.vmap(jax.grad(lambda p, t: lm_loss(p, cfg, t)), in_axes=(None, 0))(
        params, toks
    )
    g_ref = jax.tree.map(lambda t: jnp.mean(t, 0), grads)
    params_copy = jax.tree.map(jnp.array, params)

    with bundle.mesh:
        fn, _ = bundle.fns["sync_step"]
        x_new, g_new = fn(params_copy, g0, batch)
    err = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g_new), jax.tree.leaves(g_ref))
    )
    assert err < 2e-4, f"sharded sync_step grad mismatch: {err}"

    # compressed step: support/scaling invariants of Block-RandK
    params2 = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g_init = jax.tree.map(lambda t: jnp.full_like(t, 0.01), params2)
    g_keep = jax.tree.map(jnp.array, g_init)
    with bundle.mesh:
        fn, _ = bundle.fns["compressed_step"]
        x2, g2 = fn(params2, g_init, batch, jax.random.PRNGKey(2))
    delta = [a - b for a, b in zip(jax.tree.leaves(g2), jax.tree.leaves(g_keep))]
    nz = sum(int(jnp.sum(jnp.abs(t) > 1e-12)) for t in delta)
    tot = sum(int(t.size) for t in delta)
    frac = nz / tot
    assert 0.0005 < frac < 0.3, f"RandK support fraction {frac}"

    # Perm-K disjoint-shard round: the shared permutation partitions every
    # n-divisible lane dimension, so the decompressed delta is DENSE wherever
    # the gradient diff is — support must be far above the n*K randk round.
    bundle_pk = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=8, seq_len=64,
        gamma=0.1, dtype=jnp.float32, compression="permk",
    )
    params3 = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g_init3 = jax.tree.map(lambda t: jnp.full_like(t, 0.01), params3)
    g_keep3 = jax.tree.map(jnp.array, g_init3)
    with bundle_pk.mesh:
        fn, _ = bundle_pk.fns["compressed_step"]
        x3, g3 = fn(params3, g_init3, batch, jax.random.PRNGKey(2))
    delta3 = [a - b for a, b in zip(jax.tree.leaves(g3), jax.tree.leaves(g_keep3))]
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in delta3)
    nz3 = sum(int(jnp.sum(jnp.abs(t) > 1e-12)) for t in delta3)
    frac3 = nz3 / tot
    assert frac3 > 2 * frac, f"PermK support {frac3} not denser than RandK {frac}"

    # packed quantization wire (DESIGN.md 4.6): dense 4-bit QSGD round on the
    # sharded mesh — int8/uint32 payload collectives, dense finite delta.
    bundle_q = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=8, seq_len=64,
        gamma=0.1, dtype=jnp.float32, compression="qsgd", qsgd_s=7,
        packed_payload=True,
    )
    params4 = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g_init4 = jax.tree.map(lambda t: jnp.full_like(t, 0.01), params4)
    g_keep4 = jax.tree.map(jnp.array, g_init4)
    with bundle_q.mesh:
        fn, _ = bundle_q.fns["compressed_step"]
        x4, g4 = fn(params4, g_init4, batch, jax.random.PRNGKey(2))
    delta4 = [a - b for a, b in zip(jax.tree.leaves(g4), jax.tree.leaves(g_keep4))]
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in delta4)
    nz4 = sum(int(jnp.sum(jnp.abs(t) > 1e-12)) for t in delta4)
    frac4 = nz4 / tot
    assert frac4 > 2 * frac, f"QSGD support {frac4} not denser than RandK {frac}"

    # grad-carry + compressed downlink (DESIGN.md 4.7): the step carry grows
    # the per-worker h (worker-sharded like the grads, donated) and the round
    # runs ONE backprop; the downlink quantizes the aggregated delta. The
    # sync_step above already exercises the packed flat-psum exchange
    # (flat_sync is the default).
    bundle_cd = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=8, seq_len=64,
        gamma=0.1, dtype=jnp.float32, grad_carry=True, downlink="qsgd",
        downlink_s=7,
    )
    params5 = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g_init5 = jax.tree.map(lambda t: jnp.full_like(t, 0.01), params5)
    g_keep5 = jax.tree.map(jnp.array, g_init5)
    h0 = jax.tree.map(lambda t: jnp.zeros((4, *t.shape), t.dtype), params5)
    with bundle_cd.mesh:
        fn, _ = bundle_cd.fns["compressed_step"]
        x5, g5, h5 = fn(params5, g_init5, h0, batch, jax.random.PRNGKey(2))
    delta5 = [a - b for a, b in zip(jax.tree.leaves(g5), jax.tree.leaves(g_keep5))]
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in delta5)
    nz5 = sum(int(jnp.sum(jnp.abs(t) > 1e-12)) for t in delta5)
    assert nz5 > 0, "carry+downlink round produced an empty delta"
    for t in jax.tree.leaves(h5):
        assert t.shape[0] == 4 and bool(jnp.all(jnp.isfinite(t)))

    # PP-MARINA round on the model-sharded mesh (DESIGN.md 4.8): tensor
    # parallelism disqualifies the flat-PP pipeline, so this exercises the
    # per-leaf cohort fallback. With grad_carry the h slot is the
    # server-side carry table: exactly the sampled rows refresh.
    bundle_pp = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=8, seq_len=64,
        gamma=0.1, dtype=jnp.float32, grad_carry=True,
        participation=(2, "without"),
    )
    assert bundle_pp.meta["participation"] == (2, "without")
    assert not bundle_pp.meta["flat_pp"]          # model axis is sharded
    assert bundle_pp.meta["cohort_compute"]       # 2·2 batch rows over 4 shards
    params6 = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g_init6 = jax.tree.map(lambda t: jnp.full_like(t, 0.01), params6)
    g_keep6 = jax.tree.map(jnp.array, g_init6)
    h06 = jax.tree.map(lambda t: jnp.zeros((4, *t.shape), t.dtype), params6)
    sel = jnp.array([1, 3], jnp.int32)
    with bundle_pp.mesh:
        fn, _ = bundle_pp.fns["compressed_step"]
        x6, g6, h6 = fn(params6, g_init6, h06, batch, jax.random.PRNGKey(2), sel)
    delta6 = [a - b for a, b in zip(jax.tree.leaves(g6), jax.tree.leaves(g_keep6))]
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in delta6)
    nz6 = sum(int(jnp.sum(jnp.abs(t) > 1e-12)) for t in delta6)
    assert nz6 > 0, "PP round produced an empty delta"
    # the carry table refreshed EXACTLY the sampled rows
    for t in jax.tree.leaves(h6):
        row_nz = jnp.array([bool(jnp.any(jnp.abs(t[i]) > 0)) for i in range(4)])
        assert bool(row_nz[1]) and bool(row_nz[3]), "sampled rows not refreshed"
        assert not bool(row_nz[0]) and not bool(row_nz[2]), (
            "unsampled carry rows must stay stale"
        )
    # Byzantine-robust round (DESIGN.md 4.9): trimmed-mean GAR over the
    # per-worker decoded payload rows with one NaN-payload client — the
    # delta must stay finite (a plain mean would be NaN everywhere) and
    # dense like the honest qsgd wire.
    from repro.core import ServerAggregator, FaultSpec
    bundle_rb = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=8, seq_len=64,
        gamma=0.1, dtype=jnp.float32, compression="qsgd", qsgd_s=7,
        aggregator=ServerAggregator("trimmed_mean", f=1),
        faults=FaultSpec("nan", frac=0.25),
    )
    assert bundle_rb.meta["aggregator"] == "trimmed_mean"
    assert bundle_rb.meta["faults"] == "nan"
    params7 = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    g_init7 = jax.tree.map(lambda t: jnp.full_like(t, 0.01), params7)
    g_keep7 = jax.tree.map(jnp.array, g_init7)
    with bundle_rb.mesh:
        fn, _ = bundle_rb.fns["compressed_step"]
        x7, g7 = fn(params7, g_init7, batch, jax.random.PRNGKey(2))
    delta7 = [a - b for a, b in zip(jax.tree.leaves(g7), jax.tree.leaves(g_keep7))]
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in delta7), (
        "robust round leaked the NaN payload into the estimator"
    )
    nz7 = sum(int(jnp.sum(jnp.abs(t) > 1e-12)) for t in delta7)
    frac7 = nz7 / tot
    assert frac7 > 2 * frac, f"robust qsgd delta {frac7} not dense"

    print("SUBPROCESS_OK", err, frac, frac3, frac4, nz5 / tot, nz6 / tot, frac7)
    """
)


def test_sharded_steps_execute_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG],
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "SUBPROCESS_OK" in out.stdout
