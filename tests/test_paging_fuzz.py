"""Property-based fuzz of the refcounted page pool + block tables.

Random interleaved alloc / fork / COW-split / release / row-clear / free
sequences, mirrored against a dumb reference model (a dict of refcounts and
a free set). After EVERY op the pool's cross-checked audit must hold:
page conservation, page 0 never handed out or freed, no double-free,
and each refcount equal to the number of block-table rows referencing the
page. Driven by a single integer seed (hypothesis when installed, the
tests/_hyp.py sampled grid otherwise) with the repro command printed on
failure.
"""

import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core.paging import (
    NULL_PAGE,
    BlockTables,
    PagePool,
    PagedLayout,
    PoolExhausted,
)

N_OPS = 200


class _RefModel:
    """Independent bookkeeping the pool is checked against."""

    def __init__(self, layout):
        self.free = set(range(1, layout.npage))
        self.ref = {}

    def alloc(self, page):
        self.free.remove(page)
        self.ref[page] = 1

    def fork(self, page):
        self.ref[page] += 1

    def release(self, page):
        self.ref[page] -= 1
        if self.ref[page] == 0:
            del self.ref[page]
            self.free.add(page)


def _entries(tbl, layout):
    """All (slot, idx, page) triples, split into mapped and empty."""
    arr = tbl.array
    mapped, empty = [], []
    for s in range(layout.n_slots):
        for i in range(layout.max_pages):
            p = int(arr[s, i])
            (empty if p == NULL_PAGE else mapped).append((s, i, p))
    return mapped, empty


def _check(pool, tbl, model, layout, where):
    pool.check_conservation(tbl)
    assert pool.n_free == len(model.free), where
    for p in range(1, layout.npage):
        assert pool.refcount(p) == model.ref.get(p, 0), (where, p)


def _run_fuzz(seed: int) -> None:
    rng = np.random.default_rng(seed)
    layout = PagedLayout(
        npage=int(rng.integers(4, 14)),
        page_size=4,
        max_pages=int(rng.integers(2, 6)),
        n_slots=int(rng.integers(1, 5)),
    )
    pool, tbl, model = PagePool(layout), BlockTables(layout), _RefModel(layout)

    for opno in range(N_OPS):
        mapped, empty = _entries(tbl, layout)
        op = rng.choice(
            ["alloc", "fork", "cow", "release", "clear_row", "free", "abuse"]
        )
        where = f"op {opno} ({op}) layout {layout}"

        if op == "alloc" and empty:
            s, i, _ = empty[rng.integers(len(empty))]
            if pool.n_free == 0:
                with pytest.raises(PoolExhausted):
                    pool.alloc(1)
            else:
                (p,) = pool.alloc(1)
                assert p != NULL_PAGE
                tbl.set_entry(s, i, p)
                model.alloc(p)

        elif op == "fork" and mapped and empty:
            _, _, p = mapped[rng.integers(len(mapped))]
            s2, i2, _ = empty[rng.integers(len(empty))]
            pool.fork(p)
            tbl.set_entry(s2, i2, p)
            model.fork(p)

        elif op == "cow" and mapped:
            # split a shared page under one of its rows (the scheduler's
            # prepare_write path: alloc, repoint, release the old page)
            shared = [(s, i, p) for s, i, p in mapped if pool.refcount(p) > 1]
            if shared and pool.n_free > 0:
                s, i, p = shared[rng.integers(len(shared))]
                (new,) = pool.alloc(1)
                tbl.set_entry(s, i, new)
                model.alloc(new)
                pool.release(p)
                model.release(p)

        elif op == "release" and mapped:
            s, i, p = mapped[rng.integers(len(mapped))]
            tbl.set_entry(s, i, NULL_PAGE)
            left = pool.release(p)
            model.release(p)
            assert left == model.ref.get(p, 0)

        elif op == "clear_row" and mapped:
            # swap-out: drop every reference one slot holds
            s = int(rng.integers(layout.n_slots))
            for _, i, p in [(a, b, c) for a, b, c in mapped if a == s]:
                pool.release(p)
                model.release(p)
            tbl.clear(s)

        elif op == "free" and mapped:
            # the strict exclusive path, only legal at refcount exactly 1
            excl = [(s, i, p) for s, i, p in mapped if pool.refcount(p) == 1]
            if excl:
                s, i, p = excl[rng.integers(len(excl))]
                tbl.set_entry(s, i, NULL_PAGE)
                pool.free([p])
                model.release(p)

        elif op == "abuse":
            # illegal calls must raise and must not corrupt any state
            with pytest.raises(ValueError):
                pool.fork(NULL_PAGE)
            with pytest.raises(ValueError):
                pool.free([NULL_PAGE])
            if pool.n_free:
                free_page = next(
                    q for q in range(1, layout.npage) if pool.refcount(q) == 0
                )
                with pytest.raises(ValueError):
                    pool.release(free_page)
                with pytest.raises(ValueError, match="double free"):
                    pool.free([free_page])
            shared = [p for _, _, p in mapped if pool.refcount(p) > 1]
            if shared:
                with pytest.raises(ValueError, match="release"):
                    pool.free([shared[0]])
            with pytest.raises(PoolExhausted):
                pool.alloc(pool.n_free + 1)

        _check(pool, tbl, model, layout, where)

    # drain everything: the pool must come back whole
    mapped, _ = _entries(tbl, layout)
    for s, i, p in mapped:
        tbl.set_entry(s, i, NULL_PAGE)
        pool.release(p)
        model.release(p)
    _check(pool, tbl, model, layout, "drain")
    assert pool.n_free == layout.usable_pages


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1))
def test_pool_fuzz_conservation(seed):
    try:
        _run_fuzz(seed)
    except Exception:
        print(
            "\nreproduce with: PYTHONPATH=src:tests python -c "
            f'"import test_paging_fuzz as m; m._run_fuzz({seed})"'
        )
        raise
