"""PP-MARINA federated-scenario tests (Algorithm 4 + DESIGN.md §4.8).

Covers the paths that existed before this PR but were never tested, plus the
new federated extensions:

* with- vs without-replacement cohort estimator unbiasedness (both schemes
  keep the 1/r server scaling unbiased for the mean difference),
* arbitrary client weights: sync rounds aggregate Σ w_i ∇f_i and the
  compressed estimator is unbiased for Σ w_i Δ_i,
* the server-side carry table: at r = n (without replacement) the carry
  estimator coincides with the recompute path step for step,
* PP + engine trajectory equality vs the per-leaf tree path,
* the PP bits ledger books EXACTLY r·ζ_Q (wire.py drift guard),
* Dirichlet(α) partitioner / heterogeneous problem family sanity,
* mesh PP rounds (subprocess, 4 fake devices): cohort-mapped compute (the
  r clients' tokens respread over all n shards, r payload rows on the
  wire) with trajectory equality against the core PPMarina reference —
  the acceptance-criterion test.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockRandK,
    FlatEngine,
    PPMarina,
    RandK,
    make_engine,
    make_layout,
    tree_payload_bits,
)
from repro.core import wire
from repro.core.problems import (
    gradient_heterogeneity,
    make_dirichlet_binclass,
    make_shifted_quadratics,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
    quadratic_loss,
)
from repro.data import (
    client_weights_from_counts,
    dirichlet_partition,
    dirichlet_proportions,
)

N, M, D = 6, 32, 24


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    return data, jax.grad(nonconvex_binclass_loss)


# ---------------------------------------------------------------------------
# cohort estimator unbiasedness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("replace", [True, False])
def test_cohort_estimator_unbiased(replace):
    """(1/r)·Σ_{i∈I'} Q(Δ_i) is unbiased for the mean difference under BOTH
    cohort schemes (with replacement = Alg. 4; without = the experiments')."""
    r, n, d = 3, N, 16
    diffs = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    comp = RandK(k=4)

    def est(key):
        _, k_sel, k_q = jax.random.split(key, 3)
        if replace:
            sel = jax.random.randint(k_sel, (r,), 0, n)
        else:
            sel = jax.random.permutation(k_sel, n)[:r]
        qs = jax.vmap(lambda k, v: comp(k, v))(
            jax.random.split(k_q, r), diffs[sel]
        )
        return jnp.mean(qs, axis=0)

    keys = jax.random.split(jax.random.PRNGKey(2), 6000)
    mean_est = jnp.mean(jax.vmap(est)(keys), axis=0)
    err = float(jnp.linalg.norm(mean_est - jnp.mean(diffs, 0)))
    assert err < 0.12, f"cohort estimator biased: {err}"


def test_weighted_cohort_estimator_unbiased():
    """Pre-scaling sampled diffs by n·w_i makes the 1/r cohort mean unbiased
    for the WEIGHTED mean Σ w_i Δ_i (PPMarina's unbalanced-dataset mode)."""
    r, n, d = 3, N, 16
    diffs = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    w = jnp.array([0.3, 0.25, 0.2, 0.1, 0.1, 0.05])
    comp = RandK(k=4)

    def est(key):
        _, k_sel, k_q = jax.random.split(key, 3)
        sel = jax.random.permutation(k_sel, n)[:r]
        scaled = diffs[sel] * (n * w[sel])[:, None]
        qs = jax.vmap(lambda k, v: comp(k, v))(
            jax.random.split(k_q, r), scaled
        )
        return jnp.mean(qs, axis=0)

    keys = jax.random.split(jax.random.PRNGKey(3), 6000)
    mean_est = jnp.mean(jax.vmap(est)(keys), axis=0)
    target = jnp.einsum("n,nd->d", w, diffs)
    err = float(jnp.linalg.norm(mean_est - target))
    assert err < 0.12, f"weighted cohort estimator biased: {err}"


def test_weighted_sync_round_aggregates_with_weights(problem):
    """p = 1 ⇒ every round is a sync round: g^{k+1} must equal Σ w_i ∇f_i."""
    data, grad = problem
    w = jnp.array([0.4, 0.2, 0.15, 0.1, 0.1, 0.05])
    m = PPMarina(grad, RandK(k=3), 0.05, p=1.0, r=2, weights=w)
    st = m.init(jnp.zeros((D,)), data)
    st, met = jax.jit(m.step)(st, jax.random.PRNGKey(0), data)
    grads = jax.vmap(grad, in_axes=(None, 0))(st.params, data)
    # note: step evaluates at x^1 = x^0 - γ·g^0; recompute the same point
    x1 = jnp.zeros((D,)) - 0.05 * jnp.einsum(
        "n,nd->d", w, jax.vmap(grad, in_axes=(None, 0))(jnp.zeros((D,)), data)
    )
    expect = jnp.einsum(
        "n,nd->d", w, jax.vmap(grad, in_axes=(None, 0))(x1, data)
    )
    np.testing.assert_allclose(np.asarray(st.g), np.asarray(expect), atol=1e-6)


# ---------------------------------------------------------------------------
# server-side carry table
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("path", ["tree", "flat"])
def test_pp_carry_equals_recompute_at_full_cohort(path):
    """r = n without replacement ⇒ every client refreshes its table row each
    round, so the carry estimator coincides with the recompute path: g^k
    equal, lookahead params lead by exactly one step."""
    n, m, d = 4, 32, 256  # single leaf, 2 blocks of 128 → flat == tree RNG
    data = make_synthetic_binclass(jax.random.PRNGKey(0), n, m, d)
    grad = jax.grad(nonconvex_binclass_loss)
    comp = BlockRandK(kb=8, block=128)
    eng = (
        make_engine(jnp.zeros((d,)), kb=8, block=128, backend="ref")
        if path == "flat" else None
    )
    seed = PPMarina(grad, comp, 0.05, 0.3, r=n, engine=eng, replace=False)
    carry = PPMarina(
        grad, comp, 0.05, 0.3, r=n, engine=eng, replace=False, carry=True
    )

    st = seed.init(jnp.zeros((d,)), data)
    step_s = jax.jit(seed.step)
    params, gs, syncs = [np.asarray(st.params)], [], []
    for k in range(12):
        st, met = step_s(st, jax.random.PRNGKey(k), data)
        params.append(np.asarray(st.params))
        gs.append(np.asarray(st.g))
        syncs.append(int(met.sync_round))
    assert 0 in syncs and 1 in syncs

    st = carry.init(jnp.zeros((d,)), data)
    np.testing.assert_allclose(np.asarray(st.params), params[1], atol=1e-6)
    step_c = jax.jit(carry.step)
    for k in range(11):
        st, met = step_c(st, jax.random.PRNGKey(k), data)
        g = np.asarray(st.g).reshape(-1)[:d]
        np.testing.assert_allclose(g, gs[k], atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.params), params[k + 2],
                                   atol=1e-5)
        if int(met.sync_round) == 0:
            # one backprop per SAMPLED client: r/n of a full sweep
            assert float(met.oracle_calls) == 1.0  # r == n here


def test_pp_carry_refreshes_only_sampled_rows(problem):
    """Compressed carry rounds must update the h table ONLY at the cohort
    rows — unsampled clients' anchors stay stale by design."""
    data, grad = problem
    m = PPMarina(
        grad, RandK(k=3), 0.05, p=0.0, r=2, replace=False, carry=True
    )  # p = 0: every round compressed
    st = m.init(jnp.zeros((D,)), data)
    h0 = np.asarray(st.h)
    key = jax.random.PRNGKey(5)
    st2, _ = jax.jit(m.step)(st, key, data)
    _, k_sel, _ = jax.random.split(key, 3)
    sel = np.asarray(jax.random.permutation(k_sel, N)[:2])
    h1 = np.asarray(st2.h)
    changed = np.array([not np.allclose(h0[i], h1[i]) for i in range(N)])
    assert set(np.flatnonzero(changed)) == set(sel.tolist())


def test_pp_carry_converges(problem):
    """The lazy-anchor carry estimator still drives PP-MARINA to
    stationarity at r < n on the heterogeneous problem."""
    data, grad = problem
    from repro.core import pp_marina_gamma
    from repro.core.problems import binclass_smoothness, BinClassData, \
        binclass_full_grad

    L = binclass_smoothness(data)
    comp = RandK(k=3)
    r = 3
    p = comp.default_p(D) * r / N
    gamma = pp_marina_gamma(L, comp.omega(D), p, r)
    m = PPMarina(grad, comp, gamma, p, r=r, replace=False, carry=True)
    st = m.init(jnp.zeros((D,)), data)
    step = jax.jit(m.step)
    for k in range(900):
        st, _ = step(st, jax.random.PRNGKey(k), data)
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    sq = float(jnp.sum(binclass_full_grad(st.params, flat) ** 2))
    assert sq < 5e-3, f"carry PP did not converge: {sq}"


# ---------------------------------------------------------------------------
# engine vs tree trajectory + bits ledger
# ---------------------------------------------------------------------------


def test_pp_engine_equals_tree_path():
    """PP + flat engine reproduces the per-leaf tree path trajectory on a
    single-leaf block-aligned problem (same cohort, same sampler RNG)."""
    n, m, d = 4, 32, 256
    data = make_synthetic_binclass(jax.random.PRNGKey(0), n, m, d)
    grad = jax.grad(nonconvex_binclass_loss)
    comp = BlockRandK(kb=8, block=128)
    eng = FlatEngine(layout=make_layout(jnp.zeros((d,)), block=128), kb=8,
                     backend="ref")
    m_tree = PPMarina(grad, comp, 0.05, 0.3, r=2, replace=False)
    m_flat = PPMarina(grad, comp, 0.05, 0.3, r=2, replace=False, engine=eng)
    st_t = m_tree.init(jnp.zeros((d,)), data)
    st_f = m_flat.init(jnp.zeros((d,)), data)
    step_t, step_f = jax.jit(m_tree.step), jax.jit(m_flat.step)
    saw_compressed = False
    for k in range(20):
        key = jax.random.PRNGKey(k)
        st_t, met = step_t(st_t, key, data)
        st_f, _ = step_f(st_f, key, data)
        saw_compressed |= int(met.sync_round) == 0
        np.testing.assert_allclose(
            np.asarray(st_f.params), np.asarray(st_t.params), rtol=1e-5,
            atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(st_f.g), np.asarray(st_t.g), rtol=1e-5, atol=1e-6
        )
    assert saw_compressed


@pytest.mark.parametrize("path", ["tree", "flat"])
def test_pp_bits_ledger_books_r_zeta(problem, path):
    """Drift guard: the PP ledger must book n·32d on sync rounds and EXACTLY
    r·ζ_Q on compressed rounds (fleet totals / n), matching wire.py."""
    data, grad = problem
    r = 2
    if path == "flat":
        n, m, d = 4, 16, 256
        data = make_synthetic_binclass(jax.random.PRNGKey(1), n, m, d)
        comp = BlockRandK(kb=8, block=128)
        eng = make_engine(jnp.zeros((d,)), kb=8, block=128, backend="ref")
        mth = PPMarina(grad, comp, 0.05, 0.5, r=r, engine=eng, replace=False)
        st = mth.init(jnp.zeros((d,)), data)
        zeta = eng.payload_bits(r)
        nn, dd = n, d
    else:
        comp = RandK(k=3)
        mth = PPMarina(grad, comp, 0.05, 0.5, r=r, replace=False)
        st = mth.init(jnp.zeros((D,)), data)
        zeta = tree_payload_bits(comp, jnp.zeros((D,)))
        nn, dd = N, D
    step = jax.jit(mth.step)
    seen = set()
    for k in range(24):
        st, met = step(st, jax.random.PRNGKey(k), data)
        got = float(met.bits_per_worker) * nn
        if int(met.sync_round) == 1:
            assert got == wire.pp_sync_total_bits(nn, dd)
        else:
            assert got == pytest.approx(wire.pp_uplink_total_bits(r, zeta))
        seen.add(int(met.sync_round))
    assert seen == {0, 1}


def test_pp_without_replacement_converges(problem):
    """Thm 4.1 behaviour survives the without-replacement cohort (variance
    can only drop): PP-MARINA reaches stationarity on the quadratic."""
    data, L, mu = make_shifted_quadratics(
        jax.random.PRNGKey(2), 6, 16, zeta=1.0, kappa=5.0
    )
    from repro.core import pp_marina_gamma

    comp = RandK(k=4)
    r = 2
    p = comp.default_p(16) * r / 6
    gamma = pp_marina_gamma(L, comp.omega(16), p, r)
    m = PPMarina(
        jax.grad(quadratic_loss), comp, gamma, p, r=r, replace=False
    )
    st = m.init(jnp.ones((16,)), data)
    step = jax.jit(m.step)
    for k in range(800):
        st, _ = step(st, jax.random.PRNGKey(k), data)
    g = jax.grad(quadratic_loss)(st.params, jax.tree.map(
        lambda t: jnp.mean(t, 0), data))
    assert float(jnp.sum(g**2)) < 1e-4


# ---------------------------------------------------------------------------
# heterogeneity scenario layer
# ---------------------------------------------------------------------------


def test_shifted_quadratics_zeta_exact():
    """The ζ dial is exact: empirical (1/n)Σ‖∇f_i − ∇f‖² == ζ² at any x."""
    for zeta in (0.5, 2.0):
        data, L, mu = make_shifted_quadratics(
            jax.random.PRNGKey(3), 8, 12, zeta=zeta
        )
        for xseed in (0, 1):
            x = jax.random.normal(jax.random.PRNGKey(xseed), (12,))
            grads = jax.vmap(jax.grad(quadratic_loss), in_axes=(None, 0))(
                x, data
            )
            np.testing.assert_allclose(
                float(gradient_heterogeneity(grads)), zeta**2, rtol=1e-4
            )


def test_dirichlet_proportions_and_partition():
    key = jax.random.PRNGKey(4)
    # α = ∞ → uniform; α small → concentrated rows
    pu = dirichlet_proportions(key, 8, 4, np.inf)
    np.testing.assert_allclose(np.asarray(pu), 0.25)
    ps = np.asarray(dirichlet_proportions(key, 16, 8, 0.1))
    np.testing.assert_allclose(ps.sum(-1), 1.0, atol=1e-5)
    assert ps.max(-1).mean() > 0.6  # skewed clients
    # the partition is a disjoint cover of all indices
    labels = np.repeat(np.arange(5), 40)
    shards = dirichlet_partition(key, labels, 6, 0.5)
    allidx = np.sort(np.concatenate(shards))
    np.testing.assert_array_equal(allidx, np.arange(len(labels)))
    w = client_weights_from_counts([len(s) for s in shards])
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, rtol=1e-6)


def test_dirichlet_binclass_alpha_controls_heterogeneity():
    """Smaller α ⇒ larger gradient dissimilarity across clients."""
    x = jnp.zeros((10,))
    zs = {}
    for alpha in (0.1, np.inf):
        data = make_dirichlet_binclass(
            jax.random.PRNGKey(5), 16, 64, 10, alpha=alpha
        )
        grads = jax.vmap(
            jax.grad(nonconvex_binclass_loss), in_axes=(None, 0)
        )(x, data)
        zs[alpha] = float(gradient_heterogeneity(grads))
    assert zs[0.1] > 2.0 * zs[np.inf], zs


def test_lm_data_alpha_deterministic_and_skewed():
    from repro.data import make_lm_data, worker_batches

    data = make_lm_data(4, 256, 32, seed=0, alpha=0.1)
    b1 = worker_batches(data, 3, 2)
    b2 = worker_batches(data, 3, 2)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    assert b1.shape == (4, 2, 32)
    # workers concentrate on different vocab regions under small α
    data_iid = make_lm_data(4, 256, 32, seed=0, alpha=np.inf)
    b_iid = worker_batches(data_iid, 3, 2)
    spread = np.asarray(b1).reshape(4, -1).std(axis=1).mean()
    spread_iid = np.asarray(b_iid).reshape(4, -1).std(axis=1).mean()
    assert spread < spread_iid  # skewed streams are narrower per worker


def test_cohort_schedule_matches_core_sampling():
    """pp_cohort_schedule row k == the cohort PPMarina draws from the step
    key fold_in(base, k) — the prefetch cannot drift from the algorithm."""
    from repro.launch.distributed import pp_cohort_schedule

    base = jax.random.PRNGKey(9)
    n, r = 8, 3
    sched = pp_cohort_schedule(base, 12, n, r, "without")
    for k in range(12):
        _, k_sel, _ = jax.random.split(jax.random.fold_in(base, k), 3)
        expect = jax.random.permutation(k_sel, n)[:r]
        np.testing.assert_array_equal(np.asarray(sched[k]), np.asarray(expect))
    sched_w = pp_cohort_schedule(base, 5, n, r, "with")
    assert sched_w.shape == (5, r) and int(sched_w.max()) < n


# ---------------------------------------------------------------------------
# mesh PP rounds: only r of n shards compute/communicate, trajectory-equal
# to the core PPMarina reference (subprocess: fake devices)
# ---------------------------------------------------------------------------

_PP_MESH_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np

    from repro.configs import get_arch
    from repro.launch.distributed import build_train_steps, pp_cohort_schedule
    from repro.launch.topology import make_federated_mesh
    from repro.models import reduced, init_params, lm_loss
    from repro.core import PPMarina, BlockRandK, make_engine
    from repro.core.marina import MarinaState

    mesh = make_federated_mesh(4)
    arch = get_arch("qwen1.5-0.5b")
    arch = dataclasses.replace(arch, model=reduced(arch.model, layers=2, d_model=64))
    cfg = arch.model
    n, r, b = 4, 2, 2
    bundle = build_train_steps(
        arch, mesh, multi_pod=False, global_batch=n*b, seq_len=64,
        gamma=0.1, dtype=jnp.float32, replicate_params=True,
        participation=(r, "without"), p=0.3,
    )
    # only r of n shards compute: the builder took the cohort-mapped path
    assert bundle.meta["cohort_compute"], bundle.meta
    assert bundle.meta["flat_pp"], bundle.meta

    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (n, b, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks}

    # the core reference: same flat sampler, same keys
    grad_fn = jax.grad(lambda p_, t: lm_loss(p_, cfg, t))
    eng = make_engine(params, kb=8, block=1024, backend="ref")
    ref = PPMarina(grad_fn, BlockRandK(kb=8), 0.1, 0.3, r=r, engine=eng,
                   replace=False)
    g0 = jax.tree.map(jnp.zeros_like, params)
    stref = MarinaState(params=params, g=g0, step=jnp.zeros((), jnp.int32))

    base = jax.random.PRNGKey(42)
    sched = pp_cohort_schedule(base, 8, n, r, "without")
    pd = jax.tree.map(jnp.array, params)
    gd = jax.tree.map(jnp.array, g0)
    fn, _ = bundle.fns["train_step"]
    step_ref = jax.jit(ref.step)
    comp_rounds = 0
    with bundle.mesh:
        for k in range(8):
            key = jax.random.fold_in(base, k)
            pd, gd = fn(pd, gd, batch, key, sched[k])
            stref, met = step_ref(stref, key, batch["tokens"])
            comp_rounds += 1 - int(met.sync_round)
            errg = max(float(jnp.max(jnp.abs(a-c))) for a, c in zip(
                jax.tree.leaves(gd), jax.tree.leaves(stref.g)))
            errp = max(float(jnp.max(jnp.abs(a-c))) for a, c in zip(
                jax.tree.leaves(pd), jax.tree.leaves(stref.params)))
            assert errg < 1e-4 and errp < 1e-4, (k, errg, errp)
    assert comp_rounds > 0
    print("PP_MESH_OK", comp_rounds)
    """
)


def test_mesh_pp_round_trajectory_equals_core():
    """Acceptance criterion: a mesh PP round doing r/n of a full round's
    compute with r payload rows on the wire, trajectory-equal (same keys)
    to core PPMarina."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _PP_MESH_PROG],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "PP_MESH_OK" in out.stdout
